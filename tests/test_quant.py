"""Int8 implementation variants: roundtrip quality, storage accounting,
and the PIES placement behavior they exist for."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models.quant import (dequantize_tree, logit_agreement,
                                quantize_tree, quantized_bytes)
from repro.serving import Router, default_catalog, with_quantized_variants


def test_quantization_roundtrip_error_bounded():
    cfg = get_smoke_config("smollm_360m").with_(dtype="float32",
                                                param_dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    q, s = quantize_tree(params)
    deq = dequantize_tree(q, s, dtype=jnp.float32)
    for a, b, sc in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(deq),
                        jax.tree_util.tree_leaves(
                            s, is_leaf=lambda x: x is None)):
        if sc is None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            # per-channel int8: error ≤ scale/2 elementwise
            err = np.abs(np.asarray(a, np.float64) - np.asarray(b, np.float64))
            bound = np.broadcast_to(np.asarray(sc, np.float64) / 2 + 1e-8,
                                    err.shape)
            assert (err <= bound).all()


def test_quantized_storage_halves():
    cfg = get_smoke_config("yi_34b")
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    q, s = quantize_tree(params)
    qb = quantized_bytes(q, s)
    fb = sum(l.size * 2 for l in jax.tree_util.tree_leaves(params))  # bf16
    assert qb < 0.62 * fb, (qb, fb)


def test_quantized_model_agrees_with_reference():
    cfg = get_smoke_config("smollm_360m").with_(dtype="float32",
                                                param_dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    q, s = quantize_tree(params)
    deq = dequantize_tree(q, s, dtype=jnp.float32)
    agree = logit_agreement(cfg, params, deq, n_probes=4, seq=16)
    assert agree >= 0.75, f"int8 top-1 agreement too low: {agree}"


def test_placement_prefers_int8_when_storage_tight():
    """The paper's story end-to-end: under a tight storage budget EGP
    places the cheaper int8 implementations; with slack it prefers the
    higher-accuracy bf16 ones."""
    cat = with_quantized_variants(default_catalog())
    assert len(cat.models) == 2 * len(default_catalog().models)

    router = Router("egp")

    tight = cat.to_instance(150, 1, storage_capacity=45.0, seed=0)
    x_tight = router.place(tight)
    chosen_tight = {cat.models[p].arch for p in np.nonzero(x_tight[0])[0]}

    loose = cat.to_instance(150, 1, storage_capacity=2000.0, seed=0)
    x_loose = router.place(loose)

    n_int8_tight = sum(1 for a in chosen_tight if a.endswith("-int8"))
    assert n_int8_tight >= 1, f"tight budget should use int8: {chosen_tight}"
    # with slack, the best bf16 implementations must be placed
    chosen_loose = {cat.models[p].arch for p in np.nonzero(x_loose[0])[0]}
    assert any(not a.endswith("-int8") for a in chosen_loose)
    # and QoS never decreases with more storage
    from repro.core import qos_matrix_np, sigma_np
    v_tight = sigma_np(tight, x_tight, qos_matrix_np(tight))
    v_loose = sigma_np(loose, x_loose, qos_matrix_np(loose))
    assert v_loose >= v_tight - 1e-9
