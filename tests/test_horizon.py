"""repro.serving.horizon — scenario traffic through the full serving
engine: conservation, determinism, EDF vs FCFS, and the kind="serving"
sweep executor (resumable store, aggregate, CLI)."""
import json

import numpy as np
import pytest

from repro.serving.horizon import (HorizonConfig, run_horizon,
                                   split_serving_overrides)
from repro.sweeps import SweepSpec, run_sweep, summarize

#: Shrunk scenario so a horizon run costs milliseconds, sized to congest
#: the executors (small batches, long prompts) so queueing actually
#: happens and the EDF/FCFS policies can differ.
SMALL = {"n_user_slots": 32, "n_services": 8, "max_impls": 3, "n_edges": 4}
LOAD = dict(prompt_tokens=768, new_tokens=64, max_batch=4)


def _cfg(**kw):
    base = dict(scenario="flash_crowd", overrides=tuple(SMALL.items()),
                policy="edf", seed=0, n_ticks=3, **LOAD)
    base.update(kw)
    return HorizonConfig(**base)


# ===========================================================================
# The driver
# ===========================================================================

def test_horizon_conservation_and_ranges():
    """served + dropped == submitted, latencies ≥ 0, QoS within [0, 1]."""
    for scenario in ("steady", "flash_crowd"):
        res = run_horizon(_cfg(scenario=scenario, seed=1))
        assert len(res.per_tick) == 3
        for t in res.per_tick:
            assert t.served + t.dropped == t.submitted
            assert 0.0 <= t.mean_realized_qos <= 1.0
            assert t.queue_depth >= 0 and t.in_flight >= 0
        assert res.served + res.dropped == res.submitted
        assert res.served == len(res.requests)
        # every submitted request finished (drained) with sane timing
        for r in res.requests:
            assert r.finish >= r.start >= r.arrival >= 0.0
        assert 0.0 <= res.mean_realized_qos <= 1.0


def test_horizon_state_survives_tick_boundaries():
    """Under congestion, backlog must spill across ticks (the stateful
    scheduler is the point of the horizon driver)."""
    res = run_horizon(_cfg(seed=2, max_batch=2))
    assert any(t.queue_depth > 0 or t.in_flight > 0 for t in res.per_tick)
    # spilled requests finish after their arrival tick's boundary
    assert any(r.finish > (int(r.arrival) + 1) for r in res.requests)


def test_horizon_deterministic_byte_identical():
    a = run_horizon(_cfg(seed=3))
    b = run_horizon(_cfg(seed=3))
    fa = np.array([r.finish for r in a.requests])
    fb = np.array([r.finish for r in b.requests])
    assert fa.tobytes() == fb.tobytes()
    assert a.tick_values().tobytes() == b.tick_values().tobytes()


def test_edf_never_worse_than_fcfs_on_mean_realized_qos():
    """QoS-aware admission: across seeds, EDF's mean realized QoS must not
    fall below FCFS's (the paper's QoS-first ordering argument, asserted
    on the objective the engine optimizes). Raw miss *counts* are no
    longer a valid proxy since eviction requeue landed: re-routed backlog
    re-enters with its original (often blown) deadline, and EDF's
    overload pathology — spending slots on doomed earliest-deadline work
    — can cost it a few extra misses while still winning on QoS."""
    edf, fcfs = [], []
    for seed in range(4):
        edf.append(run_horizon(_cfg(seed=seed)).mean_realized_qos)
        fcfs.append(run_horizon(
            _cfg(seed=seed, policy="fcfs")).mean_realized_qos)
    assert np.mean(edf) >= np.mean(fcfs) - 1e-9


def test_placer_knobs_flow_through():
    """stickiness=0/switching_cost=0 re-places freely (more loads) vs the
    hysteresis config; both emit per-tick load counts."""
    free = run_horizon(_cfg(switching_cost=0.0, stickiness=0.0))
    sticky = run_horizon(_cfg(switching_cost=2.0, stickiness=5.0))
    assert free.per_tick[0].model_loads > 0
    assert sum(t.model_loads for t in sticky.per_tick[1:]) <= \
        sum(t.model_loads for t in free.per_tick[1:]) + 2


def test_switching_cost_is_realized_as_load_latency():
    """switching_cost must move the *measured* numbers, not just the
    bookkeeping value: same placements (same stickiness), but costly
    switches gate new implementations behind a load window, so requests
    queue through cold starts and realized QoS drops."""
    cheap = run_horizon(_cfg(switching_cost=0.0, stickiness=3.0))
    costly = run_horizon(_cfg(switching_cost=0.5, stickiness=3.0))
    # identical placements and routing → identical load counts...
    assert [t.model_loads for t in cheap.per_tick] == \
        [t.model_loads for t in costly.per_tick]
    # ...but the realized numbers must differ (tick 0 loads everything)
    assert costly.per_tick[0].mean_realized_qos < \
        cheap.per_tick[0].mean_realized_qos
    assert costly.mean_realized_qos < cheap.mean_realized_qos


def test_evicted_backlog_is_requeued_through_oms():
    """Re-placement that evicts a resident implementation mid-horizon must
    pull its queued (not in-flight) backlog and re-route it through OMS —
    counted in TickReport.requeued — with conservation intact (unroutable
    requests re-attribute as dropped at their arrival tick)."""
    res = run_horizon(_cfg(seed=0, n_ticks=4))
    assert sum(t.requeued for t in res.per_tick) > 0
    for t in res.per_tick:
        assert t.served + t.dropped == t.submitted
        assert t.stickiness == res.config.stickiness  # open loop: constant
    assert res.served == len(res.requests)
    # re-routed requests still finish with sane timing (admission never
    # happens before the eviction tick even though arrival is kept)
    for r in res.requests:
        assert r.finish >= r.start >= r.arrival >= 0.0
    # deterministic: the requeue path replays byte-identically
    again = run_horizon(_cfg(seed=0, n_ticks=4))
    assert [t.requeued for t in again.per_tick] == \
        [t.requeued for t in res.per_tick]
    assert res.tick_values().tobytes() == again.tick_values().tobytes()


def test_split_serving_overrides_and_config():
    scen, serving = split_serving_overrides(
        {"n_user_slots": 16, "switching_cost": 1.5, "max_batch": 2})
    assert scen == {"n_user_slots": 16}
    assert serving == {"switching_cost": 1.5, "max_batch": 2}
    cfg = HorizonConfig.from_overrides(
        "steady", {"n_user_slots": 16, "switching_cost": 1.5}, "fcfs",
        seed=4, n_ticks=2)
    assert cfg.overrides == (("n_user_slots", 16),)
    assert cfg.switching_cost == 1.5 and cfg.policy == "fcfs"


# ===========================================================================
# kind="serving" sweeps
# ===========================================================================

SERVING_GRID = dict(
    kind="serving", scenarios=("steady", "flash_crowd"), seeds=(0, 1),
    n_ticks=2, algos=("edf", "fcfs"),
    override_grid=(tuple(SMALL.items()) + (("switching_cost", 0.0),
                                           ("stickiness", 0.0)),
                   tuple(SMALL.items()) + (("switching_cost", 2.0),
                                           ("stickiness", 3.0))))


def test_spec_serving_kind_validation():
    spec = SweepSpec(**SERVING_GRID)
    assert spec.executor_of("edf") == "serving"
    assert len(spec.expand()) == 2 * 2 * 2 * 2 * 2
    assert all(i.executor == "serving" for i in spec.expand())
    with pytest.raises(ValueError):
        SweepSpec(kind="serving", scenarios=("synthetic",), algos=("edf",))
    with pytest.raises(ValueError):
        SweepSpec(kind="serving", algos=("egp",))
    with pytest.raises(ValueError):
        SweepSpec(kind="quantum")
    # serving items hash apart from sigma items of the same coordinates
    sigma = SweepSpec(scenarios=("steady",), seeds=(0,), n_ticks=1)
    serving = SweepSpec(kind="serving", scenarios=("steady",), seeds=(0,),
                        n_ticks=1, algos=("edf",))
    assert sigma.expand()[0].key() != serving.expand()[0].key()
    assert sigma.store_key() != serving.store_key()
    # a serving tick value depends on the whole horizon (EDF re-orders
    # earlier backlog by later arrivals), so the item key and the default
    # store pin the horizon length — unlike sigma, where tick values are
    # horizon-independent and --ticks extensions resume
    longer = SweepSpec(kind="serving", scenarios=("steady",), seeds=(0,),
                       n_ticks=2, algos=("edf",))
    assert serving.expand()[0].key() != longer.expand()[0].key()
    assert serving.store_key() != longer.store_key()
    sigma2 = SweepSpec(scenarios=("steady",), seeds=(0,), n_ticks=2)
    assert sigma.expand()[0].key() == sigma2.expand()[0].key()
    # an explicit --ticks equal to the scenario default (steady: 8) is the
    # same computation — same item keys, same store
    default_t = SweepSpec(kind="serving", scenarios=("steady",),
                          algos=("edf",))
    explicit_t = SweepSpec(kind="serving", scenarios=("steady",),
                           n_ticks=8, algos=("edf",))
    assert default_t.store_key() == explicit_t.store_key()
    assert default_t.expand()[0].key() == explicit_t.expand()[0].key()


def test_serving_sweep_end_to_end_resume_and_aggregate(tmp_path):
    spec = SweepSpec(**SERVING_GRID)
    d = tmp_path / "store"
    # "kill" after 3 of 16 seed-chunks, then resume
    partial = run_sweep(spec, store_dir=d, max_chunks=3)
    assert partial.execution["chunks_computed"] == 3
    assert not partial.complete
    before = (d / "manifest.jsonl").read_text().splitlines()
    done = run_sweep(spec, store_dir=d)
    assert done.complete and done.execution["path"] == "serving"
    assert done.execution["items_skipped"] == 3 * 2  # 2 ticks per chunk
    # completed chunks were never rewritten
    after = (d / "manifest.jsonl").read_text().splitlines()
    assert after[:3] == before
    # resumed values equal an unstored fresh run bitwise (determinism)
    fresh = run_sweep(spec)
    for k in done.values:
        np.testing.assert_array_equal(done.values[k], fresh.values[k])
    # realized QoS is a probability-like score, and the aggregate is full
    summary = summarize(done)
    for cell in summary["cells"].values():
        assert cell["sigma"]["n"] == 4  # 2 seeds × 2 ticks
        assert 0.0 <= cell["sigma"]["mean"] <= 1.0
    # re-run is a no-op
    again = run_sweep(spec, store_dir=d)
    assert again.execution["chunks_computed"] == 0


def test_serving_cli_smoke(tmp_path, capsys):
    from repro.sweeps.cli import main
    small = [a for k, v in SMALL.items()
             for a in ("--override", f"{k}={v}")]
    rc = main(["--kind", "serving", "--scenario", "flash_crowd",
               "--seeds", "0:2", "--ticks", "2",
               "--out", str(tmp_path / "store"),
               "--json", str(tmp_path / "summary.json"), "-q"] + small)
    assert rc == 0
    out = capsys.readouterr().out
    assert "flash_crowd" in out and "edf" in out and "fcfs" in out
    summary = json.loads((tmp_path / "summary.json").read_text())
    assert summary["spec"]["kind"] == "serving"
    assert len(summary["cells"]) == 2  # default algos: edf + fcfs
    # --validate has no host path to compare against for serving sweeps
    with pytest.raises(SystemExit):
        main(["--kind", "serving", "--scenario", "steady", "--no-store",
              "--validate", "-q"])
    capsys.readouterr()
