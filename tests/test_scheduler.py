"""Continuous-batching scheduler: EDF vs FCFS, slot reuse, determinism."""
import numpy as np
import pytest

from repro.serving import Router, default_catalog
from repro.serving.scheduler import (ArrivingRequest, ContinuousScheduler,
                                     ExecutorProfile, simulate)


def _req(uid, prompt, arrival=0.0, delta=10.0, new_tokens=0):
    return ArrivingRequest(uid=uid, impl=0, edge=0, arrival=arrival,
                           prompt_tokens=prompt, new_tokens=new_tokens,
                           alpha=0.0, delta=delta, accuracy=0.9)


def _routed_instance(n_users=120, seed=0):
    cat = default_catalog()
    inst = cat.to_instance(n_users, 2, storage_capacity=80.0, seed=seed)
    router = Router("egp")
    router.place(inst)
    d = router.route(inst)
    comp = np.array([m.comp_cost for m in cat.models])
    return inst, d.assignment, comp


def test_simulation_serves_everything_assigned():
    inst, assignment, comp = _routed_instance()
    out = simulate(inst, assignment, comp, policy="edf", seed=1)
    assert out["served"] == int((assignment >= 0).sum())
    assert 0.0 <= out["mean_qos"] <= 1.0


def test_edf_beats_fcfs_under_load():
    """QoS-aware admission (earliest deadline first) should not lose to
    FCFS when the cluster is congested (tight arrivals)."""
    inst, assignment, comp = _routed_instance(n_users=200, seed=3)
    edf = simulate(inst, assignment, comp, policy="edf",
                   arrival_rate=200.0, seed=3)
    fcfs = simulate(inst, assignment, comp, policy="fcfs",
                    arrival_rate=200.0, seed=3)
    assert edf["mean_qos"] >= fcfs["mean_qos"] - 1e-9
    assert edf["deadline_misses"] <= fcfs["deadline_misses"] + 2


def test_continuous_batching_reuses_slots():
    """With max_batch=1, requests serialize; the executor must keep
    admitting as slots free (total makespan ≈ sum of durations)."""
    prof = ExecutorProfile(prefill_per_token_s=1e-3,
                           decode_per_step_s=1e-3, max_batch=1)
    reqs = [ArrivingRequest(uid=i, impl=0, edge=0, arrival=0.0,
                            prompt_tokens=100, new_tokens=0, alpha=0.0,
                            delta=10.0, accuracy=0.9) for i in range(4)]
    sched = ContinuousScheduler({(0, 0): prof}, policy="fcfs")
    sched.run(reqs)
    finishes = sorted(r.finish for r in reqs)
    assert all(r.finish > 0 for r in reqs)
    np.testing.assert_allclose(finishes, [0.1, 0.2, 0.3, 0.4], rtol=1e-6)


def test_simulation_deterministic():
    inst, assignment, comp = _routed_instance(seed=7)
    a = simulate(inst, assignment, comp, seed=7)
    b = simulate(inst, assignment, comp, seed=7)
    assert a == b


# ===========================================================================
# Regression: event-heap correctness (the two pre-rewrite bugs)
# ===========================================================================

def test_regression_slot_frees_at_true_completion_time():
    """Freeing a batch slot must admit queued work at the *earliest*
    completion time. The pre-rewrite executor filtered its running heap
    with a plain list comprehension, silently breaking the heap invariant:
    with in-flight finishes [3.0, 2.0] left after the filter, the root
    (3.0) masked the true next completion (2.0), so the queued request
    started a full second late and its latency was corrupted."""
    prof = ExecutorProfile(prefill_per_token_s=1e-3, decode_per_step_s=0.0,
                           max_batch=3)
    # all arrive at t=0, fcfs order = uid order; occupancy factor 1+0.15·occ
    r0 = _req(0, 1000)   # occ 0 → dur 1.0,     finish 1.0
    r1 = _req(1, 2609)   # occ 1 → dur 3.00035, finish 3.00035
    r2 = _req(2, 1539)   # occ 2 → dur 2.0007,  finish 2.0007
    r3 = _req(3, 1600)   # queued; admitted at 1.0 (occ 2) → finish 3.08
    r4 = _req(4, 100)    # queued; must start when r2's slot frees (2.0007)
    reqs = [r0, r1, r2, r3, r4]
    ContinuousScheduler({(0, 0): prof}, policy="fcfs").run(reqs)
    assert r0.finish == pytest.approx(1.0)
    assert r3.start == pytest.approx(1.0)
    assert r3.finish == pytest.approx(1.0 + 1.6 * 1.3)
    # the regression: pre-fix r4 started at r1's finish (3.00035, and at
    # occupancy 1) instead of r2's (2.0007, occupancy 2)
    assert r4.start == pytest.approx(r2.finish)
    assert r4.finish == pytest.approx(r2.finish + 0.1 * 1.3)


def test_regression_equal_finish_times_do_not_crash():
    """Two equal finish times must not compare request objects. The
    pre-rewrite running heap pushed bare ``(finish, request)`` tuples;
    ``ArrivingRequest`` is unordered, so a tie raised TypeError."""
    prof = ExecutorProfile(prefill_per_token_s=1e-3, decode_per_step_s=0.0,
                           max_batch=64)
    # engineered bit-exact tie: (23·1e-3)·1.0 == (20·1e-3)·1.15 == 0.023
    pair = [_req(0, 23), _req(1, 20)]
    ContinuousScheduler({(0, 0): prof}, policy="fcfs").run(pair)
    assert pair[0].finish == pair[1].finish == 0.023
    # tie-heavy stress: 25 scaled tie pairs in one rolling batch, plus a
    # burst of zero-length requests (all finish at their admission instant)
    for policy in ("edf", "fcfs"):
        bulk = [r for m in range(1, 26)
                for r in (_req(2 * m, 23 * m), _req(2 * m + 1, 20 * m))]
        bulk += [_req(100 + u, 0, arrival=float(u % 3)) for u in range(50)]
        sched = ContinuousScheduler({(0, 0): prof}, policy=policy)
        sched.run(bulk)
        assert all(r.finish >= r.arrival for r in bulk)
        assert bulk[0].finish == bulk[1].finish == 0.023


def test_stateful_run_until_matches_one_shot_drain():
    """Tick-incremental operation (submit per tick + run_until) must be
    byte-identical to one-shot batch execution, with backlog visible at
    the tick boundary."""
    prof = ExecutorProfile(prefill_per_token_s=1e-3, decode_per_step_s=0.0,
                           max_batch=2)
    def mk():
        return [_req(u, 400, arrival=0.25 * u) for u in range(12)]

    one = mk()
    ContinuousScheduler({(0, 0): prof}, policy="edf").run(one)

    two = mk()
    sched = ContinuousScheduler({(0, 0): prof}, policy="edf")
    sched.submit(two[:6])           # tick 0: arrivals in [0, 1.5)
    sched.run_until(1.5)
    assert sched.in_flight() > 0    # batches survive the tick boundary
    assert sched.backlog() == 6 - len(sched.completed)
    sched.submit(two[6:])           # tick 1
    sched.run_until(3.0)
    sched.drain()
    f1 = np.array([r.finish for r in one])
    f2 = np.array([r.finish for r in two])
    assert f1.tobytes() == f2.tobytes()
    assert sched.backlog() == 0 and len(sched.completed) == 12


def test_delay_executor_gates_admission_until_load_completes():
    """A model-load gate must hold queued work (even work arriving inside
    the window) and release it the instant the load finishes."""
    prof = ExecutorProfile(prefill_per_token_s=1e-3, decode_per_step_s=0.0,
                           max_batch=2)
    sched = ContinuousScheduler({(0, 0): prof}, policy="fcfs")
    sched.delay_executor((0, 0), 2.0)
    r = _req(0, 100, arrival=1.0)
    sched.submit([r])
    sched.run_until(1.5)
    assert sched.in_flight() == 0 and sched.queue_depth() == 1
    sched.drain()
    assert r.start == pytest.approx(2.0)
    assert r.finish == pytest.approx(2.1)


def test_unknown_executor_and_policy_are_rejected():
    with pytest.raises(ValueError):
        ContinuousScheduler(policy="sjf")
    sched = ContinuousScheduler(policy="edf")
    with pytest.raises(KeyError):
        sched.submit([_req(0, 10)])
