"""Continuous-batching scheduler: EDF vs FCFS, slot reuse, determinism."""
import numpy as np

from repro.serving import Router, default_catalog
from repro.serving.scheduler import (ArrivingRequest, ContinuousScheduler,
                                     ExecutorProfile, simulate)


def _routed_instance(n_users=120, seed=0):
    cat = default_catalog()
    inst = cat.to_instance(n_users, 2, storage_capacity=80.0, seed=seed)
    router = Router("egp")
    router.place(inst)
    d = router.route(inst)
    comp = np.array([m.comp_cost for m in cat.models])
    return inst, d.assignment, comp


def test_simulation_serves_everything_assigned():
    inst, assignment, comp = _routed_instance()
    out = simulate(inst, assignment, comp, policy="edf", seed=1)
    assert out["served"] == int((assignment >= 0).sum())
    assert 0.0 <= out["mean_qos"] <= 1.0


def test_edf_beats_fcfs_under_load():
    """QoS-aware admission (earliest deadline first) should not lose to
    FCFS when the cluster is congested (tight arrivals)."""
    inst, assignment, comp = _routed_instance(n_users=200, seed=3)
    edf = simulate(inst, assignment, comp, policy="edf",
                   arrival_rate=200.0, seed=3)
    fcfs = simulate(inst, assignment, comp, policy="fcfs",
                    arrival_rate=200.0, seed=3)
    assert edf["mean_qos"] >= fcfs["mean_qos"] - 1e-9
    assert edf["deadline_misses"] <= fcfs["deadline_misses"] + 2


def test_continuous_batching_reuses_slots():
    """With max_batch=1, requests serialize; the executor must keep
    admitting as slots free (total makespan ≈ sum of durations)."""
    prof = ExecutorProfile(prefill_per_token_s=1e-3,
                           decode_per_step_s=1e-3, max_batch=1)
    reqs = [ArrivingRequest(uid=i, impl=0, edge=0, arrival=0.0,
                            prompt_tokens=100, new_tokens=0, alpha=0.0,
                            delta=10.0, accuracy=0.9) for i in range(4)]
    sched = ContinuousScheduler({(0, 0): prof}, policy="fcfs")
    sched.run(reqs)
    finishes = sorted(r.finish for r in reqs)
    assert all(r.finish > 0 for r in reqs)
    np.testing.assert_allclose(finishes, [0.1, 0.2, 0.3, 0.4], rtol=1e-6)


def test_simulation_deterministic():
    inst, assignment, comp = _routed_instance(seed=7)
    a = simulate(inst, assignment, comp, seed=7)
    b = simulate(inst, assignment, comp, seed=7)
    assert a == b
