"""Unit + property tests for the QoS model (Eqs. 1–6)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    PIESInstance,
    accuracy_satisfaction_np,
    delay_np,
    delay_satisfaction_np,
    eligibility_np,
    qos_matrix_jnp,
    qos_matrix_np,
    synthetic_instance,
)


def test_accuracy_satisfaction_cases():
    # Eq. (2): met threshold ⇒ 1; otherwise 1 − (α − A), floored at 0.
    A = np.array([0.9, 0.5, 0.1])
    alpha = np.array([0.6, 0.95])
    a = accuracy_satisfaction_np(A, alpha)
    assert a[0, 0] == 1.0                      # A=0.9 ≥ α=0.6
    np.testing.assert_allclose(a[0, 1], 1 - (0.6 - 0.5))
    np.testing.assert_allclose(a[1, 2], max(0.0, 1 - (0.95 - 0.1)))
    np.testing.assert_allclose(a[1, 0], 1 - (0.95 - 0.9))


def test_delay_satisfaction_cases():
    # Eq. (3): within threshold ⇒ 1; else linear falloff over δ_max.
    D = np.array([[1.0, 5.0, 40.0]])
    delta = np.array([2.0])
    d = delay_satisfaction_np(D, delta, delta_max=10.0)
    assert d[0, 0] == 1.0
    np.testing.assert_allclose(d[0, 1], 1 - (5.0 - 2.0) / 10.0)
    assert d[0, 2] == 0.0  # overflow past δ_max clamps to 0


def test_delay_even_sharing():
    # Eq. (5)/(6): delay scales with |U_e| (even sharing of K_e, W_e).
    def make(nu):
        return PIESInstance(
            K=np.array([100.0]), W=np.array([50.0]), R=np.array([10.0]),
            sm_service=np.array([0]), sm_acc=np.array([0.8]),
            sm_k=np.array([10.0]), sm_w=np.array([5.0]), sm_r=np.array([1.0]),
            u_edge=np.zeros(nu, dtype=int), u_service=np.zeros(nu, dtype=int),
            u_alpha=np.full(nu, 0.5), u_delta=np.full(nu, 1.0),
        )
    d1 = delay_np(make(1))[0, 0]
    d4 = delay_np(make(4))[0, 0]
    np.testing.assert_allclose(d1, 10.0 / 100.0 + 5.0 / 50.0)
    np.testing.assert_allclose(d4, 4 * d1)


def test_qos_zero_for_other_services():
    inst = synthetic_instance(50, seed=0)
    Q = qos_matrix_np(inst)
    elig = eligibility_np(inst)
    assert np.all(Q[~elig] == 0.0)


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 10_000), st.integers(1, 60))
def test_qos_matrix_bounds_property(seed, n_users):
    inst = synthetic_instance(n_users, n_edges=3, n_services=10, seed=seed)
    Q = qos_matrix_np(inst)
    assert Q.shape == (inst.U, inst.P)
    assert np.all(Q >= 0.0) and np.all(Q <= 1.0)
    assert np.all(np.isfinite(Q))


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 10_000))
def test_qos_jnp_matches_np(seed):
    inst = synthetic_instance(40, n_edges=3, n_services=12, seed=seed)
    Q = qos_matrix_np(inst)
    Qj = np.asarray(qos_matrix_jnp(inst.as_jax()))
    np.testing.assert_allclose(Qj, Q.astype(np.float32), atol=1e-5)


def test_qos_monotone_in_accuracy():
    # Holding everything fixed, a more accurate model never has lower QoS.
    inst = synthetic_instance(30, seed=7)
    Q = qos_matrix_np(inst)
    inst2 = PIESInstance(**{**inst.__dict__, "sm_acc": np.minimum(inst.sm_acc + 0.1, 1.0)})
    Q2 = qos_matrix_np(inst2)
    assert np.all(Q2 >= Q - 1e-12)
