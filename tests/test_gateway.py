"""repro.gateway — the live serving control plane: virtual-clock byte
parity vs the offline horizon, wire-protocol round-trips, TCP ingest,
the wall-clock soak harness, and the live-telemetry integration (stream
frames, gateway SLOs, dash pane)."""
import asyncio
import dataclasses
import json

import numpy as np
import pytest

from repro import obs
from repro.gateway import (Gateway, GatewayConfig, RequestEnvelope,
                           eos_frame, eot_frame, parse_frame,
                           instance_from_requests, result_digest,
                           run_loadgen, run_soak, tcp_loadgen,
                           tick_envelopes)
from repro.serving.horizon import (HorizonConfig, TickController,
                                   run_horizon)
from repro.workloads import get_scenario

SMALL = {"n_user_slots": 32, "n_services": 8, "max_impls": 3, "n_edges": 4}
LOAD = dict(prompt_tokens=768, new_tokens=64, max_batch=4)


def _cfg(**kw):
    base = dict(scenario="flash_crowd", overrides=tuple(SMALL.items()),
                policy="edf", seed=0, n_ticks=3, **LOAD)
    base.update(kw)
    return HorizonConfig(**base)


def _replay(hconfig, **gw_kw):
    """Virtual-clock in-process replay: loadgen lines → gateway."""
    gw = Gateway(GatewayConfig(horizon=hconfig, mode="virtual", **gw_kw))

    async def _run():
        async def send(line):
            gw.submit_line(line)

        task = asyncio.ensure_future(gw.run())
        await run_loadgen(send, hconfig, wall=False)
        return await task

    return asyncio.run(_run()), gw


# ===========================================================================
# Satellite 1: virtual-clock byte parity vs the offline horizon
# ===========================================================================

@pytest.mark.parametrize("policy", ["edf", "fcfs", "feedback"])
def test_virtual_clock_parity_byte_identical(policy):
    """The determinism invariant: a seeded trace replayed through the
    gateway's JSON wire + virtual clock produces TickReports and request
    timings byte-identical to run_horizon on the same (config, seed)."""
    cfg = _cfg(policy=policy, seed=3, n_ticks=4)
    live, _ = _replay(cfg)
    offline = run_horizon(cfg)
    assert result_digest(live) == result_digest(offline)
    fa = np.array([r.finish for r in live.requests])
    fb = np.array([r.finish for r in offline.requests])
    assert fa.tobytes() == fb.tobytes()
    assert live.tick_values().tobytes() == offline.tick_values().tobytes()
    for a, b in zip(live.per_tick, offline.per_tick):
        assert dataclasses.astuple(a) == dataclasses.astuple(b)


def test_parity_across_seeds_and_scenarios():
    for scenario in ("steady", "trace_replay_bursty"):
        for seed in (0, 7):
            cfg = _cfg(scenario=scenario, seed=seed, policy="feedback")
            live, _ = _replay(cfg)
            assert result_digest(live) == result_digest(run_horizon(cfg))


# ===========================================================================
# Wire protocol
# ===========================================================================

def test_envelope_wire_roundtrip_is_exact():
    """JSON floats are repr-shortest-roundtrip: α/δ/arrival survive the
    wire bit-for-bit — the precondition for instance-level parity."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        env = RequestEnvelope(tick=3, u=1, edge=2, service=5,
                              alpha=float(rng.random()),
                              delta=float(rng.random() * 10),
                              arrival=float(rng.random() * 100))
        back = RequestEnvelope.from_wire(parse_frame(env.to_line()))
        assert back == env


def test_parse_frame_rejects_garbage():
    assert parse_frame("") is None
    assert parse_frame("not json\n") is None
    assert parse_frame('{"v": 99, "type": "req"}') is None   # bad version
    assert parse_frame('{"v": 1, "type": "nope"}') is None   # bad type
    assert parse_frame('[1,2,3]') is None
    assert parse_frame(json.dumps(
        {"v": 1, "type": "eot", "tick": 2, "n": 5})) is not None


def test_malformed_lines_are_counted_not_fatal():
    cfg = _cfg(n_ticks=2)
    gw = Gateway(GatewayConfig(horizon=cfg, mode="virtual"))

    async def _run():
        async def send(line):
            gw.submit_line(line)

        task = asyncio.ensure_future(gw.run())
        gw.submit_line("garbage that is not json\n")
        await run_loadgen(send, cfg, wall=False)
        return await task

    result = asyncio.run(_run())
    assert gw.counters["gateway.malformed"] == 1
    assert result_digest(result) == result_digest(run_horizon(cfg))


def test_instance_from_requests_validates_user_set():
    sc = get_scenario("flash_crowd", **SMALL)
    cfg = _cfg()
    envs = tick_envelopes(sc, cfg, 0)
    inst, times = instance_from_requests(sc, cfg.seed, 0, envs)
    ref = sc.instance_at(cfg.seed, 0)
    np.testing.assert_array_equal(inst.u_edge, ref.u_edge)
    np.testing.assert_array_equal(inst.u_alpha, ref.u_alpha)
    assert times.shape == (inst.U,)
    with pytest.raises(ValueError):
        instance_from_requests(sc, cfg.seed, 0, [])
    with pytest.raises(ValueError):    # a hole in the user indexing
        instance_from_requests(sc, cfg.seed, 0, envs[1:])


# ===========================================================================
# TickController.step_idle (the wall-mode empty-tick path)
# ===========================================================================

def test_step_idle_keeps_reports_coherent():
    cfg = _cfg(n_ticks=3)
    ctl = TickController(cfg)
    ctl.step(0, ctl.materialize(0))
    ctl.step_idle(1)
    ctl.step(2, ctl.materialize(2))
    res = ctl.finalize()
    assert len(res.per_tick) == 3
    assert res.per_tick[1].submitted == 0
    assert res.per_tick[1].served == 0
    assert res.per_tick[1].mean_realized_qos == 0.0
    for t in res.per_tick:
        assert t.served + t.dropped == t.submitted
    assert res.served == len(res.requests)


# ===========================================================================
# TCP ingest + wall mode
# ===========================================================================

def test_tcp_ingest_wall_mode_end_to_end():
    cfg = _cfg(n_ticks=3, seed=1)
    gw = Gateway(GatewayConfig(horizon=cfg, mode="wall", speed=50.0))

    async def _run():
        server = asyncio.ensure_future(gw.serve())
        while gw.bound_port is None:
            await asyncio.sleep(0.005)
        lg = await tcp_loadgen("127.0.0.1", gw.bound_port, cfg,
                               speed=50.0, n_ticks=3)
        return await server, lg

    result, lg = asyncio.run(_run())
    assert lg.ticks == 3
    assert gw.counters["gateway.admitted"] == lg.sent
    assert gw.counters["gateway.dropped_ingress"] == 0
    assert len(result.per_tick) == 3
    # wall pacing never changes simulation-time semantics
    assert result.served + result.dropped == result.submitted
    assert result.submitted == lg.sent
    # wall mode measured its own operation
    assert gw.registry.histogram("gateway.loop_lag_ms").count == 3
    assert gw.registry.histogram("gateway.admission_ms").count == lg.sent


def test_wall_mode_empty_run_exits_cleanly():
    cfg = _cfg(n_ticks=2)
    gw = Gateway(GatewayConfig(horizon=cfg, mode="wall", speed=10.0,
                               start_timeout_s=0.05))
    result = asyncio.run(gw.run())
    assert result.per_tick == [] and result.requests == []


# ===========================================================================
# Satellite 6 (harness half): the judged soak
# ===========================================================================

def test_soak_smoke_bounded_and_clean():
    report = run_soak("flash_crowd", seed=0, policy="feedback",
                      speed=20.0, duration_s=1.5,
                      overrides={**SMALL, **LOAD})
    assert report.ticks >= 10
    assert report.admitted > 0
    assert report.admitted == report.sent  # no ingress drops at this rate
    assert report.bounded and report.ok
    assert report.sustained_rps > 0
    assert np.isfinite(report.p99_admission_ms)
    d = report.to_json()
    assert d["ok"] is True and "sustained_rps" in d
    assert "OK" in report.line()


# ===========================================================================
# Satellite 3 glue: stream frames, SLO selectors, dash pane
# ===========================================================================

def test_gateway_emits_stream_frames(tmp_path):
    """A live gateway publishes gateway + metrics frames on the PR-7
    stream — and streaming stays observational (byte-identical result)."""
    cfg = _cfg(n_ticks=3, seed=2)
    baseline, _ = _replay(cfg)
    spec = str(tmp_path / "stream.jsonl")
    obs.enable_stream(spec, source="gateway-test")
    try:
        streamed, _ = _replay(cfg, metrics_every=2)
    finally:
        obs.disable_stream()
    assert result_digest(streamed) == result_digest(baseline)
    frames = list(obs.read_stream(spec))
    kinds = [f["type"] for f in frames]
    assert kinds.count("gateway") == 3
    assert "metrics" in kinds and "tick" in kinds and "horizon" in kinds
    gw_frames = [f for f in frames if f["type"] == "gateway"]
    assert all(f["payload"]["mode"] == "virtual" for f in gw_frames)
    assert [f["payload"]["tick"] for f in gw_frames] == [0, 1, 2]
    metrics = [f for f in frames if f["type"] == "metrics"]
    names = {m["name"] for m in metrics[-1]["payload"]["metrics"]}
    assert {"gateway.loop_lag_ms", "gateway.admission_ms"} <= names
    assert "gateway.ticks" in metrics[-1]["payload"]["counters"]


def test_gateway_slos_evaluate_on_live_frames():
    from repro.obs.slo import DEFAULT_SLOS, evaluate_slos

    names = {s.name for s in DEFAULT_SLOS}
    assert {"gateway-loop-lag-p99", "gateway-admission-p99",
            "gateway-ingress-depth"} <= names
    frames = [
        {"type": "gateway", "t": 10.0,
         "payload": {"tick": 0, "ingress_depth": 12, "loop_lag_ms": 1.0}},
        {"type": "gateway", "t": 11.0,
         "payload": {"tick": 1, "ingress_depth": 40, "loop_lag_ms": 2.0}},
        {"type": "metrics", "t": 11.5, "payload": {"metrics": [
            {"kind": "histogram", "name": "gateway.loop_lag_ms",
             "labels": {}, "growth": 2.0, "min_value": 1e-9,
             "buckets": {"30": 3}, "count": 3, "sum": 4.0,
             "min": 1.0, "max": 2.0},
            {"kind": "histogram", "name": "gateway.admission_ms",
             "labels": {}, "growth": 2.0, "min_value": 1e-9,
             "buckets": {"34": 5}, "count": 5, "sum": 60.0,
             "min": 10.0, "max": 14.0}], "counters": {}}},
    ]
    by_name = {r.slo.name: r
               for r in evaluate_slos(DEFAULT_SLOS, frames=frames)}
    r = by_name["gateway-ingress-depth"]
    assert r.n_samples == 2 and r.value == 40.0 and r.ok
    assert by_name["gateway-loop-lag-p99"].n_samples == 3
    assert by_name["gateway-loop-lag-p99"].ok
    assert by_name["gateway-admission-p99"].ok
    # no gateway traffic → vacuously ok, reported n=0
    empty = {r.slo.name: r for r in evaluate_slos(DEFAULT_SLOS, frames=[])}
    assert empty["gateway-ingress-depth"].n_samples == 0
    assert empty["gateway-ingress-depth"].ok


def test_dash_renders_gateway_pane(tmp_path):
    from repro.obs.dash import DashState, render

    cfg = _cfg(n_ticks=2)
    spec = str(tmp_path / "stream.jsonl")
    obs.enable_stream(spec, source="gw")
    try:
        _replay(cfg)
    finally:
        obs.disable_stream()
    state = DashState()
    for frame in obs.read_stream(spec):
        state.update(frame)
    screen = render(state)
    assert "gateway" in screen
    assert "flash_crowd" in screen
    # the tick pane still renders too (dash unchanged against a server)
    assert "tick/s" in screen
