"""Optional-hypothesis shim: property sweeps skip cleanly when the package
(the ``dev`` extra) is absent, instead of erroring collection for the whole
module; the plain parametrized tests alongside them still run.

Usage: ``from hypothesis_compat import given, settings, st``.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    # The stub defers to pytest.importorskip at call time so each property
    # test reports the canonical per-test skip.
    def given(*_args, **_kwargs):
        def deco(_fn):
            def skipper(*_a, **_k):
                pytest.importorskip("hypothesis")
            return skipper
        return deco

    settings = given

    class _AnyStrategy:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
