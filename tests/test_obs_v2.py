"""repro.obs v2 — wire-protocol goldens and rejection cases, histogram /
registry merge parity, the stream-on byte-identity invariant (with a live
dashboard attached), fleet trace stitching across subprocess workers,
SLO evaluation + burn rates, the benchmark regression gate, and the
dash / ``fleet status --watch`` smoke."""
import io
import json
import math
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.obs.aggregate import (rollup_counters, rollup_metrics,
                                 stitch_fleet, stitch_traces,
                                 telemetry_anchors)
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.slo import SLO, compare_bench, evaluate_slos, load_slos
from repro.obs.stream import (FileSink, FrameValidator, SocketSink,
                              StreamError, StreamPublisher,
                              parse_stream_spec, read_stream)

SRC = Path(__file__).resolve().parents[1] / "src"

#: Shrunk scenario (see tests/test_horizon.py) — keeps horizons fast.
SMALL = {"n_user_slots": 32, "n_services": 8, "max_impls": 3, "n_edges": 4}


@pytest.fixture(autouse=True)
def _obs_off():
    """Tracing and streaming must never leak between tests."""
    assert not obs.enabled() and not obs.stream_active()
    yield
    obs.disable()
    obs.disable_stream()


def _fake_clock(step=1.0, start=100.0):
    state = {"t": start - step}

    def clock():
        state["t"] += step
        return state["t"]

    return clock


def _spec():
    from repro.sweeps import SweepSpec
    grid = (tuple(sorted({**SMALL, "switching_cost": 0.0,
                          "stickiness": 0.0}.items())),)
    return SweepSpec(kind="serving", scenarios=("steady",), seeds=(0, 1),
                     n_ticks=2, algos=("edf",), override_grid=grid)


# ===========================================================================
# Wire protocol: golden frames, handshake, rejection cases
# ===========================================================================

def test_stream_file_golden_lines(tmp_path):
    """The exact bytes on the wire, via the injectable clock."""
    path = tmp_path / "s.jsonl"
    pub = StreamPublisher(FileSink(path), source="test",
                          clock=_fake_clock(step=1.0, start=100.0))
    pub.emit("tick", {"tick": 0, "queue_depth": 3})
    pub.close()
    lines = path.read_text().strip().splitlines()
    assert [json.loads(line) for line in lines] == [
        {"payload": {"pid": os.getpid(), "source": "test",
                     "stream_schema": 1},
         "seq": 0, "stream_schema": 1, "t": 100.0, "type": "hello"},
        {"payload": {"queue_depth": 3, "tick": 0},
         "seq": 1, "stream_schema": 1, "t": 101.0, "type": "tick"},
        {"payload": {"n_frames": 2},
         "seq": 2, "stream_schema": 1, "t": 102.0, "type": "bye"},
    ]
    # and keys are sorted on the wire (stable goldens, diffable streams)
    assert all(line.index('"payload"') < line.index('"seq"')
               < line.index('"type"') for line in lines)


def test_read_stream_roundtrip_and_partial_tail(tmp_path):
    path = tmp_path / "s.jsonl"
    pub = StreamPublisher(FileSink(path), source="rt")
    pub.emit("tick", {"tick": 0})
    # an incomplete trailing line must be buffered, never parsed
    with open(path, "a") as f:
        f.write('{"stream_schema": 1, "seq": 2, "t": 1.0, "type": "ti')
    frames = list(read_stream(str(path), follow=False))
    assert [f["type"] for f in frames] == ["hello", "tick"]


def test_validator_rejects_missing_handshake():
    v = FrameValidator()
    with pytest.raises(StreamError, match="hello handshake"):
        v.feed({"stream_schema": 1, "seq": 0, "type": "tick",
                "payload": {}})


def test_validator_rejects_schema_mismatch():
    v = FrameValidator()
    with pytest.raises(StreamError, match="schema v99"):
        v.feed({"seq": 0, "type": "hello",
                "payload": {"stream_schema": 99}})


def test_validator_rejects_out_of_order_and_gaps():
    def hello(seq=0):
        return {"seq": seq, "type": "hello",
                "payload": {"stream_schema": 1}}

    v = FrameValidator()
    v.feed(hello())
    v.feed({"seq": 1, "type": "tick", "payload": {}})
    with pytest.raises(StreamError, match="out-of-order"):
        v.feed({"seq": 1, "type": "tick", "payload": {}})
    # contiguous mode (single-writer files): a gap is a lost frame
    v2 = FrameValidator(contiguous=True)
    v2.feed(hello())
    with pytest.raises(StreamError, match="missing frame"):
        v2.feed({"seq": 5, "type": "tick", "payload": {}})
    # socket mode tolerates gaps (broadcast drops frames for slow clients)
    v3 = FrameValidator(contiguous=False)
    v3.feed(hello())
    assert v3.feed({"seq": 5, "type": "tick", "payload": {}})["seq"] == 5


def test_validator_rejects_torn_complete_line(tmp_path):
    path = tmp_path / "s.jsonl"
    pub = StreamPublisher(FileSink(path), source="torn")
    pub.close()
    with open(path, "a") as f:
        f.write('{"seq": 3, "type": "tick", truncated-garbage}\n')
    with pytest.raises(StreamError, match="truncated/corrupt"):
        # bye at seq 1 terminates; feed the torn line directly instead
        v = FrameValidator()
        for line in path.read_text().splitlines():
            v.feed_line(line)


def test_parse_stream_spec():
    assert parse_stream_spec("1", "d.jsonl") == ("file", "d.jsonl")
    assert parse_stream_spec("true") == ("file", "obs_stream.jsonl")
    assert parse_stream_spec("unix:/tmp/x.sock") == ("unix", "/tmp/x.sock")
    assert parse_stream_spec("tcp:0.0.0.0:9000") == \
        ("tcp", ("0.0.0.0", 9000))
    assert parse_stream_spec("tcp:9000") == ("tcp", ("127.0.0.1", 9000))
    assert parse_stream_spec("/a/b.jsonl") == ("file", "/a/b.jsonl")


def test_socket_stream_replays_hello_to_late_joiner(tmp_path):
    sock = str(tmp_path / "s.sock")
    pub = StreamPublisher(SocketSink("unix", sock), source="sock")
    frames = []

    def reader():
        frames.extend(read_stream(f"unix:{sock}", timeout_s=5.0))

    th = threading.Thread(target=reader, daemon=True)
    th.start()
    deadline = time.monotonic() + 5.0
    while not pub._sink._clients and time.monotonic() < deadline:
        time.sleep(0.01)  # wait for the late joiner to be registered
    assert pub._sink._clients, "reader never connected"
    pub.emit("tick", {"tick": 7})
    pub.close()
    th.join(timeout=5.0)
    assert not th.is_alive()
    types = [f["type"] for f in frames]
    assert types[0] == "hello"          # replayed to the late joiner
    assert "tick" in types and types[-1] == "bye"
    assert not Path(sock).exists()      # close unlinks the unix path


def test_publisher_survives_sink_failure(tmp_path):
    path = tmp_path / "s.jsonl"
    pub = StreamPublisher(FileSink(path), source="fail")
    pub._sink._f.close()  # simulate the disk going away mid-run
    assert pub.emit("tick", {"tick": 0}) is False
    assert pub.failed and pub.emit("tick", {"tick": 1}) is False


def test_enable_stream_from_env(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_OBS_STREAM", raising=False)
    assert obs.enable_stream_from_env() is None
    monkeypatch.setenv("REPRO_OBS_STREAM", "off")
    assert obs.enable_stream_from_env() is None
    path = tmp_path / "env.jsonl"
    monkeypatch.setenv("REPRO_OBS_STREAM", "1")
    pub = obs.enable_stream_from_env(default_path=str(path), source="env")
    assert pub is not None and obs.stream_active()
    obs.publish("tick", tick=0)
    obs.disable_stream()
    assert not obs.stream_active()
    types = [f["type"] for f in read_stream(str(path))]
    assert types == ["hello", "tick", "bye"]


# ===========================================================================
# Histogram / registry merge: exact bucket arithmetic
# ===========================================================================

def test_histogram_merge_parity_with_concatenated_samples():
    rng = np.random.default_rng(3)
    a = rng.lognormal(mean=-3.0, sigma=1.0, size=5_000)
    b = rng.lognormal(mean=-1.0, sigma=0.5, size=3_000)
    ha, hb, hall = Histogram(), Histogram(), Histogram()
    ha.observe_many(a)
    hb.observe_many(b)
    hall.observe_many(np.concatenate([a, b]))
    merged = ha.merge(hb)
    # bucket counts, count, min, max: exactly the single-pass histogram
    assert merged._buckets == hall._buckets
    assert merged.count == hall.count
    assert merged.min == hall.min and merged.max == hall.max
    # float sum differs only by addition-order ulps
    np.testing.assert_allclose(merged.sum, hall.sum, rtol=1e-12)
    for q in (0.5, 0.95, 0.99):
        assert merged.quantile(q) == hall.quantile(q)


def test_histogram_merge_rejects_layout_mismatch():
    h1, h2 = Histogram(), Histogram(growth=2.0)
    with pytest.raises(ValueError, match="bucket layouts"):
        h1.merge(h2)


def test_histogram_record_roundtrip():
    h = Histogram()
    h.observe_many([0.001, 0.01, 0.1, 0.1])
    back = Histogram.from_record(json.loads(json.dumps(h.record())))
    assert back._buckets == h._buckets and back.count == h.count
    assert back.min == h.min and back.max == h.max and back.sum == h.sum


def test_registry_merge_and_from_snapshot():
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    r1.counter("items", executor="serving").inc(4)
    r2.counter("items", executor="serving").inc(6)
    r2.counter("items", executor="host").inc(1)
    r1.gauge("qos").set(0.5)
    r2.gauge("qos").set(0.9)
    r1.histogram("lat", scenario="steady").observe_many([0.01, 0.02])
    r2.histogram("lat", scenario="steady").observe_many([0.04])
    merged = MetricsRegistry().merge(r1).merge(r2)
    assert merged.counter("items", executor="serving").value == 10
    assert merged.counter("items", executor="host").value == 1
    assert merged.gauge("qos").value == 0.9      # last writer in order
    assert merged.histogram("lat", scenario="steady").count == 3
    # snapshot → from_snapshot is the identity on the snapshot
    snap = merged.snapshot()
    assert MetricsRegistry.from_snapshot(snap).snapshot() == snap
    with pytest.raises(ValueError, match="schema v9"):
        MetricsRegistry.from_snapshot([{"metrics_schema": 9,
                                        "kind": "counter", "name": "x"}])


# ===========================================================================
# Trace stitching: pid swimlanes, clock alignment, rollups
# ===========================================================================

def _worker_doc(pid, wall_ns, n=1):
    tr = obs.Tracer(capacity=16,
                    clock=_fake_clock(step=1000, start=1000))
    for _ in range(n):
        with tr.span("tick.place"):
            pass
    tr.count("served", 2)
    tr.metrics.histogram("serving.latency_s").observe_many([0.01, 0.02])
    doc = tr.snapshot()
    doc["pid"] = pid
    if wall_ns is None:
        doc.pop("anchor", None)
    else:
        doc["anchor"] = {"wall_ns": wall_ns, "mono_ns": 0}
    return doc


def test_stitch_traces_aligns_monotonic_clocks():
    # worker A's clock is offset +10µs on the shared wall timeline
    a = _worker_doc(pid=1, wall_ns=10_000)
    b = _worker_doc(pid=2, wall_ns=0)
    chrome = stitch_traces([a, b], labels=["wa", "wb"])
    assert obs.validate_chrome_trace(chrome) == 2
    assert chrome["otherData"]["stitched_from"] == {"wa": 1, "wb": 2}
    assert chrome["otherData"]["counters"] == {"served": 4}
    x = {ev["pid"]: ev for ev in chrome["traceEvents"]
         if ev["ph"] == "X"}
    assert x[2]["ts"] == 0.0            # earliest aligned record at t=0
    assert x[1]["ts"] == 10.0           # shifted by the anchor delta (µs)
    names = {ev["pid"]: ev["args"]["name"] for ev in chrome["traceEvents"]
             if ev.get("name") == "process_name"}
    assert names == {1: "wa", 2: "wb"}  # one swimlane per worker


def test_stitch_traces_remaps_pid_collisions_and_unanchored():
    a = _worker_doc(pid=7, wall_ns=5_000)
    b = _worker_doc(pid=7, wall_ns=None)   # pre-v2 artifact, no anchor
    chrome = stitch_traces([a, b], labels=["wa", "wb"])
    pids = set(chrome["otherData"]["stitched_from"].values())
    assert len(pids) == 2 and 7 in pids    # collision remapped, not merged
    # the unanchored artifact is start-aligned: its first record at ts=0
    b_pid = chrome["otherData"]["stitched_from"]["wb"]
    b_ts = [ev["ts"] for ev in chrome["traceEvents"]
            if ev["ph"] == "X" and ev["pid"] == b_pid]
    assert min(b_ts) == 0.0


def test_stitch_rollup_metrics_bucket_exact():
    docs = [_worker_doc(pid=1, wall_ns=0), _worker_doc(pid=2, wall_ns=0)]
    reg = rollup_metrics(docs)
    h = reg.histogram("serving.latency_s")
    assert h.count == 4 and h.min == 0.01 and h.max == 0.02
    assert rollup_counters(docs) == {"served": 4}


def test_telemetry_anchor_pairs(tmp_path):
    from repro.fleet.telemetry import WorkerTelemetry
    wt = WorkerTelemetry(tmp_path, "w0")
    wt.start()
    anchors = telemetry_anchors(tmp_path)
    assert os.getpid() in anchors
    wall_ns, mono_ns = anchors[os.getpid()]
    assert abs(wall_ns / 1e9 - time.time()) < 60.0
    assert 0 < mono_ns <= time.perf_counter_ns()


# ===========================================================================
# The hard invariant: streaming is observational only
# ===========================================================================

def test_serving_store_byte_identical_with_stream_and_dash(tmp_path,
                                                           monkeypatch):
    """REPRO_OBS_STREAM=1 + a live dashboard attached must not change one
    stored byte vs the stream-off run."""
    from repro.obs.dash import run_dash
    from repro.sweeps import SweepStore, run_sweep

    run_sweep(_spec(), store_dir=tmp_path / "off")

    stream = tmp_path / "stream.jsonl"
    monkeypatch.setenv("REPRO_OBS_STREAM", str(stream))
    obs.enable()
    obs.enable_stream_from_env(source="test")
    dash_out = io.StringIO()
    dash_rc = {}

    def _dash():
        dash_rc["rc"] = run_dash([str(stream)], interval=0.1,
                                 timeout_s=30.0, out=dash_out, clear=False)

    th = threading.Thread(target=_dash, daemon=True)
    th.start()
    run_sweep(_spec(), store_dir=tmp_path / "on")
    obs.disable()
    obs.disable_stream()        # bye frame ends the dashboard
    th.join(timeout=30.0)
    assert not th.is_alive() and dash_rc["rc"] == 0

    frames = list(read_stream(str(stream)))
    types = {f["type"] for f in frames}
    assert "tick" in types and "horizon" in types  # telemetry flowed
    assert "repro.obs dash" in dash_out.getvalue()

    off, on = SweepStore(tmp_path / "off"), SweepStore(tmp_path / "on")
    assert off.keys() == on.keys() and len(off) == 4
    for key in off.keys():
        a, b = np.float64(off.value(key)), np.float64(on.value(key))
        assert a.tobytes() == b.tobytes()
        ma, mb = off.metrics(key), on.metrics(key)
        assert ma.keys() == mb.keys()
        for name in ma:
            assert np.float64(ma[name]).tobytes() == \
                np.float64(mb[name]).tobytes(), (key, name)
    assert [c["keys"] for c in off.chunks()] == \
        [c["keys"] for c in on.chunks()]


def test_tick_reports_identical_with_stream_on(tmp_path):
    from repro.serving.horizon import HorizonConfig, run_horizon
    import dataclasses
    cfg = HorizonConfig(scenario="steady", policy="edf", seed=0, n_ticks=2,
                        overrides=tuple(sorted(SMALL.items())))
    ref = run_horizon(cfg)
    obs.enable_stream(str(tmp_path / "s.jsonl"), source="test")
    streamed = run_horizon(cfg)
    obs.disable_stream()
    np.testing.assert_array_equal(ref.tick_values(),
                                  streamed.tick_values())
    for a, b in zip(ref.per_tick, streamed.per_tick):
        assert repr(dataclasses.asdict(a)) == repr(dataclasses.asdict(b))


# ===========================================================================
# The acceptance run: 2 subprocess workers → one stitched trace
# ===========================================================================

def test_two_worker_fleet_stitches_into_one_trace(tmp_path, monkeypatch):
    from repro.fleet import plan
    from repro.fleet.cli import main as fleet_main
    from repro.fleet.worker import spawn_local_workers
    from repro.obs.cli import main as obs_main
    from repro.sweeps import run_sweep

    spec = _spec()              # 2 seeds → 2 tasks with seeds_per_task=1
    root = tmp_path / "fleet"
    plan(spec, root)
    monkeypatch.setenv("PYTHONPATH", str(SRC))
    monkeypatch.setenv("REPRO_OBS", "1")
    monkeypatch.setenv("REPRO_OBS_DIR", str(root / "obs"))
    monkeypatch.setenv("REPRO_OBS_STREAM", "1")
    # max_tasks=1 guarantees each worker executes exactly one task, so the
    # stitched trace must carry spans from two distinct pids
    procs = spawn_local_workers(root, 2, max_tasks=1)
    assert [p.wait(timeout=300) for p in procs] == [0, 0]

    out_path = tmp_path / "stitched_chrome.json"
    summary = stitch_fleet(root, out=out_path)
    chrome = summary["chrome_trace"]
    assert obs.validate_chrome_trace(chrome) >= 2
    assert summary["n_artifacts"] == 2 and len(summary["workers"]) == 2
    span_pids = {ev["pid"] for ev in chrome["traceEvents"]
                 if ev["ph"] == "X"}
    assert len(span_pids) == 2          # both workers, distinct swimlanes
    assert json.loads(out_path.read_text())["otherData"]["stitched_from"] \
        == summary["workers"]

    # fleet rollup == single-process run, exactly (bucket arithmetic):
    # serving latencies are deterministic simulation outputs, so the
    # merged per-worker histograms must equal the single-run histograms
    obs.enable()
    run_sweep(spec, store_dir=tmp_path / "single")
    tr = obs.disable()

    def _latency_records(snap):
        return sorted(
            ({k: r[k] for k in ("labels", "buckets", "count", "min",
                                "max")}
             for r in snap if r.get("kind") == "histogram"
             and r["name"] == "serving.latency_s"),
            key=lambda r: sorted(r["labels"].items()))

    assert _latency_records(summary["metrics"]) == \
        _latency_records(tr.metrics.snapshot())

    # per-worker streams landed, and both CLIs consume them: the stitch
    # CLI re-validates, dash --once renders at least one frame (exit 0),
    # and status --watch exits immediately on the drained queue
    streams = sorted((root / "stream").glob("*.jsonl"))
    assert len(streams) == 2
    assert obs_main(["stitch", "--root", str(root),
                     "--out", str(tmp_path / "cli_chrome.json"),
                     "--json", str(tmp_path / "cli_summary.json")]) == 0
    assert obs_main(["dash", "--root", str(root), "--once"]) == 0
    assert fleet_main(["status", "--root", str(root), "--watch",
                       "--interval", "0.01"]) == 0


# ===========================================================================
# SLOs: burn rates, spec files, the bench gate
# ===========================================================================

def _tick_frame(t, **payload):
    return {"stream_schema": 1, "seq": 0, "t": t, "type": "tick",
            "payload": payload}


def test_evaluate_slos_windows_and_burn_rates():
    frames = [_tick_frame(100.0 + i, miss_rate=0.2 + 0.2 * i,
                          queue_depth=10 * (i + 1)) for i in range(3)]
    slos = [SLO("miss", "tick.miss_rate", max_value=0.8),
            SLO("depth", "tick.queue_depth", max_value=20, agg="max"),
            SLO("qos", "tick.window_qos", min_value=0.5)]
    rep = {r.slo.name: r for r in evaluate_slos(slos, frames=frames)}
    assert rep["miss"].value == pytest.approx(0.4) and rep["miss"].ok
    assert rep["miss"].burn_rate == pytest.approx(0.4 / 0.8)
    assert rep["depth"].value == 30 and not rep["depth"].ok
    assert rep["depth"].burn_rate == pytest.approx(1.5)
    # no window_qos samples anywhere: vacuously ok, burn is NaN, n=0
    assert rep["qos"].ok and rep["qos"].n_samples == 0
    assert math.isnan(rep["qos"].burn_rate)
    # the sliding window drops old samples
    old = [_tick_frame(0.0, miss_rate=1.0)] + frames
    windowed = evaluate_slos([SLO("m", "tick.miss_rate", max_value=0.8,
                                  window_s=10.0)], frames=old)[0]
    assert windowed.n_samples == 3      # the t=0 frame fell out


def test_slo_hist_counter_bench_selectors():
    reg = MetricsRegistry()
    reg.histogram("serving.latency_s").observe_many([0.01] * 90 +
                                                    [10.0] * 10)
    bench = {"rows": [{"name": "obs_overhead", "us_per_call": 0.2,
                       "fields": {"disabled_pct": 0.5}}]}
    slos = [SLO("p99", "hist.serving.latency_s.p99", max_value=0.5),
            SLO("spans", "counter.n", min_value=1),
            SLO("ovh", "bench.obs_overhead.disabled_pct", max_value=3.0)]
    rep = {r.slo.name: r for r in
           evaluate_slos(slos, metrics=reg.snapshot(), counters={"n": 5},
                         bench=bench)}
    assert not rep["p99"].ok            # the 10s outlier is the p99
    assert rep["spans"].ok and rep["spans"].value == 5
    assert rep["ovh"].ok and rep["ovh"].burn_rate == \
        pytest.approx(0.5 / 3.0)
    with pytest.raises(ValueError, match="unknown metric selector"):
        evaluate_slos([SLO("x", "bogus.thing", max_value=1)])


def test_load_slos_version_checked(tmp_path):
    path = tmp_path / "slos.json"
    path.write_text(json.dumps({
        "slo_schema": 1,
        "slos": [{"name": "m", "metric": "tick.miss_rate",
                  "max_value": 0.5}]}))
    slos = load_slos(path)
    assert len(slos) == 1 and slos[0].max_value == 0.5
    path.write_text(json.dumps({"slo_schema": 99, "slos": []}))
    with pytest.raises(ValueError, match="schema v99"):
        load_slos(path)
    with pytest.raises(ValueError, match="exactly one"):
        SLO("bad", "tick.x", max_value=1, min_value=0)


def _bench_doc(**quality):
    return {"bench_schema": 1, "rows": [
        {"name": "serving_horizon", "us_per_call": 100.0,
         "fields": {"flash_qos_edf": quality.get("qos", 0.8),
                    "fit_us": quality.get("fit_us", 50.0)}}]}


def test_compare_bench_gate():
    base = _bench_doc()
    assert compare_bench(_bench_doc(), base)["violations"] == []
    # quality drift beyond tolerance fails in BOTH directions
    worse = compare_bench(_bench_doc(qos=0.5), base)
    better = compare_bench(_bench_doc(qos=0.99), base)
    assert worse["violations"] and better["violations"]
    # timing fields only fail past the slowdown factor
    slow = _bench_doc(fit_us=50.0 * 10)
    assert compare_bench(slow, base, max_slowdown=4.0)["violations"]
    assert compare_bench(slow, base, max_slowdown=20.0)["violations"] == []
    # us_per_call cliff
    cliff = _bench_doc()
    cliff["rows"][0]["us_per_call"] = 1e6
    assert any("us_per_call" in v for v in
               compare_bench(cliff, base)["violations"])
    # a requested row missing from either side is itself a violation
    res = compare_bench(_bench_doc(), base,
                        rows={"serving_horizon", "tuning_fit"})
    assert any("tuning_fit" in v for v in res["violations"])


def test_bench_cli_rows_compare_trajectory(tmp_path):
    """--rows gates row groups; --compare exits 0 on an identical baseline
    and 3 on an injected regression; --trajectory appends versioned
    records. Uses the instant roofline_table row."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    repo = Path(__file__).resolve().parents[1]
    new_json = tmp_path / "new.json"
    traj = tmp_path / "traj.jsonl"

    def run(*extra):
        return subprocess.run(
            [sys.executable, "-m", "benchmarks.run",
             "--rows", "roofline_table", *extra],
            cwd=repo, env=env, capture_output=True, text=True, timeout=300)

    p = run("--json", str(new_json), "--trajectory", str(traj))
    assert p.returncode == 0, p.stderr
    doc = json.loads(new_json.read_text())
    assert [r["name"] for r in doc["rows"]] == ["roofline_table"]
    recs = [json.loads(line) for line in
            traj.read_text().strip().splitlines()]
    assert len(recs) == 1 and recs[0]["bench_traj_schema"] == 1
    assert recs[0]["rows"][0]["name"] == "roofline_table"

    # identical baseline → pass
    assert run("--compare", str(new_json)).returncode == 0
    # inject a quality regression into the baseline → exit 3
    bad = json.loads(new_json.read_text())
    fields = bad["rows"][0]["fields"]
    numeric = [k for k, v in fields.items()
               if isinstance(v, (int, float)) and not k.endswith(
                   ("_us", "_ns", "_ms", "_per_s", "_pct"))]
    assert numeric, fields
    fields[numeric[0]] = float(fields[numeric[0]]) + 10.0
    bad_path = tmp_path / "bad.json"
    bad_path.write_text(json.dumps(bad))
    p = run("--compare", str(bad_path))
    assert p.returncode == 3 and "REGRESSION" in p.stderr
    # unknown row group is an argparse error, not a silent no-op
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--rows", "bogus"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=60)
    assert p.returncode == 2 and "unknown --rows" in p.stderr


# ===========================================================================
# Dashboard rendering (pure functions over frames)
# ===========================================================================

def test_dash_state_and_render():
    from repro.obs.dash import DashState, render
    state = DashState()
    state.update({"t": 100.0, "type": "hello",
                  "payload": {"source": "w0", "pid": 1}})
    for i in range(3):
        state.update(_tick_frame(100.0 + i, scenario="steady", seed=0,
                                 policy="edf", tick=i, queue_depth=5,
                                 in_flight=2, dropped=0, window_qos=0.8,
                                 miss_rate=0.1))
    state.update({"t": 103.0, "type": "worker",
                  "payload": {"owner": "w0", "tasks_done": 2,
                              "items_done": 8, "items_per_s": 4.0,
                              "queue_pending_items": 8}})
    state.update({"t": 103.5, "type": "chunk", "payload": {"items": 4}})
    assert state.tick_rate(state.ticks[("steady", 0, "edf")]) == \
        pytest.approx(1.0)
    screen = render(state)
    assert "steady" in screen and "edf" in screen
    assert "w0" in screen and "2s" in screen        # ETA = 8 items / 4/s
    assert "sweep chunks: 1" in screen
    assert "deadline-miss-rate" in screen           # SLO pane, n > 0
    assert "repro.obs dash" in screen and "1 source(s)" in screen


def test_run_dash_once_empty_stream_exits_2(tmp_path):
    from repro.obs.dash import run_dash
    path = tmp_path / "empty.jsonl"
    path.write_text("")
    out = io.StringIO()
    assert run_dash([str(path)], once=True, out=out) == 2
    assert "no tick frames yet" in out.getvalue()


def test_run_dash_reports_stream_errors(tmp_path):
    from repro.obs.dash import run_dash
    path = tmp_path / "bad.jsonl"
    path.write_text('{"seq": 0, "type": "tick", "payload": {}}\n')
    out = io.StringIO()
    assert run_dash([str(path)], once=True, out=out) == 1
    assert "stream error" in out.getvalue()
