"""Pallas kernel validation: interpret-mode vs pure-jnp oracles, sweeping
shapes and dtypes (hypothesis + parametrized grids)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.kernels.qos_matrix.qos_matrix import (check_service_ids,
                                                 greedy_argmax_pallas,
                                                 qos_candidates_pallas,
                                                 qos_matrix_pallas)
from repro.kernels.qos_matrix.ref import (greedy_argmax_ref,
                                          qos_candidates_ref, qos_matrix_ref)
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gqa_decode.gqa_decode import gqa_decode
from repro.kernels.gqa_decode.ref import gqa_decode_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


# ===========================================================================
# qos_matrix
# ===========================================================================

def _qos_args(U, Pn, seed):
    rng = np.random.default_rng(seed)
    j = jnp.asarray
    return dict(
        u_alpha=j(rng.uniform(0, 1, U), jnp.float32),
        u_delta=j(rng.uniform(0, 10, U), jnp.float32),
        u_share_k=j(rng.uniform(0.01, 1, U), jnp.float32),
        u_share_w=j(rng.uniform(0.01, 1, U), jnp.float32),
        u_service=j(rng.integers(0, 7, U), jnp.int32),
        sm_acc=j(rng.uniform(0, 1, Pn), jnp.float32),
        sm_k=j(rng.uniform(1, 30, Pn), jnp.float32),
        sm_w=j(rng.uniform(1, 30, Pn), jnp.float32),
        sm_service=j(rng.integers(0, 7, Pn), jnp.int32),
    )


@settings(deadline=None, max_examples=12)
@given(st.integers(1, 600), st.integers(1, 300), st.integers(0, 99))
def test_qos_matrix_kernel_shape_sweep(U, Pn, seed):
    args = _qos_args(U, Pn, seed)
    out = qos_matrix_pallas(*args.values(), delta_max=10.0,
                            block_u=128, block_p=128, interpret=True)
    ref = qos_matrix_ref(*args.values(), delta_max=10.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)
    assert out.shape == (U, Pn)


def test_qos_matrix_kernel_matches_core_model():
    from repro.core import synthetic_instance, qos_matrix_np
    from repro.kernels.qos_matrix.ops import qos_matrix_from_instance
    inst = synthetic_instance(257, seed=3)
    Q = np.asarray(qos_matrix_from_instance(inst.as_jax()))
    np.testing.assert_allclose(Q, qos_matrix_np(inst).astype(np.float32),
                               atol=1e-5)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_qos_matrix_kernel_f32_parity_vs_float64_host(seed):
    """The kernel computes in float32 by contract (the f64 host matrix is
    downcast at the boundary, never silently inside): parity vs the
    float64 ``qos_matrix_np`` holds at f32 tolerances, not f64 ones."""
    from repro.core import synthetic_instance, qos_matrix_np
    from repro.kernels.qos_matrix.ops import qos_matrix_from_instance
    inst = synthetic_instance(500, seed=seed)
    Q = np.asarray(qos_matrix_from_instance(inst.as_jax()))
    assert Q.dtype == np.float32
    np.testing.assert_allclose(Q, qos_matrix_np(inst),
                               atol=1e-5, rtol=1e-5)


def test_service_id_overflow_guard():
    """int64 service ids beyond int32 range must raise, not wrap silently
    when the kernel casts to int32."""
    ok = np.array([0, 5, 2**31 - 1], dtype=np.int64)
    check_service_ids(ok)  # in-range ids pass through
    bad = np.array([0, 2**31], dtype=np.int64)
    with pytest.raises(OverflowError):
        check_service_ids(bad)
    with pytest.raises(OverflowError):
        check_service_ids(ok, np.array([-2**31 - 1], dtype=np.int64))


# ===========================================================================
# qos_candidates (segmented QoS over [U, K] candidate pairs)
# ===========================================================================

def _cand_args(U, K, seed, frac_valid=0.8):
    rng = np.random.default_rng(seed)
    j = jnp.asarray
    return dict(
        u_alpha=j(rng.uniform(0, 1, U), jnp.float32),
        u_delta=j(rng.uniform(0, 10, U), jnp.float32),
        u_share_k=j(rng.uniform(0.01, 1, U), jnp.float32),
        u_share_w=j(rng.uniform(0.01, 1, U), jnp.float32),
        cand_acc=j(rng.uniform(0, 1, (U, K)), jnp.float32),
        cand_k=j(rng.uniform(1, 30, (U, K)), jnp.float32),
        cand_w=j(rng.uniform(1, 30, (U, K)), jnp.float32),
        cand_valid=j(rng.random((U, K)) < frac_valid, jnp.float32),
    )


@settings(deadline=None, max_examples=12)
@given(st.integers(1, 600), st.integers(1, 20), st.integers(0, 99))
def test_qos_candidates_kernel_shape_sweep(U, K, seed):
    args = _cand_args(U, K, seed)
    out = qos_candidates_pallas(*args.values(), delta_max=10.0,
                                block_u=128, block_k=128, interpret=True)
    ref = qos_candidates_ref(*args.values(), delta_max=10.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)
    assert out.shape == (U, K)
    # invalid pairs are exactly zero, not garbage from the padded lanes
    assert not np.any(np.asarray(out)[np.asarray(args["cand_valid"]) == 0])


def test_qos_candidates_matches_gathered_dense_matrix():
    """Segmented QoS over gathered pairs == gathering from the full [U, P]
    kernel output (the sparse path never materializes the latter)."""
    from repro.core import synthetic_instance
    from repro.core.candidates import impl_table_np, topk_candidates_jnp
    from repro.kernels.qos_matrix.ops import qos_matrix_from_instance
    inst = synthetic_instance(300, seed=6)
    ji = inst.as_jax()
    table = impl_table_np(inst.sm_service, inst.S)
    for use_kernel in (False, True):
        idx, q = topk_candidates_jnp(ji, np.asarray(table),
                                     use_kernel=use_kernel)
        idx, q = np.asarray(idx), np.asarray(q)
        Q = np.asarray(qos_matrix_from_instance(ji))
        valid = idx >= 0
        gathered = Q[np.arange(inst.U)[:, None], np.clip(idx, 0, None)]
        np.testing.assert_allclose(q[valid], gathered[valid],
                                   atol=1e-6, rtol=1e-6)
        assert not q[~valid].any()


# ===========================================================================
# greedy_argmax (masked per-edge argmax, Alg. 3 line 11)
# ===========================================================================

@settings(deadline=None, max_examples=12)
@given(st.integers(1, 40), st.integers(1, 400), st.integers(0, 99))
def test_greedy_argmax_kernel_shape_sweep(E, P, seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(E, P)), jnp.float32)  # negatives too
    m = jnp.asarray(rng.random((E, P)) < 0.5)
    best_k, idx_k = greedy_argmax_pallas(v, m, block_e=4, interpret=True)
    best_r, idx_r = greedy_argmax_ref(v, m)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_r))
    has = np.asarray(m).any(axis=1)
    np.testing.assert_allclose(np.asarray(best_k)[has],
                               np.asarray(best_r)[has], rtol=1e-6)
    # rows with an empty mask report idx −1 (the caller's "no candidate")
    assert np.all(np.asarray(idx_k)[~has] == -1)


def test_greedy_argmax_ties_and_empty_rows():
    v = jnp.asarray([[1.0, 3.0, 3.0, -2.0],    # tie → first occurrence
                     [-5.0, -1.0, -9.0, -1.0],  # all-negative tie
                     [7.0, 8.0, 9.0, 10.0],     # mask empty → −1
                     [0.0, 0.0, 0.0, 0.0]],     # uniform zeros
                    jnp.float32)
    m = jnp.asarray([[1, 1, 1, 1],
                     [1, 1, 1, 1],
                     [0, 0, 0, 0],
                     [0, 1, 0, 1]], bool)
    for fn in (lambda: greedy_argmax_pallas(v, m, block_e=2, interpret=True),
               lambda: greedy_argmax_ref(v, m)):
        best, idx = fn()
        assert np.asarray(idx).tolist() == [1, 1, -1, 1]
        assert float(best[0]) == 3.0 and float(best[1]) == -1.0
        assert float(best[3]) == 0.0


# ===========================================================================
# flash_attention
# ===========================================================================

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 24, 0.0), (False, 0, 0.0), (True, 0, 50.0),
])
def test_flash_attention_kernel(dtype, causal, window, softcap):
    rng = np.random.default_rng(0)
    B, Sq, Hq, Hkv, hd = 2, 80, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Sq, Hkv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Sq, Hkv, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=32, block_kv=32,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


@settings(deadline=None, max_examples=8)
@given(st.integers(1, 3), st.integers(17, 150), st.sampled_from([1, 2, 4]),
       st.sampled_from([16, 32, 64]), st.integers(0, 99))
def test_flash_attention_property_sweep(B, Sq, G, hd, seed):
    rng = np.random.default_rng(seed)
    Hkv = 2
    Hq = Hkv * G
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sq, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sq, Hkv, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_kv=48,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


# ===========================================================================
# gqa_decode
# ===========================================================================

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,ring", [(0, False), (16, False), (0, True)])
def test_gqa_decode_kernel(dtype, window, ring):
    rng = np.random.default_rng(1)
    B, Hq, Hkv, hd, Sc = 3, 8, 2, 32, 96
    q = jnp.asarray(rng.normal(size=(B, Hq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Sc, Hkv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Sc, Hkv, hd)), dtype)
    kv_len = jnp.asarray([3, 64, 200 if ring else 96])
    out = gqa_decode(q, k, v, kv_len, window=window, ring=ring,
                     block_kv=32, interpret=True)
    ref = gqa_decode_ref(q, k, v, kv_len, window=window, ring=ring)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


@settings(deadline=None, max_examples=8)
@given(st.integers(1, 4), st.integers(2, 130), st.sampled_from([1, 4, 7]),
       st.integers(0, 99))
def test_gqa_decode_property_sweep(B, Sc, G, seed):
    rng = np.random.default_rng(seed)
    Hkv, hd = 2, 16
    Hq = Hkv * G
    q = jnp.asarray(rng.normal(size=(B, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sc, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sc, Hkv, hd)), jnp.float32)
    kv_len = jnp.asarray(rng.integers(1, Sc + 1, B), jnp.int32)
    out = gqa_decode(q, k, v, kv_len, block_kv=32, interpret=True)
    ref = gqa_decode_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


# ===========================================================================
# ssd_scan
# ===========================================================================

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_scan_kernel(dtype, chunk):
    rng = np.random.default_rng(2)
    B, L, H, P, N = 2, 64, 3, 8, 16
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), dtype)
    dtA = -jnp.asarray(rng.uniform(0.01, 0.4, size=(B, L, H)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, L, N)), dtype)
    c = jnp.asarray(rng.normal(size=(B, L, N)), dtype)
    y, st_ = ssd_scan(x, dtA, b, c, chunk=chunk, interpret=True)
    yr, sr = ssd_scan_ref(x, dtA, b, c)
    tol = 3e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(sr), atol=tol, rtol=tol)


@settings(deadline=None, max_examples=6)
@given(st.integers(1, 2), st.sampled_from([16, 48, 80]),
       st.sampled_from([1, 5]), st.integers(0, 99))
def test_ssd_scan_property_sweep(B, L, H, seed):
    rng = np.random.default_rng(seed)
    P, N, chunk = 4, 8, 16
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dtA = -jnp.asarray(rng.uniform(0.01, 0.6, size=(B, L, H)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    y, st_ = ssd_scan(x, dtA, b, c, chunk=chunk, interpret=True)
    yr, sr = ssd_scan_ref(x, dtA, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=3e-4, rtol=3e-4)


def test_ssd_kernel_matches_model_layer():
    """Kernel agrees with the model's ssd_chunked implementation too."""
    from repro.models.layers import ssd_chunked
    rng = np.random.default_rng(5)
    B, L, H, P, N = 1, 32, 2, 4, 8
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dtA = -jnp.asarray(rng.uniform(0.01, 0.4, size=(B, L, H)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    y1, s1 = ssd_scan(x, dtA, b, c, chunk=8, interpret=True)
    y2, s2 = ssd_chunked(x, dtA, b, c, chunk=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4,
                               rtol=2e-4)


# ===========================================================================
# flash_attention backward (custom VJP, Pallas fwd+bwd)
# ===========================================================================

@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
def test_flash_attention_backward_matches_autodiff(causal, window):
    """Pallas dq/dk/dv (FlashAttention-2 backward) vs jax.grad of the
    naive-softmax oracle."""
    from repro.kernels.flash_attention.ops import make_trainable_attention

    rng = np.random.default_rng(0)
    B, Sq, Hq, Hkv, hd = 2, 64, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sq, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sq, Hkv, hd)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(B, Sq, Hq, hd)), jnp.float32)

    attn = make_trainable_attention(causal=causal, window=window,
                                    block_q=16, block_kv=16, interpret=True)
    gk = jax.grad(lambda *a: (attn(*a) * w).sum(), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: (attention_ref(*a, causal=causal,
                                            window=window) * w).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-4, rtol=3e-4)


@settings(deadline=None, max_examples=5)
@given(st.integers(1, 2), st.integers(20, 70), st.sampled_from([1, 2, 4]),
       st.integers(0, 99))
def test_flash_backward_property_sweep(B, Sq, G, seed):
    from repro.kernels.flash_attention.ops import make_trainable_attention

    rng = np.random.default_rng(seed)
    Hkv, hd = 2, 16
    Hq = Hkv * G
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sq, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sq, Hkv, hd)), jnp.float32)
    attn = make_trainable_attention(causal=True, block_q=16, block_kv=32,
                                    interpret=True)
    g = jax.grad(lambda *a: attn(*a).sum(), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: attention_ref(*a, causal=True).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


def test_flash_forward_lse_residual():
    from repro.kernels.flash_attention.flash_attention import flash_attention

    rng = np.random.default_rng(3)
    B, S, Hq, Hkv, hd = 1, 48, 2, 1, 16
    q = jnp.asarray(rng.normal(size=(B, S, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    out, lse = flash_attention(q, k, v, causal=True, block_q=16,
                               block_kv=16, interpret=True, return_lse=True)
    # direct logsumexp of the masked scores
    s = np.einsum("bqhd,bkhd->bhqk", np.asarray(q),
                  np.repeat(np.asarray(k), 2, axis=2)) / np.sqrt(hd)
    mask = np.tril(np.ones((S, S), bool))
    s = np.where(mask[None, None], s, -np.inf)
    ref = np.log(np.exp(s - s.max(-1, keepdims=True)).sum(-1)) \
        + s.max(-1)[...]
    np.testing.assert_allclose(np.asarray(lse), ref, atol=1e-4, rtol=1e-4)
