"""OMS (Algorithm 1 / Theorem 2) tests: per-user argmax is optimal."""
import itertools

import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import (
    eligibility_np,
    oms_np,
    qos_matrix_np,
    schedule_value_np,
    sigma_np,
    synthetic_instance,
)


def _random_placement(inst, rng):
    x = np.zeros((inst.E, inst.P), dtype=bool)
    for e in range(inst.E):
        rem = inst.R[e]
        for p in rng.permutation(inst.P):
            if inst.sm_r[p] <= rem and rng.random() < 0.5:
                x[e, p] = True
                rem -= inst.sm_r[p]
    return x


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 10_000))
def test_oms_beats_every_explicit_schedule(seed):
    """Theorem 2: OMS value ≥ value of any feasible schedule (enumerated)."""
    rng = np.random.default_rng(seed)
    inst = synthetic_instance(6, n_edges=2, n_services=3, max_impls=2, seed=seed)
    Q = qos_matrix_np(inst)
    x = _random_placement(inst, rng)
    y_star, v_star = oms_np(inst, x, Q)

    elig = eligibility_np(inst) & x[inst.u_edge]
    per_user_options = [
        [-1] + list(np.nonzero(elig[u])[0]) for u in range(inst.U)
    ]
    best = max(
        schedule_value_np(inst, np.array(combo), Q)
        for combo in itertools.product(*per_user_options)
    )
    assert v_star >= best - 1e-9
    np.testing.assert_allclose(v_star, best, atol=1e-9)


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 10_000))
def test_oms_value_equals_sigma(seed):
    rng = np.random.default_rng(seed)
    inst = synthetic_instance(40, n_edges=4, n_services=10, seed=seed)
    Q = qos_matrix_np(inst)
    x = _random_placement(inst, rng)
    _, v = oms_np(inst, x, Q)
    np.testing.assert_allclose(v, sigma_np(inst, x, Q), atol=1e-9)


def test_oms_respects_placement_and_service():
    inst = synthetic_instance(50, seed=3)
    Q = qos_matrix_np(inst)
    rng = np.random.default_rng(0)
    x = _random_placement(inst, rng)
    y, _ = oms_np(inst, x, Q)
    for u in range(inst.U):
        if y[u] >= 0:
            # constraint (7c): model placed on covering edge
            assert x[inst.u_edge[u], y[u]]
            # scheduled model implements the requested service
            assert inst.sm_service[y[u]] == inst.u_service[u]


def test_oms_empty_placement_drops_everyone():
    inst = synthetic_instance(20, seed=1)
    x = np.zeros((inst.E, inst.P), dtype=bool)
    y, v = oms_np(inst, x)
    assert v == 0.0 and np.all(y == -1)


def test_oms_jnp_matches_np():
    import jax.numpy as jnp
    from repro.core import oms_jnp, eligibility_jnp, qos_matrix_jnp

    rng = np.random.default_rng(5)
    inst = synthetic_instance(64, n_edges=4, seed=5)
    Q = qos_matrix_np(inst)
    x = _random_placement(inst, rng)
    y_np, v_np = oms_np(inst, x, Q)

    ji = inst.as_jax()
    y_j, qos_j = oms_jnp(qos_matrix_jnp(ji), eligibility_jnp(ji),
                         ji.u_edge, jnp.asarray(x))
    np.testing.assert_allclose(float(qos_j.sum()), v_np, rtol=1e-5)
    # schedules may differ only on exact ties; values per user must match
    per_user_np = np.where(y_np >= 0, Q[np.arange(inst.U), np.maximum(y_np, 0)], 0.0)
    np.testing.assert_allclose(np.asarray(qos_j), per_user_np, atol=1e-5)
