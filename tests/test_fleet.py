"""repro.fleet — lease-queue mechanics (atomic claims, heartbeat, expiry
requeue), worker drain, crash-safe merge with bit-for-bit duplicate
verification, 1-vs-4-worker subprocess parity with a SIGKILLed worker,
and the ``python -m repro.fleet`` / ``--fleet N`` CLIs."""
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.fleet import (FleetMergeConflict, LeaseQueue, Task, merge, plan,
                         reap, run_worker, status, task_spec,
                         worker_store_dir)
from repro.sweeps import SweepSpec, SweepStore, run_sweep

SRC = Path(__file__).resolve().parents[1] / "src"

#: Shrunk scenario (see tests/test_horizon.py) — keeps horizons fast.
SMALL = {"n_user_slots": 32, "n_services": 8, "max_impls": 3, "n_edges": 4}


def _grid(knobs=((0.0, 0.0),)):
    return tuple(
        tuple(sorted({**SMALL, "switching_cost": sc,
                      "stickiness": st}.items()))
        for sc, st in knobs)


def _spec(scenarios=("steady",), seeds=(0, 1), algos=("edf",),
          n_ticks=2, knobs=((0.0, 0.0),)):
    return SweepSpec(kind="serving", scenarios=scenarios, seeds=seeds,
                     n_ticks=n_ticks, algos=algos,
                     override_grid=_grid(knobs))


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _worker_cmd(root, owner, ttl=60.0):
    return [sys.executable, "-m", "repro.fleet", "worker",
            "--root", str(root), "--owner", owner, "--ttl", str(ttl)]


# ===========================================================================
# Queue mechanics
# ===========================================================================

def _task(name="000000_abcd1234", seeds=(0,)):
    return Task(name=name, scenario="steady", overrides=(("a", 1),),
                algo="edf", seeds=tuple(seeds), n_ticks=2,
                keys=(f"k{name}",))


def test_queue_put_claim_complete_roundtrip(tmp_path):
    q = LeaseQueue(tmp_path / "q", owner="w0", ttl=60.0)
    assert q.put(_task()) and not q.put(_task())  # idempotent
    assert q.pending() == ["000000_abcd1234"]
    lease = q.claim()
    assert lease is not None and lease.owner == "w0"
    assert q.pending() == [] and q.leased() == ["000000_abcd1234"]
    # the lease file carries the task doc + owner/expiry block
    doc = json.loads(lease.path.read_text())
    assert doc["lease"]["owner"] == "w0"
    assert Task.from_json(doc) == lease.task
    # no second claimant while leased
    q2 = LeaseQueue(tmp_path / "q", owner="w1", ttl=60.0)
    assert q2.claim() is None
    assert lease.renew()
    assert lease.complete()
    assert q.done() == ["000000_abcd1234"] and q.leased() == []
    st = q.status()
    assert (st["pending"], st["leased"], st["done"]) == (0, 0, 1)


def test_queue_release_returns_task(tmp_path):
    q = LeaseQueue(tmp_path / "q", owner="w0", ttl=60.0)
    q.put(_task())
    lease = q.claim()
    assert lease.release()
    assert q.pending() == ["000000_abcd1234"] and q.leased() == []
    # the requeued doc is clean (no stale lease block)
    doc = json.loads((q.task_dir / "000000_abcd1234.json").read_text())
    assert "lease" not in doc


def test_lease_expiry_reap_and_reclaim(tmp_path):
    q = LeaseQueue(tmp_path / "q", owner="dead-worker", ttl=0.15)
    q.put(_task())
    lease = q.claim()
    assert lease is not None
    # worker "dies": no heartbeat; unexpired lease is not reaped
    assert q.reap(now=lease.expires_at - 0.05) == []
    assert q.status(now=lease.expires_at + 0.05)["expired"] == 1
    assert q.reap(now=lease.expires_at + 0.05) == ["000000_abcd1234"]
    # the task is claimable again by a live worker
    q2 = LeaseQueue(tmp_path / "q", owner="w1", ttl=60.0)
    lease2 = q2.claim()
    assert lease2 is not None and lease2.owner == "w1"
    # the dead worker's stale handle cannot renew, complete, or release
    # the task out from under its new owner
    assert not lease.renew() and lease.lost
    assert not lease.complete() and not lease.release()
    assert lease2.path.exists()
    doc = json.loads(lease2.path.read_text())
    assert doc["lease"]["owner"] == "w1"
    assert lease2.complete()


def test_unreadable_task_is_quarantined_not_parked(tmp_path):
    """An externally corrupted task file must not become an unreapable
    forever-lease: claim quarantines it visibly and moves on."""
    q = LeaseQueue(tmp_path / "q", owner="w0", ttl=60.0)
    # sorts before the healthy task, so claim() visits it first
    (q.task_dir / "000000_aaaaaaaa.json").write_text("{corrupt")
    q.put(_task())
    lease = q.claim()
    assert lease is not None and lease.task.name == "000000_abcd1234"
    st = q.status()
    assert st["leased"] == 1 and st["poisoned"] == \
        ["000000_aaaaaaaa.json.poison"]
    assert q.reap() == []  # the quarantined file is not a lease


def test_heartbeat_keeps_lease_alive(tmp_path):
    q = LeaseQueue(tmp_path / "q", owner="w0", ttl=0.5)
    q.put(_task())
    lease = q.claim()
    for _ in range(3):
        time.sleep(0.1)
        assert lease.renew()
    # a renewed lease is never expired at its original deadline
    assert q.reap() == []
    assert lease.complete()


# ===========================================================================
# Plan / worker / merge — in-process
# ===========================================================================

def test_plan_worker_merge_single_worker_byte_identical(tmp_path):
    spec = _spec(seeds=(0, 1, 2))
    ref = run_sweep(spec, store_dir=tmp_path / "ref")

    root = tmp_path / "fleet"
    pl = plan(spec, root, target_store=tmp_path / "merged")
    assert pl["n_tasks"] == 3 and pl["n_items"] == 6
    summary = run_worker(root, owner="w0")
    assert summary["stop"] == "drained" and summary["n_tasks"] == 3
    mg = merge(root, tmp_path / "merged")
    assert mg["merged_items"] == 6 and mg["missing_items"] == 0

    got = run_sweep(spec, store_dir=tmp_path / "merged")
    assert got.execution["chunks_computed"] == 0  # merge made it complete
    for k in ref.values:
        assert ref.values[k].tobytes() == got.values[k].tobytes()
    # per-item metrics merged intact
    merged = SweepStore(tmp_path / "merged")
    refs = SweepStore(tmp_path / "ref")
    for key in refs.keys():
        assert merged.metrics(key) == refs.metrics(key)
        assert merged.meta(key)["fleet_worker"] == "w0"


def test_plan_skips_completed_seeds_and_rejects_foreign_spec(tmp_path):
    spec = _spec(seeds=(0, 1))
    run_sweep(spec, store_dir=tmp_path / "store")  # everything done
    pl = plan(spec, tmp_path / "fleet", target_store=tmp_path / "store")
    assert pl["n_tasks"] == 0 and pl["skipped_items"] == 4
    # a different spec cannot reuse the fleet root
    with pytest.raises(ValueError, match="one fleet root"):
        plan(_spec(seeds=(0, 1, 2)), tmp_path / "fleet")


def test_replan_after_partial_completion_enqueues_nothing_new(tmp_path):
    """Task names are pure content hashes: re-planning after some tasks
    completed (their seeds gone from the pending set) regenerates the
    SAME names for the survivors — nothing is duplicated, nothing is
    re-executed."""
    spec = _spec(seeds=(0, 1, 2, 3))
    root, store = tmp_path / "fleet", tmp_path / "store"
    plan(spec, root, target_store=store)
    run_worker(root, owner="w0", max_tasks=2)   # partial drain
    merge(root, store)
    q = LeaseQueue(root / "queue")
    names_before = set(q.pending()) | set(q.done())
    pl = plan(spec, root, target_store=store)   # straggler-recovery flow
    assert pl["n_tasks"] == 0                   # nothing new enqueued
    assert pl["skipped_items"] == 4             # 2 completed seeds skipped
    assert set(q.pending()) | set(q.done()) == names_before
    # drain the rest and verify total coverage is exact, not inflated
    run_worker(root, owner="w1")
    assert len(q.done()) == 4
    mg = merge(root, store)
    assert mg["missing_items"] == 0 and mg["target_items"] == 8


def test_read_side_entry_points_reject_missing_queue(tmp_path):
    from repro.fleet.cli import main

    with pytest.raises(ValueError, match="no fleet queue"):
        status(tmp_path / "typo")
    with pytest.raises(ValueError, match="no fleet queue"):
        reap(tmp_path / "typo")
    with pytest.raises(ValueError, match="nothing to merge"):
        merge(tmp_path / "typo", tmp_path / "store")
    # the CLI reports instead of tracebacking — and creates nothing
    assert main(["status", "--root", str(tmp_path / "typo")]) == 1
    assert not (tmp_path / "typo").exists()


def test_run_worker_restores_signal_handlers(tmp_path):
    import signal

    spec = _spec(seeds=(0,))
    root = tmp_path / "fleet"
    plan(spec, root)
    before_term = signal.getsignal(signal.SIGTERM)
    before_int = signal.getsignal(signal.SIGINT)
    run_worker(root, owner="w0")
    assert signal.getsignal(signal.SIGTERM) is before_term
    assert signal.getsignal(signal.SIGINT) is before_int


def test_worker_detects_plan_schema_skew(tmp_path):
    spec = _spec(seeds=(0,))
    root = tmp_path / "fleet"
    plan(spec, root)
    # corrupt a queued task's expected keys (simulates code/version skew)
    q = LeaseQueue(root / "queue")
    name = q.pending()[0]
    doc = json.loads((q.task_dir / f"{name}.json").read_text())
    doc["keys"] = ["not-a-real-item-hash"] * len(doc["keys"])
    (q.task_dir / f"{name}.json").write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="skew"):
        run_worker(root, owner="w0")


def test_merge_verifies_duplicates_bit_for_bit(tmp_path):
    root = tmp_path / "fleet"
    a = SweepStore(worker_store_dir(root, "a"))
    b = SweepStore(worker_store_dir(root, "b"))
    a.add_chunk(["k1", "k2"], np.array([1.5, 2.5]), np.array([0.1, 0.2]),
                metrics={"served": [3.0, 4.0]})
    # duplicate with identical values/metrics but different wall times: OK
    b.add_chunk(["k2"], np.array([2.5]), np.array([9.9]),
                metrics={"served": [4.0]})
    out = merge(root, tmp_path / "merged")
    assert out["merged_items"] == 2 and out["duplicate_items"] == 1
    # conflicting value for an existing item hash: refused loudly
    c = SweepStore(worker_store_dir(root, "c"))
    c.add_chunk(["k1"], np.array([1.5000001]), np.array([0.1]))
    with pytest.raises(FleetMergeConflict, match="bit-for-bit"):
        merge(root, tmp_path / "merged")
    # conflicting metric bytes are also refused
    d = SweepStore(worker_store_dir(tmp_path / "fleet2", "d"))
    d.add_chunk(["k9"], np.array([1.0]), np.array([0.1]),
                metrics={"served": [3.0]})
    e = SweepStore(worker_store_dir(tmp_path / "fleet2", "e"))
    e.add_chunk(["k9"], np.array([1.0]), np.array([0.1]),
                metrics={"served": [4.0]})
    with pytest.raises(FleetMergeConflict, match="metric"):
        merge(tmp_path / "fleet2", tmp_path / "merged2")


def test_task_spec_expands_to_exact_parent_keys(tmp_path):
    spec = _spec(scenarios=("steady", "flash_crowd"),
                 algos=("edf", "fcfs"), seeds=(0, 1, 2))
    root = tmp_path / "fleet"
    plan(spec, root, seeds_per_task=2)
    q = LeaseQueue(root / "queue")
    all_keys = set()
    for name in q.pending():
        task = q.read_task(name)
        sub = task_spec(spec, task)
        assert {it.key() for it in sub.expand()} == set(task.keys)
        all_keys |= set(task.keys)
    assert all_keys == {it.key() for it in spec.expand()}


# ===========================================================================
# The acceptance run: 4 subprocess workers, one SIGKILLed mid-run
# ===========================================================================

def _wait_for_lease(root, timeout=120.0):
    q = LeaseQueue(Path(root) / "queue")
    deadline = time.time() + timeout
    while time.time() < deadline:
        leased = q.leased()
        if leased:
            return leased
        time.sleep(0.05)
    raise AssertionError("no worker claimed a task in time")


def test_fleet_4_workers_one_killed_matches_single_process(
        tmp_path, monkeypatch):
    """The PR invariant: a 4-worker fleet run of a (2 scenario × 2 policy
    × 4 seed) serving grid — one worker SIGKILLed mid-run, its lease
    reaped — merges into a store whose aggregate is byte-identical to the
    single-process run, and pareto on that store does zero replays."""
    spec = _spec(scenarios=("steady", "flash_crowd"),
                 algos=("edf", "fcfs"), seeds=(0, 1, 2, 3))
    ref = run_sweep(spec, store_dir=tmp_path / "ref")

    root = tmp_path / "fleet"
    ttl = 2.0
    pl = plan(spec, root)
    assert pl["n_tasks"] == 16 and pl["n_items"] == 32

    procs = [subprocess.Popen(_worker_cmd(root, f"local-{i}", ttl=ttl),
                              env=_env(), stdout=subprocess.DEVNULL,
                              stderr=subprocess.DEVNULL)
             for i in range(4)]
    try:
        # SIGKILL whichever worker holds the first observed lease —
        # no drain, no release: the crash path the queue exists for
        leased = _wait_for_lease(root)
        q = LeaseQueue(root / "queue")
        doc = json.loads((q.lease_dir / f"{leased[0]}.json").read_text())
        victim = None
        owner = doc.get("lease", {}).get("owner", "")
        for i in range(4):
            if owner == f"local-{i}":
                victim = procs[i]
                break
        if victim is None:
            victim = procs[0]
        victim.kill()
        victim.wait()
        for p in procs:
            if p is not victim:
                assert p.wait(timeout=300) == 0
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.wait()

    # the killed worker's lease expires; reap requeues, a mop-up worker
    # (any worker — here in-process) finishes the chunk
    deadline = time.time() + 4 * ttl
    while time.time() < deadline and LeaseQueue(root / "queue").leased():
        time.sleep(0.1)
        reap(root)
    run_worker(root, owner="mopup")
    st = status(root)
    assert st["queue"]["pending"] == 0 and st["queue"]["leased"] == 0
    assert st["queue"]["done"] == 16

    mg = merge(root, tmp_path / "merged")
    assert mg["missing_items"] == 0
    # duplicates (if the victim had already appended its chunk) were
    # verified bit-for-bit rather than dropped blindly
    assert mg["target_items"] == 32

    got = run_sweep(spec, store_dir=tmp_path / "merged")
    assert got.execution["chunks_computed"] == 0
    for k in ref.values:
        assert ref.values[k].tobytes() == got.values[k].tobytes()

    # schema-v3 store: frontier extraction is a pure store read
    import repro.tuning.pareto as pareto_mod

    def boom(*a, **kw):
        raise AssertionError("pareto replayed a horizon on a v3 store")
    monkeypatch.setattr(pareto_mod, "_replay_metrics", boom)
    frontiers = pareto_mod.frontier_points(tmp_path / "merged")
    assert set(frontiers) == {"steady", "flash_crowd"}
    assert all(len(pts) == 2 for pts in frontiers.values())  # 2 policies


def test_worker_sigterm_is_a_clean_drain(tmp_path):
    """SIGTERM finishes the current task (results + completion land),
    then exits 0 — never an orphaned lease."""
    spec = _spec(seeds=(0, 1, 2, 3))
    root = tmp_path / "fleet"
    plan(spec, root)
    proc = subprocess.Popen(_worker_cmd(root, "term-w"), env=_env(),
                            stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL)
    try:
        _wait_for_lease(root)
        proc.terminate()                      # SIGTERM mid-run
        assert proc.wait(timeout=120) == 0    # clean drain exit
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()
    q = LeaseQueue(root / "queue")
    assert q.leased() == []                   # no lease left behind
    assert len(q.done()) >= 1                 # the in-flight task completed
    # everything marked done really is in the worker's store
    store = SweepStore(worker_store_dir(root, "term-w"))
    for name in q.done():
        task = q.read_task(name)
        assert all(k in store for k in task.keys)


def test_worker_wait_survives_empty_queue_and_drains_on_sigterm(tmp_path):
    """--wait long-polling (elastic fleets): a worker on an empty queue
    stays alive across plan waves instead of exiting "drained", picks up
    newly enqueued tasks, and still honors SIGTERM as a clean drain."""
    spec = _spec(seeds=(0, 1))
    root = tmp_path / "fleet"
    plan(spec, root)
    q = LeaseQueue(root / "queue")
    tasks_dir = root / "queue" / "tasks"
    stash = tmp_path / "stash"
    stash.mkdir()
    # empty the queue before the worker starts: wave 2 hasn't landed yet
    staged = list(tasks_dir.iterdir())
    assert len(staged) == 2
    for p in staged:
        p.rename(stash / p.name)
    proc = subprocess.Popen(
        _worker_cmd(root, "wait-w") + ["--wait", "--poll-interval", "0.1"],
        env=_env(), stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
        text=True)
    try:
        # several poll periods on an empty queue: a non---wait worker
        # would have exited "drained" long before this
        time.sleep(0.6)
        assert proc.poll() is None
        # the next plan wave arrives (same content the planner would
        # regenerate — task names are pure content hashes)
        for p in list(stash.iterdir()):
            p.rename(tasks_dir / p.name)
        deadline = time.time() + 120
        while len(q.done()) < 2 and time.time() < deadline:
            time.sleep(0.05)
        assert len(q.done()) == 2
        assert proc.poll() is None            # still waiting for wave 3
        proc.terminate()                      # SIGTERM = clean drain
        assert proc.wait(timeout=30) == 0
        out = proc.stdout.read()
        assert "stop=SIGTERM" in out
        assert "2 task(s)" in out
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()
    assert q.leased() == []                   # nothing orphaned
    store = SweepStore(worker_store_dir(root, "wait-w"))
    for name in q.done():
        assert all(k in store for k in q.read_task(name).keys)


# ===========================================================================
# CLI
# ===========================================================================

def test_fleet_cli_plan_worker_status_merge(tmp_path, capsys):
    from repro.fleet.cli import main

    root, store = tmp_path / "fleet", tmp_path / "store"
    spec_args = ["--kind", "serving", "--scenario", "steady",
                 "--seeds", "0:2", "--ticks", "2", "--algos", "edf"]
    for k, v in {**SMALL, "switching_cost": 0, "stickiness": 0}.items():
        spec_args += ["--override", f"{k}={v}"]
    assert main(["plan", *spec_args, "--root", str(root),
                 "--store", str(store)]) == 0
    assert "planned 2 task(s)" in capsys.readouterr().out

    assert main(["status", "--root", str(root)]) == 0
    assert "2 pending" in capsys.readouterr().out

    assert main(["worker", "--root", str(root), "--owner", "cli-w",
                 "--max-tasks", "1"]) == 0
    assert "1 task(s)" in capsys.readouterr().out
    # merge before the queue drains: partial but honest (exit code 2)
    assert main(["merge", "--root", str(root), "--store", str(store)]) == 2
    assert "still missing" in capsys.readouterr().out

    assert main(["worker", "--root", str(root), "--owner", "cli-w"]) == 0
    capsys.readouterr()
    assert main(["reap", "--root", str(root)]) == 0
    assert main(["merge", "--root", str(root), "--store", str(store)]) == 0
    capsys.readouterr()
    assert len(SweepStore(store)) == 4

    # the merged store resumes as complete under the sweeps CLI
    from repro.sweeps.cli import main as sweeps_main
    rc = sweeps_main(["--kind", "serving", "--scenario", "steady",
                      "--seeds", "0:2", "--ticks", "2", "--algos", "edf",
                      *[a for a in spec_args if "=" in a or
                        a == "--override"],
                      "--out", str(store), "-q"])
    assert rc == 0
    capsys.readouterr()


def test_sweeps_cli_fleet_flag_end_to_end(tmp_path, capsys):
    from repro.sweeps.cli import main as sweeps_main

    args = ["--kind", "serving", "--scenario", "steady", "--seeds", "0:2",
            "--ticks", "2", "--algos", "edf"]
    for k, v in {**SMALL, "switching_cost": 0, "stickiness": 0}.items():
        args += ["--override", f"{k}={v}"]

    ref_store = tmp_path / "ref"
    assert sweeps_main([*args, "--out", str(ref_store), "-q"]) == 0
    fleet_store = tmp_path / "fleet_store"
    assert sweeps_main([*args, "--out", str(fleet_store),
                        "--fleet", "2"]) == 0
    out = capsys.readouterr().out
    assert "merged" in out

    ref, got = SweepStore(ref_store), SweepStore(fleet_store)
    assert set(ref.keys()) == set(got.keys())
    for key in ref.keys():
        a = np.float64(ref.value(key))
        assert a.tobytes() == np.float64(got.value(key)).tobytes()
    # --fleet with --no-store is a usage error
    with pytest.raises(SystemExit):
        sweeps_main([*args, "--no-store", "--fleet", "2"])
    capsys.readouterr()


def test_sweeps_cli_fleet_resumes_extended_seed_range(tmp_path, capsys):
    """Extending --seeds on the same store is the documented resume
    pattern; the fleet path must plan a fresh queue for the extended
    spec (fingerprint-keyed root) and skip already-complete seeds, not
    crash on the old queue's spec fingerprint."""
    from repro.sweeps.cli import main as sweeps_main

    def args(seeds):
        out = ["--kind", "serving", "--scenario", "steady", "--seeds",
               seeds, "--ticks", "2", "--algos", "edf",
               "--out", str(tmp_path / "store"), "-q"]
        for k, v in {**SMALL, "switching_cost": 0, "stickiness": 0}.items():
            out += ["--override", f"{k}={v}"]
        return out

    assert sweeps_main([*args("0:2"), "--fleet", "1"]) == 0
    assert len(SweepStore(tmp_path / "store")) == 4
    assert sweeps_main([*args("0:3"), "--fleet", "1"]) == 0  # extended
    assert len(SweepStore(tmp_path / "store")) == 6
    # complete merges prune their fingerprint-keyed fleet roots — no
    # duplicate result shards accumulate under the store
    fleet_dir = tmp_path / "store" / "fleet"
    assert not fleet_dir.exists() or not list(fleet_dir.iterdir())
    capsys.readouterr()
