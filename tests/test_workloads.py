"""repro.workloads — determinism, conservation, batched-vs-host equality,
bucketed batching, and the dynamic-policy payoff on bursty traffic."""
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import qos_matrix_np, sigma_np, egp_np, synthetic_instance
from repro.core.dynamic import evaluate_horizon
from repro.workloads import (
    BucketedBatch,
    ChurnModel,
    DiurnalArrivals,
    MarkovMobility,
    MMPPArrivals,
    PoissonArrivals,
    TraceArrivals,
    ZipfPopularity,
    bucket_envelope,
    bucket_instances,
    evaluate_batch,
    evaluate_host,
    get_scenario,
    hash_uniform,
    horizon,
    list_scenarios,
    pad_instances,
    sweep,
)

ALL_SCENARIOS = list_scenarios()


# ===========================================================================
# (seed, tick) determinism / seekability
# ===========================================================================

def test_registry_has_the_registered_scenarios():
    assert set(ALL_SCENARIOS) == {"steady", "diurnal", "flash_crowd",
                                  "mobility_churn", "edge_failure",
                                  "trace_replay", "trace_replay_bursty",
                                  "trace_replay_azure"}


def test_trace_arrivals_from_file(tmp_path):
    p = tmp_path / "trace.csv"
    p.write_text("# a comment\n5, 9 2\n\n7.8  # trailing comment\n")
    tr = TraceArrivals.from_file(p)
    assert tr.counts == (5, 9, 2, 7)
    assert [tr.count_at(3, t) for t in range(5)] == [5, 9, 2, 7, 5]
    import pytest as _pytest
    empty = tmp_path / "empty.csv"
    empty.write_text("# nothing\n")
    with _pytest.raises(ValueError):
        TraceArrivals.from_file(empty)


def test_trace_replay_scenario_follows_bundled_trace():
    sc = get_scenario("trace_replay")
    assert isinstance(sc.arrivals, TraceArrivals)
    assert sc.n_ticks == 24
    counts = [sc.active_users_at(0, t) for t in range(24)]
    assert counts == list(sc.arrivals.counts)[:24]  # exact replay
    assert max(counts) >= 2 * min(counts)  # a real day shape, not flat


def test_trace_replay_bursty_scenario_is_bursty():
    sc = get_scenario("trace_replay_bursty")
    assert isinstance(sc.arrivals, TraceArrivals)
    assert sc.n_ticks == 48 and len(sc.arrivals.counts) == 48
    counts = np.array([sc.active_users_at(7, t) for t in range(48)])
    assert counts.tolist() == list(sc.arrivals.counts)  # exact replay
    # bursty: a flash event jumps ≥ 30 requests hour-over-hour — sharper
    # than any transition in the smooth day trace
    assert int(np.abs(np.diff(counts)).max()) >= 30
    day = np.array(get_scenario("trace_replay").arrivals.counts)
    assert np.abs(np.diff(counts)).max() > np.abs(np.diff(day)).max()


def test_trace_arrivals_from_azure_csv(tmp_path):
    p = tmp_path / "azure.csv"
    # header + 10-minute aggregates; comment and malformed rows skipped
    p.write_text("interval_start_minute,total_invocations\n"
                 "# platform-scale counts\n"
                 "0,600000\n10,300000\n50,300000\n"
                 "60,1200000\n70,1200000\n"
                 "120,2400000\n")
    tr = TraceArrivals.from_azure_csv(p, minutes_per_tick=60)
    # time normalization: minutes bucket into hourly ticks
    assert tr.counts == (1_200_000, 2_400_000, 2_400_000)
    # scale normalization: mean per-tick count rescaled, shape preserved
    norm = TraceArrivals.from_azure_csv(p, minutes_per_tick=60,
                                        target_mean=40.0)
    assert norm.counts == (24, 48, 48)
    assert np.mean(norm.counts) == 40.0
    import pytest as _pytest
    empty = tmp_path / "empty.csv"
    empty.write_text("interval_start_minute,total_invocations\n")
    with _pytest.raises(ValueError):
        TraceArrivals.from_azure_csv(empty)
    # a clock-skewed negative interval must raise, not silently fold
    # into the last tick through negative indexing
    skewed = tmp_path / "skewed.csv"
    skewed.write_text("minute,count\n-10,50000\n0,100\n")
    with _pytest.raises(ValueError, match="negative interval"):
        TraceArrivals.from_azure_csv(skewed)


def test_trace_replay_azure_scenario_replays_external_trace():
    from repro.workloads.scenarios import _FALLBACK_AZURE_TRACE
    sc = get_scenario("trace_replay_azure")
    assert isinstance(sc.arrivals, TraceArrivals)
    assert sc.n_ticks == 48 and len(sc.arrivals.counts) == 48
    counts = [sc.active_users_at(3, t) for t in range(48)]
    assert counts == list(sc.arrivals.counts)  # exact replay, no clipping
    # the normalized trace fits the slot pool (no truncation at the peak)
    assert max(counts) <= sc.n_user_slots
    # the bundled file and the built-in fallback agree exactly, so a
    # partial checkout degrades to identical traffic
    assert tuple(sc.arrivals.counts) == _FALLBACK_AZURE_TRACE
    # day-2 evening flash event: sharper jump than the smooth day trace
    assert int(np.abs(np.diff(counts)).max()) >= 20


@pytest.mark.parametrize("name", ALL_SCENARIOS)
def test_instance_at_is_deterministic_and_seekable(name):
    scenario = get_scenario(name)
    seq = scenario.horizon(seed=5, n_ticks=4)
    for tick in (0, 2, 3):
        direct = scenario.instance_at(5, tick)  # seek, no replay of horizon
        ref = seq[tick]
        np.testing.assert_array_equal(direct.u_edge, ref.u_edge)
        np.testing.assert_array_equal(direct.u_service, ref.u_service)
        np.testing.assert_allclose(direct.u_alpha, ref.u_alpha)
        np.testing.assert_allclose(direct.u_delta, ref.u_delta)
        np.testing.assert_allclose(direct.R, ref.R)
        np.testing.assert_allclose(direct.sm_acc, ref.sm_acc)


def test_arrival_processes_are_seekable_and_distinct_across_seeds():
    for proc in (PoissonArrivals(48.0),
                 MMPPArrivals(30.0, 90.0, p_burst=0.5, block=2),
                 DiurnalArrivals(48.0, amplitude=0.5, period=6),
                 TraceArrivals((8, 16, 32))):
        a = [proc.count_at(0, t) for t in range(12)]
        b = [proc.count_at(0, t) for t in range(12)]
        assert a == b, type(proc).__name__
        times = proc.times_in_tick(0, 3, tick_duration=2.0)
        assert np.all(times >= 6.0) and np.all(times < 8.0)
        assert np.all(np.diff(times) >= 0)
    # different seeds give different traffic (Poisson case)
    pa = PoissonArrivals(48.0)
    assert [pa.count_at(0, t) for t in range(8)] != \
        [pa.count_at(1, t) for t in range(8)]


def test_trace_arrivals_replay_exactly():
    tr = TraceArrivals((5, 9, 2))
    assert [tr.count_at(7, t) for t in range(6)] == [5, 9, 2, 5, 9, 2]


def test_hash_uniform_is_pure_and_in_unit_interval():
    u1 = hash_uniform(3, 11, np.arange(1000))
    u2 = hash_uniform(3, 11, np.arange(1000))
    np.testing.assert_array_equal(u1, u2)
    assert np.all((u1 >= 0.0) & (u1 < 1.0))
    assert 0.4 < u1.mean() < 0.6  # roughly uniform
    assert not np.array_equal(u1, hash_uniform(4, 11, np.arange(1000)))


def test_churn_turns_over_population_at_lifetime_rate():
    churn = ChurnModel(lifetime=8)
    pop = ZipfPopularity(16, exponent=1.0)
    s0, a0, _ = churn.attributes_at(0, 0, 512, pop)
    s1, a1, _ = churn.attributes_at(0, 1, 512, pop)
    frac_changed = float(np.mean(a0 != a1))
    assert 0.02 < frac_changed < 0.35  # ≈ 1/lifetime, de-phased
    # within a generation attributes persist: tick 0 vs tick 0
    s0b, a0b, _ = churn.attributes_at(0, 0, 512, pop)
    np.testing.assert_array_equal(s0, s0b)
    np.testing.assert_array_equal(a0, a0b)


def test_zipf_hot_spot_drifts():
    pop = ZipfPopularity(10, exponent=1.2, drift_period=2, drift_step=3)
    w0, w2 = pop.weights_at(0), pop.weights_at(2)
    assert np.argmax(w0) == 0 and np.argmax(w2) == 3
    np.testing.assert_allclose(w0.sum(), 1.0)
    np.testing.assert_allclose(np.sort(w0), np.sort(w2))  # a pure rotation


# ===========================================================================
# Mobility conservation
# ===========================================================================

def test_mobility_conserves_user_population():
    mob = MarkovMobility(n_edges=7, p_move=0.4)
    traj = mob.trajectory(seed=1, n_ticks=20, n_slots=300)
    assert traj.shape == (20, 300)
    assert traj.min() >= 0 and traj.max() < 7
    for t in range(20):
        counts = np.bincount(traj[t], minlength=7)
        assert counts.sum() == 300  # migrations never create/destroy users
    # the walk actually moves people
    assert (traj[0] != traj[-1]).mean() > 0.2
    # moves are ring-adjacent
    step = np.abs(traj[1:] - traj[:-1])
    step = np.minimum(step, 7 - step)
    assert step.max() <= 1


def test_mobility_edges_at_matches_trajectory():
    mob = MarkovMobility(n_edges=5, p_move=0.25)
    traj = mob.trajectory(seed=9, n_ticks=6, n_slots=64)
    for t in (0, 3, 5):
        np.testing.assert_array_equal(mob.edges_at(9, t, 64), traj[t])


def test_edge_failure_rehomes_users_off_dead_edges():
    scenario = get_scenario("edge_failure")
    before = scenario.instance_at(0, 0)
    after = scenario.instance_at(0, 6)  # both failures active
    dead = scenario.dead_edges_at(6)
    assert dead == [1, 4]
    assert before.U > 0 and after.U > 0
    assert not np.any(np.isin(after.u_edge, dead))
    np.testing.assert_allclose(after.R[dead], 0.0)
    # survivors unaffected
    alive = [e for e in range(scenario.n_edges) if e not in dead]
    np.testing.assert_allclose(after.R[alive], before.R[alive])


# ===========================================================================
# Padded batched evaluation == per-instance host path
# ===========================================================================

@pytest.mark.parametrize("algo", ["egp", "agp"])
def test_batched_evaluator_matches_host(algo):
    instances = []
    for name in ALL_SCENARIOS:
        instances += horizon(name, seed=0, n_ticks=2)
        instances += horizon(name, seed=1, n_ticks=2)
    assert len(instances) >= 16
    batch = pad_instances(instances)
    values, x = evaluate_batch(batch, algo=algo)
    host = evaluate_host(instances, algo=algo)
    np.testing.assert_allclose(np.asarray(values, np.float64), host,
                               atol=1e-4)
    # placements never use padded models/edges and respect storage
    x = np.asarray(x)
    for b, inst in enumerate(instances):
        U, P, E = batch.dims[b]
        assert not x[b, :, P:].any(), "padded model placed"
        assert not x[b, E:, :].any(), "padded edge used"
        used = (x[b, :E, :P] * inst.sm_r[None, :]).sum(axis=1)
        assert np.all(used <= inst.R + 1e-5)


def test_batched_sigma_matches_host_sigma_of_same_placement():
    """σ agreement is not a fluke of equal-value different placements:
    recomputing host σ on the *batched* placements matches too."""
    instances = horizon("steady", seed=3, n_ticks=3)
    batch = pad_instances(instances)
    values, x = evaluate_batch(batch, algo="egp")
    for b, inst in enumerate(instances):
        U, P, E = batch.dims[b]
        v_host = sigma_np(inst, np.asarray(x)[b, :E, :P])
        np.testing.assert_allclose(float(values[b]), v_host, atol=1e-4)


def test_sweep_runs_all_scenarios_in_one_call():
    res = sweep(ALL_SCENARIOS, seeds=(0,), n_ticks=2)
    assert set(res["values"]) == set(ALL_SCENARIOS)
    for name in ALL_SCENARIOS:
        assert res["values"][name].shape == (1, 2)
        assert np.all(res["values"][name] > 0)
    assert len(res["labels"]) == len(res["instances"]) == 2 * len(ALL_SCENARIOS)


# ===========================================================================
# Bucketed batching == global pad == host
# ===========================================================================

def _mixed_instances(sizes_seeds):
    return [synthetic_instance(n_users=u, n_edges=max(2, u // 40), seed=s)
            for u, s in sizes_seeds]


def _check_bucketed_matches_global_and_host(instances, algo="egp"):
    bb = bucket_instances(instances)
    v_b, x_b = evaluate_batch(bb, algo=algo)
    v_g, _ = evaluate_batch(pad_instances(instances), algo=algo)
    host = evaluate_host(instances, algo=algo)
    np.testing.assert_allclose(np.asarray(v_b), np.asarray(v_g, np.float64),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(v_b), host, atol=1e-4)
    # per-instance placements are at the bucket envelope, pads untouched
    for inst, x in zip(instances, x_b):
        env = bucket_envelope(inst.U, inst.P, inst.E)
        x = np.asarray(x)
        assert x.shape == (env[2], env[1])
        assert not x[:, inst.P:].any() and not x[inst.E:, :].any()
    return bb


def test_bucketed_evaluator_matches_global_pad_and_host():
    insts = _mixed_instances([(20, 0), (160, 1), (40, 2), (20, 3), (90, 4)])
    bb = _check_bucketed_matches_global_and_host(insts)
    assert bb.B == 5 and len(bb.buckets) >= 2  # sizes actually spread
    assert 0.0 <= bb.pad_waste < 1.0


def test_bucketed_single_instance_and_identical_sizes():
    one = _mixed_instances([(30, 7)])
    bb = _check_bucketed_matches_global_and_host(one)
    assert len(bb.buckets) == 1 and bb.pad_waste >= 0.0
    # identical dims (same seed → same catalog) collapse to one bucket
    same = [synthetic_instance(n_users=30, n_edges=3, seed=9)
            for _ in range(4)]
    bb = _check_bucketed_matches_global_and_host(same)
    assert len(bb.buckets) == 1
    assert all(len(i) == 4 for i in bb.index)


def test_bucketed_agp_path_matches_host_too():
    insts = _mixed_instances([(24, 0), (100, 1)])
    _check_bucketed_matches_global_and_host(insts, algo="agp")


def test_bucket_envelope_is_chunk_independent():
    """An instance's envelope depends only on its own dims — evaluating it
    in any batch composition gives bit-identical values (the property the
    sweep store's resume/fleet merge relies on)."""
    insts = _mixed_instances([(20, 0), (160, 1), (40, 2), (20, 3), (90, 4)])
    v_all, _ = evaluate_batch(bucket_instances(insts))
    for lo, hi in ((0, 2), (2, 5), (1, 4)):
        v_part, _ = evaluate_batch(bucket_instances(insts[lo:hi]))
        np.testing.assert_array_equal(np.asarray(v_part),
                                      np.asarray(v_all)[lo:hi])


@settings(deadline=None, max_examples=6)
@given(st.lists(st.tuples(st.integers(8, 200), st.integers(0, 50)),
                min_size=1, max_size=6), st.integers(0, 1))
def test_bucketed_property_random_mixes(sizes_seeds, algo_i):
    _check_bucketed_matches_global_and_host(
        _mixed_instances(sizes_seeds), algo=("egp", "agp")[algo_i])


# ===========================================================================
# Dynamic placement on bursty traffic
# ===========================================================================

def test_dynamic_placer_beats_per_tick_greedy_on_flash_crowd():
    res = evaluate_horizon("flash_crowd", switching_cost=3.0,
                           stickiness=3.0, seed=0, n_ticks=6)
    assert res["hysteresis"] > res["greedy"]


def test_evaluate_horizon_accepts_scenario_names_and_instances():
    insts = horizon("steady", seed=0, n_ticks=3)
    by_name = evaluate_horizon("steady", seed=0, n_ticks=3)
    by_list = evaluate_horizon(insts)
    assert by_name == by_list


def test_scheduler_accepts_arrival_process():
    from repro.core import oms_np
    from repro.serving.scheduler import simulate

    inst = horizon("steady", seed=0, n_ticks=1)[0]
    Q = qos_matrix_np(inst)
    y, _ = oms_np(inst, egp_np(inst, Q), Q)
    bursty = MMPPArrivals(10.0, 60.0, p_burst=0.5, block=2)
    r1 = simulate(inst, y, inst.sm_w, arrivals=bursty, seed=0)
    r2 = simulate(inst, y, inst.sm_w, arrivals=bursty, seed=0)
    assert r1 == r2  # deterministic under a seekable process
    smooth = simulate(inst, y, inst.sm_w, arrivals=PoissonArrivals(40.0),
                      seed=0)
    assert r1["served"] == smooth["served"] == int((y >= 0).sum())
