"""repro.obs — ring-buffer tracer mechanics, golden Chrome-trace export,
histogram quantile accuracy vs NumPy, the observational-only invariant
(byte-identical serving stores and TickReports with tracing on), the
disabled-path overhead guard, fleet telemetry, and the CLI."""
import dataclasses
import json
import math
import time
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.obs import trace as obs_trace
from repro.obs.cli import main as obs_main
from repro.obs.metrics import Histogram, MetricsRegistry

#: Shrunk scenario (see tests/test_horizon.py) — keeps horizons fast.
SMALL = {"n_user_slots": 32, "n_services": 8, "max_impls": 3, "n_edges": 4}


@pytest.fixture(autouse=True)
def _obs_off():
    """Tracing must be off by default and never leak between tests."""
    assert not obs.enabled()
    yield
    obs.disable()


def _fake_clock(step_ns=1000, start=1000):
    state = {"t": start - step_ns}

    def clock():
        state["t"] += step_ns
        return state["t"]

    return clock


# ===========================================================================
# Tracer core
# ===========================================================================

def test_span_records_into_ring():
    tr = obs.Tracer(capacity=16, clock=_fake_clock())
    with tr.span("outer", {"k": 1}):
        with tr.span("inner"):
            pass
    assert tr.n_spans == 2 and tr.dropped_spans == 0
    doc = tr.snapshot()
    # inner exits first, so row 0 is inner, row 1 is outer
    assert [doc["names"][i] for i in doc["spans"]["name"]] == \
        ["inner", "outer"]
    assert doc["spans"]["depth"] == [1, 0]
    assert doc["span_args"] == {"1": {"k": 1}}
    assert doc["obs_schema"] == obs.OBS_SCHEMA_VERSION


def test_ring_wrap_drops_oldest_and_counts():
    tr = obs.Tracer(capacity=4, clock=_fake_clock())
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert tr.n_spans == 10 and tr.dropped_spans == 6
    doc = tr.snapshot()
    # oldest → newest of the surviving window
    assert [doc["names"][i] for i in doc["spans"]["name"]] == \
        ["s6", "s7", "s8", "s9"]
    assert doc["dropped_spans"] == 6


def test_span_durations_and_counters_and_gauges():
    tr = obs.Tracer(capacity=8, clock=_fake_clock(step_ns=1000))
    with tr.span("work"):
        pass                        # t0=1000 t1=2000 → 1µs
    tr.count("items", 3)
    tr.count("items", 2)
    tr.sample("queue_depth", 7.5)   # t=3000
    np.testing.assert_allclose(tr.span_durations_s("work"), [1e-6])
    assert tr.span_durations_s("missing").size == 0
    assert tr.counters == {"items": 5}
    doc = tr.snapshot()
    assert doc["gauges"]["value"] == [7.5]


def test_module_level_fast_path_and_enable_disable():
    assert obs.get_tracer() is None
    # disabled: the module-level helpers are no-ops returning the shared
    # null span
    s1, s2 = obs.span("a"), obs.span("b", k=1)
    assert s1 is s2
    obs.count("n")                      # no-op, no error
    obs.sample("g", 1.0)
    assert obs.save("/nonexistent/x.json") is False
    tr = obs.enable(capacity=8)
    assert obs.enabled() and obs.get_tracer() is tr
    with obs.span("a", k=2):
        pass
    obs.count("n", 2)
    assert tr.n_spans == 1 and tr.counters == {"n": 2}
    assert obs.disable() is tr
    assert not obs.enabled()


def test_enable_from_env(monkeypatch, tmp_path):
    monkeypatch.delenv("REPRO_OBS", raising=False)
    assert obs.enable_from_env() is None and not obs.enabled()
    monkeypatch.setenv("REPRO_OBS", "0")
    assert obs.enable_from_env() is None and not obs.enabled()
    monkeypatch.setenv("REPRO_OBS", "1")
    tr = obs.enable_from_env()
    assert tr is not None and obs.get_tracer() is tr


def test_save_and_load_artifact_roundtrip(tmp_path):
    tr = obs.enable(capacity=8, clock=_fake_clock())
    with obs.span("a"):
        pass
    path = tmp_path / "trace.json"
    assert obs.save(path) is True
    doc = obs.load_artifact(path)
    assert doc["names"] == ["a"] and doc["obs_schema"] == \
        obs.OBS_SCHEMA_VERSION
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"obs_schema": 999}))
    with pytest.raises(ValueError, match="schema v999"):
        obs.load_artifact(bad)


# ===========================================================================
# Chrome-trace export (golden, via the injectable clock)
# ===========================================================================

def test_chrome_trace_golden():
    tr = obs.Tracer(capacity=8, clock=_fake_clock(step_ns=1000))
    with tr.span("tick.place", {"tick": 0}):     # t0=1000
        with tr.span("kernel.qos_matrix"):       # t0=2000 t1=3000
            pass
    #                                              t1=4000
    tr.sample("serving.queue_depth", 3.0)        # t=5000
    doc = tr.snapshot()
    doc["pid"] = 7  # pin the one environment-dependent field
    assert obs.to_chrome_trace(doc) == {
        "displayTimeUnit": "ms",
        "otherData": {"obs_schema": 2, "dropped_spans": 0, "counters": {}},
        "traceEvents": [
            {"ph": "M", "pid": 7, "tid": 0, "name": "process_name",
             "args": {"name": "repro.obs"}},
            {"ph": "X", "name": "kernel.qos_matrix", "cat": "kernel",
             "pid": 7, "tid": 0, "ts": 1.0, "dur": 1.0},
            {"ph": "X", "name": "tick.place", "cat": "tick", "pid": 7,
             "tid": 0, "ts": 0.0, "dur": 3.0, "args": {"tick": 0}},
            {"ph": "C", "name": "serving.queue_depth", "cat": "serving",
             "pid": 7, "tid": 0, "ts": 4.0, "args": {"value": 3.0}},
        ],
    }


def test_validate_chrome_trace():
    tr = obs.Tracer(capacity=8, clock=_fake_clock())
    with tr.span("a"):
        pass
    assert obs.validate_chrome_trace(tr.chrome_trace()) == 1
    with pytest.raises(ValueError, match="no traceEvents"):
        obs.validate_chrome_trace({"traceEvents": []})
    with pytest.raises(ValueError, match="malformed"):
        obs.validate_chrome_trace({"traceEvents": [{"name": "x"}]})
    with pytest.raises(ValueError, match="missing 'dur'"):
        obs.validate_chrome_trace(
            {"traceEvents": [{"ph": "X", "name": "x", "ts": 0,
                              "pid": 0, "tid": 0}]})


# ===========================================================================
# Metrics: histograms vs NumPy, registry, JSONL
# ===========================================================================

def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(7)
    samples = rng.lognormal(mean=-3.0, sigma=1.0, size=20_000)
    h = Histogram()
    h.observe_many(samples)
    assert h.count == samples.size
    np.testing.assert_allclose(h.sum, samples.sum())
    for q in (0.50, 0.95, 0.99):
        exact = float(np.percentile(samples, 100 * q))
        got = h.quantile(q)
        # log-bucketing bounds relative error by sqrt(growth)-1 ≈ 4.4%
        assert abs(got - exact) / exact < 0.05, (q, got, exact)
    s = h.summary()
    assert s["min"] == samples.min() and s["max"] == samples.max()


def test_histogram_edge_cases():
    h = Histogram()
    assert math.isnan(h.quantile(0.5)) and h.summary()["count"] == 0
    h.observe(float("nan"))     # ignored, not stored
    assert h.count == 0
    h.observe(0.0)              # underflow bucket
    h.observe(5.0)
    assert h.count == 2 and h.min == 0.0 and h.max == 5.0
    assert 0.0 <= h.quantile(0.0) <= h.quantile(1.0) <= 5.0


def test_registry_series_identity_and_jsonl():
    reg = MetricsRegistry()
    c = reg.counter("sweep.items", executor="serving")
    c.inc(4)
    assert reg.counter("sweep.items", executor="serving") is c
    assert reg.counter("sweep.items", executor="host") is not c
    reg.gauge("qos").set(0.9)
    reg.histogram("lat", scenario="steady").observe_many([0.01, 0.02])
    lines = reg.to_jsonl().strip().splitlines()
    recs = [json.loads(line) for line in lines]
    assert len(recs) == 4
    assert all(r["metrics_schema"] == obs.METRICS_SCHEMA_VERSION
               for r in recs)
    by_kind = {}
    for r in recs:
        by_kind.setdefault(r["kind"], []).append(r)
    assert by_kind["counter"][0]["labels"] == {"executor": "host"}
    hist = by_kind["histogram"][0]
    assert hist["count"] == 2 and hist["labels"] == {"scenario": "steady"}
    assert reg.histograms("lat") == \
        {"lat{scenario=steady}": reg.histogram("lat",
                                               scenario="steady").summary()}


# ===========================================================================
# The hard invariant: tracing is observational only
# ===========================================================================

def _spec():
    from repro.sweeps import SweepSpec
    grid = (tuple(sorted({**SMALL, "switching_cost": 0.0,
                          "stickiness": 0.0}.items())),)
    return SweepSpec(kind="serving", scenarios=("steady",), seeds=(0, 1),
                     n_ticks=2, algos=("edf",), override_grid=grid)


def test_serving_store_byte_identical_with_obs_on(tmp_path):
    from repro.sweeps import SweepStore, run_sweep
    run_sweep(_spec(), store_dir=tmp_path / "off")
    obs.enable()
    run_sweep(_spec(), store_dir=tmp_path / "on")
    tr = obs.disable()
    assert tr.n_spans > 0  # tracing actually happened

    off, on = SweepStore(tmp_path / "off"), SweepStore(tmp_path / "on")
    assert off.keys() == on.keys() and len(off) == 4
    for key in off.keys():
        a, b = np.float64(off.value(key)), np.float64(on.value(key))
        assert a.tobytes() == b.tobytes()
        ma, mb = off.metrics(key), on.metrics(key)
        assert ma.keys() == mb.keys()
        for name in ma:
            assert np.float64(ma[name]).tobytes() == \
                np.float64(mb[name]).tobytes(), (key, name)
    # chunk structure identical too (times are wall-clock and exempt)
    assert [c["keys"] for c in off.chunks()] == \
        [c["keys"] for c in on.chunks()]


def test_tick_reports_identical_with_obs_on():
    from repro.serving.horizon import HorizonConfig, run_horizon
    cfg = HorizonConfig(scenario="steady", policy="edf", seed=0, n_ticks=2,
                        overrides=tuple(sorted(SMALL.items())))
    ref = run_horizon(cfg)
    obs.enable()
    traced = run_horizon(cfg)
    obs.disable()
    np.testing.assert_array_equal(ref.tick_values(), traced.tick_values())
    assert len(ref.per_tick) == len(traced.per_tick)
    for a, b in zip(ref.per_tick, traced.per_tick):
        # repr-compare so NaN fields (empty-tick latencies) count as equal
        assert repr(dataclasses.asdict(a)) == repr(dataclasses.asdict(b))


# ===========================================================================
# Disabled-path overhead guard
# ===========================================================================

def test_disabled_span_overhead_under_budget():
    assert not obs.enabled()
    n = 20_000
    reps = []
    for _ in range(5):
        t0 = time.perf_counter()
        for _ in range(n):
            with obs.span("tick.place"):
                pass
        reps.append((time.perf_counter() - t0) / n)
    noop_s = min(reps)
    # the no-op span must stay in the nanosecond regime — 5µs is ~20x
    # headroom over measured (~250ns) while still failing a pathological
    # regression (e.g. building a real span or dict on the disabled path)
    assert noop_s < 5e-6, f"disabled span costs {noop_s * 1e9:.0f}ns"

    # arithmetic overhead bound for a traced workload: a serving tick
    # records ~6 span/gauge events over >= 10ms of work — even at the
    # 5µs ceiling that is 30µs/tick < 0.3%, far under the 3% contract
    events_per_tick, tick_floor_s = 6, 0.010
    assert 100 * events_per_tick * noop_s / tick_floor_s < 3.0


# ===========================================================================
# Fleet telemetry
# ===========================================================================

def test_worker_telemetry_record_and_staleness(tmp_path):
    from repro.fleet.telemetry import (DEFAULT_STALE_S, WorkerTelemetry,
                                       read_telemetry)
    now = {"t": 1000.0}
    wt = WorkerTelemetry(tmp_path, "w0", clock=lambda: now["t"])
    wt.start()
    wt.task_done("t1", 4, 0.5)
    rec = json.loads((tmp_path / "telemetry" / "w0.json").read_text())
    assert rec["owner"] == "w0" and rec["state"] == "running"
    assert rec["tasks_done"] == 1 and rec["items_done"] == 4
    assert rec["last_task"] == "t1" and rec["last_task_wall_s"] == 0.5
    assert rec["items_per_s"] > 0

    fresh = read_telemetry(tmp_path, now=now["t"])
    assert fresh["workers"]["w0"]["live"] is True
    assert fresh["rate_items_per_s"] == rec["items_per_s"]
    # beyond the staleness window the frozen file stops counting
    stale = read_telemetry(tmp_path, now=now["t"] + DEFAULT_STALE_S + 1)
    assert stale["workers"]["w0"]["live"] is False
    assert stale["rate_items_per_s"] == 0.0
    # a finished worker is never live, however fresh its record
    wt.stop("drained")
    done = read_telemetry(tmp_path, now=now["t"])
    assert done["workers"]["w0"]["state"] == "drained"
    assert done["workers"]["w0"]["live"] is False


def test_fleet_status_reports_rate_and_eta(tmp_path):
    from repro.fleet import plan, run_worker, status
    root = tmp_path / "fleet"
    pl = plan(_spec(), root)
    assert pl["n_tasks"] == 2
    st = status(root)
    # nothing ran yet: full backlog, no live rate, no ETA
    assert st["remaining_items"] == 4
    assert st["rate_items_per_s"] == 0.0 and st["eta_s"] is None
    run_worker(root, owner="w0")
    st = status(root, stale_s=1e9)  # worker already exited; keep it fresh
    assert st["remaining_items"] == 0 and st["eta_s"] is None
    tele = st["telemetry"]["w0"]
    assert tele["items_done"] == 4 and tele["state"] == "drained"
    assert tele["last_task_wall_s"] > 0


# ===========================================================================
# jax profiler adapter
# ===========================================================================

def test_kernel_span_prefix_and_named_scope():
    tr = obs.enable(capacity=8)
    with obs.kernel_span("qos_matrix", U=4):
        pass
    with obs.kernel_span("kernel.already_prefixed"):
        pass
    doc = tr.snapshot()
    assert [doc["names"][i] for i in doc["spans"]["name"]] == \
        ["kernel.qos_matrix", "kernel.already_prefixed"]
    obs.disable()
    # named_scope works outside jit and as a null context without a tracer
    with obs.named_scope("x"):
        pass
    assert obs.have_jax_profiler() in (True, False)


def test_jax_annotations_tracer_smoke():
    tr = obs.Tracer(capacity=8, jax_annotations=True)
    with tr.span("tick.place"):
        pass
    assert tr.n_spans == 1


# ===========================================================================
# CLI: report / export / tail
# ===========================================================================

def _artifact(tmp_path):
    tr = obs.Tracer(capacity=8, clock=_fake_clock())
    with tr.span("tick.place", {"tick": 0}):
        pass
    tr.count("serving.submitted", 12)
    tr.sample("serving.queue_depth", 2.0)
    tr.metrics.histogram("serving.latency_s",
                         scenario="steady").observe_many([0.01, 0.05])
    path = tmp_path / "trace.json"
    tr.save(path)
    return path


def test_cli_report(tmp_path, capsys):
    assert obs_main(["report", str(_artifact(tmp_path))]) == 0
    out = capsys.readouterr().out
    assert "tick.place" in out and "serving.submitted" in out
    assert "serving.latency_s{scenario=steady}" in out


def test_cli_export_chrome_trace_and_jsonl(tmp_path):
    art = _artifact(tmp_path)
    chrome = tmp_path / "chrome.json"
    assert obs_main(["export", str(art), "--format", "chrome-trace",
                     "--out", str(chrome)]) == 0
    doc = json.loads(chrome.read_text())
    assert obs.validate_chrome_trace(doc) == 1
    assert any(ev.get("ph") == "C" for ev in doc["traceEvents"])

    jsonl = tmp_path / "metrics.jsonl"
    assert obs_main(["export", str(art), "--format", "jsonl",
                     "--out", str(jsonl)]) == 0
    recs = [json.loads(line) for line in
            jsonl.read_text().strip().splitlines()]
    kinds = {r["kind"] for r in recs}
    assert kinds == {"histogram", "counter", "span_summary"}
    assert all(r["metrics_schema"] == obs.METRICS_SCHEMA_VERSION
               for r in recs)


def test_cli_errors(tmp_path, capsys):
    assert obs_main(["report", str(tmp_path / "missing.json")]) == 1
    assert "error" in capsys.readouterr().err


def test_cli_tail_once(tmp_path, capsys):
    from repro.fleet import plan, run_worker
    root = tmp_path / "fleet"
    plan(_spec(), root)
    run_worker(root, owner="w0")
    assert obs_main(["tail", "--root", str(root), "--once"]) == 0
    out = capsys.readouterr().out
    assert "[obs tail]" in out and "remaining 0 item(s)" in out
    assert "w0" in out
