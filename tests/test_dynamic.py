"""Dynamic (time-horizon) placement extension — the paper's future work."""
import numpy as np

from repro.core import (DynamicPlacer, evaluate_horizon, qos_matrix_np,
                        sigma_np, egp_np, synthetic_instance)


def _horizon(n_ticks=6, n_users=80, seed=0, drift=0.2):
    """Request populations that drift slowly (some users re-sampled)."""
    rng = np.random.default_rng(seed)
    base = synthetic_instance(n_users, seed=seed)
    out = [base]
    inst = base
    for t in range(1, n_ticks):
        import dataclasses
        u_service = inst.u_service.copy()
        resample = rng.random(n_users) < drift
        u_service[resample] = rng.integers(0, 100, resample.sum())
        u_alpha = inst.u_alpha.copy()
        u_alpha[resample] = 1.0 - np.clip(rng.exponential(0.125, resample.sum()), 0, 1)
        inst = dataclasses.replace(inst, u_service=u_service, u_alpha=u_alpha)
        inst.validate()
        out.append(inst)
    return out


def test_hysteresis_beats_naive_under_switching_costs():
    # high switching cost: hysteresis dominates naive re-optimization
    res = evaluate_horizon(_horizon(), switching_cost=3.0, stickiness=3.0)
    assert res["hysteresis"] > res["greedy"]
    # low switching cost: adapting (hysteresis) beats static placement too
    res2 = evaluate_horizon(_horizon(), switching_cost=1.0, stickiness=3.0)
    assert res2["hysteresis"] > res2["static"]
    assert res2["hysteresis"] >= res2["greedy"]


def test_dynamic_placer_reduces_churn():
    insts = _horizon(n_ticks=5, drift=0.15, seed=3)
    naive_loads, hyst_loads = 0, 0
    prev = None
    placer = DynamicPlacer(switching_cost=2.0, stickiness=3.0)
    for inst in insts:
        Q = qos_matrix_np(inst)
        x = egp_np(inst, Q)
        if prev is not None:
            naive_loads += int((x & ~prev).sum())
        prev = x
        _, _, loads = placer.step(inst, Q)
        hyst_loads += loads
    # subtract tick-0 loads for the hysteresis counter (prev=None skips it)
    first = insts[0]
    hyst_loads -= int(placer.step(insts[0], qos_matrix_np(insts[0]))[0].sum()) * 0
    assert hyst_loads - int(egp_np(first, qos_matrix_np(first)).sum()) <= naive_loads + 5


def test_egp_with_bias_is_egp_when_unbiased():
    """Parity guard for the hysteresis path: with no residents and zero
    bonus, the biased greedy must reproduce egp_np placement-for-placement
    on a battery of random instances (sizes, seeds, edge counts)."""
    from repro.core.dynamic import _egp_with_bias

    cases = [(40, 2, 10, 3), (80, 4, 25, 4), (100, 10, 100, 10),
             (12, 2, 4, 3), (64, 6, 24, 4)]
    for seed, (n_users, n_edges, n_services, max_impls) in enumerate(
            cases * 2):
        inst = synthetic_instance(n_users, n_edges=n_edges,
                                  n_services=n_services,
                                  max_impls=max_impls, seed=seed)
        Q = qos_matrix_np(inst)
        resident = np.zeros((inst.E, inst.P), dtype=bool)
        np.testing.assert_array_equal(
            _egp_with_bias(inst, Q, resident, 0.0), egp_np(inst, Q))


def test_zero_switching_cost_recovers_per_tick_quality():
    insts = _horizon(n_ticks=3, seed=7)
    placer = DynamicPlacer(switching_cost=0.0, stickiness=0.0)
    for inst in insts:
        Q = qos_matrix_np(inst)
        x, value, _ = placer.step(inst, Q)
        ref = sigma_np(inst, egp_np(inst, Q), Q)
        np.testing.assert_allclose(value, ref, rtol=1e-9)
