"""Model zoo tests: per-arch smoke, cache consistency, SSD correctness,
flash-vs-naive attention, GQA padding plans."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import transformer as T
from repro.models import layers as L
from repro.models.config import plan_gqa_padding


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio":
        return {"frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
                "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
                "mask": jnp.ones((B, S))}
    if cfg.frontend == "vision":
        nv = cfg.n_vision_tokens
        return {"patches": jnp.asarray(rng.normal(size=(B, nv, cfg.d_model)), jnp.float32),
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - nv))),
                "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
                "mask": jnp.ones((B, S))}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
            "mask": jnp.ones((B, S))}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_train_step(arch):
    """Reduced config: one forward + loss on CPU, shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    x = T.forward(params, cfg, batch)
    assert x.shape == (2, 32, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
    loss, grads = jax.value_and_grad(
        lambda p: T.loss_fn(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in flat)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "hubert_xlarge"])
def test_decode_matches_forward(arch):
    """Teacher-forced forward logits at the last position must equal
    prefill(S−1) + one decode step — validates KV caches (incl. SWA ring
    buffers), RoPE positions and Mamba2 state carry."""
    cfg = get_smoke_config(arch).with_(dtype="float32", remat=False)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 32
    batch = make_batch(cfg, B, S, seed=3)

    x = T.forward(params, cfg, batch)
    full_logits = T.logits_fn(params, cfg, x[:, -1:], None)[:, 0]

    if cfg.frontend == "vision":
        pre = {"patches": batch["patches"], "tokens": batch["tokens"][:, :-1]}
        last_tok = batch["tokens"][:, -1]
    else:
        pre = {"tokens": batch["tokens"][:, :-1]}
        last_tok = batch["tokens"][:, -1]
    cache, ring = T.init_cache(cfg, B, S)
    _, cache = T.prefill(params, cfg, pre, cache, ring)
    dec_logits, _ = T.decode_step(params, cfg, last_tok, cache, ring)

    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), atol=2e-3, rtol=2e-3)


def test_ssd_chunked_matches_sequential():
    """Chunked SSD (matmul form) == naive recurrence h ← h·exp(ΔA) + B⊗x."""
    rng = np.random.default_rng(0)
    b, l, h, p, n, chunk = 2, 64, 3, 8, 4, 16
    X = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    dtA = -jnp.asarray(rng.uniform(0.01, 0.5, size=(b, l, h)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)

    Y, final = L.ssd_chunked(X, dtA, B, C, chunk)

    state = np.zeros((b, h, p, n))
    Yref = np.zeros((b, l, h, p))
    for t in range(l):
        decay = np.exp(np.asarray(dtA[:, t]))[:, :, None, None]
        state = state * decay + np.einsum(
            "bn,bhp->bhpn", np.asarray(B[:, t]), np.asarray(X[:, t]))
        Yref[:, t] = np.einsum("bn,bhpn->bhp", np.asarray(C[:, t]), state)
    np.testing.assert_allclose(np.asarray(Y), Yref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(final), state, atol=1e-4, rtol=1e-4)


def test_ssd_chunked_initial_state_resume():
    """Splitting a sequence across two ssd_chunked calls with state carry
    equals one call over the full sequence."""
    rng = np.random.default_rng(1)
    b, l, h, p, n, chunk = 1, 64, 2, 4, 4, 8
    X = jnp.asarray(rng.normal(size=(b, l, h, p)), jnp.float32)
    dtA = -jnp.asarray(rng.uniform(0.01, 0.5, size=(b, l, h)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32)
    Y_full, final_full = L.ssd_chunked(X, dtA, B, C, chunk)
    half = l // 2
    Y1, s1 = L.ssd_chunked(X[:, :half], dtA[:, :half], B[:, :half], C[:, :half], chunk)
    Y2, s2 = L.ssd_chunked(X[:, half:], dtA[:, half:], B[:, half:], C[:, half:],
                           chunk, initial_state=s1)
    np.testing.assert_allclose(np.asarray(Y_full[:, half:]), np.asarray(Y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(final_full), np.asarray(s2),
                               atol=1e-4, rtol=1e-4)


def _naive_attention(q, k, v, causal, window):
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = np.einsum("bqkgh,bskh->bkgqs", np.asarray(q.reshape(B, Sq, Hkv, G, hd), np.float64),
                  np.asarray(k, np.float64)) / np.sqrt(hd)
    iq = np.arange(Sq)[:, None]
    ik = np.arange(k.shape[1])[None, :]
    ok = np.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= ik <= iq
    if window:
        ok &= ik > iq - window
    s = np.where(ok[None, None, None], s, -np.inf)
    s = s - s.max(-1, keepdims=True)
    p = np.exp(s)
    p = p / p.sum(-1, keepdims=True)
    out = np.einsum("bkgqs,bskh->bkgqh", p, np.asarray(v, np.float64))
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, hd)


@pytest.mark.parametrize("causal,window,Sq", [
    (True, 0, 48), (True, 16, 48), (False, 0, 40), (True, 0, 33),
])
def test_flash_attention_matches_naive(causal, window, Sq):
    rng = np.random.default_rng(0)
    B, Hq, Hkv, hd = 2, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sq, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sq, Hkv, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    out = L.flash_attention_jnp(q, k, v, pos, pos, causal=causal,
                                window=window, attn_softcap=0.0,
                                q_chunk=16, kv_chunk=16)
    ref = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("nq,nkv,shards", [
    (56, 8, 16), (15, 5, 16), (14, 2, 16), (64, 4, 16), (32, 16, 16),
    (64, 8, 16), (32, 32, 16), (16, 16, 16), (32, 8, 16), (8, 2, 4),
])
def test_gqa_padding_plans(nq, nkv, shards):
    p = plan_gqa_padding(nq, nkv, shards)
    assert p.n_q_pad % shards == 0 and p.n_kv_pad % shards == 0
    assert p.n_q_pad * p.n_kv >= p.n_q * p.n_kv  # sanity
    # validation of head placement happens inside plan_gqa_padding


def test_padded_attention_matches_unpadded():
    """A model padded for TP=4 must produce the same logits as the logical
    (unpadded) model when padded weight slots are mapped from the original
    weights (§DESIGN.md sharding-divisibility padding)."""
    base = get_smoke_config("yi_34b").with_(dtype="float32", remat=False,
                                            n_heads=8, n_kv_heads=2, head_dim=16)
    padded = base.with_(tp_shards=4)
    pu, pp = base.gqa, padded.gqa
    assert pu.is_identity and not pp.is_identity

    params_u = T.init_params(base, jax.random.PRNGKey(0))
    params_p = jax.tree_util.tree_map(lambda x: x, params_u)

    def pad_layer(attn):
        wq, wk, wv, wo = attn["wq"], attn["wk"], attn["wv"], attn["wo"]
        L_, D, Hq, hd = wq.shape
        nwq = jnp.zeros((L_, D, pp.n_q_pad, hd), wq.dtype)
        nwo = jnp.zeros((L_, pp.n_q_pad, hd, wo.shape[-1]), wo.dtype)
        for slot, orig in enumerate(pp.q_slot_to_q):
            if orig >= 0:
                nwq = nwq.at[:, :, slot].set(wq[:, :, orig])
                nwo = nwo.at[:, slot].set(wo[:, orig])
        nwk = jnp.zeros((L_, D, pp.n_kv_pad, hd), wk.dtype)
        nwv = jnp.zeros((L_, D, pp.n_kv_pad, hd), wv.dtype)
        for slot, orig in enumerate(pp.kv_slot_to_kv):
            if orig >= 0:
                nwk = nwk.at[:, :, slot].set(wk[:, :, orig])
                nwv = nwv.at[:, :, slot].set(wv[:, :, orig])
        return {"wq": nwq, "wk": nwk, "wv": nwv, "wo": nwo}

    params_p["layers"] = dict(params_p["layers"])
    params_p["layers"]["attn"] = pad_layer(params_u["layers"]["attn"])

    batch = make_batch(base, B=2, S=16)
    xu = T.forward(params_u, base, batch)
    xp = T.forward(params_p, padded, batch)
    np.testing.assert_allclose(np.asarray(xu), np.asarray(xp),
                               atol=1e-4, rtol=1e-4)


def test_moe_routing_respects_topk_and_capacity():
    cfg = get_smoke_config("mixtral_8x7b").with_(dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, cfg.d_model)),
                    jnp.float32)
    out = L.moe_block(params["layers"]["moe"],
                      cfg, x, ctx=None) if False else None
    # moe params are stacked [L, ...]; take layer 0
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"]["moe"])
    out = L.moe_block(lp, cfg, x, ctx=None)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
