"""repro.obs v3 — per-request causal tracing and greedy-decision
provenance: deterministic tail sampling, the byte-identity invariant
(stores/TickReports/digests unchanged with tracing on), marginal-gain
telescoping (sum of per-pick gains == realized sigma), the (1-1/e)
certificate, histogram exemplars, the explain/why CLI, chrome-trace
zero-duration rejection, and stream truncation recovery."""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro import obs
from repro.core import synthetic_instance
from repro.core.placement import (egp_np, place_and_schedule, qos_matrix_np,
                                  sigma_np, sigma_upper_bound_np)
from repro.gateway.control import result_digest
from repro.obs import ledger as obs_ledger
from repro.obs import reqtrace as obs_reqtrace
from repro.obs.cli import main as obs_main
from repro.obs.ledger import (LEDGER_SCHEMA_VERSION, DecisionLedger,
                              ingest_sparse_trace, load_ledger, why_text)
from repro.obs.metrics import Histogram
from repro.obs.reqtrace import (REQTRACE_SCHEMA_VERSION, RequestTracer,
                                explain_uid, load_reqtrace)
from repro.serving.horizon import HorizonConfig, run_horizon

#: Shrunk scenario (see tests/test_horizon.py) — keeps horizons fast.
SMALL = {"n_user_slots": 32, "n_services": 8, "max_impls": 3, "n_edges": 4}
LOAD = dict(prompt_tokens=768, new_tokens=64, max_batch=4)


def _cfg(**kw):
    base = dict(scenario="flash_crowd", overrides=tuple(SMALL.items()),
                policy="edf", seed=0, n_ticks=3, **LOAD)
    base.update(kw)
    return HorizonConfig(**base)


@pytest.fixture(autouse=True)
def _v3_off():
    """Reqtrace and ledger must be off by default and never leak."""
    assert obs_reqtrace._REQTRACER is None
    assert obs_ledger._LEDGER is None
    yield
    obs_reqtrace.disable_request_tracing()
    obs_ledger.disable_ledger()


def _traced_run(cfg, sample_every=4):
    obs_reqtrace.enable_request_tracing(sample_every=sample_every)
    obs_ledger.enable_ledger()
    res = run_horizon(cfg)
    rt = obs_reqtrace.disable_request_tracing()
    led = obs_ledger.disable_ledger()
    return res, rt, led


# ===========================================================================
# The hard invariant: tracing changes no byte of the result
# ===========================================================================

@pytest.mark.parametrize("policy", ["edf", "fcfs", "feedback"])
def test_byte_identity_traced_vs_untraced(policy):
    cfg = _cfg(policy=policy)
    off = result_digest(run_horizon(cfg))
    res_on, rt, led = _traced_run(cfg)
    assert result_digest(res_on) == off
    assert rt.kept() and led.records()


def test_tick_reports_identical_with_tracing():
    cfg = _cfg()
    plain = run_horizon(cfg)
    traced, _, _ = _traced_run(cfg)
    for a, b in zip(plain.per_tick, traced.per_tick):
        assert dataclasses.astuple(a) == dataclasses.astuple(b)


# ===========================================================================
# Deterministic tail sampling
# ===========================================================================

def test_sampled_uid_set_reproducible_across_runs():
    cfg = _cfg()
    _, rt1, _ = _traced_run(cfg)
    _, rt2, _ = _traced_run(cfg)
    assert rt1.kept_uids() == rt2.kept_uids()
    assert rt1.kept_uids()  # non-trivial sample


def test_specials_never_sampled_out():
    """Misses, drops, and requeues survive any sampling rate — including
    sample_every=0 (specials only) and very sparse hash sampling."""
    cfg = _cfg()
    _, dense, _ = _traced_run(cfg, sample_every=1)     # keep everything
    special_uids = {r["uid"] for r in dense.kept()
                    if r.get("dropped") or r.get("missed")
                    or r.get("requeued")}
    assert special_uids  # flash_crowd at this load point misses deadlines
    for sample_every in (0, 1024):
        _, rt, _ = _traced_run(cfg, sample_every=sample_every)
        assert special_uids <= set(rt.kept_uids()), sample_every
        for rec in rt.kept():
            if rec["uid"] in special_uids:
                assert rec["keep_reason"] != "sampled"


def test_sampling_differs_by_seed_salt():
    """The seed folds into the hash salt: different seeds sample
    different ordinary uids (while specials stay rule-kept)."""
    _, rt0, _ = _traced_run(_cfg(seed=0), sample_every=4)
    _, rt1, _ = _traced_run(_cfg(seed=1), sample_every=4)
    s0 = {r["uid"] for r in rt0.kept() if r["keep_reason"] == "sampled"}
    s1 = {r["uid"] for r in rt1.kept() if r["keep_reason"] == "sampled"}
    assert s0 and s1 and s0 != s1


def test_gateway_vs_offline_replay_same_sampled_uids():
    """The same (config, seed, trace) replayed through the virtual-clock
    gateway samples the exact same uid set as the offline horizon."""
    import asyncio

    from repro.gateway.loadgen import run_loadgen
    from repro.gateway.server import Gateway, GatewayConfig

    cfg = _cfg(n_ticks=2)
    _, rt_off, _ = _traced_run(cfg)

    obs_reqtrace.enable_request_tracing(sample_every=4)
    gw = Gateway(GatewayConfig(horizon=cfg, mode="virtual"))

    async def _run():
        async def send(line):
            gw.submit_line(line)
        task = asyncio.ensure_future(gw.run())
        await run_loadgen(send, cfg, wall=False)
        return await task

    live = asyncio.run(_run())
    rt_live = obs_reqtrace.disable_request_tracing()
    assert result_digest(live) == result_digest(run_horizon(cfg))
    assert rt_live.kept_uids() == rt_off.kept_uids()
    # the gateway path additionally stamps socket-receipt events
    by_reason_off = {r["uid"]: r["keep_reason"] for r in rt_off.kept()}
    assert {r["uid"]: r["keep_reason"]
            for r in rt_live.kept()} == by_reason_off


def test_tracer_ring_capacity_and_eviction():
    rt = RequestTracer(capacity=4, sample_every=1)
    for uid in range(10):
        rt.admit(uid, 0, edge=0, service=0, alpha=0.5, delta=1.0,
                 arrival=float(uid))
        rt.complete(uid, float(uid) + 0.1, latency=0.1, missed=False)
    assert len(rt.kept()) == 4
    assert rt.evicted_records == 6
    assert [r["uid"] for r in rt.kept()] == [6, 7, 8, 9]


# ===========================================================================
# Causal-chain reconstruction (explain)
# ===========================================================================

def test_explain_reconstructs_full_chain(tmp_path):
    cfg = _cfg()
    _, rt, _ = _traced_run(cfg)
    path = tmp_path / "reqtrace.json"
    rt.save(path)
    doc = load_reqtrace(path)
    assert doc["reqtrace_schema"] == REQTRACE_SCHEMA_VERSION
    uid = rt.kept_uids()[0]
    text = explain_uid(doc, uid)
    assert f"uid={uid}" in text
    assert "admit" in text and "route" in text
    assert "placement epoch" in text
    # every kept uid reconstructs, and events are time-ordered
    for rec in doc["records"]:
        chain = explain_uid(doc, rec["uid"])
        assert chain
        ts = [e["t"] for e in rec["events"]]
        assert ts == sorted(ts)


def test_explain_unknown_uid_raises():
    rt = RequestTracer(sample_every=1)
    rt.admit(3, 0, edge=0, service=0, alpha=0.5, delta=1.0, arrival=0.0)
    rt.complete(3, 0.5, latency=0.5, missed=False)
    with pytest.raises(ValueError, match="uid 999"):
        explain_uid(rt.snapshot(), 999)


def test_reqtrace_version_mismatch_rejected(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"reqtrace_schema": 99, "records": []}))
    with pytest.raises(ValueError, match="schema"):
        load_reqtrace(path)


# ===========================================================================
# Decision ledger: gains telescope to sigma, certificate holds
# ===========================================================================

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dense_gain_sum_equals_sigma(seed):
    inst = synthetic_instance(60, n_edges=4, seed=seed)
    led = DecisionLedger()
    obs_ledger._set_core_sink(led)
    try:
        led.begin(tick=0, seed=seed, algo="egp")
        Q = qos_matrix_np(inst)
        x = egp_np(inst, Q)
        led.end(sigma=sigma_np(inst, x, Q),
                sigma_bound=sigma_upper_bound_np(inst, Q))
    finally:
        obs_ledger._set_core_sink(None)
    rec = led.records()[-1]
    assert abs(rec["gain_sum"] - rec["sigma"]) <= 1e-6
    assert rec["sigma_bound"] >= rec["sigma"]
    assert rec["cert_ok"] and rec["ratio"] >= 1.0 - 1.0 / math.e - 1e-9
    # greedy picks the best candidate: rank 0 by construction, and the
    # gain curve is the cumulative gain booked in pick order
    assert all(p["rank"] == 0 for p in rec["picks"])
    curve = rec["gain_curve"]
    assert curve == sorted(curve)
    assert abs(curve[-1] - rec["gain_sum"]) <= 1e-9


def test_ledger_does_not_change_picks():
    inst = synthetic_instance(50, n_edges=3, seed=5)
    Q = qos_matrix_np(inst)
    x_plain = egp_np(inst, Q)
    led = DecisionLedger()
    obs_ledger._set_core_sink(led)
    try:
        x_led = egp_np(inst, Q)
    finally:
        obs_ledger._set_core_sink(None)
    assert np.array_equal(x_plain, x_led)


def test_place_and_schedule_certificate():
    inst = synthetic_instance(40, n_edges=3, seed=2)
    led = DecisionLedger()
    obs_ledger._set_core_sink(led)
    try:
        led.begin(tick=0, seed=2, algo="egp")
        place_and_schedule(inst)
    finally:
        obs_ledger._set_core_sink(None)
    rec = led.records()[-1]
    # sigma comes from oms_np's realized value; the greedy gains must
    # still telescope to exactly the sigma of the placement
    assert rec["sigma"] is not None and rec["cert_ok"]


def test_serving_ledger_per_tick_records():
    cfg = _cfg()
    _, _, led = _traced_run(cfg)
    assert [r["tick"] for r in led.records()] == [0, 1, 2]
    for rec in led.records():
        assert abs(rec["gain_sum"] - rec["sigma"]) <= 1e-6
        assert rec["cert_ok"]
        assert rec["algo"] == "egp_hysteresis"
        # hysteresis bias is recorded per pick so rank>0 picks are
        # attributable to stickiness, not greedy error
        for p in rec["picks"]:
            if p["rank"] > 0:
                assert any(q.get("bias") for q in rec["picks"])
                break


def test_sparse_trace_parity_and_gain_sum():
    import jax.numpy as jnp

    from repro.core.candidates import impl_table_np
    from repro.core.placement import egp_place_sparse_jax, sigma_sparse_jnp
    from repro.kernels.qos_matrix.ops import qos_candidates_from_instance

    inst = synthetic_instance(80, n_edges=4, seed=0)
    ji = inst.as_jax()
    table = impl_table_np(inst.sm_service, inst.S)
    cand_idx, cand_q = qos_candidates_from_instance(ji, table, None)
    args = (cand_idx, cand_q, ji.u_edge, ji.sm_service, ji.sm_r, ji.R)
    x_plain = egp_place_sparse_jax(*args, max_iters=inst.P + 1)
    x_tr, trace = egp_place_sparse_jax(*args, max_iters=inst.P + 1,
                                       with_trace=True)
    # the traced loop makes identical decisions
    assert np.array_equal(np.asarray(x_plain), np.asarray(x_tr))
    sigma = float(sigma_sparse_jnp(cand_idx, cand_q, ji.u_edge, x_tr))
    led = DecisionLedger()
    rec = ingest_sparse_trace(led, trace, tick=0, seed=0, sigma=sigma,
                              sigma_bound=sigma_upper_bound_np(inst))
    # f32 accumulation: documented tolerance ~1e-3 relative
    assert rec["gain_sum"] == pytest.approx(sigma, rel=1e-3)
    assert rec["algo"] == "egp_sparse"
    # the certificate is computed against the relaxation bound; a ratio
    # below 1-1/e is a flag, not a violation (the bound overshoots OPT)
    assert 0.0 < rec["ratio"] <= 1.0 and "cert_ok" in rec
    assert rec["n_picks"] == int((np.asarray(trace["pick"]) >= 0).sum())


def test_why_text_and_ledger_roundtrip(tmp_path):
    cfg = _cfg(n_ticks=2)
    _, _, led = _traced_run(cfg)
    path = tmp_path / "ledger.jsonl"
    led.save(path)
    recs = load_ledger(path)
    assert len(recs) == 2
    assert all(r["ledger_schema"] == LEDGER_SCHEMA_VERSION for r in recs)
    text = why_text(recs[-1])
    assert "benefit" in text and "gain" in text and "rank" in text
    assert "(1-1/e)" in text or "certificate" in text
    # edge filter narrows the pick table
    edges = {p["edge"] for p in recs[-1]["picks"]}
    filt = why_text(recs[-1], edge=sorted(edges)[0])
    assert len(filt) < len(text)


def test_ledger_version_mismatch_rejected(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text(json.dumps({"ledger_schema": 99, "picks": []}) + "\n")
    with pytest.raises(ValueError, match="schema"):
        load_ledger(path)


# ===========================================================================
# Histogram exemplars
# ===========================================================================

def test_exemplar_attach_cap_and_roundtrip():
    h = Histogram(exemplar_cap=2)
    for uid in range(5):
        h.observe(1.0, exemplar={"uid": uid, "tick": 0})
    h.observe(1e6, exemplar={"uid": 99, "tick": 1})
    rec = h.record()
    buckets = rec["exemplars"]
    assert sum(len(v) for v in buckets.values()) == 3  # 2 capped + 1
    assert {"uid": 0, "tick": 0} in next(iter(buckets.values()))
    h2 = Histogram.from_record(rec)
    assert h2.record()["exemplars"] == buckets
    # merge respects the cap and keeps first-N determinism
    h3 = Histogram(exemplar_cap=2)
    h3.observe(1.0, exemplar={"uid": 7, "tick": 2})
    h3.merge(h2)
    merged = h3.record()["exemplars"]
    assert sum(len(v) for v in merged.values()) == 3


def test_exemplar_key_absent_when_unused():
    h = Histogram()
    h.observe(1.0)
    assert "exemplars" not in h.record()
    assert Histogram.from_record(h.record()).record() == h.record()


def test_latency_histogram_links_traces():
    """The serving latency histogram carries exemplars pointing at kept
    request traces when tracing is on — and none when it is off."""
    cfg = _cfg()
    obs.enable()
    _, rt, _ = _traced_run(cfg)
    tr = obs.disable()
    lat = [m for m in tr.metrics.snapshot()
           if m.get("kind") == "histogram"
           and m["name"] == "serving.latency_s"]
    assert lat
    kept = set(rt.kept_uids())
    linked = [ex for m in lat
              for exs in m.get("exemplars", {}).values() for ex in exs]
    assert linked, "latency histogram should carry exemplars"
    assert all(ex["uid"] in kept for ex in linked)


# ===========================================================================
# CLI: explain / why
# ===========================================================================

def test_cli_explain_and_why(tmp_path, capsys):
    cfg = _cfg(n_ticks=2)
    _, rt, led = _traced_run(cfg)
    rt_path, led_path = tmp_path / "rt.json", tmp_path / "led.jsonl"
    rt.save(rt_path)
    led.save(led_path)
    uid = rt.kept_uids()[0]
    assert obs_main(["explain", "--uid", str(uid),
                     "--trace", str(rt_path)]) == 0
    out = capsys.readouterr().out
    assert f"uid={uid}" in out and "route" in out
    assert obs_main(["why", "--tick", "1", "--ledger", str(led_path)]) == 0
    out = capsys.readouterr().out
    assert "tick=1" in out and "sigma(greedy)" in out
    # unknown uid / tick exit 1 with a helpful message
    assert obs_main(["explain", "--uid", "123456789",
                     "--trace", str(rt_path)]) == 1
    assert obs_main(["why", "--tick", "99",
                     "--ledger", str(led_path)]) == 1
    assert "ticks with records" in capsys.readouterr().err


def test_dash_renders_requests_pane():
    from repro.obs.dash import DashState, render

    state = DashState()
    state.update({"seq": 0, "type": "hello", "t": 0.0,
                  "payload": {"source": "test", "pid": 1}})
    state.update({"seq": 1, "type": "reqtrace", "t": 1.0,
                  "payload": {"uid": 42, "tick": 0, "edge": 1,
                              "missed": True, "latency_s": 1.5,
                              "keep_reason": "deadline_miss",
                              "events": [{"stage": "route", "impl": 7}]}})
    screen = render(state)
    assert "requests" in screen and "42" in screen
    assert "deadline_miss" in screen and "missed" in screen


# ===========================================================================
# Satellite: chrome-trace duration validation
# ===========================================================================

def _x_event(dur, name="tick.place"):
    return {"ph": "X", "name": name, "cat": "serving", "pid": 1, "tid": 0,
            "ts": 1.0, "dur": dur}


def test_validate_rejects_zero_and_negative_duration():
    for dur in (0, 0.0, -1.0):
        with pytest.raises(ValueError, match="non-positive duration"):
            obs.validate_chrome_trace({"traceEvents": [_x_event(dur)]})
    assert obs.validate_chrome_trace(
        {"traceEvents": [_x_event(0.001)]}) == 1


def test_fake_clock_trace_exports_positive_durations():
    """Golden: a tracer on a monotone fake clock exports strictly
    positive durations that pass validation."""
    state = {"t": 0}

    def clock():
        state["t"] += 500  # ns
        return state["t"]

    tr = obs.Tracer(capacity=16, clock=clock)
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    chrome = obs.to_chrome_trace(tr.snapshot())
    assert obs.validate_chrome_trace(chrome) == 2
    durs = [ev["dur"] for ev in chrome["traceEvents"]
            if ev.get("ph") == "X"]
    assert all(d > 0 for d in durs)


# ===========================================================================
# Satellite: stream follow-mode truncation recovery
# ===========================================================================

def test_read_stream_survives_truncation(tmp_path):
    from repro.obs.stream import FileSink, StreamPublisher, read_stream

    path = tmp_path / "s.jsonl"
    pub = StreamPublisher(FileSink(path), source="gen1")
    for i in range(20):
        pub.emit("tick", {"tick": i})

    gen = read_stream(str(path), follow=True, timeout_s=5.0, poll_s=0.01)
    got = [next(gen) for _ in range(21)]     # hello + 20 ticks
    assert [f["type"] for f in got] == ["hello"] + ["tick"] * 20

    # writer truncates and starts a fresh (shorter) stream in place —
    # the follower must reset to offset 0 and revalidate the handshake
    path.write_text("")
    pub2 = StreamPublisher(FileSink(path), source="gen2")
    pub2.emit("tick", {"tick": 100})
    pub2.emit("bye", {})
    rest = list(gen)
    assert [f["type"] for f in rest] == ["hello", "tick", "bye"]
    assert rest[0]["payload"]["source"] == "gen2"
    assert rest[1]["payload"]["tick"] == 100


def test_frame_validator_reset():
    from repro.obs.stream import STREAM_SCHEMA_VERSION, FrameValidator

    v = FrameValidator()
    hello = {"seq": 0, "type": "hello",
             "payload": {"stream_schema": STREAM_SCHEMA_VERSION}}
    v.feed(dict(hello))
    v.feed({"seq": 1, "type": "tick", "payload": {}})
    v.reset()
    assert v.last_seq is None and v.hello is None
    v.feed(dict(hello))      # a replayed seq 0 is valid again post-reset
    v.feed({"seq": 1, "type": "tick", "payload": {}})


# ===========================================================================
# Disabled-path behavior and overhead
# ===========================================================================

def test_disabled_hooks_are_noops():
    """With tracing off, the module globals are None and the serving /
    gateway call sites reduce to one load + is-None check."""
    assert obs_reqtrace.get_request_tracer() is None
    assert obs_ledger.get_ledger() is None
    res = run_horizon(_cfg(n_ticks=1))
    assert res.submitted > 0  # ran clean with hooks disabled


def test_disabled_hook_overhead_within_span_budget():
    """The disabled reqtrace hook must cost no more than the PR-6 no-op
    span budget (the obs contract: ~0.25us; generous CI bound)."""
    import time as _time

    reps = []
    for _ in range(5):
        t0 = _time.perf_counter()
        for _ in range(10_000):
            rt = obs_reqtrace._REQTRACER
            if rt is not None:  # pragma: no cover
                rt.event(0, "receipt", 0.0)
        reps.append((_time.perf_counter() - t0) / 10_000)
    assert min(reps) < 5e-6, f"disabled hook costs {min(reps) * 1e9:.0f}ns"


def test_bench_reqtrace_overhead_row():
    from benchmarks.serving_horizon import reqtrace_overhead

    ov = reqtrace_overhead(n_ticks=1)
    assert set(ov) >= {"disabled_s", "enabled_s", "disabled_noop_ns",
                       "kept", "enabled_sampled_pct"}
    assert ov["kept"] > 0
    assert ov["disabled_noop_ns"] < 5000  # generous: budget is ~250ns
    # globals restored
    assert obs_reqtrace._REQTRACER is None
    assert obs_ledger._LEDGER is None
