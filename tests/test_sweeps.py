"""repro.sweeps — spec expansion/hash stability, store durability,
kill-and-resume, sharded-vs-vmap-vs-host parity, aggregation, CLI."""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.sweeps import (SweepSpec, SweepStore, auto_chunk_size,
                          envelope_for, materialize, ratio_frame, run_sweep,
                          summarize, variant_key)
from repro.workloads import evaluate_host

SRC = Path(__file__).resolve().parents[1] / "src"


# ===========================================================================
# Spec expansion + deterministic hashing
# ===========================================================================

def test_expand_is_stably_ordered_and_grid_complete():
    spec = SweepSpec(scenarios=("steady", "flash_crowd"), seeds=(3, 1),
                     n_ticks=2, algos=("egp", "sck"))
    items = spec.expand()
    assert len(items) == 2 * 2 * 2 * 2
    # scenario-major, then algo, then seed (in given order), then tick
    assert [i.scenario for i in items[:8]] == ["steady"] * 8
    assert [(i.seed, i.tick) for i in items[:4]] == [(3, 0), (3, 1),
                                                     (1, 0), (1, 1)]
    assert items[0].executor == "accel" and items[4].executor == "host"
    # re-expansion yields identical keys (resume depends on this)
    assert [i.key() for i in items] == [i.key() for i in spec.expand()]


def test_work_item_keys_are_schema_stable():
    # Pinned hash: changing instance/evaluator semantics must come with a
    # SCHEMA_VERSION bump (which changes this value on purpose).
    spec = SweepSpec(scenarios=("steady",), seeds=(0,), n_ticks=1)
    key = spec.expand()[0].key()
    assert key == spec.expand()[0].key()
    assert len(key) == 24 and int(key, 16) >= 0
    # v3: per-item serving metrics persisted at sweep time; pareto reads
    # frontiers from the store (see spec.py)
    assert key == "3cc25f098c2b9bfc3e36fb45"
    # a different accelerator iteration cap is a different result
    capped = SweepSpec(scenarios=("steady",), seeds=(0,), n_ticks=1,
                       max_iters=8)
    assert capped.expand()[0].key() != key
    # ...but host-path items ignore it (their reference code has no cap)
    h = SweepSpec(scenarios=("steady",), seeds=(0,), n_ticks=1,
                  algos=("sck",))
    h8 = SweepSpec(scenarios=("steady",), seeds=(0,), n_ticks=1,
                   algos=("sck",), max_iters=8)
    assert h.expand()[0].key() == h8.expand()[0].key()


def test_item_keys_distinguish_every_axis():
    base = SweepSpec(scenarios=("steady",), seeds=(0,), n_ticks=1)
    variants = [
        base,
        SweepSpec(scenarios=("diurnal",), seeds=(0,), n_ticks=1),
        SweepSpec(scenarios=("steady",), seeds=(1,), n_ticks=1),
        SweepSpec(scenarios=("steady",), seeds=(0,), n_ticks=1,
                  algos=("agp",)),
        SweepSpec(scenarios=("steady",), seeds=(0,), n_ticks=1,
                  force_host=("egp",)),
        SweepSpec(scenarios=("steady",), seeds=(0,), n_ticks=1,
                  override_grid=({"n_user_slots": 32},)),
    ]
    keys = [s.expand()[0].key() for s in variants]
    assert len(set(keys)) == len(keys)
    # ticks axis: same spec, later tick
    spec2 = SweepSpec(scenarios=("steady",), seeds=(0,), n_ticks=2)
    k0, k1 = [i.key() for i in spec2.expand()]
    assert k0 == keys[0] and k1 != k0  # n_ticks itself is NOT in the key


def test_duplicate_axis_values_are_deduped():
    spec = SweepSpec(scenarios=("steady", "steady"), seeds=(0, 1, 0),
                     n_ticks=1, algos=("egp", "egp"),
                     override_grid=((), ()))
    assert spec.scenarios == ("steady",)
    assert spec.seeds == (0, 1)
    assert spec.algos == ("egp",)
    assert spec.override_grid == ((),)
    assert len(spec.expand()) == 2


def test_unknown_algo_and_override_are_rejected():
    with pytest.raises(ValueError):
        SweepSpec(algos=("newton",))
    with pytest.raises(ValueError):
        materialize("synthetic", (("n_quarks", 3),), [(0, 0)])


def test_envelope_is_static_and_fits_materialized_instances():
    env = envelope_for("steady")
    insts = materialize("steady", (), [(0, 0), (1, 3)])
    for inst in insts:
        assert inst.U <= env[0] and inst.P <= env[1] and inst.E < env[2]
    assert envelope_for("synthetic", (("n_users", 50),)) == (50, 1000, 11)


def test_materialize_matches_scenario_horizon():
    from repro.workloads import horizon
    ref = horizon("mobility_churn", seed=4, n_ticks=3)
    got = materialize("mobility_churn", (), [(4, t) for t in range(3)])
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.u_edge, b.u_edge)
        np.testing.assert_allclose(a.u_alpha, b.u_alpha)


def test_auto_chunk_size_bounds_memory_and_rounds_to_mesh():
    env = (96, 96, 8)
    assert auto_chunk_size(env, 1, memory_budget_mb=1e-6) == 1  # floor
    cs = auto_chunk_size(env, 4, memory_budget_mb=64)
    assert cs >= 4 and cs % 4 == 0
    assert auto_chunk_size(env, 4, memory_budget_mb=64, n_items=3) == 3
    big = (1000, 1000, 11)
    assert auto_chunk_size(big, 1, memory_budget_mb=512) < \
        auto_chunk_size(env, 1, memory_budget_mb=512)


# ===========================================================================
# Store durability
# ===========================================================================

def test_store_roundtrip_and_crash_tolerance(tmp_path):
    store = SweepStore(tmp_path)
    store.add_chunk(["k1", "k2"], np.array([1.5, 2.5]),
                    np.array([0.1, 0.2]), {"algo": "egp"})
    store.add_chunk(["k3"], np.array([3.5]), np.array([0.3]))
    # fresh handle reads everything back
    again = SweepStore(tmp_path)
    assert "k1" in again and again.value("k2") == 2.5
    assert again.time("k3") == 0.3 and again.meta("k1") == {"algo": "egp"}
    # a torn (half-written) trailing manifest line is ignored
    with open(tmp_path / "manifest.jsonl", "a") as f:
        f.write('{"shard": "zzz.npz", "keys": ["k4"')
    assert "k4" not in SweepStore(tmp_path)
    # a manifest line whose shard file vanished is dropped, rest survives
    (shard, _) = again._index["k3"]
    (tmp_path / "shards" / shard).unlink()
    survivor = SweepStore(tmp_path)
    assert "k3" not in survivor and "k1" in survivor


def test_store_append_after_torn_line_does_not_glue(tmp_path):
    store = SweepStore(tmp_path)
    store.add_chunk(["k1"], np.array([1.0]), np.array([0.1]))
    # simulate a writer killed mid-append: torn final line, no newline
    with open(tmp_path / "manifest.jsonl", "ab") as f:
        f.write(b'{"shard": "zzz.npz", "keys": ["kX"')
    resumed = SweepStore(tmp_path)
    assert "k1" in resumed and "kX" not in resumed
    resumed.add_chunk(["k2"], np.array([2.0]), np.array([0.2]))
    # the new record starts on a fresh line: both chunks visible on reload
    final = SweepStore(tmp_path)
    assert "k1" in final and "k2" in final and final.value("k2") == 2.0


def test_store_concurrent_handles_never_clobber(tmp_path):
    """Two live handles on one store (fleet workers sharing a directory):
    each append re-reads the manifest under the lock, so a stale handle
    keeps the other writer's lines instead of clobbering them."""
    a = SweepStore(tmp_path)
    b = SweepStore(tmp_path)          # opened before a writes: stale view
    a.add_chunk(["k1"], np.array([1.0]), np.array([0.1]))
    assert "k1" not in b              # stale in memory...
    b.add_chunk(["k2"], np.array([2.0]), np.array([0.2]))
    assert "k1" in b and b.value("k1") == 1.0  # ...refreshed under lock
    fresh = SweepStore(tmp_path)
    assert "k1" in fresh and "k2" in fresh
    assert fresh.value("k1") == 1.0 and fresh.value("k2") == 2.0
    assert len((tmp_path / "manifest.jsonl").read_text().splitlines()) == 2


def test_store_metrics_roundtrip_and_chunk_hooks(tmp_path):
    store = SweepStore(tmp_path)
    store.add_chunk(["k1", "k2"], np.array([1.0, 2.0]),
                    np.array([0.1, 0.2]), {"algo": "edf"},
                    metrics={"served": [5.0, 6.0],
                             "latency": [0.25, float("nan")]})
    store.add_chunk(["k3"], np.array([3.0]), np.array([0.3]))
    again = SweepStore(tmp_path)
    assert again.metrics("k1") == {"served": 5.0, "latency": 0.25}
    m2 = again.metrics("k2")
    assert m2["served"] == 6.0 and np.isnan(m2["latency"])
    assert again.metrics("k3") == {}  # chunk without metrics
    # chunk-granular hooks (the fleet merge path)
    recs = again.chunks()
    assert [r["keys"] for r in recs] == [["k1", "k2"], ["k3"]]
    assert recs[0]["metrics"] == ["latency", "served"]
    data = again.chunk_data(recs[0]["shard"])
    np.testing.assert_array_equal(data["values"], [1.0, 2.0])
    np.testing.assert_array_equal(data["metric_served"], [5.0, 6.0])
    with pytest.raises(AssertionError):
        store.add_chunk(["k4"], np.array([1.0]), np.array([0.1]),
                        metrics={"served": [1.0, 2.0]})  # wrong length


def test_spec_json_roundtrip_and_schema_guard():
    spec = SweepSpec(scenarios=("steady", "flash_crowd"), seeds=(0, 3),
                     n_ticks=2, algos=("egp", "sck"),
                     override_grid=({"n_user_slots": 32},),
                     force_host=("egp",), max_iters=64)
    back = SweepSpec.from_json(spec.to_json())
    assert back == spec
    assert back.fingerprint() == spec.fingerprint()
    assert [i.key() for i in back.expand()] == \
        [i.key() for i in spec.expand()]
    # version skew must fail loudly, not silently re-key every item
    doc = spec.to_json()
    doc["schema_version"] -= 1
    with pytest.raises(ValueError, match="schema"):
        SweepSpec.from_json(doc)


def test_store_key_is_stable_across_seed_and_tick_extension():
    a = SweepSpec(scenarios=("steady",), seeds=(0, 1), n_ticks=2)
    b = SweepSpec(scenarios=("steady",), seeds=tuple(range(8)), n_ticks=4)
    c = SweepSpec(scenarios=("flash_crowd",), seeds=(0, 1), n_ticks=2)
    assert a.store_key() == b.store_key()  # same store → resume, not redo
    assert a.store_key() != c.store_key()
    assert a.fingerprint() != b.fingerprint()  # full spec hash still moves


# ===========================================================================
# Engine: host parity, resume, aggregation
# ===========================================================================

SPEC2 = dict(scenarios=("steady", "flash_crowd"), seeds=(0, 1, 2),
             n_ticks=2, algos=("egp",))


def test_engine_matches_host_path_and_aggregates(tmp_path):
    spec = SweepSpec(**SPEC2)
    res = run_sweep(spec, store_dir=tmp_path / "store")
    assert res.complete
    for name in spec.scenarios:
        insts = materialize(name, (), [(s, t) for s in spec.seeds
                                       for t in range(2)])
        host = evaluate_host(insts, algo="egp").reshape(3, 2)
        np.testing.assert_allclose(res.values[(name, "egp")], host,
                                   atol=1e-4)
    # aggregate ratios from engine values match host-side ratios at 1e-4
    summary = summarize(res)
    for name in spec.scenarios:
        cell = summary["cells"][f"{name}/egp"]
        assert cell["sigma"]["n"] == 6
        assert cell["ratio"]["mean"] == pytest.approx(1.0)  # single algo
        assert cell["sigma"]["ci95"] >= 0.0


def test_rerun_is_a_noop_and_bitwise_identical(tmp_path):
    spec = SweepSpec(**SPEC2)
    d = tmp_path / "store"
    first = run_sweep(spec, store_dir=d)
    n_chunks = first.execution["chunks_computed"]
    assert n_chunks >= 2
    second = run_sweep(spec, store_dir=d)
    assert second.execution["chunks_computed"] == 0
    assert second.execution["items_skipped"] == 12
    for k in first.values:
        np.testing.assert_array_equal(first.values[k], second.values[k])


def test_kill_and_resume_skips_completed_chunks(tmp_path):
    spec = SweepSpec(scenarios=("steady",), seeds=(0, 1), n_ticks=3)
    d = tmp_path / "store"
    # "kill" the sweep after 2 of 3 chunks
    partial = run_sweep(spec, store_dir=d, chunk_size=2, max_chunks=2)
    assert partial.execution["chunks_computed"] == 2
    assert not partial.complete
    assert np.isnan(partial.values[("steady", "egp")]).sum() == 2
    before = (d / "manifest.jsonl").read_text().splitlines()
    assert len(before) == 2

    # resume with a DIFFERENT chunk size: item-granular resume still skips
    done = run_sweep(spec, store_dir=d, chunk_size=4)
    assert done.complete
    assert done.execution["items_skipped"] == 4
    assert done.execution["chunks_computed"] == 1
    after = (d / "manifest.jsonl").read_text().splitlines()
    # completed chunks were appended to, never rewritten or recomputed
    assert after[:2] == before
    resumed_keys = set(json.loads(after[2])["keys"])
    already = {k for line in before for k in json.loads(line)["keys"]}
    assert not (resumed_keys & already)
    # the resumed sweep equals a fresh unstored run bitwise
    fresh = run_sweep(spec)
    np.testing.assert_array_equal(done.values[("steady", "egp")],
                                  fresh.values[("steady", "egp")])


def test_kill_and_resume_is_byte_identical_under_bucketing(tmp_path):
    """Bucketed chunk evaluation must not leak batch composition into item
    values: a killed+resumed bucketed sweep, a fresh bucketed sweep, and a
    global-envelope (bucketed=False) sweep all agree bitwise, and the
    resumed store's values reload bitwise."""
    spec = SweepSpec(scenarios=("steady", "flash_crowd"), seeds=(0, 1),
                     n_ticks=3,
                     override_grid=({}, {"n_user_slots": 48}))
    d = tmp_path / "store"
    partial = run_sweep(spec, store_dir=d, chunk_size=4, max_chunks=2,
                        bucketed=True)
    assert not partial.complete
    done = run_sweep(spec, store_dir=d, chunk_size=3, bucketed=True)
    assert done.complete and done.execution["items_skipped"] == 6
    # chunk meta records the bucketed pad mode on every accel chunk
    metas = [json.loads(line).get("meta", {})
             for line in (d / "manifest.jsonl").read_text().splitlines()]
    assert all(m.get("bucketed") for m in metas if m.get("executor") == "accel")

    fresh = run_sweep(spec, bucketed=True)
    flat = run_sweep(spec, bucketed=False)
    for key in done.values:
        np.testing.assert_array_equal(done.values[key], fresh.values[key])
        np.testing.assert_array_equal(done.values[key], flat.values[key])
    # and a pure reload of the store (no compute) is also bitwise equal
    reload_ = run_sweep(spec, store_dir=d, bucketed=True)
    assert reload_.execution["chunks_computed"] == 0
    for key in done.values:
        np.testing.assert_array_equal(done.values[key], reload_.values[key])


def test_host_executor_and_auto_ratio_reference():
    spec = SweepSpec(scenarios=("synthetic",), seeds=(7, 8), n_ticks=1,
                     algos=("egp", "opt", "sck"),
                     override_grid=({"n_users": 30, "n_edges": 4,
                                     "n_services": 12, "max_impls": 3},))
    res = run_sweep(spec)
    vk = variant_key("synthetic", spec.override_grid[0])
    ratios = ratio_frame(res)  # auto → vs exact opt
    assert np.all(ratios[(vk, "opt")] == 1.0)
    # float32 batched egp vs float64 exact opt: ≤ 1 up to f32 tolerance
    assert np.all(ratios[(vk, "egp")] <= 1.0 + 1e-4)
    assert ratios[(vk, "sck")].mean() <= ratios[(vk, "egp")].mean() + 1e-9
    with pytest.raises(ValueError):
        ratio_frame(res, ref="rnd")  # not swept


# ===========================================================================
# Sharded execution (subprocess: forces 4 host platform devices)
# ===========================================================================

_SHARD_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
from repro.sweeps import SweepSpec, run_sweep

spec = SweepSpec(scenarios=("steady", "flash_crowd"), seeds=(0, 1, 2),
                 n_ticks=2, algos=("egp",))
# chunk_size=5 over 6 items/group -> an uneven chunk of 5 (pads to 8 on 4
# devices) and a chunk of 1 (smaller than the device count; pads to 4)
res = run_sweep(spec, chunk_size=5)
assert res.execution["path"] == "shard_map", res.execution
assert res.execution["n_devices"] == 4, res.execution
assert res.complete
print(json.dumps({f"{v}/{a}": vals.tolist()
                  for (v, a), vals in res.values.items()}))
"""


def test_sharded_equals_vmap_equals_host_on_uneven_chunks(tmp_path):
    script = tmp_path / "sharded_run.py"
    script.write_text(_SHARD_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run([sys.executable, str(script)], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    sharded = {k: np.array(v) for k, v in
               json.loads(proc.stdout.strip().splitlines()[-1]).items()}

    spec = SweepSpec(**SPEC2)
    vmap_res = run_sweep(spec, chunk_size=5)  # this process: 1 device
    assert vmap_res.execution["path"] == "vmap"
    for name in spec.scenarios:
        # bit-for-bit: sharding is pure batch partitioning, no collectives
        np.testing.assert_array_equal(sharded[f"{name}/egp"],
                                      vmap_res.values[(name, "egp")])
        insts = materialize(name, (), [(s, t) for s in spec.seeds
                                       for t in range(2)])
        host = evaluate_host(insts, algo="egp").reshape(3, 2)
        np.testing.assert_allclose(sharded[f"{name}/egp"], host, atol=1e-4)


# ===========================================================================
# Mesh helpers + CLI plumbing
# ===========================================================================

def test_make_host_mesh_raises_clear_error_on_bad_model_degree():
    import jax

    from repro.launch.mesh import make_host_mesh, make_sweep_mesh
    n = len(jax.devices())
    with pytest.raises(ValueError, match="divisor"):
        make_host_mesh(model=n + 1)
    mesh = make_sweep_mesh(n_items=3)
    assert mesh.axis_names == ("data",)
    assert mesh.shape["data"] == min(3, n)
    assert make_sweep_mesh().shape["data"] == n


def test_cli_seed_parsing_and_override_grid():
    from repro.sweeps.cli import main, parse_seeds
    assert parse_seeds("0:4") == (0, 1, 2, 3)
    assert parse_seeds("2,5, 9") == (2, 5, 9)
    assert parse_seeds("7") == (7,)
    with pytest.raises(Exception):
        parse_seeds("4:4")


def test_cli_end_to_end_smoke(tmp_path, capsys):
    from repro.sweeps.cli import main
    rc = main(["--scenario", "steady", "--seeds", "0:2", "--ticks", "1",
               "--out", str(tmp_path / "store"), "--validate", "-q",
               "--json", str(tmp_path / "summary.json")])
    assert rc == 0
    out = capsys.readouterr().out
    assert "steady" in out and "egp" in out
    summary = json.loads((tmp_path / "summary.json").read_text())
    assert summary["cells"]["steady/egp"]["sigma"]["n"] == 2
    assert summary["validate_max_abs_diff"] <= 1e-4
    # resume through the CLI is a no-op
    rc = main(["--scenario", "steady", "--seeds", "0:2", "--ticks", "1",
               "--out", str(tmp_path / "store"), "-q"])
    assert rc == 0


def test_cli_validate_fails_on_uncomputed_cells(tmp_path, capsys):
    from repro.sweeps.cli import main
    # --max-chunks 0 computes nothing: validation must fail, not pass
    # vacuously on all-NaN values
    rc = main(["--scenario", "steady", "--seeds", "0:2", "--ticks", "1",
               "--no-store", "--max-chunks", "0", "--validate", "-q"])
    assert rc == 1
    capsys.readouterr()
