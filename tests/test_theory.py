"""Property tests for the paper's theory (Theorems 1 and 3)."""
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import (
    PIESInstance,
    opt_np,
    qos_matrix_np,
    sigma_np,
    synthetic_instance,
)


def _sigma_of_set(inst, Q, placements):
    """σ(P) for a set of (edge, model) pairs."""
    x = np.zeros((inst.E, inst.P), dtype=bool)
    for e, p in placements:
        x[e, p] = True
    return sigma_np(inst, x, Q)


def _feasible_ground_set(inst):
    out = []
    for e in range(inst.E):
        for p in range(inst.P):
            if inst.sm_r[p] <= inst.R[e]:
                out.append((e, p))
    return out


@settings(deadline=None, max_examples=40)
@given(st.integers(0, 100_000))
def test_sigma_monotone_increasing(seed):
    """Theorem 3 (part 1): adding a placement never decreases σ."""
    rng = np.random.default_rng(seed)
    inst = synthetic_instance(20, n_edges=3, n_services=6, max_impls=3, seed=seed)
    Q = qos_matrix_np(inst)
    ground = _feasible_ground_set(inst)
    A = [ground[i] for i in rng.choice(len(ground), size=min(6, len(ground)), replace=False)]
    rest = [g for g in ground if g not in A]
    if not rest:
        return
    p = rest[rng.integers(len(rest))]
    assert _sigma_of_set(inst, Q, A + [p]) >= _sigma_of_set(inst, Q, A) - 1e-9


@settings(deadline=None, max_examples=40)
@given(st.integers(0, 100_000))
def test_sigma_submodular(seed):
    """Theorem 3 (part 2): σ(A∪{p}) − σ(A) ≥ σ(B∪{p}) − σ(B) for A ⊆ B."""
    rng = np.random.default_rng(seed)
    inst = synthetic_instance(20, n_edges=3, n_services=6, max_impls=3, seed=seed)
    Q = qos_matrix_np(inst)
    ground = _feasible_ground_set(inst)
    nB = min(8, len(ground))
    B_idx = rng.choice(len(ground), size=nB, replace=False)
    B = [ground[i] for i in B_idx]
    A = [B[i] for i in range(nB) if rng.random() < 0.5]  # A ⊆ B
    rest = [g for g in ground if g not in B]
    if not rest:
        return
    p = rest[rng.integers(len(rest))]
    gain_A = _sigma_of_set(inst, Q, A + [p]) - _sigma_of_set(inst, Q, A)
    gain_B = _sigma_of_set(inst, Q, B + [p]) - _sigma_of_set(inst, Q, B)
    assert gain_A >= gain_B - 1e-9


def _knapsack_dp(values, weights, cap):
    dp = np.zeros(cap + 1)
    for v, w in zip(values, weights):
        if w <= cap:
            dp[w:] = np.maximum(dp[w:], dp[:-w] + v)
    return dp.max()


@settings(deadline=None, max_examples=30)
@given(
    st.lists(st.tuples(st.integers(1, 8), st.integers(1, 10)), min_size=1, max_size=8),
    st.integers(1, 30),
)
def test_knapsack_reduction(items, cap):
    """Theorem 1: the PIES instance built from a 0/1-knapsack instance has
    optimal σ equal to the knapsack optimum (v_i users per item, one edge,
    R = C, relaxed thresholds ⇒ every served user contributes QoS 1)."""
    values = [v for v, _ in items]
    weights = [w for _, w in items]
    n = len(items)
    U = sum(values)
    inst = PIESInstance(
        K=np.array([1e12]), W=np.array([1e12]), R=np.array([float(cap)]),
        sm_service=np.arange(n), sm_acc=np.ones(n),
        sm_k=np.ones(n), sm_w=np.ones(n), sm_r=np.array(weights, float),
        u_edge=np.zeros(U, dtype=int),
        u_service=np.repeat(np.arange(n), values),
        u_alpha=np.zeros(U),                       # α_u = 0 (relaxed)
        u_delta=np.full(U, 10.0), delta_max=10.0,  # δ_u = δ_max (relaxed)
    )
    Q = qos_matrix_np(inst)
    # relaxed thresholds ⇒ every eligible (u, p) pair has QoS exactly 1
    assert np.all(Q[Q > 0] == 1.0)
    x = opt_np(inst, Q)
    np.testing.assert_allclose(
        sigma_np(inst, x, Q), _knapsack_dp(values, weights, cap), atol=1e-9
    )
