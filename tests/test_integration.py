"""Integration tests: training loop end-to-end, checkpoint-resume equality,
grad-accumulation equivalence, compression path, serving e2e (small)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.train import run_training


def test_training_loss_decreases(tmp_path):
    out = run_training(arch="smollm_360m", steps=25, global_batch=8,
                       seq_len=64, verbose=False, seed=3)
    losses = out["losses"]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, \
        f"no learning: {losses[:3]} → {losses[-3:]}"


def test_checkpoint_resume_is_bitwise_identical(tmp_path):
    """Crash/restart: 14 straight steps == 7 steps + restart + 7 steps.
    Requires the seekable pipeline + full-state checkpointing."""
    kw = dict(arch="smollm_360m", global_batch=4, seq_len=32, verbose=False,
              seed=5, lr=1e-3, schedule_steps=14)  # same LR schedule in all runs
    ref = run_training(steps=14, **kw)

    d = tmp_path / "ckpt"
    run_training(steps=7, checkpoint_dir=str(d), ckpt_every=7, **kw)
    resumed = run_training(steps=14, checkpoint_dir=str(d), ckpt_every=7, **kw)
    assert resumed["start_step"] == 7

    ref_leaves = jax.tree_util.tree_leaves(ref["state"].params)
    res_leaves = jax.tree_util.tree_leaves(resumed["state"].params)
    for a, b in zip(ref_leaves, res_leaves):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_grad_accumulation_equivalence():
    """grad_accum=2 must match grad_accum=1 on the same global batch
    (uniform masks ⇒ microbatch-mean average == full-batch mean)."""
    from repro.configs import get_smoke_config
    from repro.data import TokenPipeline
    from repro.training import (AdamWConfig, init_train_state,
                                make_train_step)

    cfg = get_smoke_config("smollm_360m")
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    pipe = TokenPipeline(cfg, global_batch=8, seq_len=32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

    s1 = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    s2 = jax.tree_util.tree_map(lambda x: x, s1)
    step1 = jax.jit(make_train_step(cfg, opt, grad_accum=1))
    step2 = jax.jit(make_train_step(cfg, opt, grad_accum=2))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    # bf16 accumulation-order noise is amplified by Adam's 1/(√v + ε) at
    # step 1 (v ≈ 0): compare with an absolute tolerance of ~lr/100
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-3)


@pytest.mark.parametrize("method", ["topk", "int8"])
def test_training_with_compression_still_learns(method):
    out = run_training(arch="smollm_360m", steps=20, global_batch=8,
                       seq_len=48, compression=method, verbose=False, seed=7)
    losses = out["losses"]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_training_on_host_mesh():
    """Same loop through the sharded path (1-device mesh exercises the
    with_sharding_constraint / shard_map code)."""
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(model=1)
    out = run_training(arch="mixtral_8x7b", steps=6, global_batch=4,
                       seq_len=32, verbose=False, mesh=mesh, seed=1)
    assert np.isfinite(out["losses"]).all()


def test_serving_end_to_end_small():
    from repro.launch.serve import run_serving
    report = run_serving(n_users=12, n_edges=2, max_new_tokens=2,
                         verbose=False, seed=4)
    assert report.served + report.dropped == 12
    assert 0.0 <= report.mean_realized_qos <= 1.0
    assert report.served > 0


def test_training_with_sp_matmuls():
    """Megatron-SP shard_map projection paths (sp_qkv/out/mlp + MoE
    psum_scatter) — numerically sane on a host mesh."""
    from repro.launch.mesh import make_host_mesh
    from repro.models.layers import MeshContext
    from repro.configs import get_smoke_config
    from repro.data import TokenPipeline
    from repro.training import AdamWConfig, init_train_state, make_train_step

    mesh = make_host_mesh(model=1)
    ctx = MeshContext(mesh, ("data",), sp_matmuls=True)
    cfg = get_smoke_config("mixtral_8x7b")
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    pipe = TokenPipeline(cfg, global_batch=2, seq_len=32, seed=0)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt, ctx))
    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))


def test_sp_matches_baseline_forward():
    """SP projections must be numerically identical to the baseline path."""
    from repro.launch.mesh import make_host_mesh
    from repro.models.layers import MeshContext
    from repro.configs import get_smoke_config
    from repro.models import transformer as T

    mesh = make_host_mesh(model=1)
    cfg = get_smoke_config("yi_34b").with_(dtype="float32", remat=False)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)))}
    x_base = T.forward(params, cfg, batch,
                       MeshContext(mesh, ("data",), sp_matmuls=False))
    x_sp = T.forward(params, cfg, batch,
                     MeshContext(mesh, ("data",), sp_matmuls=True))
    np.testing.assert_allclose(np.asarray(x_base), np.asarray(x_sp),
                               atol=1e-5, rtol=1e-5)
