"""Config-zoo invariants: every assigned arch must be production-mesh
compatible (TP=16 padding plans, divisibility, parameter accounting)."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ALIASES, get_config, get_smoke_config

TP = 16


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_padding_plans_at_tp16(arch):
    cfg = get_config(arch, tp_shards=TP)
    # vocab pads to a shard multiple and never shrinks
    assert cfg.vocab_pad % TP == 0 and cfg.vocab_pad >= cfg.vocab_size
    assert cfg.vocab_pad - cfg.vocab_size < TP * 8
    if cfg.d_ff:
        assert cfg.d_ff_pad % TP == 0 and cfg.d_ff_pad >= cfg.d_ff
    if cfg.n_heads:
        p = cfg.gqa
        assert p.n_q_pad % TP == 0 and p.n_kv_pad % TP == 0
        assert p.n_q_pad * p.group >= 0
        # every original query head placed exactly once
        placed = sorted(q for q in p.q_slot_to_q if q >= 0)
        assert placed == list(range(cfg.n_heads))
    if cfg.uses_mamba:
        # SSD heads and conv channels must shard over the model axis
        assert cfg.ssm_heads % TP == 0
        assert (cfg.d_inner + 2 * cfg.ssm_state) % TP == 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_published_shapes_match_assignment(arch):
    """The exact figures from the assignment sheet."""
    expect = {
        "yi_34b": (60, 7168, 56, 8, 20480, 64000),
        "smollm_360m": (32, 960, 15, 5, 2560, 49152),
        "gemma2_27b": (46, 4608, 32, 16, 36864, 256000),
        "command_r_35b": (40, 8192, 64, 8, 22528, 256000),
        "hubert_xlarge": (48, 1280, 16, 16, 5120, 504),
        "zamba2_2p7b": (54, 2560, 32, 32, 10240, 32000),
        "internvl2_1b": (24, 896, 14, 2, 4864, 151655),
        "qwen3_moe_235b": (94, 4096, 64, 4, 1536, 151936),
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
        "mamba2_2p7b": (64, 2560, 0, 0, 0, 50280),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect, f"{arch}: {got} != {expect}"
    # family-specific extras
    if arch == "qwen3_moe_235b":
        assert (cfg.n_experts, cfg.top_k) == (128, 8)
    if arch == "mixtral_8x7b":
        assert (cfg.n_experts, cfg.top_k) == (8, 2)
        assert set(cfg.layer_kinds) == {"swa"}
    if arch == "zamba2_2p7b":
        assert cfg.ssm_state == 64 and cfg.shared_attn_every > 0
    if arch == "mamba2_2p7b":
        assert cfg.ssm_state == 128 and not cfg.uses_attention
    if arch == "gemma2_27b":
        assert cfg.block_pattern == ("swa", "full")
        assert cfg.logit_softcap and cfg.attn_softcap
    if arch == "hubert_xlarge":
        assert cfg.encoder_only and not cfg.causal


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_accounting(arch):
    cfg = get_config(arch)
    n = cfg.n_params
    n_act = cfg.n_active_params
    assert n > 0 and n_act > 0
    if cfg.n_experts:
        assert n_act < n, "MoE active params must be below total"
    else:
        assert n_act == n
    # order-of-magnitude sanity against the arch names
    expect_b = {"yi_34b": 34, "gemma2_27b": 27, "command_r_35b": 35,
                "qwen3_moe_235b": 235, "mixtral_8x7b": 46,
                "mamba2_2p7b": 2.7, "zamba2_2p7b": 2.7,
                "smollm_360m": 0.36, "hubert_xlarge": 0.96,
                "internvl2_1b": 0.65}[arch]
    assert 0.5 * expect_b <= n / 1e9 <= 1.8 * expect_b, \
        f"{arch}: {n/1e9:.2f}B params vs expected ~{expect_b}B"


def test_aliases_cover_assignment_names():
    for name in ["yi-34b", "smollm-360m", "gemma2-27b", "command-r-35b",
                 "hubert-xlarge", "zamba2-2.7b", "internvl2-1b",
                 "qwen3-moe-235b-a22b", "mixtral-8x7b", "mamba2-2.7b"]:
        assert get_config(name).name  # resolvable via alias


def test_smoke_configs_are_small():
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        assert cfg.n_params < 5e6, f"{arch} smoke config too big"
