"""Substrate tests: checkpointing (atomic/reshard), compression, elastic
runtime, data pipeline determinism, serving control plane."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import RequestPipeline, TokenPipeline
from repro.distributed import (ClusterState, ErrorFeedback, StragglerMonitor,
                               elastic_batch_plan, int8_compress,
                               plan_survivor_mesh, recovery_plan,
                               topk_compress)
from repro.serving import Router, default_catalog


# ===========================================================================
# checkpoint
# ===========================================================================

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
            "b": {"w": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16),
                  "step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 100, tree)
    assert latest_step(tmp_path) == 100
    out = restore_checkpoint(tmp_path, 100, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(
            np.asarray(a, dtype=np.float32), np.asarray(b, dtype=np.float32))


def test_checkpoint_keep_k_and_latest(tmp_path):
    tree = _tree()
    for s in (10, 20, 30, 40):
        save_checkpoint(tmp_path, s, tree, keep=2)
    steps = sorted(p.name for p in Path(tmp_path).iterdir())
    assert steps == ["step_000000030", "step_000000040"]
    assert latest_step(tmp_path) == 40


def test_checkpoint_corruption_detected(tmp_path):
    tree = _tree()
    path = save_checkpoint(tmp_path, 5, tree)
    leaf = next(path.glob("leaf_*.npy"))
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF
    leaf.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        restore_checkpoint(tmp_path, 5, tree)


def test_checkpoint_atomicity_partial_write_ignored(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 1, tree)
    # simulate a crash mid-write: tmp dir without manifest
    crashed = Path(tmp_path) / "step_000000002.tmp-dead"
    crashed.mkdir()
    (crashed / "leaf_00000.npy").write_bytes(b"garbage")
    assert latest_step(tmp_path) == 1  # partial write never visible


def test_checkpoint_resharding_restore(tmp_path):
    """Save replicated, restore sharded onto a different mesh layout —
    elastic-scaling restore."""
    devs = jax.devices()
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(tmp_path, 3, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pspecs = {"w": jax.sharding.PartitionSpec("data", None)}
    out = restore_checkpoint(tmp_path, 3, tree, mesh=mesh, pspecs=pspecs)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert isinstance(out["w"].sharding, jax.sharding.NamedSharding)


def test_checkpoint_manager_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, every=5)
    tree = _tree()
    assert not mgr.maybe_save(3, tree)
    assert mgr.maybe_save(5, tree)
    mgr.wait()
    step, restored = mgr.restore_latest(tree)
    assert step == 5 and restored is not None


# ===========================================================================
# gradient compression
# ===========================================================================

def test_topk_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.2, 3.0, 0.0, -0.3])
    kept, resid = topk_compress(g, frac=0.34)
    np.testing.assert_allclose(np.asarray(kept),
                               [0, -5.0, 0, 3.0, 0, 0], atol=1e-7)
    np.testing.assert_allclose(np.asarray(kept + resid), np.asarray(g),
                               atol=1e-7)


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 1000))
def test_int8_unbiased_and_bounded(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(seed), 64)
    deqs = np.stack([np.asarray(int8_compress(g, k)[0]) for k in keys])
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    # stochastic rounding: mean error → 0, per-sample error ≤ 1 quantum
    assert np.abs(deqs.mean(0) - np.asarray(g)).max() < scale
    assert np.abs(deqs - np.asarray(g)[None]).max() <= scale * (1 + 1e-5)


def test_error_feedback_preserves_signal():
    """With EF, the *cumulative* applied update converges to the cumulative
    gradient even under aggressive compression."""
    ef = ErrorFeedback(method="topk", frac=0.25)
    rng = np.random.default_rng(0)
    g_total = np.zeros(64)
    applied_total = np.zeros(64)
    grads = {"w": jnp.zeros(64)}
    carry = ef.init(grads)
    for step in range(50):
        g = rng.normal(size=64).astype(np.float32)
        g_total += g
        out, carry = ef.transform({"w": jnp.asarray(g)}, carry)
        applied_total += np.asarray(out["w"])
    resid = np.asarray(carry["w"])
    np.testing.assert_allclose(applied_total + resid, g_total, atol=1e-3)


# ===========================================================================
# elastic runtime
# ===========================================================================

def test_survivor_mesh_plan():
    st_ = ClusterState(n_hosts=8, devices_per_host=8,
                       failed_hosts=frozenset({3}))
    data, model = plan_survivor_mesh(st_, model_parallel=16)
    assert model == 16 and data == 2  # 56 devices → 3 ⌊→⌋ 2 (pow2)


def test_survivor_mesh_insufficient():
    st_ = ClusterState(n_hosts=2, devices_per_host=4,
                       failed_hosts=frozenset({0, 1}))
    with pytest.raises(RuntimeError):
        plan_survivor_mesh(st_, model_parallel=16)


def test_elastic_batch_plan():
    assert elastic_batch_plan(256, old_data=16, new_data=8) == 2
    assert elastic_batch_plan(256, old_data=16, new_data=16) == 1


def test_straggler_monitor_flags_persistent_only():
    # ema=1.0 ⇒ no smoothing: a single fast step resets the strike count
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, patience=3, ema=1.0)
    fast = [1.0, 1.0, 1.0, 1.0]
    slow = [1.0, 1.0, 1.0, 2.5]
    assert mon.observe(slow) == []
    assert mon.observe(fast) == []          # strike reset
    for _ in range(2):
        assert mon.observe(slow) == []
    assert mon.observe(slow) == [3]          # 3 consecutive strikes

    # smoothed monitor keeps striking through a single fast blip (EMA
    # memory): strikes accumulate 1, 2, 3 → flagged on the third observe
    mon2 = StragglerMonitor(n_hosts=4, threshold=1.5, patience=3, ema=0.5)
    assert mon2.observe(slow) == []
    assert mon2.observe(fast) == []   # EMA still 1.75 > 1.5×median: strike 2
    assert mon2.observe(slow) == [3]  # strike 3 ⇒ flagged


def test_recovery_plan_maps_edges():
    st_ = ClusterState(n_hosts=4, devices_per_host=64,
                       failed_hosts=frozenset({1}))
    plan = recovery_plan(st_, model_parallel=16, global_batch=256,
                         old_data=16, edge_of_host={0: 0, 1: 1, 2: 2, 3: 3})
    assert plan["dead_edges"] == [1]
    assert plan["mesh"][1] == 16


# ===========================================================================
# data pipeline
# ===========================================================================

def test_pipeline_deterministic_and_seekable():
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("smollm_360m")
    pipe = TokenPipeline(cfg, global_batch=8, seq_len=32, seed=1)
    b1 = pipe.batch_at(17)
    b2 = pipe.batch_at(17)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    b3 = pipe.batch_at(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_shard_partition():
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("smollm_360m")
    pipe = TokenPipeline(cfg, global_batch=8, seq_len=16, seed=0)
    b = pipe.batch_at(0)
    parts = [pipe.shard(b, r, 4)["tokens"] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b["tokens"])


# ===========================================================================
# serving control plane
# ===========================================================================

def test_router_end_to_end_and_failure():
    cat = default_catalog()
    inst = cat.to_instance(60, 4, seed=2)
    router = Router("egp")
    x = router.place(inst)
    d = router.route(inst)
    # storage feasibility per edge
    used = (x * inst.sm_r[None]).sum(1)
    assert np.all(used <= inst.R + 1e-9)
    # failure: no placement on dead edge; users re-homed
    inst2, x2 = router.handle_edge_failure(inst, [1])
    assert not x2[1].any()
    assert not np.any(inst2.u_edge == 1)
    d2 = router.route(inst2)
    assert d2.value > 0


def test_router_multi_implementation_routing():
    """Requests with different thresholds land on different implementations
    of the same service — the paper's core multi-implementation behavior."""
    cat = default_catalog()
    inst = cat.to_instance(200, 1, storage_capacity=1000.0, seed=3)
    router = Router("egp")
    router.place(inst)
    d = router.route(inst)
    chat_models = {i for i, m in enumerate(cat.models) if m.service == "chat"}
    used = {int(a) for a in d.assignment if a >= 0} & chat_models
    assert len(used) >= 2, "multiple chat implementations should serve"
