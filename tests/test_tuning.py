"""repro.tuning — lookup-table fit→serialize→recommend round-trip,
JAX-vs-NumPy Pareto dominance parity, closed-loop FeedbackPlacer
properties (clamps, ≥ worst open-loop grid point, byte-identical replay),
and the ``python -m repro.tuning`` CLI."""
import json

import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.serving.horizon import HorizonConfig, run_horizon
from repro.sweeps import SweepSpec, frontier_table, run_sweep
from repro.tuning import (STICKINESS_MAX, STICKINESS_MIN, FeedbackPlacer,
                          fit_table, frontier_points, frontier_rows,
                          load_table, pareto_mask_jax, pareto_mask_np,
                          read_serving_records, recommend, save_table)
from repro.tuning.fit import TABLE_ENV_VAR

#: Shrunk scenario + congested load point (see tests/test_horizon.py).
SMALL = {"n_user_slots": 32, "n_services": 8, "max_impls": 3, "n_edges": 4}
LOAD = {"prompt_tokens": 768, "new_tokens": 64, "max_batch": 4}
#: The open-loop (switching_cost, stickiness) grid the fit/frontier/
#: feedback tests share.
KNOBS = ((0.0, 0.0), (0.0, 3.0), (2.0, 0.0), (2.0, 3.0))


def _grid():
    return tuple(
        tuple(sorted({**SMALL, **LOAD, "switching_cost": sc,
                      "stickiness": st_}.items()))
        for sc, st_ in KNOBS)


def _serving_store(tmp_path, scenarios=("flash_crowd",), seeds=(0, 1),
                   n_ticks=2):
    spec = SweepSpec(kind="serving", scenarios=scenarios, seeds=seeds,
                     n_ticks=n_ticks, algos=("edf", "fcfs"),
                     override_grid=_grid())
    store_dir = tmp_path / "store"
    run_sweep(spec, store_dir=store_dir)
    return store_dir


# ===========================================================================
# fit → serialize → recommend round-trip
# ===========================================================================

def test_fit_roundtrip_and_recommend(tmp_path):
    store = _serving_store(tmp_path, scenarios=("steady", "flash_crowd"))
    table = fit_table(store)
    assert set(table["scenarios"]) == {"steady", "flash_crowd"}

    # the fitted knobs are the mean-realized-QoS argmax over the stored
    # edf grid (recomputed here independently, CI tie-break aside)
    recs = [r for r in read_serving_records(store)
            if r.scenario == "flash_crowd" and r.policy == "edf"]
    cells = {}
    for r in recs:
        cells.setdefault((r.switching_cost, r.stickiness),
                         []).append(r.value)
    means = {k: np.mean(v) for k, v in cells.items()}
    row = table["scenarios"]["flash_crowd"]
    assert row["policy"] == "edf" and row["grid_points"] == len(KNOBS)
    assert means[(row["switching_cost"], row["stickiness"])] == \
        pytest.approx(row["mean_qos"], abs=1e-5)
    assert row["mean_qos"] >= max(means.values()) - row["ci95"] - 1e-9

    # serialize → load → recommend round-trips exactly
    path = save_table(table, tmp_path / "table.json")
    loaded = load_table(path)
    assert loaded["scenarios"] == json.loads(
        json.dumps(table["scenarios"]))  # same content through JSON
    rec = recommend("flash_crowd", path=path)
    assert rec == {"switching_cost": row["switching_cost"],
                   "stickiness": row["stickiness"]}
    assert recommend("not_a_scenario", path=path) is None
    assert recommend("steady", path=tmp_path / "missing.json") is None


def test_fit_rejects_stores_without_serving_items(tmp_path):
    sigma = SweepSpec(scenarios=("steady",), seeds=(0,), n_ticks=1,
                      algos=("egp",), force_host=("egp",))
    d = tmp_path / "sigma_store"
    run_sweep(sigma, store_dir=d)
    with pytest.raises(ValueError, match="serving"):
        fit_table(d)


def test_from_overrides_consults_table_for_unset_knobs(
        tmp_path, monkeypatch):
    table = {"table_version": 1, "sweep_schema_version": 3,
             "source": "test",
             "scenarios": {"steady": {
                 "switching_cost": 0.25, "stickiness": 7.5,
                 "policy": "edf", "mean_qos": 0.9, "ci95": 0.0,
                 "n": 4, "grid_points": 4}}}
    path = save_table(table, tmp_path / "t.json")
    monkeypatch.setenv(TABLE_ENV_VAR, str(path))

    # both knobs unset → both fitted values
    cfg = HorizonConfig.from_overrides("steady", {}, "edf", seed=0)
    assert cfg.switching_cost == 0.25 and cfg.stickiness == 7.5
    # explicit override wins per knob; the other is still table-filled
    cfg = HorizonConfig.from_overrides("steady", {"stickiness": 1.0},
                                       "edf", seed=0)
    assert cfg.switching_cost == 0.25 and cfg.stickiness == 1.0
    # scenario without a row → dataclass defaults
    cfg = HorizonConfig.from_overrides("diurnal", {}, "edf", seed=0)
    assert cfg.switching_cost == HorizonConfig.switching_cost
    assert cfg.stickiness == HorizonConfig.stickiness
    # direct construction never consults the table
    assert HorizonConfig(scenario="steady").switching_cost == \
        HorizonConfig.switching_cost


def test_serving_expansion_bakes_table_knobs(tmp_path, monkeypatch):
    """A serving item's value depends on the knobs the table resolves for
    unset keys, so expansion must bake them into the item overrides: keys
    and stored meta capture the actual operating point, and a table
    refresh changes the keys (resume recomputes, never silently mixes)."""
    table = {"table_version": 1, "sweep_schema_version": 3,
             "source": "test",
             "scenarios": {"steady": {
                 "switching_cost": 0.25, "stickiness": 7.5,
                 "policy": "edf", "mean_qos": 0.9, "ci95": 0.0,
                 "n": 4, "grid_points": 4}}}
    path = save_table(table, tmp_path / "t.json")
    monkeypatch.setenv(TABLE_ENV_VAR, str(path))

    def spec():
        return SweepSpec(kind="serving", scenarios=("steady",),
                         seeds=(0,), n_ticks=1, algos=("edf",))

    item = spec().expand()[0]
    ov = dict(item.overrides)
    assert ov["switching_cost"] == 0.25 and ov["stickiness"] == 7.5
    # refreshing the table re-keys the items
    table["scenarios"]["steady"]["stickiness"] = 1.5
    save_table(table, path)
    item2 = spec().expand()[0]
    assert dict(item2.overrides)["stickiness"] == 1.5
    assert item2.key() != item.key()
    # explicitly pinned knobs never consult the table
    pinned = SweepSpec(kind="serving", scenarios=("steady",), seeds=(0,),
                       n_ticks=1, algos=("edf",),
                       override_grid=((("stickiness", 2.0),
                                       ("switching_cost", 1.0)),))
    assert dict(pinned.expand()[0].overrides) == \
        {"stickiness": 2.0, "switching_cost": 1.0}


# ===========================================================================
# Pareto dominance: NumPy reference + JAX parity
# ===========================================================================

def test_pareto_mask_np_reference_cases():
    # maximize both: (2,2) dominates (1,1); duplicates both survive
    pts = np.array([[1.0, 1.0], [2.0, 2.0], [2.0, 2.0], [0.5, 3.0]])
    keep = pareto_mask_np(pts, maximize=(True, True))
    assert keep.tolist() == [False, True, True, True]
    # orientation flip: minimize the second metric — (1, 0.4) trades
    # metric-1 for the lowest cost, (2, 0.5) the reverse, (2, 2) loses
    pts = np.array([[1.0, 0.4], [2.0, 2.0], [2.0, 0.5]])
    keep = pareto_mask_np(pts, maximize=(True, False))
    assert keep.tolist() == [True, False, True]
    # equal-on-one-axis: strictly better on the other still dominates
    pts = np.array([[1.0, 5.0], [1.0, 7.0]])
    assert pareto_mask_np(pts, maximize=(True, True)).tolist() == \
        [False, True]
    assert pareto_mask_np(np.zeros((0, 2)), maximize=(True, True)).size == 0
    with pytest.raises(ValueError):
        pareto_mask_np(np.zeros((3, 2)), maximize=(True,))
    with pytest.raises(ValueError):
        pareto_mask_np(np.zeros(3), maximize=(True,))


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("shape", [(1, 2), (17, 2), (64, 3), (128, 4)])
def test_pareto_jax_matches_numpy_on_random_grids(seed, dtype, shape):
    rng = np.random.default_rng(seed)
    # float32 runs on the device default; float64 inputs must be compared
    # in float64 (scoped x64) — either way the masks agree bit-for-bit
    # (comparisons only, nothing accumulates)
    pts = rng.normal(size=shape).astype(dtype)
    # quantize to force plenty of exact ties/duplicates across points
    pts = np.round(pts, 1)
    maximize = [bool(b) for b in rng.integers(0, 2, size=shape[1])]
    np.testing.assert_array_equal(pareto_mask_np(pts, maximize),
                                  pareto_mask_jax(pts, maximize))


def test_pareto_jax_keeps_sub_f32_resolution():
    # two points differing below float32 resolution: a silent f32 cast
    # would merge them and keep both; float64 must dominate-out the lower
    pts = np.array([[0.5, 1.0], [0.5 + 1e-12, 1.0]])
    np.testing.assert_array_equal(
        pareto_mask_jax(pts, (True, False)), [False, True])


def test_frontier_points_from_store(tmp_path):
    store = _serving_store(tmp_path)
    frontiers = frontier_points(store)
    assert set(frontiers) == {"flash_crowd"}
    pts = frontiers["flash_crowd"]
    # one point per (knob grid point × policy), metrics well-formed
    assert len(pts) == len(KNOBS) * 2
    assert all(0.0 <= p.mean_qos <= 1.0 and 0.0 <= p.miss_rate <= 1.0
               for p in pts)
    # at least one point on each frontier, and flagged sets really are
    # non-dominated under the reference mask
    assert any(p.qos_frontier for p in pts)
    assert any(p.acc_lat_frontier for p in pts)
    keep = pareto_mask_np(
        np.array([[p.mean_qos, p.miss_rate] for p in pts]),
        maximize=(True, False))
    assert [bool(k) for k in keep] == [p.qos_frontier for p in pts]
    # fig-style rendering includes every operating point
    text = frontier_table(frontier_rows(frontiers))
    assert text.count("flash_crowd") == len(pts)


def test_pareto_from_store_matches_replay_with_zero_replays(
        tmp_path, monkeypatch):
    """Schema-v3 round trip: serving sweeps persist per-item
    submitted/served/misses/latency/accuracy, so frontier extraction is a
    pure store read — zero horizon replays — and reproduces exactly what
    the legacy replay path computes."""
    import repro.tuning.pareto as pareto_mod

    store = _serving_store(tmp_path, scenarios=("steady", "flash_crowd"))

    # 1. pure store read: any replay is a failure
    def boom(*a, **kw):
        raise AssertionError("schema-v3 store must not replay horizons")
    monkeypatch.setattr(pareto_mod, "_replay_metrics", boom)
    from_store = pareto_mod.frontier_points(store)

    # 2. forced legacy path: pretend the store holds no metrics
    monkeypatch.undo()
    monkeypatch.setattr(pareto_mod, "_store_metrics",
                        lambda *a, **kw: None)
    from_replay = pareto_mod.frontier_points(store)

    assert set(from_store) == set(from_replay) == {"steady", "flash_crowd"}
    for scenario in from_store:
        assert len(from_store[scenario]) == len(KNOBS) * 2
        for a, b in zip(from_store[scenario], from_replay[scenario]):
            assert (a.scenario, a.switching_cost, a.stickiness, a.policy,
                    a.n_seeds) == (b.scenario, b.switching_cost,
                                   b.stickiness, b.policy, b.n_seeds)
            for f in ("mean_qos", "miss_rate", "mean_latency_s",
                      "mean_accuracy"):
                x, y = getattr(a, f), getattr(b, f)
                assert (np.isnan(x) and np.isnan(y)) or \
                    x == pytest.approx(y, rel=1e-9, abs=1e-12), (scenario, f)
            # the frontier memberships agree, so downstream decisions do
            assert a.qos_frontier == b.qos_frontier
            assert a.acc_lat_frontier == b.acc_lat_frontier


def test_store_metrics_roundtrip_per_item(tmp_path):
    """What the serving path persists per item is exactly the TickReport
    of that (seed, tick) — checked against a direct horizon run."""
    from repro.sweeps import SweepStore
    from repro.tuning.fit import read_serving_records

    store_dir = _serving_store(tmp_path)
    store = SweepStore(store_dir)
    recs = [r for r in read_serving_records(store)
            if r.policy == "edf" and r.switching_cost == 0.0
            and r.stickiness == 0.0 and r.seed == 0]
    assert len(recs) == 2  # the two ticks of seed 0's horizon
    cfg = HorizonConfig.from_overrides(
        "flash_crowd", dict(recs[0].overrides), "edf", 0, n_ticks=2)
    res = run_horizon(cfg)
    by_value = {round(r.value, 12): r for r in recs}
    for t in res.per_tick:
        r = by_value[round(t.mean_realized_qos, 12)]
        m = store.metrics(r.key)
        assert m["submitted"] == t.submitted and m["served"] == t.served
        assert m["misses"] == t.deadline_misses
        assert m["latency"] == pytest.approx(t.mean_latency_s, nan_ok=True)
        assert m["accuracy"] == pytest.approx(t.mean_accuracy, nan_ok=True)


def test_frontier_never_stars_nan_points(tmp_path, monkeypatch):
    """A grid point that served nothing (NaN accuracy/latency) is not an
    operating point: all-False NaN comparisons would make it undominatable
    — it must never be flagged as frontier-optimal."""
    import repro.tuning.pareto as pareto_mod

    store = _serving_store(tmp_path)
    real = pareto_mod._replay_metrics

    def nan_for_free_knobs(scenario, overrides, policy, seeds, n_ticks):
        m = real(scenario, overrides, policy, seeds, n_ticks)
        ov = dict(overrides)
        if ov["switching_cost"] == 0.0 and ov["stickiness"] == 0.0:
            m = {**m, "mean_accuracy": float("nan"),
                 "mean_latency_s": float("nan")}
        return m

    # route through the replay path (the v3 store path would be a pure
    # read) so the injected NaN metrics take effect
    monkeypatch.setattr(pareto_mod, "_store_metrics",
                        lambda *a, **kw: None)
    monkeypatch.setattr(pareto_mod, "_replay_metrics", nan_for_free_knobs)
    pts = pareto_mod.frontier_points(store)["flash_crowd"]
    nan_pts = [p for p in pts if np.isnan(p.mean_latency_s)]
    assert nan_pts and not any(p.acc_lat_frontier for p in nan_pts)
    assert any(p.acc_lat_frontier for p in pts)
    # NaN rows render (sorted last), no crash
    text = frontier_table(frontier_rows({"flash_crowd": pts}))
    assert text.splitlines()[-1].count("nan") >= 1


# ===========================================================================
# FeedbackPlacer — closed-loop properties
# ===========================================================================

def _cfg(**kw):
    base = dict(scenario="flash_crowd", overrides=tuple(SMALL.items()),
                policy="edf", seed=0, n_ticks=6, **LOAD)
    base.update(kw)
    return HorizonConfig(**base)


@settings(max_examples=25, deadline=None)
@given(obs=st.lists(st.tuples(st.floats(0.0, 1.0), st.floats(0.0, 1.0),
                              st.integers(0, 50)),
                    min_size=1, max_size=40),
       gain=st.floats(1.05, 4.0),
       s0=st.floats(-5.0, 20.0))
def test_feedback_stickiness_always_within_clamps(obs, gain, s0):
    fp = FeedbackPlacer(stickiness=s0, gain=gain)
    assert STICKINESS_MIN <= fp.current_stickiness <= STICKINESS_MAX
    for qos, miss, n in obs:
        s = fp.observe(qos, miss, n)
        assert STICKINESS_MIN <= s <= STICKINESS_MAX


def test_feedback_controller_direction():
    fp = FeedbackPlacer(stickiness=2.0, gain=2.0, target_miss=0.1)
    # sustained misses → multiplicative increase
    s = [fp.observe(0.8, 0.9, 10) for _ in range(3)][-1]
    assert s > 2.0
    # no-completion ticks carry no signal
    assert fp.observe(0.0, 0.0, 0) == s
    # misses under target + declining QoS → decrease
    fp2 = FeedbackPlacer(stickiness=4.0, gain=2.0, target_miss=0.5)
    fp2.observe(0.95, 0.0, 10)          # establishes the baseline
    for _ in range(4):
        fp2.observe(0.05, 0.0, 10)      # QoS collapses, misses fine
    assert fp2.current_stickiness < 4.0
    with pytest.raises(ValueError):
        FeedbackPlacer(gain=1.0)
    with pytest.raises(ValueError):
        FeedbackPlacer(ewma=0.0)


def test_feedback_horizon_clamped_and_byte_identical():
    res = run_horizon(_cfg(policy="feedback"))
    assert all(STICKINESS_MIN <= t.stickiness <= STICKINESS_MAX
               for t in res.per_tick)
    # the controller starts from the configured stickiness
    assert res.per_tick[0].stickiness == res.config.stickiness
    again = run_horizon(_cfg(policy="feedback"))
    fa = np.array([r.finish for r in res.requests])
    fb = np.array([r.finish for r in again.requests])
    assert fa.tobytes() == fb.tobytes()
    assert res.tick_values().tobytes() == again.tick_values().tobytes()


def test_feedback_beats_worst_open_loop_grid_point():
    """Closed-loop regression bound: on a fixed seed the feedback policy's
    mean realized QoS must be at least the *worst* fixed-(switching_cost,
    stickiness) grid point — adapting online must not be worse than the
    worst hand-picked setting it adapts between."""
    open_loop = [
        run_horizon(_cfg(switching_cost=sc, stickiness=st_))
        .mean_realized_qos
        for sc, st_ in KNOBS]
    fb = run_horizon(_cfg(policy="feedback")).mean_realized_qos
    assert fb >= min(open_loop) - 1e-9


def test_feedback_is_a_sweepable_policy(tmp_path):
    spec = SweepSpec(kind="serving", scenarios=("flash_crowd",),
                     seeds=(0,), n_ticks=2, algos=("edf", "feedback"),
                     override_grid=(tuple(sorted({**SMALL, **LOAD}.items())),))
    assert spec.executor_of("feedback") == "serving"
    res = run_sweep(spec, store_dir=tmp_path / "store")
    assert res.complete
    key = [k for k in res.values if k[1] == "feedback"]
    assert key and np.isfinite(res.values[key[0]]).all()
    # resumed values replay bitwise (the sweeps resume contract holds for
    # the closed-loop policy too)
    again = run_sweep(spec, store_dir=tmp_path / "store")
    assert again.execution["chunks_computed"] == 0
    np.testing.assert_array_equal(res.values[key[0]],
                                  again.values[key[0]])


# ===========================================================================
# CLI
# ===========================================================================

def test_tuning_cli_fit_pareto_show(tmp_path, capsys):
    from repro.tuning.cli import main

    store = _serving_store(tmp_path)
    assert main(["fit", "--store", str(store)]) == 0
    out = capsys.readouterr().out
    assert "flash_crowd" in out
    table_path = store / "tuning_table.json"
    assert table_path.exists()
    table = json.loads(table_path.read_text())
    assert set(table["scenarios"]) == {"flash_crowd"}

    rows_json = tmp_path / "frontier.json"
    assert main(["pareto", "--store", str(store),
                 "--json", str(rows_json)]) == 0
    out = capsys.readouterr().out
    assert "QF" in out and "flash_crowd" in out
    rows = json.loads(rows_json.read_text())
    assert len(rows["flash_crowd"]) == len(KNOBS) * 2

    assert main(["show", "--table", str(table_path)]) == 0
    assert "flash_crowd" in capsys.readouterr().out
    assert main(["show", "--table", str(tmp_path / "nope.json")]) == 1
    capsys.readouterr()
