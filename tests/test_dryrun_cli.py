"""The multi-pod dry-run CLI, end to end (subprocess: it must own jax init
so XLA_FLAGS can force 512 host devices)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


@pytest.mark.parametrize("arch,shape", [("smollm_360m", "prefill_32k")])
def test_dryrun_cli_single_cell(arch, shape, tmp_path):
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/tmp"}
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--single-pod"],
        capture_output=True, text=True, cwd=ROOT, env=env, timeout=560)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[ok]" in proc.stdout

    rec = json.loads(
        (ROOT / "experiments" / "dryrun" /
         f"{arch}__{shape}__pod16x16.json").read_text())
    assert rec["status"] == "ok"
    assert rec["devices"] == 256
    assert rec["corrected"]["flops"] > 0
    assert rec["memory"]["per_device_hbm_bytes"] > 0


def test_dryrun_skip_cell_reported():
    from repro.launch.shapes import cell_plan
    ok, why = cell_plan("hubert_xlarge", "decode_32k")
    assert not ok and "encoder-only" in why
    ok, why = cell_plan("yi_34b", "long_500k")
    assert not ok
    ok, _ = cell_plan("mamba2_2p7b", "long_500k")
    assert ok


def test_input_specs_shapes():
    """input_specs returns allocation-free ShapeDtypeStructs per cell."""
    import jax
    from repro.launch.specs import input_specs

    spec = input_specs("yi_34b", "train_4k")
    assert spec["batch"]["tokens"].shape == (256, 4096)
    assert all(isinstance(v, jax.ShapeDtypeStruct)
               for v in spec["batch"].values())

    spec = input_specs("qwen3_moe_235b", "decode_32k")
    assert spec["token"].shape == (128,)
    assert spec["cache"].kv_k.shape[2] == 32768

    spec = input_specs("mixtral_8x7b", "long_500k")
    assert spec["ring"]  # SWA ⇒ ring buffer bounded at the window
    assert spec["cache"].kv_k.shape[2] == 4096

    spec = input_specs("internvl2_1b", "prefill_32k")
    assert spec["batch"]["patches"].shape[1] == 1024
    assert spec["batch"]["tokens"].shape[1] == 32768 - 1024
