"""Top-k sparse candidate sets + lock-step sparse EGP: exactness vs the
dense float64 host path, np/jnp agreement, and the k < M lower bound."""
import numpy as np
import pytest

from repro.core import (
    egp_np,
    impl_table_np,
    max_impls_of,
    qos_matrix_np,
    sigma_np,
    sigma_sparse_np,
    synthetic_instance,
    topk_candidates_jnp,
    topk_candidates_np,
)
from repro.sweeps.shard import HOST_PARITY_ATOL
from repro.workloads import evaluate_host, evaluate_sparse, horizon


# ===========================================================================
# impl table / candidate selection
# ===========================================================================

def test_impl_table_lists_every_implementation_once():
    inst = synthetic_instance(200, seed=0)
    table = impl_table_np(inst.sm_service, inst.S)
    assert table.shape == (inst.S, max_impls_of(inst))
    listed = table[table >= 0]
    # every model appears exactly once, under its own service's row
    assert sorted(listed.tolist()) == list(range(inst.P))
    rows = np.repeat(np.arange(inst.S), table.shape[1])[table.ravel() >= 0]
    np.testing.assert_array_equal(inst.sm_service[listed], rows)


@pytest.mark.parametrize("k", [None, 1, 3])
def test_topk_np_matches_jnp(k):
    inst = synthetic_instance(300, seed=2)
    cand = topk_candidates_np(inst, k)
    table = impl_table_np(inst.sm_service, inst.S)
    ji, jt = inst.as_jax(), np.asarray(table)
    idx, q = topk_candidates_jnp(ji, jt, k)
    # same candidate set per user (k = M keeps table order, np sorts by
    # QoS — order is irrelevant to the sparse greedy), same QoS values
    np.testing.assert_array_equal(np.sort(np.asarray(idx, np.int64), axis=1),
                                  np.sort(cand.cand_idx, axis=1))
    np.testing.assert_allclose(np.sort(np.asarray(q, np.float64), axis=1),
                               np.sort(cand.cand_q, axis=1), atol=1e-5)
    assert cand.exact == (k is None or k >= max_impls_of(inst))


def test_candidates_cover_exactly_the_eligible_models():
    inst = synthetic_instance(150, seed=4)
    cand = topk_candidates_np(inst)  # k = M → exact
    Q = qos_matrix_np(inst)
    for u in range(inst.U):
        eligible = set(np.flatnonzero(inst.sm_service == inst.u_service[u]))
        got = set(cand.cand_idx[u][cand.cand_idx[u] >= 0].tolist())
        assert got == eligible
        for c, p in enumerate(cand.cand_idx[u]):
            if p >= 0:
                assert cand.cand_q[u, c] == pytest.approx(Q[u, p])


# ===========================================================================
# sparse EGP == dense host path (exactness at k = M)
# ===========================================================================

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sparse_egp_matches_host_sigma(seed):
    inst = synthetic_instance(400, n_edges=5, seed=seed)
    vals, xs = evaluate_sparse([inst])
    host = evaluate_host([inst])
    np.testing.assert_allclose(np.asarray(vals), host,
                               atol=HOST_PARITY_ATOL)
    # and σ recomputed on the sparse placement agrees (not an equal-value
    # different-placement fluke)
    x = np.asarray(xs[0])[:inst.E, :inst.P]
    np.testing.assert_allclose(float(vals[0]), sigma_np(inst, x),
                               atol=HOST_PARITY_ATOL)
    used = (x * inst.sm_r[None, :]).sum(axis=1)
    assert np.all(used <= inst.R + 1e-5)


def test_sparse_egp_matches_host_on_scenario_mix():
    instances = []
    for name in ("steady", "flash_crowd", "mobility_churn"):
        instances += horizon(name, seed=0, n_ticks=2)
    vals, _ = evaluate_sparse(instances)
    host = evaluate_host(instances)
    np.testing.assert_allclose(np.asarray(vals), host,
                               atol=HOST_PARITY_ATOL)


def test_sparse_kernel_path_matches_ref_path():
    inst = synthetic_instance(200, seed=7)
    v_ref, x_ref = evaluate_sparse([inst], use_kernel=False)
    v_k, x_k = evaluate_sparse([inst], use_kernel=True)
    np.testing.assert_allclose(np.asarray(v_ref), np.asarray(v_k),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(x_ref[0]), np.asarray(x_k[0]))


def test_evaluate_sparse_rejects_non_egp():
    inst = synthetic_instance(50, seed=0)
    with pytest.raises(ValueError, match="egp"):
        evaluate_sparse([inst], algo="agp")


# ===========================================================================
# σ over candidate pairs
# ===========================================================================

def test_sigma_sparse_np_matches_sigma_np_on_dense_placement():
    inst = synthetic_instance(250, seed=3)
    Q = qos_matrix_np(inst)
    x = egp_np(inst, Q)
    cand = topk_candidates_np(inst)  # exact
    assert sigma_sparse_np(inst, x, cand) == pytest.approx(sigma_np(inst, x))


def test_k_below_max_impls_is_valid_and_k_max_is_exact():
    """k < M restricts the greedy's candidate pool: the result is still a
    feasible placement with positive σ (greedy is a heuristic, so a
    *smaller* pool can land either side of the full-pool greedy — no
    ordering is asserted); k = M reproduces the dense host path exactly."""
    inst = synthetic_instance(300, seed=5)
    exact = float(evaluate_host([inst])[0])
    for k in (1, 2):
        v, xs = evaluate_sparse([inst], k=k)
        assert 0.0 < float(v[0]) <= inst.U  # σ is a sum of QoS ∈ [0, 1]
        x = np.asarray(xs[0])
        used = (x * inst.sm_r[None, :]).sum(axis=1)
        assert np.all(used <= inst.R + 1e-5)  # storage respected
    vM = float(np.asarray(evaluate_sparse([inst])[0])[0])
    assert vM == pytest.approx(exact, abs=HOST_PARITY_ATOL)  # k=M exact
