"""Placement algorithm tests: feasibility, exactness, approximation, JAX parity."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    agp_literal_np,
    agp_np,
    agp_place_jax,
    brute_force_np,
    egp_np,
    egp_place_jax,
    eligibility_jnp,
    opt_np,
    place_and_schedule,
    qos_matrix_jnp,
    qos_matrix_np,
    rnd_np,
    sck_np,
    sigma_np,
    synthetic_instance,
    tiny_instance,
)

ALGOS = ["egp", "agp", "sck"]


def _check_storage_feasible(inst, x):
    """Constraint (7b)."""
    used = (x * inst.sm_r[None, :]).sum(axis=1)
    assert np.all(used <= inst.R + 1e-9), (used, inst.R)


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 10_000), st.sampled_from(ALGOS + ["rnd", "opt"]))
def test_all_algorithms_storage_feasible(seed, algo):
    inst = synthetic_instance(50, n_edges=4, n_services=15, seed=seed)
    x, y, _ = place_and_schedule(inst, algo, seed=seed)
    _check_storage_feasible(inst, x)
    # constraint (7a)+(7c): schedule respects placement & service match
    for u in range(inst.U):
        if y[u] >= 0:
            assert x[inst.u_edge[u], y[u]]
            assert inst.sm_service[y[u]] == inst.u_service[u]


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 10_000))
def test_opt_matches_brute_force(seed):
    inst = tiny_instance(seed=seed, n_users=10, n_edges=2, n_services=4,
                         max_impls=3)
    Q = qos_matrix_np(inst)
    _, v_bf = brute_force_np(inst, Q)
    v_dp = sigma_np(inst, opt_np(inst, Q), Q)
    np.testing.assert_allclose(v_dp, v_bf, atol=1e-9)


@settings(deadline=None, max_examples=15)
@given(st.integers(0, 10_000))
def test_greedy_beats_submodular_bound(seed):
    """AGP (monotone-submodular greedy under the partition matroid) must
    achieve ≥ (1 − 1/e)·OPT; EGP matches it empirically (paper Fig. 3)."""
    inst = synthetic_instance(30, n_edges=3, n_services=8, seed=seed)
    Q = qos_matrix_np(inst)
    v_opt = sigma_np(inst, opt_np(inst, Q), Q)
    if v_opt < 1e-9:
        return
    bound = (1.0 - 1.0 / np.e) * v_opt
    assert sigma_np(inst, agp_np(inst, Q), Q) >= bound - 1e-9
    assert sigma_np(inst, egp_np(inst, Q), Q) >= bound - 1e-9


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 10_000))
def test_agp_literal_equals_fast_agp_value(seed):
    """The closed-form marginal is exactly σ(P∪{p}) − σ(P): both variants
    make identical picks modulo ties, hence identical objective values."""
    inst = synthetic_instance(16, n_edges=2, n_services=5, max_impls=3,
                              seed=seed)
    Q = qos_matrix_np(inst)
    v_fast = sigma_np(inst, agp_np(inst, Q), Q)
    v_lit = sigma_np(inst, agp_literal_np(inst, Q), Q)
    np.testing.assert_allclose(v_fast, v_lit, atol=1e-9)


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 10_000))
def test_jax_placements_match_numpy(seed):
    import jax.numpy as jnp

    inst = synthetic_instance(40, n_edges=3, n_services=10, seed=seed)
    Q = qos_matrix_np(inst)
    ji = inst.as_jax()
    Qj, elig = qos_matrix_jnp(ji), eligibility_jnp(ji)

    x_agp = np.asarray(agp_place_jax(Qj, elig, ji.u_edge, ji.sm_r, ji.R))
    x_egp = np.asarray(egp_place_jax(Qj, elig, ji.u_edge, ji.u_service,
                                     ji.sm_service, ji.sm_r, ji.R,
                                     n_services=inst.S))
    np.testing.assert_allclose(
        sigma_np(inst, x_agp, Q), sigma_np(inst, agp_np(inst, Q), Q), rtol=1e-5)
    np.testing.assert_allclose(
        sigma_np(inst, x_egp, Q), sigma_np(inst, egp_np(inst, Q), Q), rtol=1e-5)
    _check_storage_feasible(inst, x_agp)
    _check_storage_feasible(inst, x_egp)


def test_jax_placements_jit_compile():
    import jax, jax.numpy as jnp

    inst = synthetic_instance(64, n_edges=4, seed=0)
    ji = inst.as_jax()
    Qj, elig = qos_matrix_jnp(ji), eligibility_jnp(ji)
    f = jax.jit(lambda q, e: agp_place_jax(q, e, ji.u_edge, ji.sm_r, ji.R))
    x1 = f(Qj, elig)
    x2 = f(Qj, elig)
    assert np.array_equal(np.asarray(x1), np.asarray(x2))


def test_egp_uses_multiple_implementations_when_beneficial():
    """Multi-implementation is the paper's point: a strict-accuracy user and
    a tight-delay user of the same service should get *different* models."""
    inst = synthetic_instance(2, n_edges=1, n_services=1, max_impls=1, seed=0)
    # Overwrite: one service, two implementations — accurate-slow, fast-crude.
    inst.sm_service = np.array([0, 0])
    inst.sm_acc = np.array([0.99, 0.50])
    inst.sm_k = np.array([1.0, 1.0])
    inst.sm_w = np.array([400.0, 1.0])
    inst.sm_r = np.array([5.0, 5.0])
    inst.K = np.array([1000.0]); inst.W = np.array([100.0])
    inst.R = np.array([10.0])  # room for both
    inst.u_edge = np.array([0, 0]); inst.u_service = np.array([0, 0])
    inst.u_alpha = np.array([0.99, 0.1])   # user 0 wants accuracy
    inst.u_delta = np.array([10.0, 0.5])   # user 1 wants speed
    Q = qos_matrix_np(inst)
    x = egp_np(inst, Q)
    assert x[0, 0] and x[0, 1], "both implementations should be placed"
    from repro.core import oms_np
    y, _ = oms_np(inst, x, Q)
    assert y[0] == 0 and y[1] == 1, "users routed to different implementations"


def test_rnd_deterministic_given_seed():
    inst = synthetic_instance(30, seed=2)
    x1, y1 = rnd_np(inst, seed=11)
    x2, y2 = rnd_np(inst, seed=11)
    assert np.array_equal(x1, x2) and np.array_equal(y1, y2)
