"""Gradient compression for cross-pod links (distributed-optimization tricks).

The pod axis of the production mesh crosses DCN (slow inter-pod links);
gradient all-reduce over it is the one collective that cannot be hidden at
2+ pods. Two standard compressors, both stateless-in-jit with an explicit
error-feedback carry (EF-SGD style — the compression residual is added back
next step, preserving convergence):

* **top-k sparsification** — keep the k largest-|g| entries per leaf;
* **int8 quantization** — per-leaf symmetric scale with stochastic
  rounding (unbiased).

Use via ``make_train_step(..., grad_transform=compressor.transform)`` or
wrap collectives directly with :func:`compressed_psum` inside shard_map.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["topk_compress", "int8_compress", "ErrorFeedback",
           "compressed_psum"]


def topk_compress(g, frac: float = 0.01):
    """Zero all but the top ``frac`` fraction of entries by magnitude.
    Returns (compressed, residual)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    kept = jnp.where(mask, flat, 0.0).reshape(g.shape)
    return kept, g - kept


def int8_compress(g, key):
    """Symmetric int8 quantization with stochastic rounding (unbiased).
    Returns (dequantized, residual)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    x = g / scale
    lo = jnp.floor(x)
    p = x - lo
    rnd = jax.random.uniform(key, g.shape)
    q = jnp.clip(lo + (rnd < p), -127, 127).astype(jnp.int8)
    deq = q.astype(g.dtype) * scale
    return deq, g - deq


@dataclasses.dataclass
class ErrorFeedback:
    """EF compressor: carry = what compression dropped last step."""

    method: str = "topk"        # topk | int8
    frac: float = 0.01
    seed: int = 0

    def init(self, grads):
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def transform(self, grads, carry):
        """Returns (compressed_grads, new_carry)."""
        key = jax.random.PRNGKey(self.seed)
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        carry_leaves = jax.tree_util.tree_leaves(carry)
        outs, news = [], []
        for i, (g, c) in enumerate(zip(leaves, carry_leaves)):
            corrected = g.astype(jnp.float32) + c
            if self.method == "topk":
                kept, resid = topk_compress(corrected, self.frac)
            elif self.method == "int8":
                kept, resid = int8_compress(
                    corrected, jax.random.fold_in(key, i))
            else:
                raise ValueError(self.method)
            outs.append(kept.astype(g.dtype))
            news.append(resid)
        return (jax.tree_util.tree_unflatten(treedef, outs),
                jax.tree_util.tree_unflatten(treedef, news))


def compressed_psum(x, axis_name: str, key, *, method: str = "int8"):
    """psum with pre-compression — for explicit shard_map cross-pod
    reductions. Unbiased (stochastic rounding) so EF is optional here."""
    if method == "int8":
        compressed, _ = int8_compress(x, key)
    elif method == "none":
        compressed = x
    else:
        raise ValueError(method)
    return jax.lax.psum(compressed, axis_name)
