from .compression import (topk_compress, int8_compress, ErrorFeedback,
                          compressed_psum)
from .elastic import (ClusterState, StragglerMonitor, plan_survivor_mesh,
                      elastic_batch_plan, recovery_plan)
