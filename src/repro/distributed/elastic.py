"""Elastic runtime: failure handling, straggler mitigation, re-meshing.

The control flow a 1000+-node deployment needs, exercised here on
simulated topologies (the same code paths run with real
``jax.distributed`` process sets on hardware):

* **failure → survivor mesh** — given dead hosts, build the largest valid
  (data × model) mesh from survivors (model axis preserved — TP groups are
  intra-host-group; DP shrinks), restore the latest checkpoint *resharded*
  onto it, and re-run PIES placement with the dead edge groups removed
  (the paper's own optimizer is the service-level recovery mechanism).
* **straggler mitigation** — per-step time EMA; hosts slower than
  ``threshold ×`` median for ``patience`` consecutive steps are flagged
  and either swapped with hot spares or evicted (shrinking DP), since a
  single straggler gates every synchronous collective.
* **elastic batch policy** — global batch is preserved under DP shrink by
  raising grad-accumulation steps (keeps optimization semantics stable).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["ClusterState", "StragglerMonitor", "plan_survivor_mesh",
           "elastic_batch_plan"]


@dataclasses.dataclass
class ClusterState:
    n_hosts: int
    devices_per_host: int
    failed_hosts: frozenset = frozenset()

    @property
    def alive(self) -> List[int]:
        return [h for h in range(self.n_hosts) if h not in self.failed_hosts]

    @property
    def alive_devices(self) -> int:
        return len(self.alive) * self.devices_per_host


def plan_survivor_mesh(state: ClusterState, model_parallel: int = 16
                       ) -> Tuple[int, int]:
    """Largest (data, model) mesh on the survivors with the model axis
    preserved. Returns (data, model); raises if TP can't be formed."""
    dev = state.alive_devices
    if dev < model_parallel:
        raise RuntimeError(
            f"only {dev} devices alive; cannot form model axis of "
            f"{model_parallel}")
    data = dev // model_parallel
    # power-of-two DP keeps collective rings balanced
    data = 1 << (data.bit_length() - 1)
    return data, model_parallel


def elastic_batch_plan(global_batch: int, old_data: int, new_data: int,
                       old_accum: int = 1) -> int:
    """Grad-accumulation steps that preserve the global batch when DP
    shrinks (or grows)."""
    per_replica = global_batch // (old_data * old_accum)
    assert global_batch % (new_data * per_replica) == 0, \
        "global batch not preservable; adjust batch or replicas"
    return global_batch // (new_data * per_replica)


class StragglerMonitor:
    """Flags hosts whose step time exceeds ``threshold × median`` for
    ``patience`` consecutive steps (EMA-smoothed)."""

    def __init__(self, n_hosts: int, threshold: float = 1.5,
                 patience: int = 3, ema: float = 0.5):
        self.n_hosts = n_hosts
        self.threshold = threshold
        self.patience = patience
        self.ema = ema
        self._time: Optional[np.ndarray] = None
        self._strikes = np.zeros(n_hosts, dtype=int)

    def observe(self, step_times: Sequence[float]) -> List[int]:
        """Per-host step durations → list of hosts to mitigate."""
        t = np.asarray(step_times, dtype=float)
        assert t.shape == (self.n_hosts,)
        self._time = t if self._time is None else \
            self.ema * t + (1 - self.ema) * self._time
        med = np.median(self._time)
        slow = self._time > self.threshold * med
        self._strikes = np.where(slow, self._strikes + 1, 0)
        return [int(h) for h in np.nonzero(
            self._strikes >= self.patience)[0]]

    def reset(self, host: int):
        self._strikes[host] = 0


def recovery_plan(state: ClusterState, *, model_parallel: int,
                  global_batch: int, old_data: int,
                  edge_of_host: Optional[Dict[int, int]] = None) -> Dict:
    """One-call recovery: survivor mesh + batch plan + PIES edge removals.

    ``edge_of_host`` maps hosts to the edge group (PIES edge cloud) they
    serve; dead hosts ⇒ dead edge clouds ⇒ Router.handle_edge_failure.
    """
    data, model = plan_survivor_mesh(state, model_parallel)
    accum = elastic_batch_plan(global_batch, old_data, data)
    dead_edges = sorted({edge_of_host[h] for h in state.failed_hosts
                         if edge_of_host and h in edge_of_host}) \
        if edge_of_host else []
    return {"mesh": (data, model), "grad_accum": accum,
            "dead_edges": dead_edges}
