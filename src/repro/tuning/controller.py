"""Closed-loop hysteresis control — placement driven by realized QoS.

The open-loop serving horizon fixes the :class:`~repro.core.dynamic
.DynamicPlacer` knobs for the whole run; the sweep grids over
``(switching_cost × stickiness)`` then tell us *offline* which settings
were good (see :mod:`repro.tuning.fit`). This module closes the loop
*online*: :class:`FeedbackPlacer` wraps a ``DynamicPlacer`` and adapts the
stickiness bonus between control ticks from the previous ticks' realized
serving statistics — the paper's §VII "dynamic extension", driven by
measurement instead of a hand-picked σ model.

Control law (deterministic, no RNG):

* the horizon driver reports, after every tick, the mean realized QoS and
  deadline-miss rate of the requests that *completed* during that tick
  (the only signal a real controller has — still-queued work is unknown);
* both signals are EWMA-smoothed (:attr:`FeedbackPlacer.ewma`);
* **multiplicative increase**: when the smoothed miss rate exceeds
  :attr:`target_miss`, latency is suffering — churn (cold starts) and
  queue resets make it worse, never better, so the stickiness bonus is
  multiplied by :attr:`gain` to suppress re-placement;
* **multiplicative decrease**: when misses are under target but the
  smoothed QoS is *declining* (below its own longer-horizon baseline by
  more than :attr:`qos_margin`), the placement has gone stale — resident
  implementations no longer match demand — so stickiness is divided by
  :attr:`gain` and the placer tracks the workload again;
* the bonus is always clamped to ``[STICKINESS_MIN, STICKINESS_MAX]``.

Everything is a pure function of the observation sequence, so a feedback
horizon run stays byte-identical on replay (the ``repro.sweeps`` resume
contract).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.dynamic import DynamicPlacer
from repro.core.instance import PIESInstance

__all__ = ["STICKINESS_MIN", "STICKINESS_MAX", "FeedbackPlacer"]

#: Hard clamp on the adapted stickiness bonus. The lower bound is 0 (a
#: negative bonus would *penalize* residency — that is eviction pressure,
#: not hysteresis); the upper bound caps lock-in so a placement can always
#: be displaced by a large enough QoS gap.
STICKINESS_MIN = 0.0
STICKINESS_MAX = 12.0

#: Smallest stickiness a multiplicative *increase* lands on (see
#: :meth:`FeedbackPlacer.observe`).
_INCREASE_FLOOR = 0.25


@dataclasses.dataclass
class FeedbackPlacer:
    """A :class:`DynamicPlacer` whose stickiness adapts to realized QoS.

    Drop-in for ``DynamicPlacer`` in the serving horizon: :meth:`step`
    has the same ``(x, value, n_loads)`` contract and exposes the same
    ``new_loads`` / ``evicted`` masks; the extra surface is
    :meth:`observe`, which the driver calls once per tick with the tick's
    realized completion statistics.
    """

    switching_cost: float = 2.0
    stickiness: float = 3.0        # initial bonus (adapted online)
    gain: float = 1.5              # multiplicative step, > 1
    ewma: float = 0.5              # smoothing of the per-tick signals
    target_miss: float = 0.05      # acceptable deadline-miss rate
    qos_margin: float = 0.02       # QoS decline that triggers decrease

    def __post_init__(self):
        if not self.gain > 1.0:
            raise ValueError(f"gain must be > 1, got {self.gain}")
        if not 0.0 < self.ewma <= 1.0:
            raise ValueError(f"ewma must be in (0, 1], got {self.ewma}")
        self._placer = DynamicPlacer(self.switching_cost, self.stickiness)
        self._s = float(np.clip(self.stickiness,
                                STICKINESS_MIN, STICKINESS_MAX))
        self._miss_ewma = 0.0
        self._qos_ewma: Optional[float] = None      # fast signal
        self._qos_baseline: Optional[float] = None  # slow reference
        #: stickiness actually applied at each step() (for tests/reports)
        self.history: List[float] = []

    # -- DynamicPlacer surface ---------------------------------------------
    @property
    def current_stickiness(self) -> float:
        return self._s

    @property
    def new_loads(self):
        return self._placer.new_loads

    @property
    def evicted(self):
        return self._placer.evicted

    def step(self, inst: PIESInstance, Q: Optional[np.ndarray] = None):
        """One control tick under the *current* adapted stickiness."""
        self._placer.stickiness = self._s
        self.history.append(self._s)
        return self._placer.step(inst, Q)

    # -- the feedback law --------------------------------------------------
    def observe(self, mean_qos: float, miss_rate: float,
                n_completed: int) -> float:
        """Fold one tick's realized statistics into the next stickiness.

        ``mean_qos``/``miss_rate`` are over the requests that *completed*
        during the tick; a tick with no completions (``n_completed == 0``)
        carries no signal and leaves the controller untouched. Returns the
        stickiness that the next :meth:`step` will apply.
        """
        if n_completed <= 0:
            return self._s
        a = self.ewma
        self._miss_ewma = (1.0 - a) * self._miss_ewma + a * float(miss_rate)
        if self._qos_ewma is None:
            self._qos_ewma = self._qos_baseline = float(mean_qos)
        else:
            self._qos_ewma = (1.0 - a) * self._qos_ewma + a * float(mean_qos)
            # the baseline moves an order of magnitude slower than the
            # signal, so "QoS below baseline" means decline, not noise
            b = a * 0.1
            self._qos_baseline = ((1.0 - b) * self._qos_baseline
                                  + b * float(mean_qos))
        if self._miss_ewma > self.target_miss:
            # churn hurts latency: lock in. The max() floor lets the
            # controller escape a stickiness-0 start, where a pure
            # multiplicative step would be pinned at zero forever.
            self._s = max(self._s * self.gain, _INCREASE_FLOOR)
        elif self._qos_ewma < self._qos_baseline - self.qos_margin:
            self._s /= self.gain          # placement went stale: loosen
        self._s = float(np.clip(self._s, STICKINESS_MIN, STICKINESS_MAX))
        return self._s
