"""Fit placer knobs from serving sweeps — the sweep-driven auto-tuner.

A ``kind="serving"`` sweep (:mod:`repro.sweeps`) grids the
:class:`~repro.core.dynamic.DynamicPlacer` knobs — ``switching_cost`` ×
``stickiness`` — per scenario and stores one *realized* mean-QoS value per
``(seed, tick)`` item. This module reduces such a store to a per-scenario
**lookup table** of recommended settings:

* :func:`read_serving_records` walks the (possibly partial) store via its
  manifest metadata — no spec reconstruction — and yields one record per
  stored item, labelled with scenario, explicit knob values, policy, seed;
* :func:`fit_table` groups records per scenario × (switching_cost,
  stickiness), and picks the knob pair that **maximizes mean realized
  QoS**, with a **95%-CI tie-break**: every grid point whose upper
  confidence bound reaches the best mean is statistically
  indistinguishable from the winner, and among those the *smallest*
  knob pair wins (switching cost is realized cold-start latency — never
  pay real stalls for CI noise; knob pairs are unique, so the pick is
  fully deterministic);
* :func:`save_table` / :func:`load_table` serialize the result as a
  versioned JSON document (``table_version`` + the sweep engine's schema
  version), shipped under ``src/repro/tuning/tables/``;
* :func:`recommend` is the runtime face: ``HorizonConfig.from_overrides``
  consults it for any knob the caller left unset, so sweep rows and CLI
  runs that don't pin the knobs get the fitted per-scenario settings
  instead of one-size-fits-all defaults.

The shipped ``tables/default.json`` is repo content, fitted from a real
(small) serving sweep by ``python -m repro.tuning fit``; like any code
change, refreshing it changes the values of runs that rely on the
recommendation (runs that pin their knobs are unaffected).
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.sweeps.aggregate import basic_stats
from repro.sweeps.spec import SCHEMA_VERSION
from repro.sweeps.store import SweepStore

__all__ = [
    "TABLE_VERSION",
    "DEFAULT_TABLE_PATH",
    "TABLE_ENV_VAR",
    "ServingRecord",
    "read_serving_records",
    "fit_table",
    "save_table",
    "load_table",
    "recommend",
]

#: Bump when the table document layout changes (loader rejects mismatches).
TABLE_VERSION = 1

#: The packaged lookup table consulted by :func:`recommend`.
DEFAULT_TABLE_PATH = Path(__file__).resolve().parent / "tables" / \
    "default.json"

#: Point :func:`recommend` at a different table without touching code.
TABLE_ENV_VAR = "REPRO_TUNING_TABLE"



@dataclasses.dataclass(frozen=True)
class ServingRecord:
    """One stored serving item, labelled with its grid coordinates."""

    scenario: str
    switching_cost: float
    stickiness: float
    policy: str
    seed: int
    value: float               # per-(seed, tick) mean realized QoS
    overrides: Tuple[Tuple[str, Any], ...] = ()   # full stored override set
    horizon: int = 0           # run's tick count (0: unknown, older store)
    key: str = ""              # the item's store key (metrics lookup)


def read_serving_records(store: "SweepStore | os.PathLike | str"
                         ) -> List[ServingRecord]:
    """Every serving item in the store whose grid point pins *both* knobs.

    Items whose overrides leave a knob unset are skipped: their realized
    values depend on whatever default (or previously shipped table) was in
    effect when they were computed, so they are not attributable to a grid
    point. Raises ``ValueError`` if the store holds no serving items at
    all (e.g. a sigma store was passed by mistake).
    """
    if not isinstance(store, SweepStore):
        store = SweepStore(store)
    records: List[ServingRecord] = []
    n_serving = 0
    for key in store.keys():
        meta = store.meta(key)
        if meta.get("executor") != "serving":
            continue
        n_serving += 1
        ov = dict(meta.get("overrides", {}))
        if "switching_cost" not in ov or "stickiness" not in ov:
            continue
        records.append(ServingRecord(
            scenario=str(meta["scenario"]),
            switching_cost=float(ov["switching_cost"]),
            stickiness=float(ov["stickiness"]),
            policy=str(meta["algo"]),
            seed=int(meta.get("seed", -1)),
            value=store.value(key),
            overrides=tuple(sorted(ov.items())),
            horizon=int(meta.get("horizon", 0)),
            key=key,
        ))
    if n_serving == 0:
        raise ValueError(
            f"store {store.root} holds no kind='serving' items — the "
            f"auto-tuner fits from realized-QoS serving sweeps "
            f"(python -m repro.sweeps --kind serving ...)")
    return records


def fit_table(store: "SweepStore | os.PathLike | str", *,
              policy: str = "edf",
              source: Optional[str] = None) -> Dict[str, Any]:
    """Reduce a serving store to a per-scenario recommended-knob table.

    ``policy`` selects which queue policy's realized values the fit uses
    (default the QoS-aware ``edf``); scenarios where that policy was not
    swept fall back to pooling every stored policy. Selection per
    scenario: highest mean realized QoS, 95%-CI tie-break (see module
    docstring).
    """
    records = read_serving_records(store)
    if not records:
        raise ValueError(
            "no serving items with explicit (switching_cost, stickiness) "
            "overrides — sweep the knobs as grid axes, e.g. "
            "--override switching_cost=0 --override switching_cost=2 "
            "--override stickiness=0 --override stickiness=3")

    by_scenario: Dict[str, List[ServingRecord]] = {}
    for r in records:
        by_scenario.setdefault(r.scenario, []).append(r)

    scenarios: Dict[str, Dict[str, Any]] = {}
    for scenario in sorted(by_scenario):
        recs = by_scenario[scenario]
        policies = {r.policy for r in recs}
        fit_policy = policy if policy in policies else None
        if fit_policy is not None:
            recs = [r for r in recs if r.policy == fit_policy]
        cells: Dict[Tuple[float, float], List[float]] = {}
        for r in recs:
            cells.setdefault((r.switching_cost, r.stickiness),
                             []).append(r.value)
        stats = {knobs: basic_stats(vals) for knobs, vals in cells.items()}
        # all-NaN cells (a horizon that served nothing) carry no signal
        stats = {k: s for k, s in stats.items() if s["n"] > 0}
        if not stats:
            raise ValueError(
                f"scenario {scenario!r}: every stored realized-QoS value "
                f"is NaN (no grid point served any request) — nothing to "
                f"fit; check the scenario/load overrides of the sweep")
        best_mean = max(s["mean"] for s in stats.values())
        # 95%-CI tie-break: among the candidates statistically
        # indistinguishable from the best, the smallest (switching_cost,
        # stickiness) pair wins — switching_cost is also the engine's
        # realized cold-start latency, so a recommendation must not pay
        # real stalls for CI noise. Knob pairs are unique per cell, so no
        # further criterion is needed (fully deterministic).
        cand = [k for k, s in stats.items()
                if s["mean"] + s["ci95"] >= best_mean]
        pick = min(cand)
        s = stats[pick]
        scenarios[scenario] = {
            "switching_cost": pick[0],
            "stickiness": pick[1],
            "policy": fit_policy or "pooled:" + ",".join(sorted(policies)),
            "mean_qos": round(s["mean"], 6),
            "ci95": round(s["ci95"], 6),
            "n": s["n"],
            "grid_points": len(cells),
        }

    root = store.root if isinstance(store, SweepStore) else Path(store)
    return {
        "table_version": TABLE_VERSION,
        "sweep_schema_version": SCHEMA_VERSION,
        "source": source or str(root),
        "scenarios": scenarios,
    }


# ===========================================================================
# Serialization + the runtime lookup
# ===========================================================================

def save_table(table: Mapping[str, Any], path: "os.PathLike | str") -> Path:
    """Write the table JSON (stable key order) and drop the load cache."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(table, indent=1, sort_keys=True) + "\n")
    _TABLE_CACHE.clear()
    return path


#: resolved path -> (mtime_ns, parsed table) — recommend() runs on the
#: serving hot path (every HorizonConfig.from_overrides), so the JSON is
#: parsed once per file version, not once per call.
_TABLE_CACHE: Dict[str, Tuple[int, Optional[Dict[str, Any]]]] = {}


def load_table(path: "os.PathLike | str | None" = None
               ) -> Optional[Dict[str, Any]]:
    """Load a lookup table; None when absent (callers fall back to
    defaults). Resolution: explicit ``path`` → ``$REPRO_TUNING_TABLE`` →
    the packaged :data:`DEFAULT_TABLE_PATH`."""
    if path is None:
        path = os.environ.get(TABLE_ENV_VAR) or DEFAULT_TABLE_PATH
    path = Path(path)
    try:
        mtime = path.stat().st_mtime_ns
    except OSError:
        return None
    cached = _TABLE_CACHE.get(str(path))
    if cached is not None and cached[0] == mtime:
        return cached[1]
    try:
        table = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        table = None
    if table is not None and table.get("table_version") != TABLE_VERSION:
        table = None   # future/foreign layout: ignore, don't crash serving
    _TABLE_CACHE[str(path)] = (mtime, table)
    return table


def recommend(scenario: str, *,
              table: Optional[Mapping[str, Any]] = None,
              path: "os.PathLike | str | None" = None
              ) -> Optional[Dict[str, float]]:
    """Fitted ``{"switching_cost": ..., "stickiness": ...}`` for a
    scenario, or None when no table (or no row) exists. This is what
    ``HorizonConfig.from_overrides`` consults for knobs the caller left
    unset; explicit overrides always win."""
    if table is None:
        table = load_table(path)
    if not table:
        return None
    row = table.get("scenarios", {}).get(scenario)
    if not row:
        return None
    return {"switching_cost": float(row["switching_cost"]),
            "stickiness": float(row["stickiness"])}
