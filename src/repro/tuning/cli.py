"""Command-line entry point: ``python -m repro.tuning``.

Subcommands::

    # reduce a kind="serving" sweep store to per-scenario recommended
    # (switching_cost, stickiness) settings; writes <store>/tuning_table
    # .json unless --out points elsewhere (e.g. the packaged default
    # table src/repro/tuning/tables/default.json)
    python -m repro.tuning fit --store experiments/sweeps/<key>

    # accuracy/latency + QoS/miss-rate Pareto frontiers from the same
    # store (--jax routes the dominance check through the batched
    # on-device path)
    python -m repro.tuning pareto --store experiments/sweeps/<key>

    # what the serving engine will recommend right now
    python -m repro.tuning show [--table PATH]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.sweeps.aggregate import frontier_table

from .fit import (DEFAULT_TABLE_PATH, fit_table, load_table, save_table)
from .pareto import frontier_points, frontier_rows

__all__ = ["main"]


def _cmd_fit(args: argparse.Namespace) -> int:
    table = fit_table(args.store, policy=args.policy)
    out = Path(args.out) if args.out else \
        Path(args.store) / "tuning_table.json"
    save_table(table, out)
    rows = table["scenarios"]
    print(f"[tuning] fitted {len(rows)} scenario(s) from {args.store} "
          f"-> {out}")
    print(f"{'scenario':<22} {'sw_cost':>8} {'stickiness':>10} "
          f"{'mean qos':>9} {'±95%':>7} {'n':>5} {'grid':>5}")
    for name in sorted(rows):
        r = rows[name]
        print(f"{name:<22} {r['switching_cost']:>8.2f} "
              f"{r['stickiness']:>10.2f} {r['mean_qos']:>9.4f} "
              f"{r['ci95']:>7.4f} {r['n']:>5d} {r['grid_points']:>5d}")
    return 0


def _cmd_pareto(args: argparse.Namespace) -> int:
    frontiers = frontier_points(
        args.store,
        scenarios=args.scenario.split(",") if args.scenario else None,
        use_jax=args.jax)
    rows = frontier_rows(frontiers)
    print(frontier_table(rows))
    if args.json:
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(rows, indent=1))
    return 0


def _cmd_show(args: argparse.Namespace) -> int:
    table = load_table(args.table)
    if table is None:
        where = args.table or DEFAULT_TABLE_PATH
        print(f"[tuning] no lookup table at {where} — serving runs fall "
              f"back to the HorizonConfig defaults", file=sys.stderr)
        return 1
    print(f"[tuning] table v{table['table_version']} "
          f"(sweep schema v{table.get('sweep_schema_version', '?')}) "
          f"from {table.get('source', '?')}")
    for name in sorted(table.get("scenarios", {})):
        r = table["scenarios"][name]
        print(f"  {name:<22} switching_cost={r['switching_cost']:<6g} "
              f"stickiness={r['stickiness']:<6g} "
              f"(mean qos {r['mean_qos']:.4f} ±{r['ci95']:.4f}, "
              f"n={r['n']}, {r['grid_points']} grid points, "
              f"fit policy {r['policy']})")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tuning",
        description="Sweep-driven auto-tuner: fit per-scenario placer "
                    "knobs, extract Pareto frontiers, inspect the shipped "
                    "lookup table.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    fit = sub.add_parser("fit", help="fit a per-scenario knob lookup table "
                                     "from a kind='serving' sweep store")
    fit.add_argument("--store", required=True,
                     help="sweep store directory (see python -m "
                          "repro.sweeps --kind serving)")
    fit.add_argument("--out", default=None,
                     help="table path (default: <store>/tuning_table.json; "
                          "point at src/repro/tuning/tables/default.json "
                          "to refresh the shipped table)")
    fit.add_argument("--policy", default="edf",
                     help="queue policy whose realized values drive the "
                          "fit (default: edf; scenarios without it pool "
                          "all stored policies)")
    fit.set_defaults(fn=_cmd_fit)

    par = sub.add_parser("pareto", help="non-dominated (QoS, miss) and "
                                        "(accuracy, latency) frontiers")
    par.add_argument("--store", required=True)
    par.add_argument("--scenario", default=None,
                     help="comma-separated subset (default: all stored)")
    par.add_argument("--jax", action="store_true",
                     help="batched on-device dominance check instead of "
                          "the NumPy reference")
    par.add_argument("--json", default=None, metavar="PATH",
                     help="also write the frontier rows as JSON")
    par.set_defaults(fn=_cmd_pareto)

    show = sub.add_parser("show", help="print the active lookup table")
    show.add_argument("--table", default=None,
                      help="table path (default: $REPRO_TUNING_TABLE or "
                           "the packaged default)")
    show.set_defaults(fn=_cmd_show)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
