"""Sweep-driven Pareto frontiers — the accuracy/latency trade-off view.

The paper's core premise is that every service ships *multiple
implementations* trading accuracy against latency; Hosseinzadeh et al.
(arXiv:2011.08381) make the same trade-off explicit as accuracy/time
Pareto frontiers. This module extracts those frontiers from a
``kind="serving"`` sweep store: every stored grid point — a
``(switching_cost, stickiness, policy)`` operating point of one scenario —
becomes a point in two metric planes,

* **(realized QoS ↑, deadline-miss-rate ↓)** — the serving-quality plane;
* **(mean served accuracy ↑, mean realized latency ↓)** — the
  accuracy/time plane of the multi-implementation trade-off;

and the non-dominated set in each plane is the menu an operator actually
chooses from.

The dominance check itself is a batched ``O(N²·M)`` tensor comparison:

* :func:`pareto_mask_np` — NumPy float64 reference;
* :func:`pareto_mask_jax` — the same computation in JAX, jit-compiled and
  fully batched over the grid (one ``[N, N, M]`` comparison tensor, no
  Python loop), so frontier extraction over large sweep grids runs
  on-device next to the sweep itself. The two paths agree exactly on the
  same inputs (pure comparisons — no floating-point accumulation to
  reassociate).

Point metrics beyond the stored mean QoS (miss rate, latency, served
accuracy) come **straight from the store**: schema-v3 serving sweeps
persist per-item ``submitted``/``served``/``misses``/``latency``/
``accuracy`` arrays at sweep time (see
:data:`repro.sweeps.shard.SERVING_METRIC_NAMES`), and
:func:`frontier_points` reconstructs the horizon-level metrics from them
as a pure store read — zero horizon replays. Only *legacy* stores
(written before schema v3, or with partially stored seeds) fall back to
replaying each grid point's horizon — ``run_horizon`` is a pure function
of ``(config, seed)``, so the replay is byte-identical to the run that
filled the store.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.horizon import (HorizonConfig, run_horizon,
                                   split_serving_overrides)
from repro.sweeps.store import SweepStore

from .fit import ServingRecord, read_serving_records

__all__ = [
    "pareto_mask_np",
    "pareto_mask_jax",
    "FrontierPoint",
    "frontier_points",
    "frontier_rows",
]


# ===========================================================================
# Dominance check — NumPy reference + batched JAX path
# ===========================================================================

def _signs(maximize: Sequence[bool], m: int) -> np.ndarray:
    maximize = list(maximize)
    if len(maximize) != m:
        raise ValueError(f"maximize has {len(maximize)} entries for "
                         f"{m} metric column(s)")
    return np.where(np.asarray(maximize, bool), 1.0, -1.0)


def pareto_mask_np(points: np.ndarray,
                   maximize: Sequence[bool]) -> np.ndarray:
    """[N] bool keep-mask of the non-dominated points (NumPy reference).

    ``points`` is ``[N, M]``; ``maximize[j]`` orients metric column ``j``
    (False = smaller is better). Point *i* is dominated iff some *j* is at
    least as good on every metric and strictly better on one; duplicates
    never dominate each other, so tied optima are all kept.
    """
    pts = np.asarray(points, np.float64)
    if pts.ndim != 2:
        raise ValueError(f"points must be [N, M], got shape {pts.shape}")
    if pts.shape[0] == 0:
        return np.zeros(0, bool)
    s = pts * _signs(maximize, pts.shape[1])[None, :]
    ge = (s[None, :, :] >= s[:, None, :]).all(-1)   # [i, j]: j ≥ i everywhere
    gt = (s[None, :, :] > s[:, None, :]).any(-1)    # [i, j]: j > i somewhere
    return ~(ge & gt).any(axis=1)


#: lazily-jitted dominance kernel (shared across calls; retraces per shape)
_JAX_MASK = None


def pareto_mask_jax(points, maximize: Sequence[bool]) -> np.ndarray:
    """JAX twin of :func:`pareto_mask_np` — jit-compiled, batched over the
    whole grid, so large sweeps stay on-device. Returns a NumPy bool [N]
    for drop-in parity with the reference.

    float64 inputs are compared *in float64* (scoped ``enable_x64``, one
    trace per dtype) — a silent cast to float32 could merge points that
    differ below f32 resolution and disagree with the reference mask.
    """
    import jax
    import jax.numpy as jnp

    global _JAX_MASK
    if _JAX_MASK is None:
        def _mask(signed):
            ge = (signed[None, :, :] >= signed[:, None, :]).all(-1)
            gt = (signed[None, :, :] > signed[:, None, :]).any(-1)
            return ~(ge & gt).any(axis=1)
        _JAX_MASK = jax.jit(_mask)

    pts = np.asarray(points)
    if pts.ndim != 2:
        raise ValueError(f"points must be [N, M], got shape {pts.shape}")
    if pts.shape[0] == 0:
        return np.zeros(0, bool)
    sign = _signs(maximize, pts.shape[1])

    def call():
        # orientation by sign flip, applied on-device in the input dtype
        # so the comparisons see exactly the reference path's values
        signed = jnp.asarray(pts) * jnp.asarray(sign, pts.dtype)[None, :]
        return np.asarray(_JAX_MASK(signed))

    if pts.dtype == np.float64:
        with jax.experimental.enable_x64():
            return call()
    return call()


# ===========================================================================
# Frontier extraction from a serving store
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class FrontierPoint:
    """One (scenario, knob, policy) operating point with replay metrics."""

    scenario: str
    switching_cost: float
    stickiness: float
    policy: str
    n_seeds: int
    mean_qos: float          # mean realized QoS (over seeds)
    miss_rate: float         # deadline-miss rate (over seeds)
    mean_latency_s: float    # mean realized latency of served requests
    mean_accuracy: float     # mean A_sm of the implementations that served
    qos_frontier: bool = False      # non-dominated in (QoS ↑, miss ↓)
    acc_lat_frontier: bool = False  # non-dominated in (acc ↑, latency ↓)


#: Per-item metric names a schema-v3 cell must hold for the pure-store
#: path; anything less falls back to horizon replay.
_REQUIRED_METRICS = frozenset(
    {"submitted", "served", "misses", "latency", "accuracy"})


def _seed_reduce(qos, miss, lat, acc) -> Dict[str, float]:
    """Per-seed metric lists → the cell's FrontierPoint metric dict."""
    return {"mean_qos": float(np.mean(qos)),
            "miss_rate": float(np.mean(miss)),
            "mean_latency_s": float(np.mean(lat)) if lat else float("nan"),
            "mean_accuracy": float(np.mean(acc)) if acc else float("nan")}


def _accumulate_seed(a: Dict[str, np.ndarray],
                     qos: list, miss: list, lat: list, acc: list) -> None:
    """Fold one seed's per-tick arrays into the per-seed metric lists.

    The *single* reduction both metric sources share: the store path feeds
    it the persisted per-item arrays, the replay path feeds it the same
    numbers straight from the ``TickReport``\\ s — so the two paths are
    bit-identical, and frontier flags never flip between them on exact
    metric ties. Per seed: submission-weighted mean QoS, misses over
    served, and served-weighted latency/accuracy means over the ticks
    that served anything (a seed that served nothing contributes to
    QoS/miss but not to latency/accuracy).
    """
    n_sub, n_served = a["submitted"].sum(), a["served"].sum()
    qos.append(float((a["values"] * a["submitted"]).sum() / n_sub)
               if n_sub else 0.0)
    miss.append(float(a["misses"].sum() / n_served) if n_served else 0.0)
    if n_served:
        hot = a["served"] > 0  # ticks that served nothing carry NaN means
        lat.append(float((a["latency"][hot] * a["served"][hot]).sum()
                         / n_served))
        acc.append(float((a["accuracy"][hot] * a["served"][hot]).sum()
                         / n_served))


def _replay_metrics(scenario: str, overrides: Tuple[Tuple[str, Any], ...],
                    policy: str, seeds: Sequence[int],
                    n_ticks: int) -> Dict[str, float]:
    """Legacy fallback: replay each seed's horizon for the metrics a
    pre-v3 store does not hold, reduced through the same arithmetic as
    the store path (replay is byte-identical to the original run, so the
    two paths agree bit-for-bit on complete stores)."""
    qos, miss, lat, acc = [], [], [], []
    for seed in seeds:
        cfg = HorizonConfig.from_overrides(scenario, dict(overrides), policy,
                                           seed, n_ticks=n_ticks)
        res = run_horizon(cfg)
        pt = res.per_tick
        _accumulate_seed({
            "values": res.tick_values(),
            "submitted": np.array([t.submitted for t in pt], np.float64),
            "served": np.array([t.served for t in pt], np.float64),
            "misses": np.array([t.deadline_misses for t in pt], np.float64),
            "latency": np.array([t.mean_latency_s for t in pt], np.float64),
            "accuracy": np.array([t.mean_accuracy for t in pt], np.float64),
        }, qos, miss, lat, acc)
    return _seed_reduce(qos, miss, lat, acc)


def _store_metrics(store: SweepStore, records: Sequence[ServingRecord],
                   n_ticks: int) -> Optional[Dict[str, float]]:
    """Horizon-level metrics reconstructed purely from stored per-item
    arrays — or None when the cell cannot support it (pre-v3 chunks
    without metrics, unknown horizon, or a seed with missing ticks) and
    the caller must replay.

    Mirrors :func:`_replay_metrics` exactly: per seed, mean QoS is the
    submission-weighted mean of per-tick values, miss rate is total
    misses over total served, and latency/accuracy are served-weighted
    means over the ticks that served anything (seeds that served nothing
    contribute to QoS/miss but not to latency/accuracy, like a replay
    with an empty ``res.requests``).
    """
    if n_ticks <= 0:
        return None
    by_seed: Dict[int, List[ServingRecord]] = {}
    for r in records:
        by_seed.setdefault(r.seed, []).append(r)
    qos, miss, lat, acc = [], [], [], []
    for seed in sorted(by_seed):
        recs = by_seed[seed]
        if len(recs) != n_ticks:
            return None  # partially stored seed: not reconstructible
        a = {name: np.zeros(len(recs))
             for name in ("values", "submitted", "served", "misses",
                          "latency", "accuracy")}
        for i, r in enumerate(recs):
            if not r.key:
                return None
            m = store.metrics(r.key)
            if not _REQUIRED_METRICS <= m.keys():
                return None  # legacy chunk without per-item metrics
            a["values"][i] = r.value
            for name in _REQUIRED_METRICS:
                a[name][i] = m[name]
        _accumulate_seed(a, qos, miss, lat, acc)
    return _seed_reduce(qos, miss, lat, acc)


def _resolve_horizon(store_root: Path, scenario: str,
                     overrides: Tuple[Tuple[str, Any], ...]) -> int:
    """Tick count for stores whose chunk meta predates the ``horizon``
    field: the stored spec's ``n_ticks``, else the scenario default."""
    try:
        spec = json.loads((store_root / "spec.json").read_text())
        if spec.get("n_ticks"):
            return int(spec["n_ticks"])
    except (OSError, json.JSONDecodeError):
        pass
    from repro.workloads import get_scenario
    scen_ov, _ = split_serving_overrides(dict(overrides))
    return int(get_scenario(scenario, **scen_ov).n_ticks)


def frontier_points(store: "SweepStore | str", *,
                    scenarios: Optional[Sequence[str]] = None,
                    use_jax: bool = False) -> Dict[str, List[FrontierPoint]]:
    """Per-scenario operating points with both frontier flags set.

    Walks every stored serving grid point (explicit knobs), reconstructs
    its miss-rate/latency/accuracy metrics **from the stored per-item
    metric arrays** (schema v3 — a pure store read, zero horizon
    replays), and marks non-domination in the (QoS, miss-rate) and
    (accuracy, latency) planes — ``use_jax=True`` routes the dominance
    check through the batched on-device path. Cells a legacy (pre-v3)
    store cannot reconstruct fall back to deterministic horizon replay.
    """
    if not isinstance(store, SweepStore):
        store = SweepStore(store)
    records = read_serving_records(store)
    mask_fn = pareto_mask_jax if use_jax else pareto_mask_np

    #: (scenario, overrides, policy) -> that cell's records
    cells: Dict[Tuple[str, Tuple, str], List[ServingRecord]] = {}
    for r in records:
        if scenarios is not None and r.scenario not in scenarios:
            continue
        cells.setdefault((r.scenario, r.overrides, r.policy), []).append(r)

    out: Dict[str, List[FrontierPoint]] = {}
    for (scenario, overrides, policy), recs in sorted(
            cells.items(), key=lambda kv: (kv[0][0], kv[0][1], kv[0][2])):
        T = max(r.horizon for r in recs) or \
            _resolve_horizon(Path(store.root), scenario, overrides)
        seeds = sorted({r.seed for r in recs})
        m = _store_metrics(store, recs, T)
        if m is None:  # legacy store without per-item metrics
            m = _replay_metrics(scenario, overrides, policy, seeds, T)
        out.setdefault(scenario, []).append(FrontierPoint(
            scenario=scenario, switching_cost=recs[0].switching_cost,
            stickiness=recs[0].stickiness, policy=policy,
            n_seeds=len(seeds), **m))

    def _keep(plane: np.ndarray) -> np.ndarray:
        # a point with NaN metrics (a grid point that served nothing) is
        # not an operating point: NaN comparisons are all-False, so it
        # could never be dominated and would fraudulently star itself —
        # exclude it from the plane and never flag it
        keep = np.zeros(plane.shape[0], bool)
        finite = ~np.isnan(plane).any(axis=1)
        if finite.any():
            keep[finite] = mask_fn(plane[finite], maximize=(True, False))
        return keep

    for scenario, pts in out.items():
        qos_keep = _keep(np.array([[p.mean_qos, p.miss_rate]
                                   for p in pts]))
        acc_keep = _keep(np.array([[p.mean_accuracy, p.mean_latency_s]
                                   for p in pts]))
        out[scenario] = [
            dataclasses.replace(p, qos_frontier=bool(qk),
                                acc_lat_frontier=bool(ak))
            for p, qk, ak in zip(pts, qos_keep, acc_keep)]
    return out


def frontier_rows(frontiers: Dict[str, List[FrontierPoint]]
                  ) -> Dict[str, List[Dict[str, Any]]]:
    """Plain-dict view of :func:`frontier_points` output — the shape
    :func:`repro.sweeps.aggregate.frontier_table` renders."""
    return {scenario: [dataclasses.asdict(p) for p in pts]
            for scenario, pts in frontiers.items()}
