"""repro.tuning — sweep-driven auto-tuning and closed-loop placement.

The decision layer on top of the measurement stack: ``repro.sweeps``
(kind ``"serving"``) grids the :class:`~repro.core.dynamic.DynamicPlacer`
knobs per scenario and stores realized QoS; this package turns those
stores into decisions —

* :mod:`~repro.tuning.fit` fits per-scenario recommended
  ``(switching_cost, stickiness)`` settings (mean-realized-QoS argmax,
  95%-CI tie-break) and ships them as a versioned JSON lookup table that
  ``HorizonConfig.from_overrides`` consults for unset knobs;
* :mod:`~repro.tuning.pareto` extracts non-dominated
  (QoS, deadline-miss-rate) and (accuracy, latency) frontiers per
  scenario — vectorized dominance in JAX (batched over the grid) with a
  NumPy reference path;
* :mod:`~repro.tuning.controller` closes the loop online:
  :class:`FeedbackPlacer` adapts the stickiness bonus from realized
  per-tick QoS/miss-rate (EWMA signals, multiplicative increase/decrease,
  clamped), exposed as serving policy ``"feedback"``.

    python -m repro.tuning fit --store experiments/sweeps/<key>
    python -m repro.tuning pareto --store experiments/sweeps/<key>
    python -m repro.tuning show
"""
from .controller import STICKINESS_MAX, STICKINESS_MIN, FeedbackPlacer
from .fit import (DEFAULT_TABLE_PATH, TABLE_ENV_VAR, TABLE_VERSION,
                  ServingRecord, fit_table, load_table, read_serving_records,
                  recommend, save_table)
from .pareto import (FrontierPoint, frontier_points, frontier_rows,
                     pareto_mask_jax, pareto_mask_np)

__all__ = [
    "FeedbackPlacer", "STICKINESS_MIN", "STICKINESS_MAX",
    "ServingRecord", "fit_table", "save_table", "load_table", "recommend",
    "read_serving_records", "TABLE_VERSION", "TABLE_ENV_VAR",
    "DEFAULT_TABLE_PATH",
    "FrontierPoint", "frontier_points", "frontier_rows",
    "pareto_mask_np", "pareto_mask_jax",
]
