"""Command-line entry point: ``python -m repro.fleet``.

The multi-worker face of :mod:`repro.sweeps` — same grid grammar, but the
work list becomes a shared lease queue that any number of worker
processes (one host or many, over a shared filesystem) drain into one
store::

    # 1. coordinator: expand the grid into the fleet's task queue
    python -m repro.fleet plan --kind serving --scenario flash_crowd \\
        --seeds 0:32 --override switching_cost=0 --override \\
        switching_cost=2 --root experiments/fleet/demo \\
        --store experiments/sweeps/demo

    # 2. workers: run as many as you like, anywhere that sees the root
    python -m repro.fleet worker --root experiments/fleet/demo

    # 3. watch / recover / combine
    python -m repro.fleet status --root experiments/fleet/demo   # --watch
    #    (with REPRO_OBS_STREAM set, --watch tails the workers' live
    #     telemetry streams under <root>/stream/ — see repro.obs.stream)
    python -m repro.fleet reap   --root experiments/fleet/demo
    python -m repro.fleet merge  --root experiments/fleet/demo \\
        --store experiments/sweeps/demo

(Or let ``python -m repro.sweeps ... --fleet N`` do all of it locally.)

A SIGKILLed worker's lease expires and any other worker (or ``reap``)
requeues its chunk; ``merge`` dedups by item hash and verifies duplicate
values bit-for-bit, so the merged store is byte-identical in aggregate to
a single-process run of the same spec.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.sweeps.cli import add_spec_arguments, build_spec

from .coordinator import merge, plan, reap, status
from .queue import DEFAULT_TTL_S
from .worker import run_worker

__all__ = ["main"]


def _cmd_plan(args: argparse.Namespace) -> int:
    spec = build_spec(args)
    out = plan(spec, args.root, target_store=args.store,
               seeds_per_task=args.seeds_per_task)
    print(f"[fleet] planned {out['n_tasks']} task(s) / {out['n_items']} "
          f"item(s) under {out['fleet_root']} "
          f"(spec {out['fingerprint']}; {out['skipped_tasks']} task(s) "
          f"already queued, {out['skipped_items']} item(s) already in "
          f"the target store)")
    return 0


def _cmd_worker(args: argparse.Namespace) -> int:
    from repro import obs
    from .queue import default_owner
    obs.enable_from_env()  # REPRO_OBS=1 propagated by spawn_local_workers
    owner = args.owner or default_owner()
    # REPRO_OBS_STREAM=1 → per-worker JSONL under <root>/stream/ (the
    # dashboard and `status --watch` tail these); explicit specs
    # (unix:/tcp:/path) are honored as given.
    obs.enable_stream_from_env(
        default_path=str(Path(args.root) / "stream" / f"{owner}.jsonl"),
        source=owner)
    summary = run_worker(args.root, owner=owner, ttl=args.ttl,
                         max_tasks=args.max_tasks,
                         memory_budget_mb=args.memory_budget_mb,
                         wait=args.wait, poll_interval=args.poll_interval,
                         verbose=args.verbose)
    if not args.verbose:
        print(f"[fleet:{summary['owner']}] {summary['n_tasks']} task(s), "
              f"{summary['n_items']} item(s), stop={summary['stop']}")
    return 0


def _print_status(out: dict) -> None:
    q = out["queue"]
    print(f"[fleet] queue: {q['pending']} pending, {q['leased']} leased "
          f"({q['expired']} expired), {q['done']} done"
          + (f", {len(q['poisoned'])} POISONED ({', '.join(q['poisoned'])})"
             if q.get("poisoned") else "")
          + (f"; spec items: {out['n_spec_items']}"
             if out.get("n_spec_items") is not None else ""))
    rate = out.get("rate_items_per_s") or 0.0
    eta = out.get("eta_s")
    line = (f"[fleet] remaining: {out.get('remaining_items', 0)} item(s)")
    if rate > 0:
        line += f" at {rate:.2f} items/s (live workers)"
    if eta is not None:
        line += f", ETA {eta:.0f}s"
    print(line)
    tele = out.get("telemetry", {})
    for name, n in sorted(out["workers"].items()):
        w = tele.get(name)
        extra = ""
        if w is not None:
            extra = (f"  [{w.get('state')}] "
                     f"{w.get('items_per_s', 0.0):.2f} items/s")
            wall = w.get("last_task_wall_s")
            if wall is not None:
                extra += f", last chunk {wall:.2f}s"
        print(f"  worker {name:<24} {n:>6d} item(s){extra}")
    if "target_items" in out:
        missing = out.get("target_missing")
        print(f"  target store: {out['target_items']} item(s)"
              + (f", {missing} missing" if missing is not None else ""))


def _cmd_status(args: argparse.Namespace) -> int:
    if getattr(args, "watch", False):
        return _watch_status(args)
    out = status(args.root, target_store=args.store)
    _print_status(out)
    if args.json:
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(out, indent=1))
    return 0


def _watch_status(args: argparse.Namespace) -> int:
    """``status --watch``: live refresh until the queue drains.

    Prefers the workers' live telemetry streams (``<root>/stream/*.jsonl``
    — present when the fleet runs with ``REPRO_OBS_STREAM``); without
    them it degrades to plain heartbeat polling of the telemetry files,
    exactly like repeated ``status`` calls. Exits 0 when no pending or
    leased work remains.
    """
    import time

    from repro.obs.dash import DashState, render
    from repro.obs.stream import StreamError, read_stream

    root = Path(args.root)
    interval = max(float(getattr(args, "interval", 2.0)), 0.05)
    clear = sys.stdout.isatty()
    out = None

    def _drain_streams() -> None:
        streams = sorted((root / "stream").glob("*.jsonl"))
        if not streams:
            return
        state = DashState()
        for p in streams:
            try:
                for frame in read_stream(str(p), follow=False):
                    state.update(frame)
            except (StreamError, OSError):
                continue  # torn tail of a live file; retry next tick
        if state.n_frames:
            print()
            print(render(state))

    while True:
        out = status(args.root, target_store=args.store)
        if clear:
            sys.stdout.write("\x1b[H\x1b[2J")
        _print_status(out)
        q = out["queue"]
        if q["pending"] == 0 and q["leased"] == 0:
            # Final flush: workers emit their last frames (bye, metrics
            # rollups) around the moment the queue drains — re-read the
            # streams once after observing the drain so those frames make
            # the final screen instead of being dropped on exit.
            time.sleep(min(interval, 0.2))
            _drain_streams()
            break
        _drain_streams()
        time.sleep(interval)
    if args.json and out is not None:
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(out, indent=1))
    return 0


def _cmd_reap(args: argparse.Namespace) -> int:
    names = reap(args.root, ttl=args.ttl)
    print(f"[fleet] requeued {len(names)} expired lease(s)"
          + (": " + ", ".join(names) if names else ""))
    return 0


def _cmd_merge(args: argparse.Namespace) -> int:
    out = merge(args.root, args.store)
    print(f"[fleet] merged {out['merged_items']} item(s) from "
          f"{len(out['workers'])} worker store(s); "
          f"{out['duplicate_items']} duplicate(s) verified bit-for-bit; "
          f"target now holds {out['target_items']} item(s)"
          + (f", {out['missing_items']} still missing"
             if out.get("missing_items") else ""))
    return 0 if not out.get("missing_items") else 2


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet",
        description="Multi-worker sweep dispatch: one spec, one lease "
                    "queue, N workers, one crash-safe merged store.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    pl = sub.add_parser("plan", help="expand a sweep grid into the fleet "
                                     "task queue")
    add_spec_arguments(pl)
    pl.add_argument("--root", required=True,
                    help="fleet root directory (queue + worker stores)")
    pl.add_argument("--store", default=None,
                    help="target store: seeds already complete there are "
                         "not enqueued")
    pl.add_argument("--seeds-per-task", type=int, default=1,
                    help="seeds per claimable task (default: 1; lease "
                         "TTL is a worker property — see worker --ttl)")
    pl.set_defaults(fn=_cmd_plan)

    wk = sub.add_parser("worker", help="claim/execute/append until the "
                                       "queue drains (SIGTERM = clean "
                                       "drain after the current task)")
    wk.add_argument("--root", required=True)
    wk.add_argument("--owner", default=None,
                    help="worker id (default: <host>-<pid>)")
    wk.add_argument("--ttl", type=float, default=DEFAULT_TTL_S)
    wk.add_argument("--max-tasks", type=int, default=None,
                    help="exit after N tasks (smoke/testing)")
    wk.add_argument("--memory-budget-mb", type=float, default=None,
                    help="accelerator memory budget per in-flight chunk "
                         "(default: the sweep engine's)")
    wk.add_argument("--wait", action="store_true",
                    help="long-poll an empty queue for the next plan "
                         "wave instead of exiting (elastic fleets); "
                         "exit via SIGTERM drain or --max-tasks")
    wk.add_argument("--poll-interval", type=float, default=2.0,
                    help="--wait polling period in seconds")
    wk.add_argument("--verbose", action="store_true")
    wk.set_defaults(fn=_cmd_worker)

    st = sub.add_parser("status", help="queue + worker-store accounting")
    st.add_argument("--root", required=True)
    st.add_argument("--store", default=None)
    st.add_argument("--json", default=None, metavar="PATH")
    st.add_argument("--watch", action="store_true",
                    help="refresh until the queue drains; tails the "
                         "workers' live streams (<root>/stream/*.jsonl) "
                         "when present, else polls heartbeats")
    st.add_argument("--interval", type=float, default=2.0,
                    help="--watch refresh period in seconds")
    st.set_defaults(fn=_cmd_status)

    rp = sub.add_parser("reap", help="requeue expired leases (crash "
                                     "recovery)")
    rp.add_argument("--root", required=True)
    rp.add_argument("--ttl", type=float, default=None,
                    help="TTL for leases whose block never landed")
    rp.set_defaults(fn=_cmd_reap)

    mg = sub.add_parser("merge", help="dedup/verify worker stores into "
                                      "the target store")
    mg.add_argument("--root", required=True)
    mg.add_argument("--store", required=True)
    mg.set_defaults(fn=_cmd_merge)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except ValueError as e:
        # operator-facing failures (missing queue, spec mismatch, merge
        # conflict) — report, don't traceback
        print(f"[fleet] error: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
