"""Fleet worker: claim → execute → append → release, until drained.

A worker is one process pointed at a fleet root. Its loop:

1. :meth:`~repro.fleet.queue.LeaseQueue.claim` a task (atomic rename);
   when nothing is claimable it first :meth:`reap`\\ s expired leases —
   picking up the chunks of crashed workers — and exits once the queue is
   truly drained;
2. execute the task through the **existing sweep engine**:
   :func:`task_spec` rebuilds the task's single-group
   :class:`~repro.sweeps.spec.SweepSpec` and
   :func:`~repro.sweeps.shard.run_sweep` evaluates it into the worker's
   *private* store (``<fleet_root>/workers/<owner>/``) — same chunking,
   same envelopes, same serving horizons, so per-item values are
   byte-identical to a single-process run of the whole sweep;
3. heartbeat the lease from a daemon thread every ``ttl / 3`` while
   executing, then mark the task done (atomic rename into ``done/``).

``SIGTERM``/``SIGINT`` trigger a **clean drain**: the current task runs to
completion (its results land durably and its lease is completed), then the
loop exits with the stop reason recorded. ``SIGKILL`` is the crash path
the queue is built for: the orphaned lease expires and any other worker's
``reap`` requeues the chunk.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro import obs
from repro.sweeps.spec import SweepSpec
from repro.sweeps.shard import run_sweep

from .queue import DEFAULT_TTL_S, Lease, LeaseQueue, Task, default_owner
from .telemetry import WorkerTelemetry

__all__ = ["task_spec", "run_worker", "spawn_local_workers",
           "worker_store_dir", "load_fleet_spec"]

_QUEUE_DIR = "queue"
_WORKERS_DIR = "workers"


def worker_store_dir(fleet_root: os.PathLike | str, owner: str) -> Path:
    return Path(fleet_root) / _WORKERS_DIR / owner


def load_fleet_spec(fleet_root: os.PathLike | str) -> SweepSpec:
    """The sweep spec this fleet was planned from (version-checked)."""
    path = Path(fleet_root) / "spec.json"
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise ValueError(f"fleet root {fleet_root} has no readable "
                         f"spec.json — run `repro.fleet plan` first") from e
    return SweepSpec.from_json(doc)


def task_spec(parent: SweepSpec, task: Task) -> SweepSpec:
    """The task's single-group sub-spec.

    Pins the group's scenario, override set (knobs already resolved at
    plan time — no tuning-table re-resolution drift), algorithm, the
    task's seed slice, and the *resolved* tick count, so the sub-spec
    expands to exactly the parent's item keys for this slice.
    """
    return SweepSpec(
        scenarios=(task.scenario,),
        seeds=task.seeds,
        n_ticks=task.n_ticks,
        algos=(task.algo,),
        override_grid=(task.overrides,),
        force_host=tuple(a for a in parent.force_host if a == task.algo),
        max_iters=parent.max_iters,
        kind=parent.kind,
    )


class _Heartbeat(threading.Thread):
    """Renews a lease every ``ttl / 3`` while the task executes."""

    def __init__(self, lease: Lease, interval: float):
        super().__init__(daemon=True)
        self.lease = lease
        self.interval = max(float(interval), 0.05)
        self._halt = threading.Event()  # NB: Thread reserves `_stop`

    def run(self) -> None:
        while not self._halt.wait(self.interval):
            try:
                if not self.lease.renew():
                    return  # lease lost: stop beating, let the task finish
            except OSError:
                return

    def stop(self) -> None:
        self._halt.set()
        self.join(timeout=5.0)


def run_worker(fleet_root: os.PathLike | str, *,
               owner: Optional[str] = None,
               ttl: float = DEFAULT_TTL_S,
               max_tasks: Optional[int] = None,
               memory_budget_mb: Optional[float] = None,
               install_signal_handlers: bool = True,
               wait: bool = False,
               poll_interval: float = 2.0,
               verbose: bool = False) -> Dict[str, Any]:
    """Drain the fleet queue from this process; returns a run summary.

    Exits when the queue has no claimable *or* reapable work left (other
    workers' live leases are not waited on — the coordinator's final
    ``merge``/``run_sweep`` pass covers stragglers), after ``max_tasks``
    tasks, or on a clean SIGTERM drain. With ``wait=True`` an empty
    queue is not an exit: the worker long-polls every ``poll_interval``
    seconds for the next plan wave (elastic fleets keep their workers
    across waves), so the only exits are SIGTERM/SIGINT (clean drain)
    and ``max_tasks``.
    """
    fleet_root = Path(fleet_root)
    owner = owner or default_owner()
    spec = load_fleet_spec(fleet_root)
    queue = LeaseQueue(fleet_root / _QUEUE_DIR, owner=owner, ttl=ttl)
    store_dir = worker_store_dir(fleet_root, owner)
    store_dir.mkdir(parents=True, exist_ok=True)

    stop = {"reason": None}

    def _drain(signum, frame):  # noqa: ARG001 - signal signature
        stop["reason"] = signal.Signals(signum).name

    previous_handlers = {}
    if install_signal_handlers:
        try:
            for sig in (signal.SIGTERM, signal.SIGINT):
                previous_handlers[sig] = signal.signal(sig, _drain)
        except ValueError:  # not the main thread: caller manages signals
            previous_handlers = {}

    try:
        return _worker_loop(queue, spec, store_dir, owner, stop,
                            max_tasks, memory_budget_mb, verbose,
                            telemetry=WorkerTelemetry(fleet_root, owner),
                            wait=wait, poll_interval=poll_interval)
    finally:
        # an in-process caller (tests, benchmarks) keeps its own Ctrl-C
        for sig, handler in previous_handlers.items():
            signal.signal(sig, handler)


def _poll_sleep(stop: Dict[str, Any], interval: float) -> None:
    """Sleep ``interval`` seconds in short slices so a SIGTERM drain
    request interrupts the long-poll promptly instead of after a full
    poll period."""
    deadline = time.perf_counter() + max(interval, 0.0)
    while stop["reason"] is None:
        remain = deadline - time.perf_counter()
        if remain <= 0:
            return
        time.sleep(min(remain, 0.2))


def _worker_loop(queue: LeaseQueue, spec: SweepSpec, store_dir: Path,
                 owner: str, stop: Dict[str, Any],
                 max_tasks: Optional[int],
                 memory_budget_mb: Optional[float],
                 verbose: bool,
                 telemetry: Optional[WorkerTelemetry] = None,
                 wait: bool = False,
                 poll_interval: float = 2.0) -> Dict[str, Any]:
    executed: List[str] = []
    items = 0
    t0 = time.perf_counter()
    if telemetry is not None:
        telemetry.start()
    while stop["reason"] is None:
        if max_tasks is not None and len(executed) >= max_tasks:
            stop["reason"] = "max_tasks"
            break
        lease = queue.claim()
        if lease is None:
            # nothing claimable: pick up crashed workers' chunks, else done
            if queue.reap():
                continue
            if wait:
                # elastic fleets: survive the gap between plan waves
                _poll_sleep(stop, poll_interval)
                continue
            stop["reason"] = "drained"
            break
        task = lease.task
        sub = task_spec(spec, task)
        expect = {it.key() for it in sub.expand()}
        if expect != set(task.keys):
            lease.release()
            raise ValueError(
                f"task {task.name} expands to different item keys than "
                f"planned — code/schema skew between coordinator and "
                f"worker; re-plan the fleet")
        hb = _Heartbeat(lease, interval=queue.ttl / 3.0)
        hb.start()
        task_t0 = time.perf_counter()
        try:
            kwargs = {} if memory_budget_mb is None else \
                {"memory_budget_mb": memory_budget_mb}
            run_sweep(sub, store_dir=store_dir, verbose=False, **kwargs)
        finally:
            hb.stop()
        items += len(task.keys)
        completed = lease.complete()
        executed.append(task.name)
        if telemetry is not None:
            telemetry.task_done(task.name, len(task.keys),
                                time.perf_counter() - task_t0)
        pub = obs.get_publisher()
        if pub is not None:
            # Live "worker" frame for the dashboard / `status --watch`.
            # Pending *items* is an estimate (pending tasks × this
            # worker's mean items/task) — the queue only counts tasks.
            try:
                n_pending = len(queue.pending())
            except OSError:
                n_pending = None
            elapsed = time.perf_counter() - t0
            pub.emit("worker", {
                "owner": owner,
                "task": task.name,
                "tasks_done": len(executed),
                "items_done": items,
                "items_per_s": round(items / elapsed, 6)
                if elapsed > 0 else 0.0,
                "queue_pending_tasks": n_pending,
                "queue_pending_items": None if n_pending is None
                else int(round(n_pending * items / len(executed))),
                "task_wall_s": round(time.perf_counter() - task_t0, 6),
            })
        if verbose:
            state = "done" if completed else "done (lease was reaped)"
            print(f"[fleet:{owner}] {task.name}: {len(task.keys)} item(s) "
                  f"{state}", flush=True)

    if telemetry is not None:
        telemetry.stop(stop["reason"] or "drained")
    summary = {"owner": owner, "tasks": executed, "n_tasks": len(executed),
               "n_items": items, "stop": stop["reason"],
               "wall_s": time.perf_counter() - t0,
               "store": str(store_dir)}
    if verbose:
        print(f"[fleet:{owner}] exit ({stop['reason']}): "
              f"{len(executed)} task(s), {items} item(s) in "
              f"{summary['wall_s']:.2f}s", flush=True)
    return summary


def spawn_local_workers(fleet_root: os.PathLike | str, n: int, *,
                        ttl: float = DEFAULT_TTL_S,
                        max_tasks: Optional[int] = None,
                        memory_budget_mb: Optional[float] = None,
                        quiet: bool = True,
                        silence: bool = False) -> List[subprocess.Popen]:
    """Fork ``n`` local worker processes (``python -m repro.fleet worker``)
    against ``fleet_root`` — the ``--fleet N`` convenience path. The
    caller waits on the returned processes and then merges. ``silence``
    drops worker stdout/stderr entirely (benchmarks emitting structured
    output)."""
    import repro

    env = dict(os.environ)
    # repro may be a namespace package (no __init__.py): __path__ always
    # exists where __file__ may be None
    pkg_root = str(Path(list(repro.__path__)[0]).resolve().parent)
    env["PYTHONPATH"] = pkg_root + os.pathsep + env.get("PYTHONPATH", "")
    sink = subprocess.DEVNULL if silence else None
    procs = []
    for i in range(int(n)):
        cmd = [sys.executable, "-m", "repro.fleet", "worker",
               "--root", str(fleet_root), "--owner", f"local-{i}",
               "--ttl", str(ttl)]
        if max_tasks is not None:
            cmd += ["--max-tasks", str(max_tasks)]
        if memory_budget_mb is not None:
            cmd += ["--memory-budget-mb", str(memory_budget_mb)]
        if not quiet:
            cmd.append("--verbose")
        procs.append(subprocess.Popen(cmd, env=env, stdout=sink,
                                      stderr=sink))
    return procs
