"""Worker telemetry: heartbeat-published throughput files.

Each worker publishes one JSON file at
``<fleet_root>/telemetry/<owner>.json`` (atomic tempfile-rename, same
primitive as the store) and rewrites it after every completed task. The
record is observational only — nothing in the queue, the merge, or the
determinism contract reads it; it exists so ``repro.fleet status`` and
``python -m repro.obs tail`` can show live per-worker rates and a fleet
ETA without touching worker stores or replaying manifests.

Record fields (``telemetry_schema`` = :data:`TELEMETRY_SCHEMA_VERSION`):

``owner``              worker name
``state``              ``running`` or the worker's stop reason
``started_at``         wall-clock epoch seconds of the worker's first task
``updated_at``         epoch seconds of the last rewrite (staleness gate)
``tasks_done``         completed task count
``items_done``         completed item count
``items_per_s``        lifetime items/s (items_done over active wall time)
``last_task``          name of the most recently completed task
``last_task_wall_s``   wall seconds of that task
``pid``                the worker process id
``anchor_mono_ns``     ``time.perf_counter_ns()`` sampled at the same
                       instant as ``updated_at`` — a wall/monotonic
                       anchor pair used by :mod:`repro.obs.aggregate`
                       to align per-worker trace clocks when stitching

A worker that is SIGKILLed simply stops updating its file; readers treat
records older than their staleness window as dead and exclude them from
the live rate (the file is evidence of past throughput, not liveness —
liveness is the lease's job).
"""
from __future__ import annotations

import json
import math
import os
import time
from pathlib import Path
from typing import Any, Dict, Optional

from repro.sweeps.store import atomic_write

__all__ = ["TELEMETRY_SCHEMA_VERSION", "DEFAULT_STALE_S", "WorkerTelemetry",
           "read_telemetry", "telemetry_dir"]

TELEMETRY_SCHEMA_VERSION = 1

#: Records not rewritten within this window count as dead for rate/ETA.
DEFAULT_STALE_S = 30.0

_TELEMETRY_DIR = "telemetry"


def telemetry_dir(fleet_root: "os.PathLike | str") -> Path:
    return Path(fleet_root) / _TELEMETRY_DIR


class WorkerTelemetry:
    """One worker's publisher. Failures to publish never fail the worker —
    telemetry is strictly best-effort."""

    def __init__(self, fleet_root: "os.PathLike | str", owner: str, *,
                 clock=time.time):
        self.owner = owner
        self.path = telemetry_dir(fleet_root) / f"{owner}.json"
        self._clock = clock
        self._started_at: Optional[float] = None
        self._t0: Optional[float] = None  # perf_counter anchor for rate
        self.tasks_done = 0
        self.items_done = 0
        self._last_task: Optional[str] = None
        self._last_task_wall_s: Optional[float] = None

    def start(self) -> None:
        self._started_at = self._clock()
        self._t0 = time.perf_counter()
        self._publish("running")

    def task_done(self, name: str, n_items: int, wall_s: float) -> None:
        if self._t0 is None:  # start() failed or was skipped
            self.start()
        self.tasks_done += 1
        self.items_done += int(n_items)
        self._last_task = name
        self._last_task_wall_s = float(wall_s)
        self._publish("running")

    def stop(self, reason: str) -> None:
        self._publish(str(reason))

    def _rate(self) -> float:
        if self._t0 is None:
            return 0.0
        elapsed = time.perf_counter() - self._t0
        return self.items_done / elapsed if elapsed > 0 else 0.0

    def record(self, state: str) -> Dict[str, Any]:
        return {
            "telemetry_schema": TELEMETRY_SCHEMA_VERSION,
            "owner": self.owner,
            "state": state,
            "started_at": self._started_at,
            "updated_at": self._clock(),
            "tasks_done": self.tasks_done,
            "items_done": self.items_done,
            "items_per_s": round(self._rate(), 6),
            "last_task": self._last_task,
            "last_task_wall_s": None if self._last_task_wall_s is None
            else round(self._last_task_wall_s, 6),
            "pid": os.getpid(),
            "anchor_mono_ns": time.perf_counter_ns(),
        }

    def _publish(self, state: str) -> None:
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            atomic_write(self.path, json.dumps(
                self.record(state), separators=(",", ":")).encode())
        except OSError:
            pass  # telemetry must never take a worker down


def read_telemetry(fleet_root: "os.PathLike | str", *,
                   now: Optional[float] = None,
                   stale_s: float = DEFAULT_STALE_S) -> Dict[str, Any]:
    """All worker records plus the fleet-wide live rate.

    Returns ``{"workers": {owner: record}, "rate_items_per_s": float}``
    where the rate sums ``items_per_s`` over workers whose record is in
    state ``running`` and was rewritten within ``stale_s`` seconds — a
    killed worker's frozen file stops counting once the window passes.
    """
    now = time.time() if now is None else float(now)
    workers: Dict[str, Dict[str, Any]] = {}
    d = telemetry_dir(fleet_root)
    if d.is_dir():
        for p in sorted(d.glob("*.json")):
            try:
                rec = json.loads(p.read_text())
            except (OSError, json.JSONDecodeError):
                continue  # mid-rename race or torn external write
            if rec.get("telemetry_schema") != TELEMETRY_SCHEMA_VERSION:
                continue
            workers[rec.get("owner", p.stem)] = rec
    rate = 0.0
    for rec in workers.values():
        fresh = (now - float(rec.get("updated_at") or 0.0)) <= stale_s
        rec["live"] = bool(fresh and rec.get("state") == "running")
        if rec["live"]:
            r = float(rec.get("items_per_s") or 0.0)
            if math.isfinite(r):
                rate += r
    return {"workers": workers, "rate_items_per_s": round(rate, 6)}
