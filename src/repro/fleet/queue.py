"""Directory-backed lease queue — the fleet's shared work manifest.

The queue is three sibling directories on a filesystem every worker can
reach (one host's disk, or NFS/Lustre across hosts)::

    <fleet_root>/queue/
        tasks/NNNNNN_<h>.json   # claimable task documents
        leases/NNNNNN_<h>.json  # claimed tasks (doc + owner/ttl lease block)
        done/NNNNNN_<h>.json    # completed tasks

Every state transition is a single atomic ``os.rename`` on one file, so
exactly one worker wins any claim and no state is ever half-visible:

* **claim** — ``rename(tasks/T, leases/T)``: atomic, single winner; the
  winner then republishes the file with an embedded lease block (owner,
  ``claimed_at``, ``expires_at``) via ``O_EXCL`` tempfile + rename;
* **heartbeat** — the owner republishes the lease file with a fresh
  ``expires_at`` (tempfile + rename, atomic);
* **complete** — ``rename(leases/T, done/T)``;
* **requeue** (crash recovery) — anyone may ``rename(leases/T, tasks/T)``
  once the lease has expired: a worker SIGKILLed mid-chunk stops
  heartbeating, its lease runs out, and :meth:`LeaseQueue.reap` puts the
  task back for the next claimant.

The expiry/requeue race (a paused-but-alive worker loses its lease and a
second worker re-executes the chunk) is *safe by construction*: task
execution is deterministic, every worker appends to its own store, and
the coordinator's merge dedups by item hash and verifies duplicate values
bit-for-bit — a re-executed chunk is wasted work, never wrong data.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import socket
import time
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.sweeps.store import atomic_write

__all__ = ["DEFAULT_TTL_S", "Task", "Lease", "LeaseQueue",
           "default_owner"]

#: Default lease time-to-live. A worker heartbeats at ``ttl / 3``, so the
#: TTL bounds how long a crashed worker's chunk stays stuck, not how long
#: a chunk may take.
DEFAULT_TTL_S = 60.0

_TASKS, _LEASES, _DONE = "tasks", "leases", "done"
_POISON_SUFFIX = ".poison"


def default_owner() -> str:
    """``<host>-<pid>`` — unique per live worker process."""
    return f"{socket.gethostname()}-{os.getpid()}"


@dataclasses.dataclass(frozen=True)
class Task:
    """One claimable unit: a (scenario, overrides, algo) group's seed
    slice, plus the item keys it is expected to produce (the coordinator
    audits completeness against them)."""

    name: str
    scenario: str
    overrides: Tuple[Tuple[str, Any], ...]
    algo: str
    seeds: Tuple[int, ...]
    n_ticks: int
    keys: Tuple[str, ...]

    def to_json(self) -> Dict[str, Any]:
        return {"name": self.name, "scenario": self.scenario,
                "overrides": [list(kv) for kv in self.overrides],
                "algo": self.algo, "seeds": list(self.seeds),
                "n_ticks": self.n_ticks, "keys": list(self.keys)}

    @classmethod
    def from_json(cls, doc: Mapping[str, Any]) -> "Task":
        return cls(name=str(doc["name"]), scenario=str(doc["scenario"]),
                   overrides=tuple((str(k), v)
                                   for k, v in doc["overrides"]),
                   algo=str(doc["algo"]),
                   seeds=tuple(int(s) for s in doc["seeds"]),
                   n_ticks=int(doc["n_ticks"]),
                   keys=tuple(str(k) for k in doc["keys"]))


def _write_atomic(path: Path, doc: Mapping[str, Any]) -> None:
    """Crash-safe JSON publish — the store's shared fsync'd
    tempfile+rename primitive."""
    atomic_write(path, json.dumps(doc, indent=1).encode())


def _read_json(path: Path) -> Optional[Dict[str, Any]]:
    try:
        return json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return None  # vanished mid-scan (raced transition) or mid-write


@dataclasses.dataclass
class Lease:
    """A claimed task. The holder renews it while executing; anyone may
    requeue it once ``expires_at`` passes."""

    queue: "LeaseQueue"
    task: Task
    owner: str
    expires_at: float
    lost: bool = False

    @property
    def path(self) -> Path:
        return self.queue.lease_dir / f"{self.task.name}.json"

    def _still_mine(self) -> bool:
        """Ownership check against the on-disk lease: after an expiry
        reap, the same path may hold *another* worker's lease on the same
        task — a stale holder must neither renew over it nor complete it.
        (The read-then-act window is unsynchronized, but both outcomes
        are benign: results are appended before completion, and the merge
        verifies duplicates bit-for-bit.)"""
        if self.lost:
            return False
        doc = _read_json(self.path)
        if doc is None:
            self.lost = True
            return False
        owner = doc.get("lease", {}).get("owner", self.owner)
        if owner != self.owner:
            self.lost = True
            return False
        return True

    def renew(self, ttl: Optional[float] = None) -> bool:
        """Push ``expires_at`` out by ``ttl`` (the heartbeat). Returns
        False — and flags the lease lost — if the lease was reaped after
        an expiry (the task is someone else's now)."""
        if not self._still_mine():
            return False
        self.expires_at = time.time() + float(ttl or self.queue.ttl)
        doc = self.task.to_json()
        doc["lease"] = {"owner": self.owner, "expires_at": self.expires_at}
        _write_atomic(self.path, doc)
        return True

    def complete(self) -> bool:
        """tasks→done transition; False if the lease was lost meanwhile
        (results are still durable in the worker's store — the merge
        dedups the re-executed duplicate)."""
        if not self._still_mine():
            return False
        try:
            os.rename(self.path, self.queue.done_dir /
                      f"{self.task.name}.json")
            return True
        except OSError:
            self.lost = True
            return False

    def release(self) -> bool:
        """Give the (unfinished) task back to the queue."""
        if not self._still_mine():
            return False
        doc = self.task.to_json()  # strip the lease block
        try:
            _write_atomic(self.path, doc)
            os.rename(self.path, self.queue.task_dir /
                      f"{self.task.name}.json")
            return True
        except OSError:
            self.lost = True
            return False


class LeaseQueue:
    """The shared task queue under ``<fleet_root>/queue``."""

    def __init__(self, root: os.PathLike | str, *,
                 owner: Optional[str] = None, ttl: float = DEFAULT_TTL_S,
                 create: bool = True):
        """``create=False`` is for read-side consumers (status/reap over
        an operator-typed path): a queue that does not exist is an error
        to report, not an empty-healthy one to silently fabricate."""
        self.root = Path(root)
        self.task_dir = self.root / _TASKS
        self.lease_dir = self.root / _LEASES
        self.done_dir = self.root / _DONE
        if create:
            for d in (self.task_dir, self.lease_dir, self.done_dir):
                d.mkdir(parents=True, exist_ok=True)
        elif not self.task_dir.is_dir():
            raise ValueError(f"no fleet queue at {self.root} — "
                             f"run `repro.fleet plan` first (or check "
                             f"the --root path)")
        self.owner = owner or default_owner()
        self.ttl = float(ttl)

    # -- enqueue ----------------------------------------------------------
    def put(self, task: Task) -> bool:
        """Enqueue ``task`` unless it already exists in any state (makes
        re-planning idempotent). Returns True if enqueued."""
        name = f"{task.name}.json"
        if any((d / name).exists()
               for d in (self.task_dir, self.lease_dir, self.done_dir)):
            return False
        _write_atomic(self.task_dir / name, task.to_json())
        return True

    # -- listing ----------------------------------------------------------
    def _names(self, d: Path) -> List[str]:
        return sorted(p.stem for p in d.glob("*.json"))

    def pending(self) -> List[str]:
        return self._names(self.task_dir)

    def leased(self) -> List[str]:
        return self._names(self.lease_dir)

    def done(self) -> List[str]:
        return self._names(self.done_dir)

    def read_task(self, name: str) -> Optional[Task]:
        for d in (self.task_dir, self.lease_dir, self.done_dir):
            doc = _read_json(d / f"{name}.json")
            if doc is not None:
                return Task.from_json(doc)
        return None

    # -- claim / recover --------------------------------------------------
    def claim(self) -> Optional[Lease]:
        """Claim the first available task, or None when none is claimable.

        The claim itself is ``rename(tasks/T, leases/T)`` — atomic, single
        winner even with N workers scanning the same directory; losers see
        ``ENOENT`` and move on to the next candidate.
        """
        for name in self.pending():
            src = self.task_dir / f"{name}.json"
            dst = self.lease_dir / f"{name}.json"
            try:
                os.rename(src, dst)
            except OSError:
                continue  # raced: someone else claimed (or reaped) it
            doc = _read_json(dst)
            if doc is None:
                # unreadable task file (external corruption — our own
                # writes are atomic): quarantine it visibly instead of
                # parking an unreapable lease; status() reports it
                with contextlib.suppress(OSError):
                    os.rename(dst, Path(str(dst) + _POISON_SUFFIX))
                continue
            lease = Lease(queue=self, task=Task.from_json(doc),
                          owner=self.owner, expires_at=0.0)
            lease.renew()
            return lease
        return None

    def _lease_expiry(self, path: Path) -> Optional[float]:
        doc = _read_json(path)
        if doc is None:
            return None  # raced transition; not ours to judge
        lease = doc.get("lease")
        if lease is not None:
            return float(lease.get("expires_at", 0.0))
        # claimed but killed before the lease block landed: fall back to
        # the rename mtime + one TTL
        try:
            return path.stat().st_mtime + self.ttl
        except OSError:
            return None

    def reap(self, now: Optional[float] = None) -> List[str]:
        """Requeue every expired lease (crash recovery); returns the
        requeued task names. Safe to call from any process at any time."""
        now = time.time() if now is None else float(now)
        reaped: List[str] = []
        for name in self.leased():
            path = self.lease_dir / f"{name}.json"
            expiry = self._lease_expiry(path)
            if expiry is None or expiry > now:
                continue
            doc = _read_json(path)
            if doc is None:
                continue
            doc.pop("lease", None)
            try:
                _write_atomic(path, doc)
                os.rename(path, self.task_dir / f"{name}.json")
            except OSError:
                continue  # raced with the owner's complete()/heartbeat
            reaped.append(name)
        return reaped

    # -- accounting -------------------------------------------------------
    def status(self, now: Optional[float] = None) -> Dict[str, Any]:
        now = time.time() if now is None else float(now)
        expired = []
        for name in self.leased():
            expiry = self._lease_expiry(self.lease_dir / f"{name}.json")
            if expiry is not None and expiry <= now:
                expired.append(name)
        return {"pending": len(self.pending()),
                "leased": len(self.leased()),
                "expired": len(expired),
                "done": len(self.done()),
                "expired_names": expired,
                "poisoned": sorted(
                    p.name for p in
                    self.lease_dir.glob("*" + _POISON_SUFFIX))}
