"""Fleet coordinator: plan, status, reap, and the crash-safe merge.

``plan`` turns a :class:`~repro.sweeps.spec.SweepSpec` into the fleet's
on-disk layout::

    <fleet_root>/
        spec.json        # SweepSpec.to_json() — version-checked by workers
        queue/           # the lease queue (tasks/ leases/ done/)
        workers/<owner>/ # each worker's private SweepStore

One task is one (scenario, overrides, algo) group's slice of
``seeds_per_task`` seeds over the group's full resolved horizon — the
smallest unit the serving executor can compute (a seed's horizon is
atomic) that still expands to exactly the parent spec's item keys.
Seeds whose items are already complete in the target store are not
enqueued (fleet resume is seed-granular; the final ``run_sweep`` pass
stays item-granular).

``merge`` drains every worker store into the target
:class:`~repro.sweeps.store.SweepStore`, chunk by chunk in deterministic
order (sorted worker names, manifest order). Items the target already
holds — from a previous merge, a partial single-process run, or a
*re-executed* chunk whose first executor was presumed dead but had
already appended — are **verified bit-for-bit** (float64 value and
metric bytes must match exactly; wall-clock ``times`` are measurements
and exempt) before being dropped as duplicates; any mismatch raises
:class:`FleetMergeConflict`, because two byte-different results for one
item hash mean the determinism contract broke (code skew between
workers, a corrupted store) and silently keeping either would poison
the aggregate.
"""
from __future__ import annotations

import hashlib
import json
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.sweeps.spec import SweepSpec
from repro.sweeps.store import SweepStore, atomic_write

from .queue import DEFAULT_TTL_S, LeaseQueue, Task
from .telemetry import DEFAULT_STALE_S, read_telemetry
from .worker import _QUEUE_DIR, _WORKERS_DIR, load_fleet_spec

__all__ = ["FleetMergeConflict", "plan", "status", "merge", "reap",
           "worker_stores"]


class FleetMergeConflict(ValueError):
    """Two byte-different stored results for the same item hash."""


def _chunked(seq: Sequence, n: int) -> List[Sequence]:
    n = max(int(n), 1)
    return [seq[i:i + n] for i in range(0, len(seq), n)]


def plan(spec: SweepSpec, fleet_root, *,
         target_store=None, seeds_per_task: int = 1) -> Dict[str, Any]:
    """Write the fleet layout and enqueue one task per pending seed slice.

    Idempotent: task names are pure content hashes of (scenario,
    overrides, algo, seed slice), so re-planning the same spec — even
    after some tasks completed and their seeds dropped out of the
    pending set — regenerates identical names and skips everything that
    already exists in any queue state. (With ``seeds_per_task > 1`` a
    partially completed grid can re-slice the *remaining* seeds into new
    combinations; the re-executed overlap is wasted, never wrong — the
    merge dedups bit-for-bit.) Planning a *different* spec into an
    existing fleet root is rejected (one fleet per spec — fingerprints
    must match).
    """
    fleet_root = Path(fleet_root)
    fleet_root.mkdir(parents=True, exist_ok=True)
    spec_path = fleet_root / "spec.json"
    doc = spec.to_json()
    have = None
    if spec_path.exists():
        try:
            have = json.loads(spec_path.read_text())
        except json.JSONDecodeError:
            have = None  # torn by a killed pre-atomic-write coordinator
    if have is not None and have.get("fingerprint") != doc["fingerprint"]:
        raise ValueError(
            f"fleet root {fleet_root} was planned for spec "
            f"{have.get('fingerprint')!r}, got {doc['fingerprint']!r} "
            f"— one fleet root serves one spec")
    if have is None:
        atomic_write(spec_path, json.dumps(doc, indent=1).encode())

    target = SweepStore(target_store) if target_store is not None else None
    # NB: no TTL here — lease TTL is a *worker* property (each worker
    # stamps and renews its own leases); the planner only enqueues
    queue = LeaseQueue(fleet_root / _QUEUE_DIR)

    n_tasks = n_items = n_skipped_items = skipped_tasks = 0
    for (scenario, overrides, algo), items in spec.groups():
        T = spec.ticks_for(scenario, overrides)
        by_seed: Dict[int, List] = {}
        for it in items:
            by_seed.setdefault(it.seed, []).append(it)
        pending_seeds = []
        for seed in spec.seeds:
            seed_items = by_seed.get(seed, [])
            done = target is not None and \
                all(it.key() in target for it in seed_items)
            if done:
                n_skipped_items += len(seed_items)
            else:
                pending_seeds.append(seed)
        for seeds in _chunked(pending_seeds, seeds_per_task):
            keys = tuple(it.key() for s in seeds for it in by_seed[s])
            # the name is a pure content hash — no running index, which
            # would shift when completed seeds drop out of pending and
            # re-enqueue surviving tasks under new names
            h = hashlib.sha256(json.dumps(
                [scenario, list(map(list, overrides)), algo, list(seeds)],
                separators=(",", ":")).encode()).hexdigest()[:16]
            task = Task(name=h, scenario=scenario,
                        overrides=overrides, algo=algo,
                        seeds=tuple(seeds), n_ticks=T, keys=keys)
            if queue.put(task):
                n_tasks += 1
                n_items += len(keys)
            else:
                skipped_tasks += 1
    return {"fleet_root": str(fleet_root), "n_tasks": n_tasks,
            "n_items": n_items, "skipped_tasks": skipped_tasks,
            "skipped_items": n_skipped_items,
            "fingerprint": doc["fingerprint"]}


def worker_stores(fleet_root) -> List[Path]:
    """Every worker store directory under the fleet root, sorted (the
    deterministic merge order)."""
    root = Path(fleet_root) / _WORKERS_DIR
    if not root.is_dir():
        return []
    return sorted(d for d in root.iterdir() if (d / "manifest.jsonl").exists()
                  or (d / "shards").is_dir())


def status(fleet_root, *, target_store=None,
           stale_s: float = DEFAULT_STALE_S) -> Dict[str, Any]:
    """Queue counts, per-worker completed items, target completeness —
    plus live throughput: ``remaining_items`` (summed over pending and
    leased task keys), per-worker ``telemetry`` records,
    ``rate_items_per_s`` (live workers only — telemetry fresher than
    ``stale_s``), and ``eta_s`` (remaining over rate, ``None`` when no
    worker is live)."""
    fleet_root = Path(fleet_root)
    queue = LeaseQueue(fleet_root / _QUEUE_DIR, create=False)
    out: Dict[str, Any] = {"queue": queue.status(), "workers": {}}
    for wdir in worker_stores(fleet_root):
        out["workers"][wdir.name] = len(SweepStore(wdir))
    remaining = 0
    for name in queue.pending() + queue.leased():
        task = queue.read_task(name)
        if task is not None:
            remaining += len(task.keys)
    out["remaining_items"] = remaining
    tele = read_telemetry(fleet_root, stale_s=stale_s)
    out["telemetry"] = tele["workers"]
    out["rate_items_per_s"] = tele["rate_items_per_s"]
    # Live-stream awareness: workers launched with REPRO_OBS_STREAM leave
    # one JSONL stream each under <root>/stream/ — `status --watch` and
    # the dashboard tail these instead of polling heartbeats.
    stream_dir = fleet_root / "stream"
    out["stream_files"] = sorted(
        p.name for p in stream_dir.glob("*.jsonl")) \
        if stream_dir.is_dir() else []
    out["eta_s"] = (round(remaining / out["rate_items_per_s"], 3)
                    if remaining and out["rate_items_per_s"] > 0 else None)
    try:
        spec = load_fleet_spec(fleet_root)
        out["n_spec_items"] = len(spec.expand())
    except ValueError:
        out["n_spec_items"] = None
    if target_store is not None:
        target = SweepStore(target_store)
        out["target_items"] = len(target)
        if out["n_spec_items"] is not None:
            spec = load_fleet_spec(fleet_root)
            out["target_missing"] = sum(
                1 for it in spec.expand() if it.key() not in target)
    return out


def reap(fleet_root, *, ttl: Optional[float] = None) -> List[str]:
    """Requeue expired leases; returns the requeued task names."""
    queue = LeaseQueue(Path(fleet_root) / _QUEUE_DIR,
                       ttl=ttl if ttl is not None else DEFAULT_TTL_S,
                       create=False)
    return queue.reap()


def _verify_duplicate(key: str, target: SweepStore,
                      data: Mapping[str, np.ndarray], row: int,
                      worker: str) -> None:
    """A duplicate item must match the target bit-for-bit (values and
    metrics; ``times`` are wall-clock measurements and exempt)."""
    mine = np.float64(data["values"][row])
    have = np.float64(target.value(key))
    conflicts = []
    if mine.tobytes() != have.tobytes():
        conflicts.append(f"value {have!r} != {mine!r}")
    have_metrics = target.metrics(key)
    for name, arr in data.items():
        if not name.startswith("metric_"):
            continue
        short = name[len("metric_"):]
        if short not in have_metrics:
            continue  # target row predates metrics; value check governs
        a = np.float64(have_metrics[short])
        b = np.float64(arr[row])
        # NaN is a legitimate stored metric (a tick that served nothing)
        # and NaN != NaN, so compare representations, not floats
        if a.tobytes() != b.tobytes():
            conflicts.append(f"metric {short} {a!r} != {b!r}")
    if conflicts:
        raise FleetMergeConflict(
            f"item {key} from worker store {worker!r} disagrees with the "
            f"target bit-for-bit: {'; '.join(conflicts)} — determinism "
            f"contract broken (code skew between workers?); refusing to "
            f"merge")


def merge(fleet_root, target_store, *, workers=None) -> Dict[str, Any]:
    """Merge every worker store into ``target_store``; returns stats.

    Dedup is by item hash; duplicate items are verified bit-for-bit
    before being dropped (see module docstring). New items are appended
    chunk-wise, preserving each chunk's meta plus a ``fleet_worker``
    provenance tag.
    """
    fleet_root = Path(fleet_root)
    target = SweepStore(target_store)
    try:
        spec = load_fleet_spec(fleet_root)
        target.write_spec(spec.to_json())
    except ValueError:
        spec = None
    stores = worker_stores(fleet_root)
    if spec is None and not stores:
        raise ValueError(f"no fleet at {fleet_root} (no spec.json, no "
                         f"worker stores) — nothing to merge")
    if workers is not None:
        want = set(workers)
        stores = [d for d in stores if d.name in want]
    merged = duplicates = 0
    for wdir in stores:
        wstore = SweepStore(wdir)
        for rec in wstore.chunks():
            keys = rec["keys"]
            data = wstore.chunk_data(rec["shard"])
            fresh = [i for i, k in enumerate(keys) if k not in target]
            for i, k in enumerate(keys):
                if i in fresh:
                    continue
                _verify_duplicate(k, target, data, i, wdir.name)
                duplicates += 1
            if not fresh:
                continue
            meta = dict(rec.get("meta", {}))
            meta["fleet_worker"] = wdir.name
            metrics = {name[len("metric_"):]: arr[fresh]
                       for name, arr in data.items()
                       if name.startswith("metric_")}
            target.add_chunk([keys[i] for i in fresh],
                             data["values"][fresh], data["times"][fresh],
                             meta=meta, metrics=metrics or None)
            merged += len(fresh)
    out = {"merged_items": merged, "duplicate_items": duplicates,
           "workers": [d.name for d in stores],
           "target_items": len(target)}
    if spec is not None:
        out["missing_items"] = sum(
            1 for it in spec.expand() if it.key() not in target)
    return out
