"""repro.fleet — multi-worker sweep dispatch over a lease-based queue.

The horizontal-scale layer between :mod:`repro.sweeps` and the hardware:
a :class:`~repro.sweeps.spec.SweepSpec` manifest becomes a shared,
directory-backed work queue (:mod:`~repro.fleet.queue` — atomic rename
claims, owner+TTL lease files, heartbeat renewal, expired-lease requeue),
N independent worker processes (:mod:`~repro.fleet.worker`) drain it
through the existing sweep engine into private stores, and the
coordinator (:mod:`~repro.fleet.coordinator`) merges them into one
:class:`~repro.sweeps.store.SweepStore` — deduping by item hash and
verifying duplicate values bit-for-bit, so a fleet of any worker count
(including one SIGKILLed mid-chunk and reaped) aggregates byte-identically
to the single-process ``repro.sweeps`` run of the same spec.

    python -m repro.fleet plan --scenario flash_crowd --seeds 0:32 \\
        --root experiments/fleet/demo --store experiments/sweeps/demo
    python -m repro.fleet worker --root experiments/fleet/demo   # × N
    python -m repro.fleet merge --root experiments/fleet/demo \\
        --store experiments/sweeps/demo

or, all-local: ``python -m repro.sweeps ... --fleet N``.
"""
from .coordinator import (FleetMergeConflict, merge, plan, reap, status,
                          worker_stores)
from .queue import DEFAULT_TTL_S, Lease, LeaseQueue, Task, default_owner
from .worker import (load_fleet_spec, run_worker, spawn_local_workers,
                     task_spec, worker_store_dir)

__all__ = [
    "DEFAULT_TTL_S", "Task", "Lease", "LeaseQueue", "default_owner",
    "task_spec", "run_worker", "spawn_local_workers", "worker_store_dir",
    "load_fleet_spec",
    "FleetMergeConflict", "plan", "status", "merge", "reap",
    "worker_stores",
]
