"""Serving launcher: PIES-placed edge cluster serving batched requests.

    PYTHONPATH=src python -m repro.launch.serve --users 48 --edges 2

Builds the multi-implementation service catalog (the 10-arch zoo), samples
a request population with the paper's threshold distributions, runs EGP
placement + OMS routing, executes every request on real (reduced-config)
models, and reports expected vs realized QoS. ``--fail-edge`` demonstrates
elastic re-placement after an edge-cloud loss.
"""
from __future__ import annotations

import argparse

import numpy as np


def run_serving(n_users: int = 48, n_edges: int = 2, seed: int = 0,
                storage: float = 60.0, placement: str = "egp",
                max_new_tokens: int = 4, fail_edge: int = -1,
                verbose: bool = True):
    from repro.serving import EdgeCluster, default_catalog

    catalog = default_catalog()
    cluster = EdgeCluster(catalog, n_edges=n_edges, placement_algo=placement)
    inst = catalog.to_instance(n_users, n_edges, storage_capacity=storage,
                               seed=seed)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, 200, size=(n_users, 16)).astype(np.int32)

    report = cluster.serve(inst, prompts, max_new_tokens=max_new_tokens)
    if verbose:
        print(f"[serve] served={report.served} dropped={report.dropped} "
              f"skipped={report.skipped} "
              f"expectedQoS={report.mean_expected_qos:.3f} "
              f"realizedQoS={report.mean_realized_qos:.3f} "
              f"wall={report.total_wall_s:.1f}s")
        for name, n in sorted(report.per_model_counts.items()):
            print(f"[serve]   {name:20s} {n:4d} requests")

    if fail_edge >= 0:
        inst2, _ = cluster.router.handle_edge_failure(inst, [fail_edge])
        report2 = cluster.serve(inst2, prompts,
                                max_new_tokens=max_new_tokens)
        if verbose:
            print(f"[serve] after edge-{fail_edge} failure: "
                  f"served={report2.served} "
                  f"expectedQoS={report2.mean_expected_qos:.3f}")
        return report, report2
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--users", type=int, default=48)
    ap.add_argument("--edges", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--storage", type=float, default=60.0)
    ap.add_argument("--placement", default="egp",
                    choices=["egp", "agp", "opt"])
    ap.add_argument("--max-new-tokens", type=int, default=4)
    ap.add_argument("--fail-edge", type=int, default=-1)
    args = ap.parse_args()
    run_serving(args.users, args.edges, args.seed, args.storage,
                args.placement, args.max_new_tokens, args.fail_edge)


if __name__ == "__main__":
    main()
