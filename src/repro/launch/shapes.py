"""Assigned input-shape cells and per-architecture applicability rules."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.configs import ARCH_IDS, get_config

__all__ = ["ShapeCell", "SHAPES", "cell_plan", "all_cells"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}

#: archs allowed to run long_500k (sub-quadratic decode state growth is
#: bounded: SSM / hybrid / SWA-only). gemma2's alternating *global* layers
#: keep full-range KV ⇒ excluded (see DESIGN.md §Arch-applicability).
LONG_OK = {"mamba2_2p7b", "zamba2_2p7b", "mixtral_8x7b"}


def cell_plan(arch: str, shape: str) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped)."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    if cfg.encoder_only and cell.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape == "long_500k" and arch not in LONG_OK:
        return False, ("full-attention arch: 500k decode KV state grows "
                       "unboundedly (assignment rule: skip)")
    return True, ""


def all_cells() -> List[Tuple[str, str, bool, str]]:
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            ok, why = cell_plan(arch, shape)
            out.append((arch, shape, ok, why))
    return out
