"""Per-cell lowering specs: step fn + ShapeDtypeStruct inputs + shardings.

``input_specs(arch, shape)`` follows the shannon/kernels pattern: weak-type
correct, shardable stand-ins, zero device allocation. ``build_cell`` wraps
them with the jitted step function for ``.lower().compile()``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.layers import MeshContext
from repro.training import (AdamWConfig, init_train_state, make_train_step,
                            train_state_pspecs)
from .shapes import SHAPES, ShapeCell, cell_plan

__all__ = ["build_cell", "input_specs", "serving_config", "training_config"]

#: per-arch optimizer-state dtype (memory fit policy; see EXPERIMENTS.md)
OPT_STATE_DTYPE = {"qwen3_moe_235b": "bfloat16"}
#: per-arch master-param dtype for training. 235B on a 256-chip v5e pod
#: cannot hold f32 master + grads + Adam state in 16 GB/chip; bf16 master
#: (Gopher-style, pair with stochastic rounding on real hardware) is the
#: documented production trade-off. Everything else trains f32-master.
TRAIN_PARAM_DTYPE = {"qwen3_moe_235b": "bfloat16"}


def training_config(arch: str, tp: int) -> ModelConfig:
    return get_config(arch, tp_shards=tp,
                      param_dtype=TRAIN_PARAM_DTYPE.get(arch, "float32"),
                      dtype="bfloat16", remat=True)


def serving_config(arch: str, tp: int) -> ModelConfig:
    return get_config(arch, tp_shards=tp, param_dtype="bfloat16",
                      dtype="bfloat16", remat=False)


def _sh(mesh, spec_tree):
    """PartitionSpec tree → NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _batch_struct(cfg: ModelConfig, cell: ShapeCell, baxes,
                  with_targets: bool):
    """(ShapeDtypeStruct tree, PartitionSpec tree) for a model input batch."""
    B, S = cell.global_batch, cell.seq_len
    i32, f32, act = jnp.int32, jnp.float32, jnp.dtype(cfg.dtype)
    st, sp = {}, {}
    if cfg.frontend == "audio":
        st["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), act)
        sp["frames"] = P(baxes, "model", None)
    elif cfg.frontend == "vision":
        nv = cfg.n_vision_tokens
        st["patches"] = jax.ShapeDtypeStruct((B, nv, cfg.d_model), act)
        sp["patches"] = P(baxes, "model", None)
        st["tokens"] = jax.ShapeDtypeStruct((B, S - nv), i32)
        sp["tokens"] = P(baxes, "model")
    else:
        st["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        sp["tokens"] = P(baxes, "model")
    if with_targets:
        st["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        sp["targets"] = P(baxes, "model")
        st["mask"] = jax.ShapeDtypeStruct((B, S), f32)
        sp["mask"] = P(baxes, "model")
    return st, sp


def _cache_struct(cfg: ModelConfig, B: int, S: int, baxes,
                  shard_seq_cache: bool):
    spec, ring = T.cache_spec(cfg, B, S)
    struct = T.Cache(**{k: jax.ShapeDtypeStruct(s, jnp.dtype(d))
                        for k, (s, d) in spec.items()})
    # attention-free archs have zero-size kv buffers with degenerate head
    # dims — leave those unsharded (they carry no bytes anyway)
    kv_head_ax = "model" if spec["kv_k"][0][0] > 0 else None
    if shard_seq_cache:  # batch too small to shard (long_500k): shard seq
        pspecs = T.Cache(
            kv_k=P(None, None, baxes, kv_head_ax, None),
            kv_v=P(None, None, baxes, kv_head_ax, None),
            conv=P(None, None, None, "model"),
            ssm=P(None, None, "model", None, None),
            pos=P(None),
        )
    else:
        pspecs = T.Cache(
            kv_k=P(None, baxes, None, kv_head_ax, None),
            kv_v=P(None, baxes, None, kv_head_ax, None),
            conv=P(None, baxes, None, "model"),
            ssm=P(None, baxes, "model", None, None),
            pos=P(baxes),
        )
    return struct, pspecs, ring


def input_specs(arch: str, shape: str, multi_pod: bool = False,
                tp: int = 16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cell = SHAPES[shape]
    baxes = ("pod", "data") if multi_pod else ("data",)
    if cell.kind == "train":
        cfg = training_config(arch, tp)
        st, sp = _batch_struct(cfg, cell, baxes, with_targets=True)
        return {"batch": st, "batch_pspecs": sp, "config": cfg}
    cfg = serving_config(arch, tp)
    if cell.kind == "prefill":
        st, sp = _batch_struct(cfg, cell, baxes, with_targets=False)
        out = {"batch": st, "batch_pspecs": sp, "config": cfg}
        if not cfg.encoder_only:
            cs, cp, ring = _cache_struct(cfg, cell.global_batch, cell.seq_len,
                                         baxes, shard_seq_cache=False)
            out.update({"cache": cs, "cache_pspecs": cp, "ring": ring})
        return out
    # decode
    shard_seq = cell.global_batch == 1
    cs, cp, ring = _cache_struct(cfg, cell.global_batch, cell.seq_len,
                                 baxes, shard_seq_cache=shard_seq)
    tok = jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32)
    tok_sp = P(baxes) if not shard_seq else P(None)
    return {"token": tok, "token_pspecs": tok_sp,
            "cache": cs, "cache_pspecs": cp, "ring": ring, "config": cfg}


def build_cell(arch: str, shape: str, mesh, multi_pod: bool):
    """Returns (jitted_fn, args_structs, meta) ready for .lower(*args)."""
    ok, why = cell_plan(arch, shape)
    if not ok:
        raise ValueError(f"cell ({arch}, {shape}) skipped: {why}")
    cell = SHAPES[shape]
    baxes = ("pod", "data") if multi_pod else ("data",)
    tp = mesh.shape["model"]
    import os
    sp = os.environ.get("REPRO_SP", "0") == "1"
    ctx = MeshContext(mesh, baxes, sp_matmuls=sp)

    def fsdp(spec_tree):
        # multi-pod: FSDP (ZeRO-3) spans the whole DP domain (pod × data)
        return T.retarget_fsdp(spec_tree, baxes) if multi_pod else spec_tree

    if cell.kind == "train":
        cfg = training_config(arch, tp)
        opt_cfg = AdamWConfig(state_dtype=OPT_STATE_DTYPE.get(arch, "float32"))
        step = make_train_step(cfg, opt_cfg, ctx)
        state_struct = jax.eval_shape(
            lambda: init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0)))
        state_sp = fsdp(train_state_pspecs(cfg))
        bst, bsp = _batch_struct(cfg, cell, baxes, with_targets=True)
        jitted = jax.jit(
            step,
            in_shardings=(_sh(mesh, state_sp), _sh(mesh, bsp)),
            out_shardings=(_sh(mesh, state_sp), None),
            donate_argnums=(0,),
        )
        return jitted, (state_struct, bst), {"config": cfg, "kind": "train"}

    cfg = serving_config(arch, tp)
    params_struct = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    params_sp = fsdp(T.param_pspecs(cfg))

    if cell.kind == "prefill":
        bst, bsp = _batch_struct(cfg, cell, baxes, with_targets=False)
        if cfg.encoder_only:
            def encode(params, batch):
                x = T.forward(params, cfg, batch, ctx)
                return T.logits_fn(params, cfg, x, ctx)
            jitted = jax.jit(
                encode,
                in_shardings=(_sh(mesh, params_sp), _sh(mesh, bsp)),
                out_shardings=_sh(mesh, P(baxes, "model", None)),
            )
            return jitted, (params_struct, bst), {"config": cfg,
                                                  "kind": "encode"}
        cs, cp, ring = _cache_struct(cfg, cell.global_batch, cell.seq_len,
                                     baxes, shard_seq_cache=False)

        def prefill_fn(params, batch, cache):
            return T.prefill(params, cfg, batch, cache, ring, ctx)

        jitted = jax.jit(
            prefill_fn,
            in_shardings=(_sh(mesh, params_sp), _sh(mesh, bsp), _sh(mesh, cp)),
            out_shardings=(_sh(mesh, P(baxes, "model")), _sh(mesh, cp)),
            donate_argnums=(2,),
        )
        return jitted, (params_struct, bst, cs), {"config": cfg,
                                                  "kind": "prefill"}

    # decode
    shard_seq = cell.global_batch == 1
    cs, cp, ring = _cache_struct(cfg, cell.global_batch, cell.seq_len,
                                 baxes, shard_seq_cache=shard_seq)
    tok_sp = P(baxes) if not shard_seq else P(None)
    logits_sp = P(baxes, "model") if not shard_seq else P(None, "model")

    def decode_fn(params, token, cache):
        return T.decode_step(params, cfg, token, cache, ring, ctx)

    jitted = jax.jit(
        decode_fn,
        in_shardings=(_sh(mesh, params_sp), _sh(mesh, tok_sp), _sh(mesh, cp)),
        out_shardings=(_sh(mesh, logits_sp), _sh(mesh, cp)),
        donate_argnums=(2,),
    )
    tok = jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32)
    return jitted, (params_struct, tok, cs), {"config": cfg, "kind": "decode"}
