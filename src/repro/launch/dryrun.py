import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count at first
init, and the production meshes need 512 host placeholder devices. Run:

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi_34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod/--single-pod]

Artifacts (memory analysis, cost analysis, per-collective byte totals) are
written to experiments/dryrun/<arch>__<shape>__<mesh>.json, consumed by
benchmarks/roofline.py and EXPERIMENTS.md §Dry-run/§Roofline.
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every tensor literal in an HLO type string
    (handles tuples like ``(f32[8,128], bf16[4])``)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Parse per-device optimized HLO; sum operand bytes per collective op.

    Operand shapes are recovered from each instruction's own result type
    table built in a first pass (covers named operands); fused constants and
    literals contribute 0.
    """
    result_type = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?([\w.\-]+) = (\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*) ", line)
        if m:
            result_type[m.group(1)] = m.group(2)

    stats = {c: {"count": 0, "operand_bytes": 0, "result_bytes": 0}
             for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?([\w.\-]+) = (\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*) ([a-z\-]+)\((.*)", line)
        if not m:
            continue
        name, rtype, op, rest = m.groups()
        kind = next((c for c in _COLLECTIVES if op == c or op == c + "-start"
                     or op == c + "-done"), None)
        if kind is None or op.endswith("-done"):
            continue
        stats[kind]["count"] += 1
        stats[kind]["result_bytes"] += _shape_bytes(rtype)
        # operand names up to the closing paren of the call
        args = rest.split(")")[0]
        ob = 0
        for tok in args.split(","):
            tok = tok.strip().lstrip("%")
            tok = tok.split(" ")[0]
            if tok in result_type:
                ob += _shape_bytes(result_type[tok])
        stats[kind]["operand_bytes"] += ob
    stats["total_operand_bytes"] = sum(
        v["operand_bytes"] for k, v in stats.items() if isinstance(v, dict))
    stats["total_result_bytes"] = sum(
        v["result_bytes"] for k, v in stats.items() if isinstance(v, dict))
    return stats


def memory_summary(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {"error": "memory_analysis() returned None"}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes", "host_argument_size_in_bytes",
                 "host_output_size_in_bytes", "host_temp_size_in_bytes",
                 "serialized_size_in_bytes"):
        try:
            v = getattr(ma, attr)
            if isinstance(v, int):
                out[attr] = v
        except Exception:
            pass
    if "argument_size_in_bytes" in out:
        out["per_device_hbm_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, save: bool = True,
             keep_hlo: bool = False) -> dict:
    import jax
    from repro.launch.mesh import make_production_mesh, mesh_devices
    from repro.launch.specs import build_cell
    from repro.launch.shapes import cell_plan

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    ok, why = cell_plan(arch, shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "status": "skip", "skip_reason": why}
    if not ok:
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        jitted, args, meta = build_cell(arch, shape, mesh, multi_pod)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        # cost_analysis() returns a dict on recent jax, [dict] on older
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        cost = dict(cost)
        mem = memory_summary(compiled)
        hlo = compiled.as_text()
        colls = collective_stats(hlo)
        from repro.analysis.hlo_cost import analyze_hlo
        corrected = analyze_hlo(hlo)

    cfg = meta["config"]
    rec.update({
        "status": "ok",
        "kind": meta["kind"],
        "devices": mesh_devices(mesh),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory": mem,
        "collectives": colls,          # raw text scan (no trip scaling)
        "corrected": corrected,        # trip-count-aware per-device model
        "n_params": cfg.n_params,
        "n_active_params": cfg.n_active_params,
        "hlo_lines": hlo.count("\n"),
    })
    if keep_hlo:
        rec["hlo_path"] = str(ARTIFACT_DIR / f"{arch}__{shape}__{mesh_name}.hlo")
        ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
        Path(rec["hlo_path"]).write_text(hlo)
    if save:
        ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
        path = ARTIFACT_DIR / f"{arch}__{shape}__{mesh_name}.json"
        path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true", default=None,
                    dest="multi_pod")
    ap.add_argument("--single-pod", action="store_false", dest="multi_pod")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.launch.shapes import all_cells

    if args.all:
        cells = [(a, s) for a, s, ok, _ in all_cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if args.multi_pod is None else [args.multi_pod]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            out = ARTIFACT_DIR / f"{arch}__{shape}__{mesh_name}.json"
            if args.skip_existing and out.exists():
                prev = json.loads(out.read_text())
                if prev.get("status") in ("ok", "skip"):
                    print(f"[cached] {arch} {shape} {mesh_name}: {prev['status']}")
                    continue
            try:
                rec = run_cell(arch, shape, mp, keep_hlo=args.keep_hlo)
                if rec["status"] == "skip":
                    print(f"[skip]   {arch} {shape} {mesh_name}: {rec['skip_reason']}")
                else:
                    mem = rec["memory"].get("per_device_hbm_bytes")
                    memg = f"{mem/2**30:.2f}GiB" if mem else "?"
                    fl = rec["corrected"]["flops"]
                    cb = rec["corrected"]["collectives"]["total_operand_bytes"]
                    print(f"[ok]     {arch} {shape} {mesh_name}: "
                          f"mem/dev={memg} flops/dev={fl:.3e} "
                          f"coll/dev={cb/2**30:.2f}GiB "
                          f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
            except Exception as e:
                failures += 1
                print(f"[FAIL]   {arch} {shape} {mesh_name}: {e}")
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "fail", "error": str(e)}
                ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
                out.write_text(json.dumps(rec, indent=1))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
