"""Production mesh factory.

Defined as functions (never module-level constants) so importing this
module never touches jax device state. The dry-run entry point
(launch/dryrun.py) sets ``XLA_FLAGS=--xla_force_host_platform_device_count
=512`` *before* any jax import; everything else sees the real device count.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The target mesh: one v5e pod = (data=16, model=16) = 256 chips;
    multi-pod = (pod=2, data=16, model=16) = 512 chips with pure-DP across
    the `pod` axis (DCN-crossing collectives are gradient all-reduce only).
    """
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None):
    """A mesh over whatever devices actually exist (tests / examples)."""
    import jax

    n = len(jax.devices())
    model = model or 1
    if model <= 0 or n % model != 0:
        raise ValueError(
            f"cannot build a (data={n}//{model}, model={model}) mesh: the "
            f"model-parallel degree must be a positive divisor of the "
            f"{n} available device(s); pick a divisor of {n} or use "
            f"make_sweep_mesh() for 1-D batch sharding")
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_sweep_mesh(n_items: Optional[int] = None):
    """A 1-D ``("data",)`` mesh for batch-sharded Monte-Carlo sweeps.

    Picks the largest usable device count: all devices, capped at
    ``n_items`` when given — sharding a chunk smaller than the machine
    across every device would leave devices with zero rows, which
    ``shard_map`` cannot express; capping instead lets uneven chunks pad up
    to the next multiple of the mesh size (see repro.sweeps.shard).
    """
    import jax

    n = len(jax.devices())
    d = n if n_items is None else max(1, min(int(n_items), n))
    return jax.make_mesh((d,), ("data",))


def batch_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def mesh_devices(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
