"""Production mesh factory.

Defined as functions (never module-level constants) so importing this
module never touches jax device state. The dry-run entry point
(launch/dryrun.py) sets ``XLA_FLAGS=--xla_force_host_platform_device_count
=512`` *before* any jax import; everything else sees the real device count.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The target mesh: one v5e pod = (data=16, model=16) = 256 chips;
    multi-pod = (pod=2, data=16, model=16) = 512 chips with pure-DP across
    the `pod` axis (DCN-crossing collectives are gradient all-reduce only).
    """
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None):
    """A mesh over whatever devices actually exist (tests / examples)."""
    import jax

    n = len(jax.devices())
    model = model or 1
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def batch_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def mesh_devices(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
