"""Training launcher: mesh-aware train loop with the full FT stack.

    PYTHONPATH=src python -m repro.launch.train --arch smollm_360m \
        --preset tiny --steps 50 --checkpoint-dir /tmp/ckpt

Wires together: config zoo → TokenPipeline (seekable) → make_train_step
(remat, grad-accum, optional gradient compression) → CheckpointManager
(async, atomic, keep-k, auto-resume) → StragglerMonitor hooks. On CPU it
runs reduced presets; on a TPU slice the same code path takes the
production mesh from launch.mesh.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import numpy as np


def run_training(arch: str = "smollm_360m", preset: str = "tiny",
                 steps: int = 30, global_batch: int = 8, seq_len: int = 64,
                 checkpoint_dir: Optional[str] = None, ckpt_every: int = 10,
                 grad_accum: int = 1, compression: Optional[str] = None,
                 lr: float = 1e-3, seed: int = 0, log_every: int = 10,
                 mesh=None, verbose: bool = True,
                 schedule_steps: int = 0):
    """Returns dict with loss trace and final state. Pure-CPU friendly."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_smoke_config
    from repro.data import TokenPipeline
    from repro.checkpoint import CheckpointManager
    from repro.distributed import ErrorFeedback
    from repro.models.layers import MeshContext
    from repro.training import (AdamWConfig, TrainState, init_train_state,
                                make_train_step, train_state_pspecs)

    cfg = get_smoke_config(arch) if preset == "tiny" else get_config(arch)
    cfg = cfg.with_(remat=True)
    sched = schedule_steps or steps
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(2, sched // 10),
                          total_steps=max(sched, 10))
    ctx = None
    if mesh is not None:
        ctx = MeshContext(mesh, ("data",))

    ef = ErrorFeedback(method=compression) if compression else None

    if ef is None:
        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, ctx, grad_accum=grad_accum),
            donate_argnums=(0,))
    else:
        # split step: grads → EF compression (stateful carry) → optimizer
        from repro.training.trainer import make_grad_and_apply
        grad_fn, apply_fn = map(jax.jit, make_grad_and_apply(cfg, opt_cfg, ctx))
        ef_transform = jax.jit(ef.transform)

    pipe = TokenPipeline(cfg, global_batch=global_batch, seq_len=seq_len,
                         seed=seed)
    state = init_train_state(cfg, opt_cfg, jax.random.PRNGKey(seed))
    start_step = 0
    carry = ef.init(state.params) if ef is not None else None

    mgr = None
    if checkpoint_dir:
        mgr = CheckpointManager(checkpoint_dir, keep=3, every=ckpt_every)
        restored = mgr.restore_latest(state)
        if restored[0] is not None:
            start_step, state = restored
            if verbose:
                print(f"[train] resumed from step {start_step}")

    losses = []
    t0 = time.perf_counter()
    for step in range(start_step, steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        if ef is None:
            state, metrics = step_fn(state, batch)
        else:
            loss_v, grads = grad_fn(state.params, batch)
            grads, carry = ef_transform(grads, carry)
            state, metrics = apply_fn(grads, state)
            metrics["loss"] = loss_v
        loss = float(metrics["loss"])
        losses.append(loss)
        if mgr:
            mgr.maybe_save(step + 1, state)
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"({(time.perf_counter()-t0)/(step-start_step+1):5.2f}s/it)")
    if mgr:
        mgr.wait()
    return {"losses": losses, "state": state, "config": cfg,
            "start_step": start_step}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compression", default=None,
                    choices=[None, "topk", "int8"])
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = run_training(**{k.replace("-", "_"): v
                          for k, v in vars(args).items()})
    print(f"[train] done; loss {out['losses'][0]:.4f} → {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
