"""AdamW with sharding-aware, dtype-configurable state.

No optax offline — this is a minimal production AdamW: decoupled weight
decay, bias correction, global-norm clipping, cosine LR schedule, and an
optimizer-state dtype policy (``float32`` default; ``bfloat16`` m/v for
memory-tight giants like qwen3-235B, where it halves optimizer HBM).
State pspecs mirror parameter pspecs exactly (states are elementwise), so
optimizer memory is fully sharded over the (data × model) mesh (ZeRO-3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: Any       # scalar int32
    m: Any          # pytree like params
    v: Any          # pytree like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    #: apply stacked-leaf updates layer-by-layer via lax.map. Measured on
    #: the XLA-CPU dry-run this *increased* peak temp bytes (scheduler kept
    #: slices live); default off. Left as a switch for TPU profiling.
    chunked_update: bool = False
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def adamw_state_pspecs(param_pspecs) -> AdamWState:
    from jax.sharding import PartitionSpec as P
    return AdamWState(
        step=P(),
        m=param_pspecs,
        v=param_pspecs,
    )


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else 1.0
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    def upd_leaf(p, g, m, v):
        # stacked per-layer leaves: apply the update layer-by-layer so the
        # f32 working copies are 1/n_layers of the leaf (peak-memory win on
        # 94-layer stacks — see EXPERIMENTS.md §Perf).
        if cfg.chunked_update and p.ndim >= 3 and p.shape[0] > 1 \
                and p.size > 2 ** 24:
            return jax.lax.map(lambda t: upd(*t), (p, g, m, v))
        return upd(p, g, m, v)

    out = jax.tree_util.tree_map(upd_leaf, params, grads, state.m, state.v)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
