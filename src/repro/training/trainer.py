"""Training step factory: mixed precision, grad accumulation, remat, and
optional gradient compression hooks (see repro.distributed.compression).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.layers import MeshContext
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, key) -> TrainState:
    params = T.init_params(cfg, key)
    return TrainState(params=params, opt=adamw_init(params, opt_cfg))


def train_state_pspecs(cfg: ModelConfig) -> TrainState:
    from .optimizer import adamw_state_pspecs
    pspecs = T.param_pspecs(cfg)
    return TrainState(params=pspecs, opt=adamw_state_pspecs(pspecs))


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    ctx: Optional[MeshContext] = None,
                    grad_accum: int = 1,
                    grad_transform: Optional[Callable] = None):
    """Build a jit-able ``train_step(state, batch) -> (state, metrics)``.

    ``grad_accum > 1`` scans microbatches (the per-microbatch gradient
    reduce-scatter overlaps the next microbatch's compute under XLA's
    latency-hiding scheduler — the standard comm/compute overlap trick).
    ``grad_transform`` hooks gradient compression (top-k / int8) before the
    optimizer; see repro.distributed.compression.
    """

    def loss_of(params, batch):
        return T.loss_fn(params, cfg, batch, ctx)

    def step(state: TrainState, batch: Dict[str, Any]):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_of)(state.params, batch)
        else:
            def micro(i, carry):
                loss_acc, grads_acc = carry
                mb = jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // grad_accum),
                        x.shape[0] // grad_accum, axis=0), batch)
                l, g = jax.value_and_grad(loss_of)(state.params, mb)
                return (loss_acc + l,
                        jax.tree_util.tree_map(jnp.add, grads_acc, g))
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            loss, grads = jax.lax.fori_loop(
                0, grad_accum, micro, (jnp.zeros((), jnp.float32), zeros))
            loss = loss / grad_accum
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, metrics = adamw_update(
            grads, state.opt, state.params, opt_cfg)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt), metrics

    return step


def make_grad_and_apply(cfg: ModelConfig, opt_cfg: AdamWConfig,
                        ctx: Optional[MeshContext] = None):
    """Split step for host-side gradient-compression loops:
    ``grad_fn(params, batch) -> (loss, grads)`` and
    ``apply_fn(grads, state) -> (state, metrics)`` — compression (with its
    error-feedback carry) runs between the two, outside the fused step."""

    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch, ctx))(params)

    def apply_fn(grads, state: TrainState):
        new_params, new_opt, metrics = adamw_update(
            grads, state.opt, state.params, opt_cfg)
        return TrainState(new_params, new_opt), metrics

    return grad_fn, apply_fn
