"""repro.training — optimizer, trainer, losses."""
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update, adamw_state_pspecs, lr_schedule, global_norm
from .trainer import TrainState, init_train_state, train_state_pspecs, make_train_step
