"""repro.kernels — Pallas TPU kernels for the compute hot spots.

Each kernel package: <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd dispatcher; interpret-mode off-TPU), ref.py (pure-jnp oracle).

* qos_matrix      — PIES control plane: tiled (users × implementations)
                    QoS evaluation (the paper's Eq. 1–6 at fleet scale).
* flash_attention — prefill/training attention, GQA-native, online softmax.
* gqa_decode      — single-token decode vs KV cache (bandwidth-bound path).
* ssd_scan        — Mamba2 SSD chunked scan (MXU-matmul reformulation).
"""
from .qos_matrix import ops as qos_ops
from .flash_attention import ops as attention_ops
from .gqa_decode import ops as decode_ops
from .ssd_scan import ops as ssd_ops
