"""Jit'd dispatcher for GQA decode attention."""
from __future__ import annotations

import functools

import jax

from .gqa_decode import gqa_decode
from .ref import gqa_decode_ref


@functools.partial(jax.jit, static_argnames=(
    "window", "ring", "softcap", "block_kv", "use_kernel"))
def decode_attention(q, k_cache, v_cache, kv_len, *, window: int = 0,
                     ring: bool = False, softcap: float = 0.0,
                     block_kv: int = 1024, use_kernel: bool = True):
    if not use_kernel:
        return gqa_decode_ref(q, k_cache, v_cache, kv_len, window=window,
                              ring=ring, softcap=softcap)
    return gqa_decode(q, k_cache, v_cache, kv_len, window=window, ring=ring,
                      softcap=softcap, block_kv=block_kv,
                      interpret=jax.default_backend() != "tpu")
