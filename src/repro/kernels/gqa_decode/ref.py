"""Pure-jnp oracle for the GQA decode kernel (mirrors layers.decode_attention_jnp)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gqa_decode_ref(q, k_cache, v_cache, kv_len, *, window: int = 0,
                   ring: bool = False, softcap: float = 0.0):
    B, Hq, hd = q.shape
    Sc, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(hd)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    idx = jnp.arange(Sc)[None, :]
    if ring:
        ok = (idx < kv_len[:, None]) | (kv_len[:, None] > Sc)
    else:
        ok = idx < kv_len[:, None]
        if window:
            ok &= idx > (kv_len[:, None] - 1 - window)
    s = jnp.where(ok[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, hd).astype(q.dtype)
