"""Pallas TPU GQA decode attention (one query token vs a KV cache).

Decode is HBM-bandwidth bound: the whole useful cache is read once per
step. Grid ``(B, Hkv, nk)`` streams kv blocks innermost; the G query heads
of a KV group attend together ([G, hd] query tile ⇒ the score matmul is
[G, hd]×[hd, bkv] on the MXU). Online-softmax state lives in VMEM scratch;
validity masking (cache length / sliding window / ring wrap) is computed
from the per-row cache length carried in a [B, 1] SMEM-friendly tile.

Block sizes: kv block 1024 at hd=128 ⇒ k+v tiles ≈ 512 KiB — sized to
keep the streaming pipeline deep rather than for MXU occupancy (decode is
a bandwidth workload; see EXPERIMENTS.md §Roofline decode rows).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
SAFE = -1e20


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, window: int, ring: bool,
                   kv_steps: int, block_kv: int, cache_len: int,
                   softcap: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)          # [bkv, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)
    kv_len = len_ref[0, 0]                          # valid entries (= pos+1)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    idx = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if ring:
        # ring buffer of size cache_len: every slot valid once wrapped
        ok = jnp.logical_or(idx < kv_len, kv_len > cache_len)
    else:
        ok = idx < kv_len
        if window:
            ok &= idx > kv_len - 1 - window
    s = jnp.where(ok, s, NEG)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    m_safe = jnp.maximum(m_new, SAFE)
    p = jnp.exp(s - m_safe)
    corr = jnp.exp(jnp.maximum(m_prev, SAFE) - m_safe) \
        * (m_prev > NEG / 2).astype(jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_prev * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == kv_steps - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def gqa_decode(q, k_cache, v_cache, kv_len, *, window: int = 0,
               ring: bool = False, softcap: float = 0.0,
               block_kv: int = 1024, interpret: bool = False):
    """q: [B, Hq, hd]; k/v_cache: [B, Sc, Hkv, hd]; kv_len: [B] int32.
    Returns [B, Hq, hd]."""
    B, Hq, hd = q.shape
    Sc, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    bkv = min(block_kv, Sc)
    nk = pl.cdiv(Sc, bkv)
    scale = 1.0 / math.sqrt(hd)

    def padseq(x):
        n = nk * bkv
        return jnp.pad(x, ((0, 0), (0, n - x.shape[1]), (0, 0), (0, 0))) \
            if n != x.shape[1] else x

    qg = q.reshape(B, Hkv, G, hd)
    kp, vp = padseq(k_cache), padseq(v_cache)
    lens = kv_len.astype(jnp.int32).reshape(B, 1)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, ring=ring,
        kv_steps=nk, block_kv=bkv, cache_len=Sc, softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bkv, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bkv, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kp, vp, lens)
    return out.reshape(B, Hq, hd)
