"""Jit'd dispatcher for the SSD chunk scan."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_scan
from .ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel"))
def ssd(x, dtA, b, c, *, chunk: int = 256, use_kernel: bool = True):
    if not use_kernel:
        return ssd_scan_ref(x, dtA, b, c)
    L = x.shape[1]
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtA = jnp.pad(dtA, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    y, st = ssd_scan(x, dtA, b, c, chunk=chunk,
                     interpret=jax.default_backend() != "tpu")
    return y[:, :L], st
