"""Pure-jnp oracle for the SSD scan kernel: the naive recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dtA, b, c):
    """Sequential reference: h ← h·exp(ΔA) + B ⊗ x; y = C·h.
    x: [B, L, H, P]; dtA: [B, L, H]; b, c: [B, L, N]."""
    Bsz, L, H, P = x.shape
    N = b.shape[-1]

    def step(state, t):
        xt, at, bt, ct = t
        state = state * jnp.exp(at)[..., None, None] \
            + jnp.einsum("bn,bhp->bhpn", bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    s0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dtA.transpose(1, 0, 2).astype(jnp.float32),
          b.transpose(1, 0, 2).astype(jnp.float32),
          c.transpose(1, 0, 2).astype(jnp.float32))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), final
