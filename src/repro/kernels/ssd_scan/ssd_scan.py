"""Pallas TPU kernel for the Mamba2 SSD chunked scan (arXiv:2405.21060).

TPU adaptation of the CUDA selective-scan: instead of warp-level scans,
the sequence is chunked so almost all work is MXU matmuls —

  per chunk c (grid innermost, sequential):
    L      = exp(segsum(ΔA))              [Q, Q] lower-triangular decay
    Y_diag = (C Bᵀ ∘ L) X                 intra-chunk (two [Q,·] matmuls)
    Y_off  = C · stateᵀ ∘ exp(cumΔA)      inter-chunk from carried state
    state  = state·exp(sumΔA) + (B ∘ decay)ᵀ X    [P, N] carried in VMEM

The recurrent state ([P, N] f32, e.g. 64×128 = 32 KiB) lives in VMEM
scratch across the chunk axis — the only sequential dependence — while
X/B/C chunk tiles stream through. Q=256, P=64, N=128 keeps every matmul
dimension MXU-friendly and the working set ≈ 1.5 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_out_ref, state_ref, *,
                chunks: int, block_q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)        # [Q, P]
    a = a_ref[0, 0].astype(jnp.float32)        # [Q]   (Δ·A, ≤ 0)
    bm = b_ref[0].astype(jnp.float32)          # [Q, N]
    cm = c_ref[0].astype(jnp.float32)          # [Q, N]

    a_cum = jnp.cumsum(a)                      # [Q]
    # lower-triangular pairwise decay L[i, j] = exp(Σ_{j<t≤i} a_t)
    seg = a_cum[:, None] - a_cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)

    # intra-chunk: scores [Q, Q] = (C Bᵀ) ∘ L, then Y_diag = scores @ X
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    state = state_ref[...]                     # [P, N]
    y_off = jax.lax.dot_general(cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + y_off * jnp.exp(a_cum)[:, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: state·exp(ΣΔA) + Xᵀ (B ∘ decay)
    decay = jnp.exp(a_cum[-1] - a_cum)         # [Q]
    contrib = jax.lax.dot_general(
        x, bm * decay[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)    # [P, N]
    new_state = state * jnp.exp(a_cum[-1]) + contrib
    state_ref[...] = new_state

    @pl.when(ci == chunks - 1)
    def _final():
        st_out_ref[0, 0] = new_state.astype(st_out_ref.dtype)


def ssd_scan(x, dtA, b, c, *, chunk: int = 256, interpret: bool = False):
    """x: [B, L, H, P] (already Δ-scaled); dtA: [B, L, H]; b, c: [B, L, N].
    Returns (y [B, L, H, P] f32, final_state [B, H, P, N] f32).
    L must be a multiple of ``chunk`` (callers pad)."""
    Bsz, Lseq, H, Pdim = x.shape
    N = b.shape[-1]
    assert Lseq % chunk == 0, "pad sequence to the chunk size"
    nc = Lseq // chunk

    xh = x.transpose(0, 2, 1, 3)               # [B, H, L, P]
    ah = dtA.transpose(0, 2, 1)                # [B, H, L]

    kernel = functools.partial(_ssd_kernel, chunks=nc, block_q=chunk)
    y, st = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, Pdim), lambda bi, h, ci: (bi, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, h, ci: (bi, h, ci)),
            pl.BlockSpec((1, chunk, N), lambda bi, h, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, h, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, Pdim), lambda bi, h, ci: (bi, h, ci, 0)),
            pl.BlockSpec((1, 1, Pdim, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, Lseq, Pdim), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, Pdim, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Pdim, N), jnp.float32)],
        interpret=interpret,
    )(xh, ah, b, c)
    return y.transpose(0, 2, 1, 3), st
