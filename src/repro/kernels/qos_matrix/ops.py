"""Jit'd dispatcher: Pallas on TPU, interpret-mode kernel or jnp elsewhere."""
from __future__ import annotations

import functools

import jax

from .qos_matrix import qos_matrix_pallas
from .ref import qos_matrix_ref


@functools.partial(jax.jit, static_argnames=("delta_max", "use_kernel"))
def qos_matrix(u_alpha, u_delta, u_share_k, u_share_w, u_service,
               sm_acc, sm_k, sm_w, sm_service, *, delta_max: float,
               use_kernel: bool = True):
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel:
        return qos_matrix_pallas(
            u_alpha, u_delta, u_share_k, u_share_w, u_service,
            sm_acc, sm_k, sm_w, sm_service, delta_max=delta_max,
            interpret=not on_tpu)
    return qos_matrix_ref(
        u_alpha, u_delta, u_share_k, u_share_w, u_service,
        sm_acc, sm_k, sm_w, sm_service, delta_max=delta_max)


def qos_matrix_from_instance(jinst, use_kernel: bool = True):
    """Convenience wrapper over a repro.core JaxInstance."""
    return qos_matrix(
        jinst.u_alpha, jinst.u_delta, jinst.u_share_k, jinst.u_share_w,
        jinst.u_service, jinst.sm_acc, jinst.sm_k, jinst.sm_w,
        jinst.sm_service, delta_max=float(jinst.delta_max),
        use_kernel=use_kernel)
