"""Jit'd dispatcher: Pallas on TPU, interpret-mode kernel or jnp elsewhere."""
from __future__ import annotations

import functools

import jax

from repro.obs import kernel_span, named_scope

from .qos_matrix import (greedy_argmax_pallas, qos_candidates_pallas,
                         qos_matrix_pallas)
from .ref import greedy_argmax_ref, qos_candidates_ref, qos_matrix_ref


@functools.partial(jax.jit, static_argnames=("delta_max", "use_kernel"))
def qos_matrix(u_alpha, u_delta, u_share_k, u_share_w, u_service,
               sm_acc, sm_k, sm_w, sm_service, *, delta_max: float,
               use_kernel: bool = True):
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel:
        with named_scope("qos_matrix_pallas"):
            return qos_matrix_pallas(
                u_alpha, u_delta, u_share_k, u_share_w, u_service,
                sm_acc, sm_k, sm_w, sm_service, delta_max=delta_max,
                interpret=not on_tpu)
    with named_scope("qos_matrix_ref"):
        return qos_matrix_ref(
            u_alpha, u_delta, u_share_k, u_share_w, u_service,
            sm_acc, sm_k, sm_w, sm_service, delta_max=delta_max)


@functools.partial(jax.jit, static_argnames=("delta_max", "use_kernel"))
def qos_candidates(u_alpha, u_delta, u_share_k, u_share_w,
                   cand_acc, cand_k, cand_w, cand_valid, *,
                   delta_max: float, use_kernel: bool = True):
    """Segmented QoS over pre-gathered ``(user, candidate)`` pairs [U, K]."""
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel:
        with named_scope("qos_candidates_pallas"):
            return qos_candidates_pallas(
                u_alpha, u_delta, u_share_k, u_share_w,
                cand_acc, cand_k, cand_w, cand_valid,
                delta_max=delta_max, interpret=not on_tpu)
    with named_scope("qos_candidates_ref"):
        return qos_candidates_ref(
            u_alpha, u_delta, u_share_k, u_share_w,
            cand_acc, cand_k, cand_w, cand_valid, delta_max=delta_max)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def greedy_argmax(v, mask, *, use_kernel: bool = True):
    """Masked per-edge argmax over the benefit map (Alg. 3 line 11)."""
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel:
        with named_scope("greedy_argmax_pallas"):
            return greedy_argmax_pallas(v, mask, interpret=not on_tpu)
    with named_scope("greedy_argmax_ref"):
        return greedy_argmax_ref(v, mask)


def qos_matrix_from_instance(jinst, use_kernel: bool = True):
    """Convenience wrapper over a repro.core JaxInstance."""
    from .qos_matrix import check_service_ids

    check_service_ids(jinst.u_service, jinst.sm_service)
    # the obs span covers dispatch only (JAX is async); benchmarks that
    # want honest kernel wall time block_until_ready inside their own span
    with kernel_span("qos_matrix", U=int(jinst.u_alpha.shape[0]),
                     P=int(jinst.sm_acc.shape[0]), use_kernel=use_kernel):
        return qos_matrix(
            jinst.u_alpha, jinst.u_delta, jinst.u_share_k, jinst.u_share_w,
            jinst.u_service, jinst.sm_acc, jinst.sm_k, jinst.sm_w,
            jinst.sm_service, delta_max=float(jinst.delta_max),
            use_kernel=use_kernel)


def qos_candidates_from_instance(jinst, table, k=None, *,
                                 use_kernel: bool = True):
    """Top-k candidate build (gather + segmented QoS kernel + top-k) from a
    JaxInstance and a host-built impl table; returns ``(cand_idx, cand_q)``.
    """
    from repro.core.candidates import topk_candidates_jnp

    U = int(jinst.u_alpha.shape[0])
    with kernel_span("qos_candidates", U=U, k=-1 if k is None else int(k),
                     use_kernel=use_kernel):
        return topk_candidates_jnp(jinst, table, k, use_kernel=use_kernel)
