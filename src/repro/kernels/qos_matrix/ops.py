"""Jit'd dispatcher: Pallas on TPU, interpret-mode kernel or jnp elsewhere."""
from __future__ import annotations

import functools

import jax

from repro.obs import kernel_span, named_scope

from .qos_matrix import qos_matrix_pallas
from .ref import qos_matrix_ref


@functools.partial(jax.jit, static_argnames=("delta_max", "use_kernel"))
def qos_matrix(u_alpha, u_delta, u_share_k, u_share_w, u_service,
               sm_acc, sm_k, sm_w, sm_service, *, delta_max: float,
               use_kernel: bool = True):
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel:
        with named_scope("qos_matrix_pallas"):
            return qos_matrix_pallas(
                u_alpha, u_delta, u_share_k, u_share_w, u_service,
                sm_acc, sm_k, sm_w, sm_service, delta_max=delta_max,
                interpret=not on_tpu)
    with named_scope("qos_matrix_ref"):
        return qos_matrix_ref(
            u_alpha, u_delta, u_share_k, u_share_w, u_service,
            sm_acc, sm_k, sm_w, sm_service, delta_max=delta_max)


def qos_matrix_from_instance(jinst, use_kernel: bool = True):
    """Convenience wrapper over a repro.core JaxInstance."""
    # the obs span covers dispatch only (JAX is async); benchmarks that
    # want honest kernel wall time block_until_ready inside their own span
    with kernel_span("qos_matrix", U=int(jinst.u_alpha.shape[0]),
                     P=int(jinst.sm_acc.shape[0]), use_kernel=use_kernel):
        return qos_matrix(
            jinst.u_alpha, jinst.u_delta, jinst.u_share_k, jinst.u_share_w,
            jinst.u_service, jinst.sm_acc, jinst.sm_k, jinst.sm_w,
            jinst.sm_service, delta_max=float(jinst.delta_max),
            use_kernel=use_kernel)
