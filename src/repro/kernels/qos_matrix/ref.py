"""Pure-jnp oracle for the QoS matrix kernel."""
from __future__ import annotations

import jax.numpy as jnp


def qos_matrix_ref(u_alpha, u_delta, u_share_k, u_share_w, u_service,
                   sm_acc, sm_k, sm_w, sm_service, *, delta_max: float):
    f32 = jnp.float32
    adiff = u_alpha.astype(f32)[:, None] - sm_acc.astype(f32)[None, :]
    a_hat = jnp.where(adiff <= 0.0, 1.0, jnp.maximum(0.0, 1.0 - adiff))
    d = (sm_k.astype(f32)[None, :] * u_share_k.astype(f32)[:, None]
         + sm_w.astype(f32)[None, :] * u_share_w.astype(f32)[:, None])
    over = d - u_delta.astype(f32)[:, None]
    d_hat = jnp.where(over <= 0.0, 1.0,
                      jnp.maximum(0.0, 1.0 - over / delta_max))
    elig = (u_service[:, None] == sm_service[None, :]).astype(f32)
    return 0.5 * (a_hat + d_hat) * elig
