"""Pure-jnp oracles for the QoS-matrix / segmented-placement kernels."""
from __future__ import annotations

import jax.numpy as jnp


def qos_matrix_ref(u_alpha, u_delta, u_share_k, u_share_w, u_service,
                   sm_acc, sm_k, sm_w, sm_service, *, delta_max: float):
    f32 = jnp.float32
    adiff = u_alpha.astype(f32)[:, None] - sm_acc.astype(f32)[None, :]
    a_hat = jnp.where(adiff <= 0.0, 1.0, jnp.maximum(0.0, 1.0 - adiff))
    d = (sm_k.astype(f32)[None, :] * u_share_k.astype(f32)[:, None]
         + sm_w.astype(f32)[None, :] * u_share_w.astype(f32)[:, None])
    over = d - u_delta.astype(f32)[:, None]
    d_hat = jnp.where(over <= 0.0, 1.0,
                      jnp.maximum(0.0, 1.0 - over / delta_max))
    elig = (u_service[:, None] == sm_service[None, :]).astype(f32)
    return 0.5 * (a_hat + d_hat) * elig


def qos_candidates_ref(u_alpha, u_delta, u_share_k, u_share_w,
                       cand_acc, cand_k, cand_w, cand_valid, *,
                       delta_max: float):
    """Segmented QoS over pre-gathered ``(user, candidate)`` pairs [U, K]."""
    f32 = jnp.float32
    adiff = u_alpha.astype(f32)[:, None] - cand_acc.astype(f32)
    a_hat = jnp.where(adiff <= 0.0, 1.0, jnp.maximum(0.0, 1.0 - adiff))
    d = (cand_k.astype(f32) * u_share_k.astype(f32)[:, None]
         + cand_w.astype(f32) * u_share_w.astype(f32)[:, None])
    over = d - u_delta.astype(f32)[:, None]
    d_hat = jnp.where(over <= 0.0, 1.0,
                      jnp.maximum(0.0, 1.0 - over / delta_max))
    return 0.5 * (a_hat + d_hat) * cand_valid.astype(f32)


def greedy_argmax_ref(v, mask):
    """Masked row argmax: ``(best [E] f32, idx [E] i32)``, −1 on empty rows."""
    f32 = jnp.float32
    NEG = f32(-1e30)
    masked = jnp.where(mask.astype(f32) > 0.0, v.astype(f32), NEG)
    best = jnp.max(masked, axis=1)
    idx = jnp.argmax(masked, axis=1).astype(jnp.int32)
    has = (mask.astype(f32) > 0.0).any(axis=1)
    return jnp.where(has, best, NEG), jnp.where(has, idx, -1)
