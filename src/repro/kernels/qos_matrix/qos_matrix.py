"""Pallas TPU kernels for the PIES placement hot path.

At fleet scale the placement controller evaluates ``Q(u, s, m)`` for every
(request × implementation) pair each control tick — U ~ 10⁶, P ~ 10³ — and
this elementwise-broadcast evaluation is the control-plane hot spot. Three
kernels, all pure VPU work (compare/select/FMA — no MXU):

* :func:`qos_matrix_pallas` — the dense ``[U, P]`` QoS matrix (Eqs. 1–6),
  tiled (users × service-models): per-user vectors arrive as [BU, 1]
  column tiles, per-model vectors as [1, BP] row tiles.
* :func:`qos_candidates_pallas` — the *segmented* variant: QoS over
  pre-gathered ``(user, candidate)`` pairs in ``[BU, BK]`` tiles, where
  ``K = top-k`` eligible implementations per user (≈ 10) instead of all
  ``P``. Work and memory scale with ``U·k``, which is what the sparse EGP
  path at 10⁵–10⁶ users runs on.
* :func:`greedy_argmax_pallas` — masked per-edge argmax over the greedy
  benefit map ``v [E, P]`` (the segment-max that picks line 11's ``p*``
  for every edge at once), with ``jnp.argmax``'s first-maximum tie rule.

Tile sizes default to (256, 256) for the dense kernel: (1 + 1 + out)
tiles ≈ 256·256·4 B ≈ 260 KiB ≪ 16 MiB VMEM, and the lane dimension is a
multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

import numpy as np

_I32 = np.iinfo(np.int32)


def check_service_ids(*arrays) -> None:
    """Guard the kernels' int32 id downcast.

    The kernels compare service ids in int32. Concrete integer inputs that
    do not fit int32 would wrap silently on ``.astype(int32)`` and corrupt
    the eligibility mask, so reject them loudly. Tracers (inside ``jit``)
    are skipped — values are unknown there, and every realistic catalog
    (ids < 2³¹) is unaffected.
    """
    for x in arrays:
        if isinstance(x, jax.core.Tracer):
            continue
        arr = np.asarray(x)
        if np.issubdtype(arr.dtype, np.integer) and arr.size:
            lo, hi = int(arr.min()), int(arr.max())
            if hi > _I32.max or lo < _I32.min:
                raise OverflowError(
                    f"service ids [{lo}, {hi}] overflow int32; the Pallas "
                    "QoS kernels compare ids in int32 — re-index the "
                    "service catalog below 2**31 entries")


def _qos_kernel(alpha_ref, delta_ref, sk_ref, sw_ref, us_ref,
                acc_ref, k_ref, w_ref, ms_ref, out_ref, *, delta_max: float):
    alpha = alpha_ref[...]          # [BU, 1]
    delta = delta_ref[...]          # [BU, 1]
    share_k = sk_ref[...]           # [BU, 1]  |U_e|/K_e gathered per user
    share_w = sw_ref[...]           # [BU, 1]
    uservc = us_ref[...]            # [BU, 1]  requested service id
    acc = acc_ref[...]              # [1, BP]
    kcost = k_ref[...]              # [1, BP]
    wcost = w_ref[...]              # [1, BP]
    msvc = ms_ref[...]              # [1, BP]  model's service id

    # Eq. (2): accuracy satisfaction
    adiff = alpha - acc
    a_hat = jnp.where(adiff <= 0.0, 1.0, jnp.maximum(0.0, 1.0 - adiff))
    # Eq. (4)–(6): delay under even sharing
    d = kcost * share_k + wcost * share_w
    over = d - delta
    # Eq. (3): delay satisfaction
    d_hat = jnp.where(over <= 0.0, 1.0,
                      jnp.maximum(0.0, 1.0 - over / delta_max))
    elig = (uservc == msvc).astype(a_hat.dtype)
    out_ref[...] = 0.5 * (a_hat + d_hat) * elig


def qos_matrix_pallas(u_alpha, u_delta, u_share_k, u_share_w, u_service,
                      sm_acc, sm_k, sm_w, sm_service, *, delta_max: float,
                      block_u: int = 256, block_p: int = 256,
                      interpret: bool = False):
    """Q [U, P] float32. Inputs are 1-D per-user / per-model vectors.

    Dtype contract: the kernel computes in **float32** — float inputs are
    downcast with ``.astype(float32)`` (float64 loses precision beyond
    ~7 decimal digits; parity with the float64 host path
    :func:`repro.core.qos.qos_matrix_np` holds to ~1e-6 relative, and
    callers comparing against it must use f32 tolerances, not exact
    equality). Service ids are compared in **int32**; concrete ids outside
    int32 range raise :class:`OverflowError` instead of wrapping (see
    :func:`check_service_ids`).
    """
    check_service_ids(u_service, sm_service)
    U, Pn = u_alpha.shape[0], sm_acc.shape[0]
    gu, gp = pl.cdiv(U, block_u), pl.cdiv(Pn, block_p)
    Upad, Ppad = gu * block_u, gp * block_p

    def pad(x, n):
        return jnp.pad(x, (0, n - x.shape[0])) if n != x.shape[0] else x

    ucol = lambda x: pad(x, Upad).reshape(Upad, 1)
    prow = lambda x: pad(x, Ppad).reshape(1, Ppad)

    f32 = jnp.float32
    args = (
        ucol(u_alpha.astype(f32)), ucol(u_delta.astype(f32)),
        ucol(u_share_k.astype(f32)), ucol(u_share_w.astype(f32)),
        ucol(u_service.astype(jnp.int32)),
        prow(sm_acc.astype(f32)), prow(sm_k.astype(f32)),
        prow(sm_w.astype(f32)), prow(sm_service.astype(jnp.int32)),
    )
    uspec = pl.BlockSpec((block_u, 1), lambda i, j: (i, 0))
    pspec = pl.BlockSpec((1, block_p), lambda i, j: (0, j))
    out = pl.pallas_call(
        functools.partial(_qos_kernel, delta_max=float(delta_max)),
        grid=(gu, gp),
        in_specs=[uspec] * 5 + [pspec] * 4,
        out_specs=pl.BlockSpec((block_u, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Upad, Ppad), f32),
        interpret=interpret,
    )(*args)
    return out[:U, :Pn]


def _qos_cand_kernel(alpha_ref, delta_ref, sk_ref, sw_ref,
                     acc_ref, k_ref, w_ref, valid_ref, out_ref,
                     *, delta_max: float):
    alpha = alpha_ref[...]          # [BU, 1] per-user columns
    delta = delta_ref[...]
    share_k = sk_ref[...]
    share_w = sw_ref[...]
    acc = acc_ref[...]              # [BU, BK] pre-gathered candidate attrs
    kcost = k_ref[...]
    wcost = w_ref[...]
    valid = valid_ref[...]          # [BU, BK] 1.0 where the slot is real

    adiff = alpha - acc             # Eq. (2)
    a_hat = jnp.where(adiff <= 0.0, 1.0, jnp.maximum(0.0, 1.0 - adiff))
    d = kcost * share_k + wcost * share_w     # Eqs. (4)–(6)
    over = d - delta
    d_hat = jnp.where(over <= 0.0, 1.0,       # Eq. (3)
                      jnp.maximum(0.0, 1.0 - over / delta_max))
    out_ref[...] = 0.5 * (a_hat + d_hat) * valid


def qos_candidates_pallas(u_alpha, u_delta, u_share_k, u_share_w,
                          cand_acc, cand_k, cand_w, cand_valid, *,
                          delta_max: float, block_u: int = 256,
                          block_k: int = 128, interpret: bool = False):
    """Segmented QoS over ``(user, candidate)`` pairs → ``[U, K] float32``.

    Inputs: per-user vectors ``u_* [U]`` plus candidate attribute tables
    ``cand_* [U, K]`` pre-gathered by :func:`repro.core.candidates
    .topk_candidates_jnp` (model accuracy / kernel cost / weight cost per
    candidate slot) and ``cand_valid [U, K]`` float mask (0 for padded
    slots, whose output is forced to 0 — eligibility is already baked into
    the candidate gather, so no id compare happens here).

    Same float32 dtype contract as :func:`qos_matrix_pallas`. ``K`` is
    padded up to a lane multiple (``block_k``); the caller's true K (≈ 10)
    makes this kernel's footprint ``U·block_k`` — independent of ``P``.
    """
    U, K = cand_acc.shape
    gu, gk = pl.cdiv(U, block_u), pl.cdiv(K, block_k)
    Upad, Kpad = gu * block_u, gk * block_k
    f32 = jnp.float32

    def pad2(x):
        if x.shape == (Upad, Kpad):
            return x.astype(f32)
        return jnp.pad(x.astype(f32),
                       ((0, Upad - U), (0, Kpad - K)))

    def ucol(x):
        x = x.astype(f32)
        if U != Upad:
            x = jnp.pad(x, (0, Upad - U))
        return x.reshape(Upad, 1)

    args = (ucol(u_alpha), ucol(u_delta), ucol(u_share_k), ucol(u_share_w),
            pad2(cand_acc), pad2(cand_k), pad2(cand_w), pad2(cand_valid))
    uspec = pl.BlockSpec((block_u, 1), lambda i, j: (i, 0))
    kspec = pl.BlockSpec((block_u, block_k), lambda i, j: (i, j))
    out = pl.pallas_call(
        functools.partial(_qos_cand_kernel, delta_max=float(delta_max)),
        grid=(gu, gk),
        in_specs=[uspec] * 4 + [kspec] * 4,
        out_specs=pl.BlockSpec((block_u, block_k), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Upad, Kpad), f32),
        interpret=interpret,
    )(*args)
    return out[:U, :K]


def _greedy_argmax_kernel(v_ref, mask_ref, best_ref, idx_ref):
    v = v_ref[...]                  # [BE, Kp] benefit rows (full width)
    m = mask_ref[...]               # [BE, Kp] 1.0 on candidate slots
    Kp = v.shape[1]
    NEG = jnp.float32(-1e30)
    masked = jnp.where(m > 0.0, v, NEG)
    best = jnp.max(masked, axis=1, keepdims=True)          # [BE, 1]
    cols = jax.lax.broadcasted_iota(jnp.int32, masked.shape, 1)
    # first-maximum tie rule, same as jnp.argmax
    idx = jnp.min(jnp.where(masked == best, cols, Kp), axis=1,
                  keepdims=True)
    has = jnp.max(m, axis=1, keepdims=True) > 0.0
    best_ref[...] = jnp.where(has, best, NEG)
    idx_ref[...] = jnp.where(has, idx, -1)


def greedy_argmax_pallas(v, mask, *, block_e: int = 8,
                         interpret: bool = False):
    """Masked row argmax for the per-edge greedy pick (Alg. 3 line 11).

    ``v [E, P] float32`` is the benefit map, ``mask [E, P]`` float (1.0 on
    unconsidered relevant candidates — the segment of each edge's benefit
    row still in play). Returns ``(best [E] float32, idx [E] int32)`` with
    ``idx = -1`` (and ``best = -1e30``) for rows with an empty mask.
    Tie-break matches ``jnp.argmax`` (first maximum). Benefit values may
    be negative — masking uses a −1e30 sentinel, not 0.

    Each grid step loads ``block_e`` full benefit rows (P padded to a lane
    multiple of 128): at P ~ 10³ a [8, 1024] tile is 32 KiB — the argmax
    is row-local so no cross-tile reduction is needed.
    """
    E, P = v.shape
    ge = pl.cdiv(E, block_e)
    Epad = ge * block_e
    Ppad = pl.cdiv(P, 128) * 128
    f32 = jnp.float32

    def pad2(x):
        if x.shape == (Epad, Ppad):
            return x.astype(f32)
        return jnp.pad(x.astype(f32), ((0, Epad - E), (0, Ppad - P)))

    rspec = pl.BlockSpec((block_e, Ppad), lambda i: (i, 0))
    best, idx = pl.pallas_call(
        _greedy_argmax_kernel,
        grid=(ge,),
        in_specs=[rspec, rspec],
        out_specs=[pl.BlockSpec((block_e, 1), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((Epad, 1), f32),
                   jax.ShapeDtypeStruct((Epad, 1), jnp.int32)],
        interpret=interpret,
    )(pad2(v), pad2(mask))
    return best[:E, 0], idx[:E, 0]
