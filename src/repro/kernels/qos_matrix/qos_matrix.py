"""Pallas TPU kernel for the PIES QoS matrix (Eqs. 1–6).

At fleet scale the placement controller evaluates ``Q(u, s, m)`` for every
(request × implementation) pair each control tick — U ~ 10⁶, P ~ 10³ — and
this elementwise-broadcast evaluation is the control-plane hot spot. The
kernel tiles (users × service-models) into VMEM blocks: per-user vectors
arrive as [BU, 1] column tiles, per-model vectors as [1, BP] row tiles, and
the [BU, BP] output tile is pure VPU work (compare/select/FMA — no MXU).

Tile sizes default to (256, 256): (1 + 1 + out) tiles ≈ 256·256·4 B ≈
260 KiB ≪ 16 MiB VMEM, and the lane dimension (BP) is a multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qos_kernel(alpha_ref, delta_ref, sk_ref, sw_ref, us_ref,
                acc_ref, k_ref, w_ref, ms_ref, out_ref, *, delta_max: float):
    alpha = alpha_ref[...]          # [BU, 1]
    delta = delta_ref[...]          # [BU, 1]
    share_k = sk_ref[...]           # [BU, 1]  |U_e|/K_e gathered per user
    share_w = sw_ref[...]           # [BU, 1]
    uservc = us_ref[...]            # [BU, 1]  requested service id
    acc = acc_ref[...]              # [1, BP]
    kcost = k_ref[...]              # [1, BP]
    wcost = w_ref[...]              # [1, BP]
    msvc = ms_ref[...]              # [1, BP]  model's service id

    # Eq. (2): accuracy satisfaction
    adiff = alpha - acc
    a_hat = jnp.where(adiff <= 0.0, 1.0, jnp.maximum(0.0, 1.0 - adiff))
    # Eq. (4)–(6): delay under even sharing
    d = kcost * share_k + wcost * share_w
    over = d - delta
    # Eq. (3): delay satisfaction
    d_hat = jnp.where(over <= 0.0, 1.0,
                      jnp.maximum(0.0, 1.0 - over / delta_max))
    elig = (uservc == msvc).astype(a_hat.dtype)
    out_ref[...] = 0.5 * (a_hat + d_hat) * elig


def qos_matrix_pallas(u_alpha, u_delta, u_share_k, u_share_w, u_service,
                      sm_acc, sm_k, sm_w, sm_service, *, delta_max: float,
                      block_u: int = 256, block_p: int = 256,
                      interpret: bool = False):
    """Q [U, P] float32. Inputs are 1-D per-user / per-model vectors."""
    U, Pn = u_alpha.shape[0], sm_acc.shape[0]
    gu, gp = pl.cdiv(U, block_u), pl.cdiv(Pn, block_p)
    Upad, Ppad = gu * block_u, gp * block_p

    def pad(x, n):
        return jnp.pad(x, (0, n - x.shape[0])) if n != x.shape[0] else x

    ucol = lambda x: pad(x, Upad).reshape(Upad, 1)
    prow = lambda x: pad(x, Ppad).reshape(1, Ppad)

    f32 = jnp.float32
    args = (
        ucol(u_alpha.astype(f32)), ucol(u_delta.astype(f32)),
        ucol(u_share_k.astype(f32)), ucol(u_share_w.astype(f32)),
        ucol(u_service.astype(jnp.int32)),
        prow(sm_acc.astype(f32)), prow(sm_k.astype(f32)),
        prow(sm_w.astype(f32)), prow(sm_service.astype(jnp.int32)),
    )
    uspec = pl.BlockSpec((block_u, 1), lambda i, j: (i, 0))
    pspec = pl.BlockSpec((1, block_p), lambda i, j: (0, j))
    out = pl.pallas_call(
        functools.partial(_qos_kernel, delta_max=float(delta_max)),
        grid=(gu, gp),
        in_specs=[uspec] * 5 + [pspec] * 4,
        out_specs=pl.BlockSpec((block_u, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Upad, Ppad), f32),
        interpret=interpret,
    )(*args)
    return out[:U, :Pn]
