"""Jit'd dispatcher for flash attention (Pallas on TPU, interpret off-TPU)."""
from __future__ import annotations

import functools

import jax

from repro.obs import named_scope

from .flash_attention import flash_attention
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_kv", "use_kernel"))
def attention(q, k, v, *, causal: bool = True, window: int = 0,
              softcap: float = 0.0, block_q: int = 512, block_kv: int = 512,
              use_kernel: bool = True):
    if not use_kernel:
        with named_scope("attention_ref"):
            return attention_ref(q, k, v, causal=causal, window=window,
                                 softcap=softcap)
    with named_scope("flash_attention_pallas"):
        return flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            block_q=block_q, block_kv=block_kv,
            interpret=jax.default_backend() != "tpu")


def make_trainable_attention(*, causal: bool = True, window: int = 0,
                             block_q: int = 512, block_kv: int = 512,
                             interpret=None):
    """Differentiable flash attention: Pallas forward + Pallas backward via
    custom_vjp (the training path on TPU). Softcap is fwd-only here."""
    import jax as _jax
    from .backward import flash_attention_bwd

    itp = (_jax.default_backend() != "tpu") if interpret is None else interpret

    @_jax.custom_vjp
    def attn(q, k, v):
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=itp)

    def fwd(q, k, v):
        o, lse = flash_attention(q, k, v, causal=causal, window=window,
                                 block_q=block_q, block_kv=block_kv,
                                 interpret=itp, return_lse=True)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        q, k, v, o, lse = res
        return flash_attention_bwd(
            q, k, v, o, do, lse, causal=causal, window=window,
            block_q=block_q, block_kv=block_kv, interpret=itp)

    attn.defvjp(fwd, bwd)
    return attn
