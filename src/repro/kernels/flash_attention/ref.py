"""Independent naive-softmax oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """q: [B, Sq, Hq, hd]; k, v: [B, Skv, Hkv, hd]. Full softmax, f32."""
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    iq = jnp.arange(Sq)[:, None]
    ik = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= ik <= iq
    if window:
        ok &= ik > iq - window
    s = jnp.where(ok[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, hd).astype(q.dtype)
