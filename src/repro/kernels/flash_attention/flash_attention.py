"""Pallas TPU flash attention (prefill/training fwd), GQA-native.

Grid ``(B, Hq, nq, nk)`` — the last axis is innermost and sequential on
TPU, so the online-softmax state (m, l, acc) lives in VMEM scratch across
kv steps and the output tile is written once at the last step. GQA needs
no KV expansion: the K/V BlockSpec index map sends query head ``h`` to KV
head ``h // G``. Causal/sliding-window masks are computed from grid
indices (no S×S mask in HBM), and fully-out-of-range tiles skip the MXU
work via ``pl.when``.

Default blocks (q=512, kv=512): q/k/v/out tiles ≈ 4·512·hd·2 B ≈ 512 KiB
at hd=128, scratch ≈ 260 KiB — comfortably inside 16 MiB VMEM with room
for double buffering; all matmul dims are multiples of 128 (MXU-aligned).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
SAFE = -1e20


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
                  acc_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  kv_steps: int, block_q: int, block_kv: int, seq_kv: int):
    i = pl.program_id(2)            # q block
    j = pl.program_id(3)            # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_first = i * block_q
    kv_first = j * block_kv
    # tile-level skip: entirely above the causal diagonal / past the window
    needed = True
    if causal:
        needed = kv_first <= q_first + block_q - 1
    if window:
        needed = jnp.logical_and(
            needed, kv_first + block_kv - 1 > q_first - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # [bq, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)     # [bkv, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = kv_first + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = kpos < seq_kv
        if causal:
            ok &= kpos <= qpos
        if window:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG)

        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        m_safe = jnp.maximum(m_new, SAFE)
        p = jnp.exp(s - m_safe)
        corr = jnp.exp(jnp.maximum(m_prev, SAFE) - m_safe) \
            * (m_prev > NEG / 2).astype(jnp.float32)
        l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc_prev * corr + pv

    @pl.when(j == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        # logsumexp residual for the backward kernels (FlashAttention-2)
        lse_ref[0, 0] = jnp.maximum(m_ref[...], SAFE) + jnp.log(l)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 512,
                    block_kv: int = 512, interpret: bool = False,
                    return_lse: bool = False):
    """q: [B, Sq, Hq, hd]; k, v: [B, Skv, Hkv, hd] with Hq % Hkv == 0.
    Positions are assumed contiguous from 0 (prefill). Returns
    [B, Sq, Hq, hd] (and the [B, Hq, Sq] logsumexp when ``return_lse``).
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    bq, bkv = min(block_q, Sq), min(block_kv, Skv)
    nq, nk = pl.cdiv(Sq, bq), pl.cdiv(Skv, bkv)

    def padseq(x, n):
        return jnp.pad(x, ((0, 0), (0, n - x.shape[1]), (0, 0), (0, 0))) \
            if n != x.shape[1] else x

    qp = padseq(q, nq * bq).transpose(0, 2, 1, 3)     # [B, Hq, Sq, hd]
    kp = padseq(k, nk * bkv)                          # [B, Skv, Hkv, hd]
    vp = padseq(v, nk * bkv)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, kv_steps=nk, block_q=bq, block_kv=bkv, seq_kv=Skv)
    out, lse = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, bkv, 1, hd),
                         lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bkv, 1, hd),
                         lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, nq * bq, hd), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, nq * bq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    out = out.transpose(0, 2, 1, 3)[:, :Sq]
    if return_lse:
        return out, lse[..., 0][:, :, :Sq]
    return out
