"""Pallas TPU flash-attention backward (dq / dk / dv), GQA-native.

Standard two-kernel FlashAttention-2 backward. The forward saves the
per-row logsumexp ``L_i = m_i + log l_i`` so probabilities are recomputed
tile-by-tile (never materializing S×S):

    P_ij  = exp(S_ij − L_i)
    D_i   = rowsum(dO_i ∘ O_i)                       (computed in jnp)
    dV_j += P_ijᵀ dO_i
    dS_ij = P_ij ∘ (dO_i V_jᵀ − D_i)
    dQ_i += dS_ij K_j · scale        (kernel 1: grid q-outer, kv-inner)
    dK_j += dS_ijᵀ Q_i · scale       (kernel 2: grid kv-outer, (g,q)-inner)

GQA accumulation: kernel 2's grid is (B, Hkv, nk, G, nq) — the dk/dv
output block index is constant over the two innermost axes, so the scratch
accumulator integrates all G query heads of the group before writing.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _mask(qpos, kpos, causal, window, seq_kv):
    ok = kpos < seq_kv
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    return ok


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref, dq_ref,
               acc_ref, *, scale, causal, window, kv_steps, block_q,
               block_kv, seq_kv):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [bq, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)       # [bkv, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)        # [bq, hd]
    lse = lse_ref[0, 0]                          # [bq, 1]
    dsum = dsum_ref[0, 0]                        # [bq, 1]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    p = jnp.where(_mask(qpos, kpos, causal, window, seq_kv),
                  jnp.exp(s - lse), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dsum)
    acc_ref[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    @pl.when(j == kv_steps - 1)
    def _done():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal, window,
                q_steps, groups, block_q, block_kv, seq_kv):
    j = pl.program_id(2)        # kv block
    g = pl.program_id(3)        # query head within the GQA group
    i = pl.program_id(4)        # q block

    @pl.when((g == 0) & (i == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32)          # [bq, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)       # [bkv, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    dsum = dsum_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    p = jnp.where(_mask(qpos, kpos, causal, window, seq_kv),
                  jnp.exp(s - lse), 0.0)
    # dV_j += P^T dO ;  dK_j += dS^T Q
    dv_acc[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dsum)
    dk_acc[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    @pl.when((g == groups - 1) & (i == q_steps - 1))
    def _done():
        dk_ref[0, :, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, do, lse, *, causal: bool = True,
                        window: int = 0, block_q: int = 512,
                        block_kv: int = 512, interpret: bool = False):
    """q: [B,Sq,Hq,hd]; k,v: [B,Skv,Hkv,hd]; o,do like q; lse: [B,Hq,Sq].
    Returns (dq, dk, dv)."""
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    bq, bkv = min(block_q, Sq), min(block_kv, Skv)
    nq, nk = pl.cdiv(Sq, bq), pl.cdiv(Skv, bkv)

    def padseq(x, n):
        return jnp.pad(x, ((0, 0), (0, n - x.shape[1]), (0, 0), (0, 0))) \
            if n != x.shape[1] else x

    qp = padseq(q, nq * bq).transpose(0, 2, 1, 3)      # [B,Hq,Sq,hd]
    dop = padseq(do, nq * bq).transpose(0, 2, 1, 3)
    op = padseq(o, nq * bq).transpose(0, 2, 1, 3)
    kp, vp = padseq(k, nk * bkv), padseq(v, nk * bkv)
    # pad lse with +inf ⇒ exp(s − inf) = 0 on padded rows
    lsep = jnp.pad(lse, ((0, 0), (0, 0), (0, nq * bq - Sq)),
                   constant_values=jnp.inf)[..., None]  # [B,Hq,Sq,1]
    dsum = (op.astype(jnp.float32) * dop.astype(jnp.float32)) \
        .sum(-1, keepdims=True)                        # [B,Hq,Sq,1]

    qspec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0))
    kvspec4 = pl.BlockSpec((1, bkv, 1, hd),
                           lambda b, h, i, j: (b, j, h // G, 0))
    rowspec = pl.BlockSpec((1, 1, bq, 1), lambda b, h, i, j: (b, h, i, 0))
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, kv_steps=nk, block_q=bq,
                          block_kv=bkv, seq_kv=Skv),
        grid=(B, Hq, nq, nk),
        in_specs=[qspec, kvspec4, kvspec4, qspec, rowspec, rowspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, nq * bq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, dsum)

    # kernel 2: kv-outer, (g, q)-inner — GQA group accumulates in scratch
    qspec5 = pl.BlockSpec((1, 1, bq, hd),
                          lambda b, kh, j, g, i: (b, kh * G + g, i, 0))
    kvspec5 = pl.BlockSpec((1, bkv, 1, hd),
                           lambda b, kh, j, g, i: (b, j, kh, 0))
    rowspec5 = pl.BlockSpec((1, 1, bq, 1),
                            lambda b, kh, j, g, i: (b, kh * G + g, i, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, q_steps=nq, groups=G, block_q=bq,
                          block_kv=bkv, seq_kv=Skv),
        grid=(B, Hkv, nk, G, nq),
        in_specs=[qspec5, kvspec5, kvspec5, qspec5, rowspec5, rowspec5],
        out_specs=[kvspec5, kvspec5],
        out_shape=[jax.ShapeDtypeStruct((B, nk * bkv, Hkv, hd), k.dtype),
                   jax.ShapeDtypeStruct((B, nk * bkv, Hkv, hd), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bkv, hd), jnp.float32),
                        pltpu.VMEM((bkv, hd), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, dsum)

    return (dq.transpose(0, 2, 1, 3)[:, :Sq],
            dk[:, :Skv], dv[:, :Skv])
