"""Model scheduling: OMS (Algorithm 1) and the set-objective σ (Eq. 9/10).

Theorem 2: given a placement ``x``, the optimal schedule assigns each user
the placed implementation of its requested service with maximal QoS — the
maximum-spanning-tree of the auxiliary multigraph degenerates to a per-user
argmax because every user node hangs off the root independently.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .instance import PIESInstance, JaxInstance
from .qos import qos_matrix_np, eligibility_np

__all__ = [
    "oms_np",
    "sigma_np",
    "sigma_user_np",
    "schedule_value_np",
    "oms_jnp",
    "sigma_jnp",
]


def oms_np(
    inst: PIESInstance,
    x: np.ndarray,
    Q: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, float]:
    """Optimal Model Scheduling (Algorithm 1).

    Args:
      inst: the problem instance.
      x: [E, P] boolean placement decision.
      Q: optional precomputed QoS matrix (recomputed when omitted).

    Returns:
      ``(y, value)`` — ``y`` [U] int with the scheduled model index per user
      (−1 ⇒ request dropped to the central cloud), and the objective value
      Eq. (7) under this schedule.
    """
    if Q is None:
        Q = qos_matrix_np(inst)
    elig = eligibility_np(inst) & x[inst.u_edge]  # [U, P]
    masked = np.where(elig, Q, -1.0)
    y = masked.argmax(axis=1)
    served = masked[np.arange(inst.U), y] >= 0.0
    value = float(np.where(served, Q[np.arange(inst.U), y], 0.0).sum())
    y = np.where(served, y, -1)
    return y, value


def sigma_user_np(inst: PIESInstance, x: np.ndarray,
                  Q: Optional[np.ndarray] = None) -> np.ndarray:
    """Eq. (10): per-user optimal QoS σ_u(P) under placement ``x``."""
    if Q is None:
        Q = qos_matrix_np(inst)
    elig = eligibility_np(inst) & x[inst.u_edge]
    return np.where(elig, Q, 0.0).max(axis=1, initial=0.0)


def sigma_np(inst: PIESInstance, x: np.ndarray,
             Q: Optional[np.ndarray] = None) -> float:
    """Eq. (9): σ(P) = Σ_u σ_u(P) — objective value under optimal OMS."""
    return float(sigma_user_np(inst, x, Q).sum())


def schedule_value_np(inst: PIESInstance, y: np.ndarray,
                      Q: Optional[np.ndarray] = None) -> float:
    """Objective Eq. (7) of an explicit (possibly suboptimal) schedule."""
    if Q is None:
        Q = qos_matrix_np(inst)
    served = y >= 0
    return float(np.where(served, Q[np.arange(inst.U), np.maximum(y, 0)], 0.0).sum())


# ===========================================================================
# jnp twins
# ===========================================================================

def oms_jnp(Q, elig, u_edge, x):
    """jit-able OMS. ``Q``/``elig`` are [U, P]; ``x`` is [E, P] bool.

    Returns ``(y, per_user_qos)`` with ``y = −1`` for dropped requests.
    """
    import jax.numpy as jnp

    ok = elig & x[u_edge]
    masked = jnp.where(ok, Q, -1.0)
    y = jnp.argmax(masked, axis=1)
    best = jnp.take_along_axis(masked, y[:, None], axis=1)[:, 0]
    served = best >= 0.0
    qos = jnp.where(served, jnp.take_along_axis(Q, y[:, None], axis=1)[:, 0], 0.0)
    return jnp.where(served, y, -1), qos


def sigma_jnp(Q, elig, u_edge, x):
    """Eq. (9) as a jnp scalar."""
    import jax.numpy as jnp

    ok = elig & x[u_edge]
    return jnp.where(ok, Q, 0.0).max(axis=1).sum()
