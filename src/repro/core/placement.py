"""Placement algorithms for PIES (§V of the paper).

Host (NumPy) implementations that follow the paper's pseudocode:

* :func:`egp_np`  — Efficient Greedy Placement (Algorithm 3).
* :func:`agp_np`  — Approximate Greedy Placement (Algorithm 2) with the
  exact-marginal vectorization (σ(P∪{p}) − σ(P) = Σ_u max(0, Q[u,p] −
  best_u), which is mathematically identical to recomputing OMS per
  candidate as the paper does, but O(U·P) per pick instead of O(U·P²)).
* :func:`agp_literal_np` — Algorithm 2 exactly as written (recomputes
  optimal scheduling for every candidate at every pick); kept to reproduce
  the paper's Fig. 3b runtime separation.
* :func:`sck_np`  — the knapsack-DP baseline ("SCK").
* :func:`rnd_np`  — random placement + random eligible scheduling ("RND").

JAX implementations (jit-able, fixed-shape, masked; the composable modules
the serving control plane uses):

* :func:`egp_place_jax`, :func:`agp_place_jax` — vmapped-over-edges masked
  ``lax.while_loop`` greedy selection over the QoS matrix.
* :func:`egp_place_sparse_jax`, :func:`sigma_sparse_jnp` — the same
  Algorithm 3 decisions driven from a top-k ``(user, candidate)`` pair set
  (:mod:`repro.core.candidates`), all edges advanced in lock-step by one
  joint ``lax.while_loop``; state is O(U·k + E·P) instead of the dense
  path's O(E·U·P), which is what makes 10⁵–10⁶-user ticks feasible.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

from .instance import PIESInstance
from .qos import qos_matrix_np, eligibility_np
from .scheduling import oms_np, sigma_np

__all__ = [
    "FEASIBILITY_TOL",
    "egp_np", "agp_np", "agp_literal_np", "sck_np", "rnd_np",
    "egp_place_jax", "agp_place_jax", "place_and_schedule",
    "egp_place_sparse_jax", "sigma_sparse_jnp",
    "sigma_upper_bound_np",
]

#: Shared feasibility slack for ``r_sm ≤ R̂`` checks. One constant for the
#: host (float64) and JAX (float32) paths: 1e-6 is representable at float32
#: resolution around typical storage magnitudes, so a boundary-cost model
#: (``r_sm == R̂`` exactly) is accepted or rejected identically by
#: :func:`agp_np` and :func:`_agp_one_edge` — they can never disagree on
#: which placements are feasible.
FEASIBILITY_TOL = 1e-6

#: Decision-ledger hook. ``repro.obs.ledger.enable_ledger()`` installs a
#: :class:`~repro.obs.ledger.DecisionLedger` here (the core never imports
#: obs); the greedy pick loops book every consideration through it. The
#: disabled path is one global load + ``is None`` per placement call, and
#: the ledger is observational — picks are recorded, never influenced.
_DECISION_SINK = None


def sigma_upper_bound_np(inst: PIESInstance,
                         Q: Optional[np.ndarray] = None) -> float:
    """Per-user relaxation upper bound σ̄ on the optimum of Eq. (1).

    Every user is served by its best eligible implementation that would
    fit its edge's *whole* storage budget on its own — i.e. the LP/ILP
    with all coupling (shared budgets across services) relaxed away. By
    construction ``σ̄ ≥ OPT ≥ σ(x)`` for any feasible ``x``, so the
    Theorem-2 certificate ``σ(greedy) ≥ (1 − 1/e)·σ̄`` is strictly
    stronger than the guarantee against OPT (and, being a relaxation,
    σ̄ can overshoot — a ratio below the line flags a placement for
    inspection rather than refuting the theorem).
    """
    if Q is None:
        Q = qos_matrix_np(inst)
    fits = inst.sm_r[None, :] <= (inst.R[inst.u_edge][:, None]
                                  + FEASIBILITY_TOL)  # [U, P]
    # Q is already zero for ineligible (user, impl) pairs
    return float(np.where(fits, Q, 0.0).max(axis=1).sum())


# ===========================================================================
# Algorithm 3: Efficient Greedy Placement (EGP)
# ===========================================================================

def egp_np(inst: PIESInstance, Q: Optional[np.ndarray] = None) -> np.ndarray:
    """Efficient Greedy Placement — Algorithm 3, line-by-line.

    Per edge cloud: seed the benefit map ``v[(s,m)] = Σ_{u∈U_e} Q(u,s_u,m)``
    (lines 3–6); repeatedly take the highest-benefit unconsidered model
    (line 11), place it if it fits (lines 12–14), re-score the *sibling*
    implementations of the same service against the newly placed one over
    the not-yet-satisfied users (lines 15–16), mark it considered (17) and
    absorb fully-satisfied users into ``B`` (18–19); stop when storage is
    exhausted, everyone is satisfied, or all candidates were considered
    (line 20).
    """
    if Q is None:
        Q = qos_matrix_np(inst)
    x = np.zeros((inst.E, inst.P), dtype=bool)
    sink = _DECISION_SINK

    for e in range(inst.E):
        users = inst.users_of_edge(e)
        if users.size == 0:
            continue
        req_services = np.unique(inst.u_service[users])
        keys = np.nonzero(np.isin(inst.sm_service, req_services))[0]
        if keys.size == 0:
            continue
        Qe = Q[users]  # [|U_e|, P]
        v = {int(p): float(Qe[:, p].sum()) for p in keys}

        considered: set = set()           # A
        satisfied = np.zeros(users.size, dtype=bool)  # B (mask over users)
        remaining = float(inst.R[e])      # R̂
        if sink is not None:
            best = np.zeros(users.size)   # σ_u over placed impls at e

        while True:
            cand = [p for p in v if p not in considered]
            if not cand:
                break
            p_star = max(cand, key=lambda p: (v[p], -p))
            benefit = v[p_star]
            placed = inst.sm_r[p_star] <= remaining + FEASIBILITY_TOL
            if placed:
                x[e, p_star] = True
                remaining -= float(inst.sm_r[p_star])
                # lines 15–16: re-score sibling implementations of s*
                s_star = inst.sm_service[p_star]
                unsat = ~satisfied
                for p in keys:
                    p = int(p)
                    if (inst.sm_service[p] == s_star and p != p_star
                            and p not in considered):
                        v[p] = float(
                            (Qe[unsat, p] - Qe[unsat, p_star]).sum()
                        )
                # lines 18–19: users fully satisfied by (s*, m*)
                satisfied |= Qe[:, p_star] >= 1.0 - 1e-9
            considered.add(p_star)
            if sink is not None:
                gain = 0.0
                if placed:
                    # exact marginal: the gains over placed picks
                    # telescope to the realized σ of the edge
                    gain = float(np.maximum(Qe[:, p_star] - best,
                                            0.0).sum())
                    best = np.maximum(best, Qe[:, p_star])
                # rank 0 by construction: p_star is the benefit argmax
                sink.pick(edge=e, impl=p_star, benefit=benefit,
                          gain=gain, remaining=remaining,
                          n_candidates=len(cand), rank=0, placed=placed)
            if remaining <= FEASIBILITY_TOL or satisfied.all() or len(considered) == len(v):
                break
    return x


# ===========================================================================
# Algorithm 2: Approximate Greedy Placement (AGP)
# ===========================================================================

def agp_np(inst: PIESInstance, Q: Optional[np.ndarray] = None) -> np.ndarray:
    """Approximate Greedy Placement — Algorithm 2 with exact marginals.

    Identical picks to the literal pseudocode (argmax of σ(P ∪ {(e,(s,m))})
    over feasible candidates) but computes each marginal in closed form:
    adding model ``p`` at edge ``e`` improves only users in ``U_e`` whose
    current best QoS is below ``Q[u, p]``.
    """
    if Q is None:
        Q = qos_matrix_np(inst)
    x = np.zeros((inst.E, inst.P), dtype=bool)
    best = np.zeros(inst.U)  # σ_u under current placement

    for e in range(inst.E):
        users = inst.users_of_edge(e)
        remaining = float(inst.R[e])
        placed = np.zeros(inst.P, dtype=bool)
        while True:
            feasible = (~placed) & (inst.sm_r <= remaining + FEASIBILITY_TOL)
            if not feasible.any():
                break
            if users.size:
                gains = np.maximum(Q[users] - best[users, None], 0.0).sum(axis=0)
            else:
                gains = np.zeros(inst.P)
            gains = np.where(feasible, gains, -np.inf)
            p_star = int(np.argmax(gains))
            x[e, p_star] = True
            placed[p_star] = True
            remaining -= float(inst.sm_r[p_star])
            if users.size:
                best[users] = np.maximum(best[users], Q[users, p_star])
    return x


def agp_literal_np(inst: PIESInstance,
                   Q: Optional[np.ndarray] = None) -> np.ndarray:
    """Algorithm 2 exactly as printed: every candidate evaluated by running
    optimal scheduling on σ(P ∪ {(e,(s,m))}) from scratch. O(U·P²) per pick
    — this is the runtime the paper complains about in Fig. 3b."""
    if Q is None:
        Q = qos_matrix_np(inst)
    x = np.zeros((inst.E, inst.P), dtype=bool)
    for e in range(inst.E):
        remaining = float(inst.R[e])
        placed = np.zeros(inst.P, dtype=bool)
        while True:
            feasible = np.nonzero((~placed) & (inst.sm_r <= remaining + FEASIBILITY_TOL))[0]
            if feasible.size == 0:
                break
            best_val, best_p = -np.inf, -1
            for p in feasible:
                x[e, p] = True
                val = sigma_np(inst, x, Q)  # full optimal scheduling
                x[e, p] = False
                if val > best_val:
                    best_val, best_p = val, int(p)
            x[e, best_p] = True
            placed[best_p] = True
            remaining -= float(inst.sm_r[best_p])
    return x


# ===========================================================================
# Baselines: SCK (knapsack DP) and RND
# ===========================================================================

def sck_np(inst: PIESInstance, Q: Optional[np.ndarray] = None,
           resolution: int = 1) -> np.ndarray:
    """0/1-knapsack adaptation (the paper's "SCK" baseline).

    Per edge cloud: items are the individual service models, weights are
    their storage costs, values are their *standalone* total QoS
    ``Σ_{u∈U_e} Q(u, s_u, m)`` (Eq. 1 summed over covered users — ignoring
    that multiple implementations of one service overlap, which is exactly
    why SCK underperforms). Solved with the standard DP; scheduling is then
    done with OMS (Alg. 1), as in the paper.
    """
    if Q is None:
        Q = qos_matrix_np(inst)
    x = np.zeros((inst.E, inst.P), dtype=bool)
    weights_all = np.round(inst.sm_r * resolution).astype(np.int64)

    for e in range(inst.E):
        users = inst.users_of_edge(e)
        if users.size == 0:
            continue
        values_all = Q[users].sum(axis=0)
        items = np.nonzero(values_all > 0.0)[0]
        if items.size == 0:
            continue
        cap = int(np.floor(inst.R[e] * resolution))
        dp = np.zeros(cap + 1)
        choice = np.zeros((items.size, cap + 1), dtype=bool)
        for i, p in enumerate(items):
            w, val = int(weights_all[p]), float(values_all[p])
            if w > cap:
                continue
            cand = dp[: cap - w + 1] + val
            upd = cand > dp[w:]
            choice[i, w:] = upd
            dp[w:] = np.where(upd, cand, dp[w:])
        # backtrack
        c = cap
        for i in range(items.size - 1, -1, -1):
            if choice[i, c]:
                p = items[i]
                x[e, p] = True
                c -= int(weights_all[p])
    return x


def rnd_np(inst: PIESInstance, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Random placement + random eligible scheduling baseline.

    Returns ``(x, y)`` — unlike the greedy algorithms, RND also randomizes
    the schedule (uniform over placed implementations of the requested
    service; −1 if none).
    """
    rng = np.random.default_rng(seed)
    x = np.zeros((inst.E, inst.P), dtype=bool)
    for e in range(inst.E):
        remaining = float(inst.R[e])
        for p in rng.permutation(inst.P):
            if inst.sm_r[p] <= remaining + FEASIBILITY_TOL:
                x[e, p] = True
                remaining -= float(inst.sm_r[p])
    elig = eligibility_np(inst) & x[inst.u_edge]
    y = np.full(inst.U, -1, dtype=np.int64)
    for u in range(inst.U):
        opts = np.nonzero(elig[u])[0]
        if opts.size:
            y[u] = int(rng.choice(opts))
    return x, y


# ===========================================================================
# JAX implementations — fixed-shape, masked, vmapped over edge clouds
# ===========================================================================

def _agp_one_edge(Q, umask, sm_r, R_e, max_iters):
    """Greedy exact-marginal placement for a single edge (jnp, masked)."""
    import jax
    import jax.numpy as jnp

    P = Q.shape[1]
    Qe = Q * umask[:, None]  # zero out other edges' users

    def cond(state):
        _, _, _, _, done = state
        return ~done

    def body(state):
        x_e, best, remaining, it, done = state
        feasible = (~x_e) & (sm_r <= remaining + FEASIBILITY_TOL)
        any_feasible = feasible.any()
        gains = jnp.maximum(Qe - best[:, None], 0.0).sum(axis=0)
        gains = jnp.where(feasible, gains, -jnp.inf)
        p_star = jnp.argmax(gains)
        do = any_feasible & ~done
        x_e = x_e.at[p_star].set(jnp.where(do, True, x_e[p_star]))
        remaining = remaining - jnp.where(do, sm_r[p_star], 0.0)
        best = jnp.where(do, jnp.maximum(best, Qe[:, p_star]), best)
        it = it + 1
        done = done | ~any_feasible | (it >= max_iters)
        return x_e, best, remaining, it, done

    U = Q.shape[0]
    init = (jnp.zeros(P, bool), jnp.zeros(U, jnp.float32),
            R_e.astype(jnp.float32), jnp.int32(0), jnp.bool_(False))
    x_e, *_ = jax.lax.while_loop(cond, body, init)
    return x_e


def agp_place_jax(Q, elig, u_edge, sm_r, R, *, max_iters: int = 256):
    """jit-able AGP over all edges. ``Q`` [U,P] float32 (pre-masked by
    eligibility or not — it is re-masked here), returns x [E,P] bool."""
    import jax
    import jax.numpy as jnp

    E = R.shape[0]
    Qm = jnp.where(elig, Q, 0.0)
    umask = (u_edge[None, :] == jnp.arange(E)[:, None]).astype(Qm.dtype)
    fn = functools.partial(_agp_one_edge, Qm, sm_r=sm_r, max_iters=max_iters)
    return jax.vmap(lambda m, r: fn(m, R_e=r))(umask, R)


def _egp_one_edge(Q, umask, sm_service, sm_r, R_e, relevant, max_iters):
    """Algorithm 3 for a single edge (jnp, masked)."""
    import jax
    import jax.numpy as jnp

    U, P = Q.shape
    Qe = Q * umask[:, None]
    NEG = jnp.float32(-1e30)

    def cond(state):
        return ~state[-1]

    def body(state):
        x_e, v, considered, satisfied, remaining, it, done = state
        cand = relevant & ~considered
        any_cand = cand.any()
        p_star = jnp.argmax(jnp.where(cand, v, NEG))
        fits = sm_r[p_star] <= remaining + FEASIBILITY_TOL
        place = fits & any_cand & ~done
        x_e = x_e.at[p_star].set(x_e[p_star] | place)
        remaining = remaining - jnp.where(place, sm_r[p_star], 0.0)
        # lines 15–16: re-score unconsidered siblings of s* over unsatisfied
        q_star = Qe[:, p_star]
        unsat = (umask > 0) & ~satisfied
        diff = jnp.where(unsat[:, None], Q - q_star[:, None], 0.0).sum(axis=0)
        sib = (sm_service == sm_service[p_star]) & ~considered \
            & (jnp.arange(P) != p_star) & relevant
        v = jnp.where(place & sib, diff, v)
        satisfied = satisfied | (place & (umask > 0) & (q_star >= 1.0 - 1e-6))
        considered = considered.at[p_star].set(considered[p_star] | any_cand)
        it = it + 1
        all_sat = (satisfied | (umask == 0)).all()
        all_cons = (considered | ~relevant).all()
        done = done | ~any_cand | (remaining <= 1e-6) | all_sat | all_cons \
            | (it >= max_iters)
        return x_e, v, considered, satisfied, remaining, it, done

    v0 = Qe.sum(axis=0)
    init = (jnp.zeros(P, bool), v0, jnp.zeros(P, bool), jnp.zeros(U, bool),
            R_e.astype(jnp.float32), jnp.int32(0), jnp.bool_(False))
    x_e, *_ = jax.lax.while_loop(cond, body, init)
    return x_e


def egp_place_jax(Q, elig, u_edge, u_service, sm_service, sm_r, R, n_services,
                  *, max_iters: int = 512):
    """jit-able EGP over all edges: returns x [E, P] bool."""
    import jax
    import jax.numpy as jnp

    E = R.shape[0]
    Qm = jnp.where(elig, Q, 0.0).astype(jnp.float32)
    umask = (u_edge[None, :] == jnp.arange(E)[:, None]).astype(jnp.float32)
    # relevant[e, p] ⇔ some user covered by e requests service of p
    req = jnp.zeros((E, n_services), bool).at[u_edge, u_service].set(True)
    relevant = req[:, sm_service]  # [E, P]

    def run(m, r, rel):
        return _egp_one_edge(Qm, m, sm_service, sm_r, r, rel, max_iters)

    return jax.vmap(run)(umask, R, relevant)


def egp_place_sparse_jax(cand_idx, cand_q, u_edge, sm_service, sm_r, R,
                         *, max_iters: int = 512, use_kernel: bool = False,
                         with_trace: bool = False):
    """Algorithm 3 over a top-k sparse candidate set, all edges in lock-step.

    Takes the ``(cand_idx, cand_q) [U, k]`` pairs from
    :func:`repro.core.candidates.topk_candidates_jnp` instead of a dense
    ``[U, P]`` QoS matrix. One joint ``lax.while_loop`` advances every edge
    by one greedy pick per iteration (edges that finish early are masked by
    ``done``), so the working set is the O(E·P) greedy state plus O(U·k)
    candidate pairs — never the dense path's per-edge O(E·U·P) masked QoS
    copies. With ``k ≥ M`` (every eligible implementation kept) the picks,
    tie-breaks, and stop conditions are *identical* to
    :func:`egp_place_jax` / :func:`egp_np`: ineligible users contribute 0
    to every benefit sum in the dense path, so dropping them changes
    nothing; with ``k < M`` this is the documented top-k approximation.

    ``use_kernel=True`` routes the per-iteration masked per-edge argmax
    through the Pallas ``greedy_argmax`` kernel
    (:mod:`repro.kernels.qos_matrix`); the default uses the identical jnp
    reduction (interpret-mode Pallas inside a while_loop is slow on CPU).

    ``with_trace=True`` additionally returns a per-iteration decision
    trace for the observability ledger: ``[max_iters, E]`` arrays of the
    pick (``impl``, −1 where an edge had no candidate / was done), its
    benefit, exact marginal gain (booked in f32 against a per-user
    ``best`` carry — gains telescope to ``sigma_sparse_jnp`` of the
    result up to f32 summation, documented tolerance ~1e-3 relative),
    the post-pick remaining budget, the candidate count, and the placed
    mask. The traced and untraced paths make **identical decisions** —
    the trace arrays are write-only extensions of the loop carry.

    Returns ``x [E, P]`` bool (or ``(x, trace_dict)`` with
    ``with_trace=True``).
    """
    import jax
    import jax.numpy as jnp

    U, K = cand_q.shape
    P = sm_service.shape[0]
    E = R.shape[0]
    NEG = jnp.float32(-1e30)

    valid = cand_idx >= 0
    # Sentinel column P absorbs scatters from padded candidate slots.
    col = jnp.where(valid, cand_idx, P).astype(jnp.int32)
    qpair = jnp.where(valid, cand_q, 0.0).astype(jnp.float32)
    erow = u_edge.astype(jnp.int32)
    sm_r = sm_r.astype(jnp.float32)
    p_arange = jnp.arange(P)
    e_arange = jnp.arange(E)

    def scatter_ep(w):
        """Σ over (user, candidate) pairs into the [E, P] model grid."""
        out = jnp.zeros((E, P + 1), jnp.float32)
        out = out.at[erow[:, None], col].add(w)
        return out[:, :P]

    relevant = scatter_ep(valid.astype(jnp.float32)) > 0.0  # [E, P]
    v0 = scatter_ep(qpair)  # lines 3–6: v[(s,m)] = Σ_{u∈U_e} Q(u,s_u,m)

    def masked_argmax(v, cand):
        if use_kernel:
            from repro.kernels.qos_matrix.ops import greedy_argmax
            _, idx = greedy_argmax(v, cand.astype(jnp.float32),
                                   use_kernel=True)
            return jnp.clip(idx, 0, None)
        return jnp.argmax(jnp.where(cand, v, NEG), axis=1)

    def cond(state):
        # `it` and `done` sit at fixed positions in both carry layouts
        # (with and without the trace extension)
        done, it = state[-1], state[5]
        return (~done.all()) & (it < max_iters)

    def body(state):
        if with_trace:
            (x, v, considered, satisfied, remaining, it,
             best_u, tr, done) = state
        else:
            x, v, considered, satisfied, remaining, it, done = state
        cand = relevant & ~considered
        any_cand = cand.any(axis=1)                       # [E]
        p_star = masked_argmax(v, cand)                   # [E] line 11
        fits = sm_r[p_star] <= remaining + FEASIBILITY_TOL
        place = fits & any_cand & ~done                   # lines 12–14
        active = any_cand & ~done     # edges actually picking this iter
        benefit = jnp.take_along_axis(v, p_star[:, None], 1)[:, 0]
        x = x.at[e_arange, p_star].set(x[e_arange, p_star] | place)
        remaining = remaining - jnp.where(place, sm_r[p_star], 0.0)

        pstar_u = p_star[erow]                            # [U] p* of u's edge
        place_u = place[erow]
        # Q(u, s_u, m*) per user — 0 unless p* is one of u's candidates.
        qstar_u = jnp.where(col == pstar_u[:, None], qpair, 0.0).sum(axis=1)

        if with_trace:
            # exact marginal per placed pick, booked before best_u moves
            imp_u = jnp.where(place_u,
                              jnp.maximum(qstar_u - best_u, 0.0), 0.0)
            gain_e = jnp.zeros(E, jnp.float32).at[erow].add(imp_u)
            best_u = jnp.where(place_u, jnp.maximum(best_u, qstar_u),
                               best_u)
            t_pick, t_place, t_ben, t_gain, t_rem, t_ncand = tr
            tr = (
                t_pick.at[it].set(jnp.where(active, p_star, -1)),
                t_place.at[it].set(place),
                t_ben.at[it].set(jnp.where(active, benefit, 0.0)),
                t_gain.at[it].set(gain_e),
                t_rem.at[it].set(remaining),
                t_ncand.at[it].set(cand.sum(axis=1).astype(jnp.int32)),
            )

        def rescore(arg):
            # lines 15–16: v[p] = Σ_unsat (Q[u,p] − Q[u,p*]) for siblings
            # of s*. O(U·k) pair scatter — only run when something placed.
            v, satisfied = arg
            unsat_u = place_u & ~satisfied
            w = jnp.where(unsat_u[:, None] & valid,
                          qpair - qstar_u[:, None], 0.0)
            diff = scatter_ep(w)
            sib = (sm_service[None, :] == sm_service[p_star][:, None]) \
                & ~considered & (p_arange[None, :] != p_star[:, None]) \
                & relevant
            v = jnp.where(place[:, None] & sib, diff, v)
            # lines 18–19: users fully satisfied by (s*, m*)
            satisfied = satisfied | (place_u & (qstar_u >= 1.0 - 1e-6))
            return v, satisfied

        v, satisfied = jax.lax.cond(place.any(), rescore, lambda a: a,
                                    (v, satisfied))
        considered = considered.at[e_arange, p_star].set(
            considered[e_arange, p_star] | any_cand)      # line 17
        n_unsat = jnp.zeros(E, jnp.int32).at[erow].add(
            (~satisfied).astype(jnp.int32))
        all_sat = n_unsat == 0
        all_cons = (considered | ~relevant).all(axis=1)
        # line 20 — same stop conditions (and tolerances) as _egp_one_edge
        done = done | ~any_cand | (remaining <= 1e-6) | all_sat | all_cons
        if with_trace:
            return (x, v, considered, satisfied, remaining, it + 1,
                    best_u, tr, done)
        return x, v, considered, satisfied, remaining, it + 1, done

    init_core = (jnp.zeros((E, P), bool), v0, jnp.zeros((E, P), bool),
                 jnp.zeros(U, bool), R.astype(jnp.float32), jnp.int32(0))
    if with_trace:
        tr0 = (jnp.full((max_iters, E), -1, jnp.int32),
               jnp.zeros((max_iters, E), bool),
               jnp.zeros((max_iters, E), jnp.float32),
               jnp.zeros((max_iters, E), jnp.float32),
               jnp.zeros((max_iters, E), jnp.float32),
               jnp.zeros((max_iters, E), jnp.int32))
        init = init_core + (jnp.zeros(U, jnp.float32), tr0,
                            jnp.zeros(E, bool))
        out = jax.lax.while_loop(cond, body, init)
        x, tr = out[0], out[7]
        trace = {"pick": tr[0], "placed": tr[1], "benefit": tr[2],
                 "gain": tr[3], "remaining": tr[4],
                 "n_candidates": tr[5], "n_iters": out[5]}
        return x, trace
    init = init_core + (jnp.zeros(E, bool),)
    x, *_ = jax.lax.while_loop(cond, body, init)
    return x


def sigma_sparse_jnp(cand_idx, cand_q, u_edge, x):
    """σ (Eq. 9 with OMS folded in) over candidate pairs: each user gets its
    best *placed* candidate at its own edge. Exact vs
    :func:`repro.core.scheduling.sigma_jnp` when the candidate set kept
    every eligible implementation (``k ≥ M``)."""
    import jax.numpy as jnp

    valid = cand_idx >= 0
    safe = jnp.clip(cand_idx, 0, None)
    placed = x[u_edge[:, None], safe] & valid
    return jnp.where(placed, cand_q, 0.0).max(axis=1).sum()


def place_and_schedule(inst: PIESInstance, algo: str = "egp", seed: int = 0,
                       Q: Optional[np.ndarray] = None):
    """Convenience host entry point: returns ``(x, y, objective_value)``."""
    if Q is None:
        Q = qos_matrix_np(inst)
    if algo == "egp":
        x = egp_np(inst, Q)
    elif algo == "agp":
        x = agp_np(inst, Q)
    elif algo == "agp_literal":
        x = agp_literal_np(inst, Q)
    elif algo == "sck":
        x = sck_np(inst, Q)
    elif algo == "rnd":
        x, y = rnd_np(inst, seed)
        from .scheduling import schedule_value_np
        return x, y, schedule_value_np(inst, y, Q)
    elif algo == "opt":
        from .opt import opt_np
        x = opt_np(inst, Q)
    else:
        raise ValueError(f"unknown algorithm {algo!r}")
    y, value = oms_np(inst, x, Q)
    if _DECISION_SINK is not None and algo == "egp":
        # close the ledger record with the Theorem-2 certificate:
        # σ(greedy) vs (1 − 1/e) · σ̄ (relaxation upper bound)
        _DECISION_SINK.end(sigma=value,
                           sigma_bound=sigma_upper_bound_np(inst, Q))
    return x, y, value
