"""Top-k sparse candidate sets for placement at scale.

The dense evaluator materializes the full QoS matrix ``Q [U, P]`` (and the
greedy loop's per-edge masked copies, ``[E, U, P]`` under ``vmap``) — fine
at the paper's 10²–10³ users, hopeless at 10⁶. But eligibility is sparse
by construction: user ``u`` can only ever be served by the implementations
of its requested service ``s_u``, of which there are at most ``M =
max_impls`` (≈ 10 in the paper's §VI-B setup). This module exploits that:

* :func:`impl_table_np` — the ``[S, M]`` service → implementation index
  table (−1 padded) that makes per-user candidate gathering O(1);
* :func:`topk_candidates_np` / :func:`topk_candidates_jnp` — the ``k``
  highest-QoS eligible implementations per user (``k = M`` keeps *every*
  eligible implementation, making the sparse path **exact**, not an
  approximation; ``k < M`` trades QoS for memory);
* :class:`CandidateSet` — the ``(cand_idx, cand_q) [U, k]`` pair
  representation consumed by
  :func:`repro.core.placement.egp_place_sparse_jax` and
  :func:`~repro.core.placement.sigma_sparse_jnp`.

Memory scales as ``U·k`` (+ ``E·P`` greedy state) instead of ``U×P×E`` —
the representation change behind the ``placement_scale`` benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .instance import PIESInstance
from .qos import qos_matrix_np

__all__ = [
    "CandidateSet",
    "impl_table_np",
    "max_impls_of",
    "topk_candidates_np",
    "topk_candidates_jnp",
    "sigma_sparse_np",
]


@dataclasses.dataclass
class CandidateSet:
    """Sparse ``(user, candidate)`` pair representation of eligibility.

    ``cand_idx[u, c]`` is a model index into the instance's flattened
    ``(s, m)`` table, −1 for padding (user ``u`` has fewer than ``k``
    eligible implementations); ``cand_q[u, c]`` is the corresponding QoS
    (Eq. 1), 0 for padding. ``exact`` records whether the set kept every
    eligible implementation (``k ≥ M``), in which case sparse placement
    and scheduling reproduce the dense path's decisions.
    """

    cand_idx: np.ndarray  # [U, k] int64, −1 padded
    cand_q: np.ndarray    # [U, k] float64, 0 padded
    k: int
    exact: bool

    @property
    def U(self) -> int:
        return int(self.cand_idx.shape[0])


def max_impls_of(inst: PIESInstance) -> int:
    """``M`` — the largest implementation count over services."""
    if inst.P == 0:
        return 0
    return int(np.bincount(inst.sm_service, minlength=inst.S).max())


def impl_table_np(sm_service: np.ndarray,
                  n_services: Optional[int] = None) -> np.ndarray:
    """``[S, M]`` int64 table of model indices per service, −1 padded.

    Row ``s`` lists the flattened model indices implementing service ``s``
    in ascending index order — the gather target that turns per-user
    candidate enumeration into ``table[u_service]``.
    """
    sm_service = np.asarray(sm_service)
    P = sm_service.shape[0]
    S = int(n_services if n_services is not None
            else (sm_service.max() + 1 if P else 0))
    counts = np.bincount(sm_service, minlength=S)
    M = int(counts.max()) if P else 0
    table = np.full((S, M), -1, dtype=np.int64)
    order = np.argsort(sm_service, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(P) - np.repeat(starts, counts)
    table[sm_service[order], pos] = order
    return table


def topk_candidates_np(inst: PIESInstance, k: Optional[int] = None,
                       Q: Optional[np.ndarray] = None) -> CandidateSet:
    """NumPy reference top-k candidate selection (by QoS, ties → smaller
    model index, matching ``lax.top_k``'s first-occurrence order)."""
    if Q is None:
        Q = qos_matrix_np(inst)
    table = impl_table_np(inst.sm_service, inst.S)
    M = table.shape[1]
    k_eff = M if k is None else min(int(k), M)
    cand = table[inst.u_service]                       # [U, M]
    valid = cand >= 0
    q = np.where(valid,
                 Q[np.arange(inst.U)[:, None], np.clip(cand, 0, None)],
                 -1.0)
    order = np.argsort(-q, axis=1, kind="stable")[:, :k_eff]
    idx = np.take_along_axis(cand, order, axis=1)
    vals = np.take_along_axis(q, order, axis=1)
    kept = vals >= 0.0                                  # drop −1 pad rows
    return CandidateSet(cand_idx=np.where(kept, idx, -1),
                        cand_q=np.where(kept, vals, 0.0),
                        k=k_eff, exact=k_eff >= M)


def topk_candidates_jnp(jinst, table, k: Optional[int] = None, *,
                        use_kernel: bool = False):
    """jit-able top-k candidates from a :class:`~repro.core.instance
    .JaxInstance` and a host-built :func:`impl_table_np`.

    Returns ``(cand_idx [U, k] int32, cand_q [U, k] float32)``. QoS per
    ``(user, candidate)`` pair is computed by the segmented kernel
    dispatcher (:func:`repro.kernels.qos_matrix.ops.qos_candidates` —
    Pallas on TPU / when ``use_kernel``, jnp reference otherwise); no
    ``[U, P]`` matrix is ever materialized.
    """
    import jax.numpy as jnp
    from jax import lax

    from repro.kernels.qos_matrix.ops import qos_candidates

    table = jnp.asarray(table, jnp.int32)
    M = int(table.shape[1])
    k_eff = M if k is None else min(int(k), M)
    cand = table[jinst.u_service]                      # [U, M]
    valid = cand >= 0
    safe = jnp.clip(cand, 0, None)
    q = qos_candidates(
        jinst.u_alpha, jinst.u_delta, jinst.u_share_k, jinst.u_share_w,
        jinst.sm_acc[safe], jinst.sm_k[safe], jinst.sm_w[safe],
        valid.astype(jnp.float32), delta_max=float(jinst.delta_max),
        use_kernel=use_kernel)
    q = jnp.where(valid, q, -1.0)                      # pad rows sort last
    if k_eff < M:
        vals, order = lax.top_k(q, k_eff)
        idx = jnp.take_along_axis(cand, order, axis=1)
    else:
        vals, idx = q, cand
    kept = vals >= 0.0
    return (jnp.where(kept, idx, -1).astype(jnp.int32),
            jnp.where(kept, vals, 0.0).astype(jnp.float32))


def sigma_sparse_np(inst: PIESInstance, x: np.ndarray,
                    cand: CandidateSet) -> float:
    """σ (Eq. 9) evaluated over the candidate pairs only.

    Exact when ``cand.exact`` (every eligible implementation present); a
    lower bound otherwise (a placed implementation outside the top-k is
    invisible to the sparse schedule).
    """
    valid = cand.cand_idx >= 0
    placed = np.zeros_like(valid)
    rows = np.broadcast_to(inst.u_edge[:, None], cand.cand_idx.shape)
    placed[valid] = x[rows[valid], cand.cand_idx[valid]]
    best = np.where(placed, cand.cand_q, 0.0).max(axis=1, initial=0.0)
    return float(best.sum())
