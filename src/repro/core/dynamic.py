"""Dynamic (time-horizon) placement — the paper's stated future work.

The paper (§VII): "we plan to consider more dynamic extension of this work
where service placement decisions are made over a time horizon rather than
all at once." This module implements that extension:

* request populations arrive per control tick (repro.data.RequestPipeline);
* re-placing a model that is already resident is free, placing a new one
  pays a *switching cost* (model load/transfer time expressed in QoS
  units) — so naive per-tick re-optimization churns;
* :class:`DynamicPlacer` runs EGP with **hysteresis**: resident
  implementations get a stickiness bonus in the benefit map, trading a
  little instantaneous QoS for amortized stability.

``evaluate_horizon`` compares three policies over a tick sequence:
``static`` (place once on tick 0), ``greedy`` (EGP from scratch every
tick, pays switching), ``hysteresis`` (ours).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

import numpy as np

from .instance import PIESInstance
from .qos import qos_matrix_np
from . import placement as _placement
from .placement import FEASIBILITY_TOL, egp_np, sigma_upper_bound_np
from .scheduling import sigma_np

__all__ = ["DynamicPlacer", "evaluate_horizon"]


def _egp_with_bias(inst: PIESInstance, Q: np.ndarray,
                   resident: np.ndarray, bonus: float) -> np.ndarray:
    """EGP (Alg. 3) with a per-(edge, model) additive benefit bonus for
    already-resident implementations (hysteresis)."""
    x = np.zeros((inst.E, inst.P), dtype=bool)
    # decision-ledger sink (installed by repro.obs.ledger; observational)
    sink = _placement._DECISION_SINK
    for e in range(inst.E):
        users = inst.users_of_edge(e)
        if users.size == 0:
            continue
        req = np.unique(inst.u_service[users])
        keys = np.nonzero(np.isin(inst.sm_service, req))[0]
        if keys.size == 0:
            continue
        Qe = Q[users]
        v = {int(p): float(Qe[:, p].sum())
             + (bonus if resident[e, p] else 0.0) for p in keys}
        considered: set = set()
        satisfied = np.zeros(users.size, dtype=bool)
        remaining = float(inst.R[e])
        if sink is not None:
            best = np.zeros(users.size)
        while True:
            cand = [p for p in v if p not in considered]
            if not cand:
                break
            p_star = max(cand, key=lambda p: (v[p], -p))
            benefit = v[p_star]
            rank = 0
            bias_star = 0.0
            if sink is not None:
                # rank of the chosen candidate by *unbiased* benefit,
                # against the v values the argmax actually saw (the
                # same-service marginal rewrite below must not leak in):
                # > 0 means the stickiness bonus overrode the pure-QoS
                # argmax — the hysteresis override made visible
                bias_star = bonus if resident[e, p_star] else 0.0
                u_star = v[p_star] - bias_star
                rank = sum(
                    1 for q in cand
                    if (v[q] - (bonus if resident[e, q] else 0.0), -q)
                    > (u_star, -p_star))
            placed = inst.sm_r[p_star] <= remaining + FEASIBILITY_TOL
            if placed:
                x[e, p_star] = True
                remaining -= float(inst.sm_r[p_star])
                s_star = inst.sm_service[p_star]
                unsat = ~satisfied
                for p in keys:
                    p = int(p)
                    if (inst.sm_service[p] == s_star and p != p_star
                            and p not in considered):
                        v[p] = float((Qe[unsat, p] - Qe[unsat, p_star]).sum()) \
                            + (bonus if resident[e, p] else 0.0)
                satisfied |= Qe[:, p_star] >= 1.0 - 1e-9
            considered.add(p_star)
            if sink is not None:
                gain = 0.0
                if placed:
                    gain = float(np.maximum(Qe[:, p_star] - best,
                                            0.0).sum())
                    best = np.maximum(best, Qe[:, p_star])
                sink.pick(edge=e, impl=p_star, benefit=benefit,
                          gain=gain, remaining=remaining,
                          n_candidates=len(cand), rank=rank,
                          placed=placed, bias=bias_star)
            if remaining <= FEASIBILITY_TOL or satisfied.all() \
                    or len(considered) == len(v):
                break
    return x


@dataclasses.dataclass
class DynamicPlacer:
    switching_cost: float = 2.0   # QoS units per newly-loaded model
    stickiness: float = 3.0       # benefit bonus for resident models

    def __post_init__(self):
        self._resident: Optional[np.ndarray] = None
        #: [E, P] bool — implementations newly loaded by the latest step()
        #: (the mask behind its n_loads); consumers that *realize* loads
        #: (the serving horizon's cold-start gating) read this instead of
        #: shadowing the resident-set bookkeeping.
        self.new_loads: Optional[np.ndarray] = None
        #: [E, P] bool — implementations the latest step() *evicted* (were
        #: resident, no longer placed); the serving horizon re-routes work
        #: still queued on these instead of executing it on an evicted model.
        self.evicted: Optional[np.ndarray] = None

    def step(self, inst: PIESInstance, Q: Optional[np.ndarray] = None):
        """One control tick: returns (x, value, n_loads).

        ``self.stickiness`` is read afresh every step, so a feedback
        controller (:class:`repro.tuning.controller.FeedbackPlacer`) can
        adapt the hysteresis online between ticks.
        """
        if Q is None:
            Q = qos_matrix_np(inst)
        if self._resident is None:
            self._resident = np.zeros((inst.E, inst.P), dtype=bool)
        x = _egp_with_bias(inst, Q, self._resident, self.stickiness)
        self.new_loads = x & ~self._resident
        self.evicted = self._resident & ~x
        loads = int(self.new_loads.sum())
        sigma = sigma_np(inst, x, Q)
        value = sigma - self.switching_cost * loads
        sink = _placement._DECISION_SINK
        if sink is not None:
            # close the tick's ledger record with the certificate
            sink.end(sigma=sigma,
                     sigma_bound=sigma_upper_bound_np(inst, Q))
        self._resident = x
        return x, value, loads


def evaluate_horizon(instances: Union[str, List[PIESInstance]],
                     switching_cost: float = 2.0,
                     stickiness: float = 3.0, *,
                     seed: int = 0,
                     n_ticks: Optional[int] = None) -> Dict[str, float]:
    """Total (QoS − switching) over a tick sequence for three policies.

    ``instances`` is either an explicit tick sequence or the name of a
    registered :mod:`repro.workloads` scenario (``"flash_crowd"``, ...),
    materialized with ``(seed, n_ticks)``.
    """
    if isinstance(instances, str):
        from repro.workloads import horizon  # deferred: workloads uses core
        instances = horizon(instances, seed=seed, n_ticks=n_ticks)
    Qs = [qos_matrix_np(i) for i in instances]

    # static: tick-0 placement forever
    x0 = egp_np(instances[0], Qs[0])
    static = sum(sigma_np(i, x0, q) for i, q in zip(instances, Qs)) \
        - switching_cost * int(x0.sum())

    # greedy: re-place from scratch each tick, pay for every change
    greedy, prev = 0.0, np.zeros_like(x0)
    for i, q in zip(instances, Qs):
        x = egp_np(i, q)
        greedy += sigma_np(i, x, q) - switching_cost * int((x & ~prev).sum())
        prev = x

    # hysteresis
    placer = DynamicPlacer(switching_cost, stickiness)
    hyst = 0.0
    for i, q in zip(instances, Qs):
        _, value, _ = placer.step(i, q)
        hyst += value

    return {"static": float(static), "greedy": float(greedy),
            "hysteresis": float(hyst)}
