"""Quality-of-Service model (Eqs. 1–6 of the paper), vectorized.

The central object is the dense **QoS matrix** ``Q ∈ [0,1]^{U×P}`` with
``Q[u, p] = Q(u, s_p, m_p)`` per Eq. (1): zero when user ``u`` did not
request the service of model ``p``, otherwise the mean of the accuracy-
satisfaction term (Eq. 2) and the delay-satisfaction term (Eq. 3), where
the delay ``D`` (Eq. 4) is transmission (Eq. 5) + computation (Eq. 6)
under even sharing of the covering edge cloud's capacities.

Three implementations, one contract (tested against each other):

* :func:`qos_matrix_np` — host NumPy (reference, feeds the exact solver);
* :func:`qos_matrix_jnp` — jit-able jnp (feeds the JAX placement modules);
* :mod:`repro.kernels.qos_matrix` — Pallas TPU kernel tiled over
  (users × service-models) for the production control plane.
"""
from __future__ import annotations

import numpy as np

from .instance import PIESInstance, JaxInstance

__all__ = [
    "accuracy_satisfaction_elem_np",
    "accuracy_satisfaction_np",
    "delay_np",
    "delay_satisfaction_elem_np",
    "delay_satisfaction_np",
    "qos_matrix_np",
    "eligibility_np",
    "qos_matrix_jnp",
    "eligibility_jnp",
]


# ===========================================================================
# NumPy reference
# ===========================================================================

def accuracy_satisfaction_elem_np(A, alpha) -> np.ndarray:
    """Eq. (2) with broadcasting left to the caller — the single source of
    the accuracy-satisfaction formula (matrix *and* per-request paths)."""
    diff = np.asarray(alpha, np.float64) - np.asarray(A, np.float64)
    return np.where(diff <= 0.0, 1.0, np.maximum(0.0, 1.0 - diff))


def accuracy_satisfaction_np(A: np.ndarray, alpha: np.ndarray) -> np.ndarray:
    """Eq. (2): ``â_sm(u)`` — broadcasts ``A`` [P] against ``alpha`` [U]."""
    return accuracy_satisfaction_elem_np(A[None, :], alpha[:, None])


def delay_np(inst: PIESInstance) -> np.ndarray:
    """Eq. (4)–(6): expected delay ``D_sm(u)`` as a [U, P] matrix."""
    counts = inst.covered_counts()
    share_k = counts[inst.u_edge] / inst.K[inst.u_edge]  # |U_e|/K_e
    share_w = counts[inst.u_edge] / inst.W[inst.u_edge]  # |U_e|/W_e
    return (
        inst.sm_k[None, :] * share_k[:, None]
        + inst.sm_w[None, :] * share_w[:, None]
    )


def delay_satisfaction_elem_np(D, delta, delta_max: float) -> np.ndarray:
    """Eq. (3) with broadcasting left to the caller (expected *or*
    realized delay against the threshold)."""
    over = np.asarray(D, np.float64) - np.asarray(delta, np.float64)
    return np.where(over <= 0.0, 1.0,
                    np.maximum(0.0, 1.0 - over / float(delta_max)))


def delay_satisfaction_np(D: np.ndarray, delta: np.ndarray,
                          delta_max: float) -> np.ndarray:
    """Eq. (3): ``d̂_sm(u)`` from the delay matrix [U, P]."""
    return delay_satisfaction_elem_np(D, delta[:, None], delta_max)


def eligibility_np(inst: PIESInstance) -> np.ndarray:
    """[U, P] bool — model ``p`` implements user ``u``'s requested service."""
    return inst.u_service[:, None] == inst.sm_service[None, :]


def qos_matrix_np(inst: PIESInstance) -> np.ndarray:
    """Eq. (1): the dense QoS matrix ``Q`` [U, P], float64."""
    a_hat = accuracy_satisfaction_np(inst.sm_acc, inst.u_alpha)
    d_hat = delay_satisfaction_np(delay_np(inst), inst.u_delta, inst.delta_max)
    return 0.5 * (a_hat + d_hat) * eligibility_np(inst)


# ===========================================================================
# jnp implementation (shape-polymorphic, jit-able)
# ===========================================================================

def qos_matrix_jnp(inst: JaxInstance):
    """jnp twin of :func:`qos_matrix_np` over a :class:`JaxInstance`."""
    import jax.numpy as jnp

    adiff = inst.u_alpha[:, None] - inst.sm_acc[None, :]
    a_hat = jnp.where(adiff <= 0.0, 1.0, jnp.maximum(0.0, 1.0 - adiff))
    D = (
        inst.sm_k[None, :] * inst.u_share_k[:, None]
        + inst.sm_w[None, :] * inst.u_share_w[:, None]
    )
    over = D - inst.u_delta[:, None]
    d_hat = jnp.where(over <= 0.0, 1.0,
                      jnp.maximum(0.0, 1.0 - over / inst.delta_max))
    elig = inst.u_service[:, None] == inst.sm_service[None, :]
    return (0.5 * (a_hat + d_hat) * elig).astype(jnp.float32)


def eligibility_jnp(inst: JaxInstance):
    return inst.u_service[:, None] == inst.sm_service[None, :]
