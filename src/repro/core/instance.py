"""PIES problem instances (§III of the paper).

An instance bundles the three entity families of the system model:

* edge clouds  ``e ∈ E`` with capacities ``K_e`` (communication), ``W_e``
  (computation), ``R_e`` (storage);
* service models ``(s, m) ∈ SM`` — flattened to ``P`` rows — with accuracy
  ``A_sm`` and costs ``k_sm`` (communication), ``w_sm`` (computation),
  ``r_sm`` (storage);
* user requests ``u ∈ U`` with covering edge ``e_u``, requested service
  ``s_u``, accuracy threshold ``α_u`` and delay threshold ``δ_u``.

Everything is stored as flat ``numpy`` arrays so the whole QoS model is
vectorizable; :meth:`PIESInstance.as_jax` mirrors the arrays into ``jnp``
for the jit-able implementations in :mod:`repro.core` and the Pallas
kernel in :mod:`repro.kernels.qos_matrix`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "PIESInstance",
    "synthetic_instance",
    "realworld_instance",
    "REALWORLD_CATALOG",
    "tiny_instance",
    "draw_edge_capacities",
    "draw_service_catalog",
]


@dataclasses.dataclass
class PIESInstance:
    """A complete PIES problem instance (all arrays are host numpy)."""

    # --- edge clouds -----------------------------------------------------
    K: np.ndarray  # [E] communication capacity
    W: np.ndarray  # [E] computation capacity
    R: np.ndarray  # [E] storage capacity

    # --- service models (flattened (s, m) pairs) -------------------------
    sm_service: np.ndarray  # [P] int — service id of each model
    sm_acc: np.ndarray      # [P] A_sm ∈ [0, 1]
    sm_k: np.ndarray        # [P] communication cost
    sm_w: np.ndarray        # [P] computation cost
    sm_r: np.ndarray        # [P] storage cost

    # --- user requests ----------------------------------------------------
    u_edge: np.ndarray     # [U] int — covering edge cloud e_u
    u_service: np.ndarray  # [U] int — requested service s_u
    u_alpha: np.ndarray    # [U] accuracy threshold α_u ∈ [0, 1]
    u_delta: np.ndarray    # [U] delay threshold δ_u ∈ [0, δ_max]

    delta_max: float = 10.0

    # optional human-readable names (real-world catalog)
    model_names: Optional[Sequence[str]] = None

    # ---------------------------------------------------------------------
    @property
    def E(self) -> int:
        return int(self.K.shape[0])

    @property
    def P(self) -> int:
        return int(self.sm_service.shape[0])

    @property
    def U(self) -> int:
        return int(self.u_edge.shape[0])

    @property
    def S(self) -> int:
        return int(self.sm_service.max()) + 1 if self.P else 0

    def covered_counts(self) -> np.ndarray:
        """``|U_e|`` for every edge cloud ``e`` (Eq. 5/6 sharing factor)."""
        return np.bincount(self.u_edge, minlength=self.E).astype(np.float64)

    def users_of_edge(self, e: int) -> np.ndarray:
        return np.nonzero(self.u_edge == e)[0]

    def models_of_service(self, s: int) -> np.ndarray:
        return np.nonzero(self.sm_service == s)[0]

    def validate(self) -> None:
        assert self.u_edge.min(initial=0) >= 0 and (
            self.U == 0 or self.u_edge.max() < self.E
        )
        assert np.all(self.sm_acc >= 0.0) and np.all(self.sm_acc <= 1.0)
        assert np.all(self.u_alpha >= 0.0) and np.all(self.u_alpha <= 1.0)
        assert np.all(self.u_delta >= 0.0) and np.all(
            self.u_delta <= self.delta_max + 1e-9
        )
        assert np.all(self.sm_r > 0), "storage costs must be positive"
        # every service has ≥ 1 implementation (paper assumption m_s ≥ 1)
        if self.U:
            req = np.unique(self.u_service)
            have = np.unique(self.sm_service)
            assert np.all(np.isin(req, have)), "user requests unknown service"

    def as_jax(self):
        """Return a :class:`JaxInstance` pytree mirror of this instance."""
        import jax.numpy as jnp

        counts = self.covered_counts()
        return JaxInstance(
            u_alpha=jnp.asarray(self.u_alpha, jnp.float32),
            u_delta=jnp.asarray(self.u_delta, jnp.float32),
            u_service=jnp.asarray(self.u_service, jnp.int32),
            u_edge=jnp.asarray(self.u_edge, jnp.int32),
            u_share_k=jnp.asarray(counts[self.u_edge] / self.K[self.u_edge], jnp.float32),
            u_share_w=jnp.asarray(counts[self.u_edge] / self.W[self.u_edge], jnp.float32),
            sm_service=jnp.asarray(self.sm_service, jnp.int32),
            sm_acc=jnp.asarray(self.sm_acc, jnp.float32),
            sm_k=jnp.asarray(self.sm_k, jnp.float32),
            sm_w=jnp.asarray(self.sm_w, jnp.float32),
            sm_r=jnp.asarray(self.sm_r, jnp.float32),
            R=jnp.asarray(self.R, jnp.float32),
            delta_max=jnp.float32(self.delta_max),
        )


@dataclasses.dataclass
class JaxInstance:
    """jnp mirror of :class:`PIESInstance` with the per-user sharing factors
    ``|U_e|/K_e`` and ``|U_e|/W_e`` pre-gathered (Eq. 5/6)."""

    u_alpha: "object"
    u_delta: "object"
    u_service: "object"
    u_edge: "object"
    u_share_k: "object"  # [U] = |U_{e_u}| / K_{e_u}
    u_share_w: "object"  # [U] = |U_{e_u}| / W_{e_u}
    sm_service: "object"
    sm_acc: "object"
    sm_k: "object"
    sm_w: "object"
    sm_r: "object"
    R: "object"
    delta_max: "object"


def _register_jax_instance():  # pragma: no cover - import-time plumbing
    try:
        import jax
    except Exception:
        return
    fields = [f.name for f in dataclasses.fields(JaxInstance)]
    jax.tree_util.register_pytree_node(
        JaxInstance,
        lambda x: ([getattr(x, f) for f in fields], None),
        lambda _, leaves: JaxInstance(**dict(zip(fields, leaves))),
    )


_register_jax_instance()


# ===========================================================================
# Instance generators
# ===========================================================================

def draw_edge_capacities(rng: np.random.Generator, n_edges: int):
    """§VI-B edge-cloud draws (the single source of the paper's ranges):
    ``K_e, W_e ~ U{300..600}``, ``R_e ~ U{100..200}``. Returns (K, W, R)."""
    K = rng.integers(300, 601, size=n_edges).astype(np.float64)
    W = rng.integers(300, 601, size=n_edges).astype(np.float64)
    R = rng.integers(100, 201, size=n_edges).astype(np.float64)
    return K, W, R


def draw_service_catalog(rng: np.random.Generator, n_services: int,
                         max_impls: int):
    """§VI-B service-model draws: ``U{1..max_impls}`` implementations per
    service, ``k, w ~ U{15..30}``, ``r ~ U{10..20}``,
    ``A ~ clip(N(0.65, 0.1), 0, 1)``.

    Returns ``(sm_service, sm_acc, sm_k, sm_w, sm_r)``.
    """
    impls = rng.integers(1, max_impls + 1, size=n_services)
    sm_service = np.repeat(np.arange(n_services), impls)
    P = sm_service.shape[0]
    sm_k = rng.integers(15, 31, size=P).astype(np.float64)
    sm_w = rng.integers(15, 31, size=P).astype(np.float64)
    sm_r = rng.integers(10, 21, size=P).astype(np.float64)
    sm_acc = np.clip(rng.normal(0.65, 0.1, size=P), 0.0, 1.0)
    return sm_service, sm_acc, sm_k, sm_w, sm_r


def synthetic_instance(
    n_users: int,
    n_edges: int = 10,
    n_services: int = 100,
    max_impls: int = 10,
    delta_max: float = 10.0,
    seed: int = 0,
    alpha_scale: float = 0.125,
    delta_scale: float = 1.5,
) -> PIESInstance:
    """Numerical-simulation setup of §VI-B, parameter-for-parameter.

    ``K_e, W_e ~ U{300..600}``, ``R_e ~ U{100..200}``; per service model
    ``k, w ~ U{15..30}``, ``r ~ U{10..20}``, ``A ~ clip(N(0.65, 0.1), 0, 1)``;
    each service has ``U{1..max_impls}`` implementations; user services are
    uniform; ``α_u = 1 − ε`` with ``ε ~ clip(Exp(scale=0.125), 0, 1)``;
    ``δ_u ~ clip(Exp(scale=1.5), 0, δ_max)`` with ``δ_max = 10``.

    The paper writes the exponential parameters as rates ``λ``; we follow
    the conventional NumPy ``scale`` reading (``scale = 0.125`` ⇒ strict
    accuracy thresholds near 1), which reproduces the paper's reported
    approximation-ratio regime (see EXPERIMENTS.md §Paper-validation).
    """
    rng = np.random.default_rng(seed)
    K, W, R = draw_edge_capacities(rng, n_edges)
    sm_service, sm_acc, sm_k, sm_w, sm_r = draw_service_catalog(
        rng, n_services, max_impls)

    u_edge = rng.integers(0, n_edges, size=n_users)
    u_service = rng.integers(0, n_services, size=n_users)
    u_alpha = 1.0 - np.clip(rng.exponential(alpha_scale, size=n_users), 0.0, 1.0)
    u_delta = np.clip(rng.exponential(delta_scale, size=n_users), 0.0, delta_max)

    inst = PIESInstance(
        K=K, W=W, R=R,
        sm_service=sm_service, sm_acc=sm_acc, sm_k=sm_k, sm_w=sm_w, sm_r=sm_r,
        u_edge=u_edge, u_service=u_service, u_alpha=u_alpha, u_delta=u_delta,
        delta_max=delta_max,
    )
    inst.validate()
    return inst


#: Table I of the paper: (name, accuracy A_sm, avg. computation delay sec).
REALWORLD_CATALOG = [
    ("AlexNet", 0.5652, 0.04),
    ("DenseNet", 0.7714, 0.47),
    ("GoogLeNet", 0.6978, 0.13),
    ("MobileNet", 0.7188, 0.06),
    ("ResNet", 0.6976, 0.08),
    ("SqueezeNet", 0.5809, 0.07),
]


def realworld_instance(
    n_users: int = 300,
    seed: int = 0,
    tran_delay: float = 0.05,
    comp_contention: float = 2.0,
    delta_max: float = 1.0,
) -> PIESInstance:
    """Real-world setup of §VI-C: one edge cloud (the iMac), one image-
    classification service with the six Table-I implementations, 300
    requests (3 IoT devices × 100 images).

    ``R_e = 1`` and ``r_sm = 1`` (single placement slot), ``k_sm = 1``.
    ``α_u = 1 − ε``, ``ε ~ clip(Exp(scale=0.0625), 0, 1)``;
    ``δ_u ~ clip(N(0.5, 0.125), 0, 1)``, ``δ_max = 1`` second.

    ``K_e``/``W_e`` are "robustly tuned to match the real-world computation
    and communication delay" (paper §VI-C): we pick ``W_e = |U_e| /
    comp_contention`` so a model's effective computation delay is its
    measured Table-I delay times the contention factor, and ``K_e = |U_e| ·
    k_sm / tran_delay`` so transmission costs ``tran_delay`` seconds.
    """
    rng = np.random.default_rng(seed)
    names = [n for n, _, _ in REALWORLD_CATALOG]
    acc = np.array([a for _, a, _ in REALWORLD_CATALOG])
    comp = np.array([c for _, _, c in REALWORLD_CATALOG])

    P = len(names)
    K = np.array([n_users * 1.0 / tran_delay])
    W = np.array([n_users / comp_contention])
    R = np.array([1.0])

    inst = PIESInstance(
        K=K, W=W, R=R,
        sm_service=np.zeros(P, dtype=np.int64),
        sm_acc=acc,
        sm_k=np.ones(P),
        sm_w=comp,  # D_comp = w · |U_e| / W_e = comp · contention
        sm_r=np.ones(P),
        u_edge=np.zeros(n_users, dtype=np.int64),
        u_service=np.zeros(n_users, dtype=np.int64),
        u_alpha=1.0 - np.clip(rng.exponential(0.0625, size=n_users), 0.0, 1.0),
        u_delta=np.clip(rng.normal(0.5, 0.125, size=n_users), 0.0, delta_max),
        delta_max=delta_max,
        model_names=names,
    )
    inst.validate()
    return inst


def tiny_instance(seed: int = 0, n_users: int = 12, n_edges: int = 2,
                  n_services: int = 4, max_impls: int = 3) -> PIESInstance:
    """A brute-forceable instance for exactness tests."""
    return synthetic_instance(
        n_users=n_users, n_edges=n_edges, n_services=n_services,
        max_impls=max_impls, seed=seed,
    )
