"""repro.core — the PIES problem (paper's contribution) as a library.

Host reference (NumPy, paper-pseudocode-faithful) and jit-able JAX
implementations of: the QoS model (Eqs. 1–6), OMS scheduling (Alg. 1),
AGP (Alg. 2), EGP (Alg. 3), the SCK/RND baselines and an exact solver.
"""
from .instance import (
    PIESInstance,
    JaxInstance,
    synthetic_instance,
    realworld_instance,
    tiny_instance,
    REALWORLD_CATALOG,
    draw_edge_capacities,
    draw_service_catalog,
)
from .qos import (
    qos_matrix_np,
    qos_matrix_jnp,
    eligibility_np,
    eligibility_jnp,
    delay_np,
    accuracy_satisfaction_np,
    delay_satisfaction_np,
)
from .scheduling import oms_np, oms_jnp, sigma_np, sigma_jnp, sigma_user_np, schedule_value_np
from .placement import (
    egp_np,
    agp_np,
    agp_literal_np,
    sck_np,
    rnd_np,
    egp_place_jax,
    agp_place_jax,
    egp_place_sparse_jax,
    sigma_sparse_jnp,
    place_and_schedule,
)
from .candidates import (
    CandidateSet,
    impl_table_np,
    max_impls_of,
    topk_candidates_np,
    topk_candidates_jnp,
    sigma_sparse_np,
)
from .opt import opt_np, opt_edge_np, brute_force_np

__all__ = [
    "PIESInstance", "JaxInstance", "synthetic_instance", "realworld_instance",
    "tiny_instance", "REALWORLD_CATALOG",
    "draw_edge_capacities", "draw_service_catalog",
    "qos_matrix_np", "qos_matrix_jnp", "eligibility_np", "eligibility_jnp",
    "delay_np", "accuracy_satisfaction_np", "delay_satisfaction_np",
    "oms_np", "oms_jnp", "sigma_np", "sigma_jnp", "sigma_user_np",
    "schedule_value_np",
    "egp_np", "agp_np", "agp_literal_np", "sck_np", "rnd_np",
    "egp_place_jax", "agp_place_jax", "egp_place_sparse_jax",
    "sigma_sparse_jnp", "place_and_schedule",
    "CandidateSet", "impl_table_np", "max_impls_of", "topk_candidates_np",
    "topk_candidates_jnp", "sigma_sparse_np",
    "opt_np", "opt_edge_np", "brute_force_np",
]
from .dynamic import DynamicPlacer, evaluate_horizon  # noqa: E402
