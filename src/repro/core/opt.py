"""Exact PIES solver ("OPT").

The paper solves the ILP (Eq. 7) with PuLP + CBC (footnote 2: >20 hours on
larger instances). CBC is unavailable offline, and — more importantly — the
PIES objective *decomposes across edge clouds* (each user is covered by
exactly one edge and clouds do not collaborate, §III-A), and *within* an
edge it decomposes across services up to the shared storage budget. We
exploit this for an exact polynomial-×-2^{m_s} dynamic program that is
orders of magnitude faster than the MILP:

  per edge e:
    for every service s requested by a covered user:
        enumerate all subsets of its implementations (m_s ≤ 10 in the
        paper's setup ⇒ ≤ 1024 subsets), score each subset's exact value
        Σ_{u∈U_e} max_{p∈subset} Q[u, p] and weight Σ r; Pareto-prune.
    grouped knapsack DP over services with integer storage capacity R_e.

Requires integer storage costs (true in both paper setups: r ∈ {10..20}
and r = 1); :func:`opt_np` rescales fractional costs by ``resolution``.
Validated against :func:`brute_force_np` on small instances and used as
the denominator of every approximation ratio in EXPERIMENTS.md.
"""
from __future__ import annotations

import itertools
from typing import List, Optional, Tuple

import numpy as np

from .instance import PIESInstance
from .qos import qos_matrix_np
from .scheduling import sigma_np

__all__ = ["opt_np", "opt_edge_np", "brute_force_np", "MAX_SUBSET_IMPLS"]

MAX_SUBSET_IMPLS = 16  # 2^16 subsets per service is the enumeration guard


def _service_groups(inst: PIESInstance, e: int, Q: np.ndarray,
                    resolution: int):
    """Yield per-service (subset_values, subset_weights, subset_members)."""
    users = inst.users_of_edge(e)
    cap = int(np.floor(inst.R[e] * resolution))
    groups = []
    for s in np.unique(inst.u_service[users]):
        impls = inst.models_of_service(int(s))
        impls = impls[np.round(inst.sm_r[impls] * resolution) <= cap]
        if impls.size == 0:
            continue
        if impls.size > MAX_SUBSET_IMPLS:
            raise ValueError(
                f"service {s} has {impls.size} implementations; exact subset "
                f"enumeration capped at {MAX_SUBSET_IMPLS}")
        Qs = Q[np.ix_(users, impls)]  # [|U_e|, m_s]
        w = np.round(inst.sm_r[impls] * resolution).astype(np.int64)
        # enumerate subsets; Pareto-prune (higher value, lower weight wins)
        subsets: List[Tuple[float, int, Tuple[int, ...]]] = [(0.0, 0, ())]
        for k in range(1, impls.size + 1):
            for combo in itertools.combinations(range(impls.size), k):
                wt = int(w[list(combo)].sum())
                if wt > cap:
                    continue
                val = float(Qs[:, list(combo)].max(axis=1).sum())
                subsets.append((val, wt, combo))
        # Pareto prune: sort by weight then keep strictly increasing value
        subsets.sort(key=lambda t: (t[1], -t[0]))
        pruned: List[Tuple[float, int, Tuple[int, ...]]] = []
        best = -1.0
        for val, wt, combo in subsets:
            if val > best + 1e-12:
                pruned.append((val, wt, combo))
                best = val
        groups.append((pruned, impls))
    return groups, cap


def opt_edge_np(inst: PIESInstance, e: int, Q: np.ndarray,
                resolution: int = 1) -> Tuple[np.ndarray, float]:
    """Exact optimal placement for one edge cloud. Returns (x_e [P], value)."""
    x_e = np.zeros(inst.P, dtype=bool)
    users = inst.users_of_edge(e)
    if users.size == 0:
        return x_e, 0.0
    groups, cap = _service_groups(inst, e, Q, resolution)
    if not groups:
        return x_e, 0.0

    NEG = -np.inf
    f = np.zeros(cap + 1)
    # choices[g][c] = index of subset chosen for group g at capacity c
    choice_tables = []
    for pruned, _ in groups:
        f_new = np.full(cap + 1, NEG)
        pick = np.zeros(cap + 1, dtype=np.int32)
        for idx, (val, wt, _) in enumerate(pruned):
            cand = np.full(cap + 1, NEG)
            cand[wt:] = f[: cap + 1 - wt] + val
            upd = cand > f_new
            f_new = np.where(upd, cand, f_new)
            pick = np.where(upd, idx, pick)
        f = f_new
        choice_tables.append(pick)

    c = int(np.argmax(f))
    total = float(f[c])
    # backtrack
    for g in range(len(groups) - 1, -1, -1):
        pruned, impls = groups[g]
        idx = int(choice_tables[g][c])
        val, wt, combo = pruned[idx]
        for j in combo:
            x_e[impls[j]] = True
        c -= wt
    return x_e, total


def opt_np(inst: PIESInstance, Q: Optional[np.ndarray] = None,
           resolution: int = 1) -> np.ndarray:
    """Exact optimal placement for the whole instance (per-edge DP)."""
    if Q is None:
        Q = qos_matrix_np(inst)
    x = np.zeros((inst.E, inst.P), dtype=bool)
    for e in range(inst.E):
        x[e], _ = opt_edge_np(inst, e, Q, resolution)
    return x


def brute_force_np(inst: PIESInstance,
                   Q: Optional[np.ndarray] = None) -> Tuple[np.ndarray, float]:
    """Exhaustive search over all feasible placements (tests only).

    Enumerates, per edge, every subset of service models fitting in R_e and
    takes the per-edge best (valid because the objective decomposes across
    edges). Exponential — keep instances tiny.
    """
    if Q is None:
        Q = qos_matrix_np(inst)
    x = np.zeros((inst.E, inst.P), dtype=bool)
    total = 0.0
    for e in range(inst.E):
        users = inst.users_of_edge(e)
        if users.size == 0:
            continue
        # restrict to models some covered user requests (others add 0)
        cands = np.nonzero(Q[users].sum(axis=0) > 0.0)[0]
        cands = cands[inst.sm_r[cands] <= inst.R[e]]
        best_val, best_set = 0.0, ()
        for k in range(len(cands) + 1):
            for combo in itertools.combinations(cands, k):
                if inst.sm_r[list(combo)].sum() > inst.R[e] + 1e-12:
                    continue
                if combo:
                    val = float(Q[np.ix_(users, list(combo))].max(axis=1).sum())
                else:
                    val = 0.0
                if val > best_val + 1e-12:
                    best_val, best_set = val, combo
        for p in best_set:
            x[e, p] = True
        total += best_val
    assert abs(sigma_np(inst, x, Q) - total) < 1e-6
    return x, total
