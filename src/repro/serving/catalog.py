"""Service catalog: the bridge from the model zoo to the PIES problem.

A *service* is a task family (``chat``, ``audio-encode``, ``vlm-caption``);
each architecture config registered under a service is one *service model*
``(s, m)`` in the paper's sense, with:

* ``accuracy``  — published eval quality mapped to [0, 1] (the paper treats
  A_sm as a cached metric from offline evaluation; sources inline);
* ``comm_cost k_sm``  — request payload units (∝ prompt/frame bytes);
* ``comp_cost w_sm``  — compute units (∝ active params — measured latency
  can be substituted via :meth:`Catalog.profile_with`);
* ``storage r_sm``    — resident HBM GiB (params + steady-state KV).

``to_instance`` assembles a full :class:`repro.core.PIESInstance` from the
catalog plus a request population, so the whole PIES pipeline (EGP/AGP/OMS)
drives real placement decisions for the zoo.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.instance import PIESInstance
from repro.configs import get_config

__all__ = ["ServiceModel", "Catalog", "default_catalog"]


@dataclasses.dataclass
class ServiceModel:
    service: str
    arch: str
    accuracy: float          # A_sm ∈ [0, 1]
    comm_cost: float         # k_sm
    comp_cost: float         # w_sm
    storage: float           # r_sm (GiB-ish units)
    note: str = ""


@dataclasses.dataclass
class Catalog:
    models: List[ServiceModel]

    @property
    def services(self) -> List[str]:
        out = []
        for m in self.models:
            if m.service not in out:
                out.append(m.service)
        return out

    def profile_with(self, arch: str, *, comp_cost: Optional[float] = None,
                     accuracy: Optional[float] = None) -> "Catalog":
        """Override catalog entries with live-measured profiles."""
        models = []
        for m in self.models:
            if m.arch == arch:
                m = dataclasses.replace(
                    m,
                    comp_cost=comp_cost if comp_cost is not None else m.comp_cost,
                    accuracy=accuracy if accuracy is not None else m.accuracy)
            models.append(m)
        return Catalog(models)

    def to_instance(
        self,
        n_users: int,
        n_edges: int = 4,
        *,
        storage_capacity: float = 60.0,
        comm_capacity: Tuple[float, float] = (300.0, 600.0),
        comp_capacity: Tuple[float, float] = (300.0, 600.0),
        delta_max: float = 10.0,
        alpha_scale: float = 0.125,
        delta_scale: float = 1.5,
        seed: int = 0,
    ) -> PIESInstance:
        rng = np.random.default_rng(seed)
        svc_index = {s: i for i, s in enumerate(self.services)}
        P = len(self.models)
        inst = PIESInstance(
            K=rng.uniform(*comm_capacity, size=n_edges),
            W=rng.uniform(*comp_capacity, size=n_edges),
            R=np.full(n_edges, storage_capacity),
            sm_service=np.array([svc_index[m.service] for m in self.models]),
            sm_acc=np.array([m.accuracy for m in self.models]),
            sm_k=np.array([m.comm_cost for m in self.models]),
            sm_w=np.array([m.comp_cost for m in self.models]),
            sm_r=np.array([m.storage for m in self.models]),
            u_edge=rng.integers(0, n_edges, size=n_users),
            u_service=rng.integers(0, len(self.services), size=n_users),
            u_alpha=1.0 - np.clip(rng.exponential(alpha_scale, n_users), 0, 1),
            u_delta=np.clip(rng.exponential(delta_scale, n_users), 0, delta_max),
            delta_max=delta_max,
            model_names=[f"{m.service}/{m.arch}" for m in self.models],
        )
        inst.validate()
        return inst


def _storage_gib(arch: str) -> float:
    cfg = get_config(arch)
    return round(cfg.n_params * 2 / 2**30, 1)  # bf16 resident params


def with_quantized_variants(cat: "Catalog", *, storage_ratio: float = 0.52,
                            accuracy_retention: float = 0.985,
                            comp_ratio: float = 0.8) -> "Catalog":
    """Add an int8 weight-only variant of every implementation — a second
    point on each service's accuracy/cost frontier (the paper's
    multi-implementation premise, manufactured from the same checkpoint).

    Defaults come from repro.models.quant measurements on the reduced
    configs (storage ≈ 0.52× for int8+scales; top-1 agreement ≈ 0.98–1.0;
    comp_ratio reflects faster weight streaming in the bandwidth-bound
    regimes). Pass live-measured values to override.
    """
    extra = [
        dataclasses.replace(
            m, arch=m.arch + "-int8",
            accuracy=round(m.accuracy * accuracy_retention, 4),
            storage=round(m.storage * storage_ratio, 2),
            comp_cost=round(m.comp_cost * comp_ratio, 2),
            note=(m.note + " (int8 weight-only)").strip())
        for m in cat.models
    ]
    return Catalog(cat.models + extra)


def default_catalog() -> Catalog:
    """The assigned zoo as a multi-implementation service catalog.

    Accuracies are published benchmark results normalized to [0, 1]
    (MMLU for chat LMs, ImageNet-style proxies elsewhere) — the paper's
    Table-I workflow with cached metrics. comp_cost ∝ active GFLOPs/token.
    """
    def comp(arch):
        return round(get_config(arch).n_active_params * 2 / 1e9, 2)

    rows = [
        # service     arch              A_sm   k_sm  note
        ("chat",      "smollm_360m",    0.34,  1.0, "SmolLM-360M eval"),
        ("chat",      "zamba2_2p7b",    0.55,  1.0, "Zamba2-2.7B MMLU"),
        ("chat",      "mamba2_2p7b",    0.48,  1.0, "Mamba2-2.7B avg"),
        ("chat",      "mixtral_8x7b",   0.71,  1.0, "Mixtral MMLU"),
        ("chat",      "yi_34b",         0.76,  1.0, "Yi-34B MMLU"),
        ("chat",      "gemma2_27b",     0.75,  1.0, "Gemma2-27B MMLU"),
        ("chat",      "command_r_35b",  0.68,  1.0, "Command-R MMLU"),
        ("chat",      "qwen3_moe_235b", 0.88,  1.0, "Qwen3-235B-A22B"),
        ("audio-encode", "hubert_xlarge", 0.95, 4.0, "HuBERT-XL phoneme"),
        ("vlm-caption",  "internvl2_1b",  0.61, 6.0, "InternVL2-1B avg"),
    ]
    return Catalog([
        ServiceModel(service=s, arch=a, accuracy=acc, comm_cost=k,
                     comp_cost=comp(a), storage=_storage_gib(a), note=n)
        for s, a, acc, k, n in rows
    ])
