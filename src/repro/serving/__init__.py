"""repro.serving — the EI serving control+data plane (paper's system)."""
from .catalog import (Catalog, ServiceModel, default_catalog,
                      with_quantized_variants)
from .router import Router, RoutingDecision
from .engine import ModelServer, Request
from .cluster import EdgeCluster, ServeReport
from .scheduler import (ArrivingRequest, ContinuousScheduler,
                        ExecutorProfile, simulate)
from .horizon import (HorizonConfig, HorizonResult, TickReport,
                      run_horizon, split_serving_overrides)
