"""Multi-tick serving driver: scenario traffic through the full engine.

The analytic pipeline (``repro.sweeps`` kind ``"sigma"``) scores
placements with the closed-form objective σ — *expected* QoS under the
paper's delay model. This module instead drives every registered
:mod:`repro.workloads` scenario end-to-end through the serving engine and
scores **realized** QoS from simulated serving latency, the way the
paper's real-world experiment (§VI-C) does with measured latency:

per control tick, :func:`run_horizon`

1. materializes the tick's :class:`~repro.core.instance.PIESInstance`
   from the scenario (arrival counts + population dynamics);
2. re-places via :class:`~repro.core.dynamic.DynamicPlacer` (EGP with
   hysteresis — switching costs and a stickiness bonus for resident
   implementations); switching cost is *realized*, not just booked:
   a newly placed implementation spends ``switching_cost`` seconds
   loading and serves nothing until then, so placement churn costs
   real latency (cold starts) and hysteresis pays off measurably;
3. routes each request with OMS (Alg. 1) under the tick's placement;
4. submits the tick's requests — timestamped by the scenario's arrival
   process *within* the tick window — into one **stateful**
   :class:`~repro.serving.scheduler.ContinuousScheduler` whose queues and
   in-flight batches survive tick boundaries (backlog from a flash crowd
   spills into the next tick, exactly like a real engine).

Each tick emits a :class:`TickReport` (realized QoS, deadline misses,
queue depth, in-flight count, model loads); requests are *attributed to
their arrival tick* even when they finish later, and dropped requests
(OMS returns −1: no placed implementation of the requested service)
score 0 QoS — so ``per_tick[t].mean_realized_qos`` is an unconditional
per-tick service-quality number and conservation holds exactly
(``served + dropped == submitted``).

Everything is a pure function of ``(config, seed)``: same seed →
byte-identical per-request finish times, which is what lets
``repro.sweeps`` (kind ``"serving"``) resume killed sweeps item-granularly
by replaying a seed's horizon.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.dynamic import DynamicPlacer
from repro.core.qos import qos_matrix_np
from repro.core.scheduling import oms_np

from .scheduler import (ArrivingRequest, ContinuousScheduler,
                        ExecutorProfile, realized_qos_np)

__all__ = ["SERVING_PARAM_KEYS", "HorizonConfig", "TickReport",
           "HorizonResult", "run_horizon", "split_serving_overrides"]

#: Override keys consumed by the serving driver (everything else is a
#: scenario/instance override). The sweep spec routes a flat override
#: mapping through :func:`split_serving_overrides` so one ``--override``
#: grammar covers both layers.
SERVING_PARAM_KEYS = ("switching_cost", "stickiness", "tick_duration",
                      "prompt_tokens", "new_tokens", "max_batch")


def split_serving_overrides(
        overrides: Mapping[str, Any] | Tuple[Tuple[str, Any], ...]
) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Split a flat override mapping into (scenario, serving) key sets."""
    items = dict(overrides)
    serving = {k: v for k, v in items.items() if k in SERVING_PARAM_KEYS}
    scenario = {k: v for k, v in items.items() if k not in SERVING_PARAM_KEYS}
    return scenario, serving


@dataclasses.dataclass(frozen=True)
class HorizonConfig:
    """One serving-horizon run = (scenario, policy, placer knobs, seed)."""

    scenario: str = "steady"
    overrides: Tuple[Tuple[str, Any], ...] = ()   # scenario-level overrides
    policy: str = "edf"             # continuous-batching queue policy
    #: DynamicPlacer's QoS-units switching cost — and, *realized*, the
    #: model-load latency in seconds: a newly placed implementation cannot
    #: serve until ``switching_cost`` seconds into its tick (arrivals
    #: queue meanwhile), so churny placements pay a cold-start penalty in
    #: realized QoS and the (switching_cost × stickiness) sweep grid
    #: measures a real trade-off, not a bookkeeping discount.
    switching_cost: float = 2.0
    stickiness: float = 3.0         # DynamicPlacer: resident benefit bonus
    seed: int = 0
    n_ticks: Optional[int] = None   # default: the scenario's horizon
    tick_duration: float = 1.0      # seconds of serving time per tick
    prompt_tokens: int = 128
    new_tokens: int = 32
    max_batch: int = 8

    @classmethod
    def from_overrides(cls, scenario: str, overrides, policy: str,
                       seed: int, n_ticks: Optional[int] = None
                       ) -> "HorizonConfig":
        """Build a config from a flat sweep-style override mapping."""
        scen_ov, serving = split_serving_overrides(overrides)
        return cls(scenario=scenario,
                   overrides=tuple(sorted(scen_ov.items())),
                   policy=policy, seed=int(seed), n_ticks=n_ticks,
                   **serving)


@dataclasses.dataclass
class TickReport:
    """Realized serving statistics of one control tick (arrival-attributed)."""

    tick: int
    submitted: int            # requests arriving this tick (inst.U)
    served: int               # submitted − dropped (all eventually finish)
    dropped: int              # OMS −1: no placed impl of the service
    mean_realized_qos: float  # over ALL submitted (dropped score 0)
    deadline_misses: int
    mean_latency_s: float     # over served requests (NaN if none)
    queue_depth: int          # backlog queued at the tick boundary
    in_flight: int            # sequences still running at the boundary
    model_loads: int          # newly loaded implementations this tick
    placement_value: float    # DynamicPlacer value (σ − switching·loads)


@dataclasses.dataclass
class HorizonResult:
    config: HorizonConfig
    per_tick: List[TickReport]
    requests: List[ArrivingRequest]   # every served request, finish set

    # -- horizon-level aggregates -----------------------------------------
    @property
    def submitted(self) -> int:
        return sum(t.submitted for t in self.per_tick)

    @property
    def served(self) -> int:
        return sum(t.served for t in self.per_tick)

    @property
    def dropped(self) -> int:
        return sum(t.dropped for t in self.per_tick)

    @property
    def deadline_misses(self) -> int:
        return sum(t.deadline_misses for t in self.per_tick)

    @property
    def mean_realized_qos(self) -> float:
        """Submission-weighted mean over the whole horizon."""
        n = self.submitted
        return float(sum(t.mean_realized_qos * t.submitted
                         for t in self.per_tick) / n) if n else 0.0

    @property
    def miss_rate(self) -> float:
        return self.deadline_misses / self.served if self.served else 0.0

    def tick_values(self) -> np.ndarray:
        """[T] per-tick mean realized QoS — the sweep-item values."""
        return np.array([t.mean_realized_qos for t in self.per_tick],
                        np.float64)


def _arrival_times(scenario, seed: int, tick: int, n: int,
                   tick_duration: float) -> np.ndarray:
    """``n`` arrival timestamps inside tick ``tick``'s window.

    The scenario's arrival process supplies the offsets; the active
    population is its count clipped to the slot pool, so surplus arrivals
    are truncated and a shortfall (count 0 → the 1-user floor) is padded
    with deterministic mid-tick timestamps.
    """
    times = np.asarray(scenario.arrivals.times_in_tick(
        seed, tick, tick_duration), np.float64)
    if times.size < n:
        pad = (tick + (np.arange(times.size, n) + 0.5) / n) * tick_duration
        times = np.sort(np.concatenate([times, pad]))
    return times[:n]


def run_horizon(config: HorizonConfig) -> HorizonResult:
    """Drive one scenario horizon through placement → routing → serving."""
    from repro.workloads import get_scenario  # deferred: workloads uses core

    sc = get_scenario(config.scenario, **dict(config.overrides))
    T = int(config.n_ticks or sc.n_ticks)
    placer = DynamicPlacer(config.switching_cost, config.stickiness)
    sched = ContinuousScheduler(policy=config.policy)

    mobility_cache = sc.mobility_trajectory(config.seed, T)

    tick_reqs: List[List[ArrivingRequest]] = []
    meta: List[Dict[str, Any]] = []
    boundary: List[Tuple[int, int]] = []   # (queue_depth, in_flight) per tick
    uid = 0
    for t in range(T):
        inst = sc.instance_at(config.seed, t, mobility_cache=mobility_cache)
        Q = qos_matrix_np(inst)
        x, value, loads = placer.step(inst, Q)
        # cold starts: every implementation the placer just loaded spends
        # the first switching_cost seconds of the tick loading and serves
        # nothing until then — gated up front, so an impl placed now but
        # first routed to next tick still queues through its load window
        if config.switching_cost > 0.0:
            ready_at = t * config.tick_duration + config.switching_cost
            for e, p in np.argwhere(placer.new_loads):
                key = (int(e), int(p))
                sched.add_executor(key, ExecutorProfile.from_comp_cost(
                    float(inst.sm_w[p]), config.max_batch))
                sched.delay_executor(key, ready_at)
        y, _ = oms_np(inst, x, Q)

        times = _arrival_times(sc, config.seed, t, inst.U,
                               config.tick_duration)
        reqs: List[ArrivingRequest] = []
        for u in range(inst.U):
            p = int(y[u])
            if p < 0:
                continue
            e = int(inst.u_edge[u])
            if (e, p) not in sched.executors:
                sched.add_executor(
                    (e, p), ExecutorProfile.from_comp_cost(
                        float(inst.sm_w[p]), config.max_batch))
            reqs.append(ArrivingRequest(
                uid=uid + u, impl=p, edge=e, arrival=float(times[u]),
                prompt_tokens=config.prompt_tokens,
                new_tokens=config.new_tokens,
                alpha=float(inst.u_alpha[u]), delta=float(inst.u_delta[u]),
                accuracy=float(inst.sm_acc[p])))
        uid += inst.U
        sched.submit(reqs)
        sched.run_until((t + 1) * config.tick_duration)

        tick_reqs.append(reqs)
        boundary.append((sched.queue_depth(), sched.in_flight()))
        meta.append({"submitted": inst.U, "dropped": int((y < 0).sum()),
                     "loads": loads, "value": float(value),
                     "delta_max": float(inst.delta_max)})

    # Backlog left at the horizon end drains to completion (graceful
    # shutdown); its requests stay attributed to their arrival ticks.
    sched.drain()

    per_tick: List[TickReport] = []
    for t in range(T):
        reqs, m = tick_reqs[t], meta[t]
        if reqs:
            lats = np.maximum(
                np.array([r.finish - r.arrival for r in reqs]), 0.0)
            qos, missed = realized_qos_np(
                lats, np.array([r.delta for r in reqs]),
                np.array([r.accuracy for r in reqs]),
                np.array([r.alpha for r in reqs]), m["delta_max"])
        else:
            lats, qos, missed = np.zeros(0), np.zeros(0), np.zeros(0, bool)
        per_tick.append(TickReport(
            tick=t, submitted=m["submitted"], served=len(reqs),
            dropped=m["dropped"],
            # dropped requests contribute 0 — divide by ALL submitted
            mean_realized_qos=float(qos.sum() / m["submitted"])
            if m["submitted"] else 0.0,
            deadline_misses=int(missed.sum()),
            mean_latency_s=float(lats.mean()) if reqs else float("nan"),
            queue_depth=boundary[t][0], in_flight=boundary[t][1],
            model_loads=m["loads"], placement_value=m["value"]))

    return HorizonResult(config=config, per_tick=per_tick,
                         requests=[r for reqs in tick_reqs for r in reqs])
