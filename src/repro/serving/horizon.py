"""Multi-tick serving driver: scenario traffic through the full engine.

The analytic pipeline (``repro.sweeps`` kind ``"sigma"``) scores
placements with the closed-form objective σ — *expected* QoS under the
paper's delay model. This module instead drives every registered
:mod:`repro.workloads` scenario end-to-end through the serving engine and
scores **realized** QoS from simulated serving latency, the way the
paper's real-world experiment (§VI-C) does with measured latency:

per control tick, :func:`run_horizon`

1. materializes the tick's :class:`~repro.core.instance.PIESInstance`
   from the scenario (arrival counts + population dynamics);
2. re-places via :class:`~repro.core.dynamic.DynamicPlacer` (EGP with
   hysteresis — switching costs and a stickiness bonus for resident
   implementations); switching cost is *realized*, not just booked:
   a newly placed implementation spends ``switching_cost`` seconds
   loading and serves nothing until then, so placement churn costs
   real latency (cold starts) and hysteresis pays off measurably;
3. routes each request with OMS (Alg. 1) under the tick's placement;
4. submits the tick's requests — timestamped by the scenario's arrival
   process *within* the tick window — into one **stateful**
   :class:`~repro.serving.scheduler.ContinuousScheduler` whose queues and
   in-flight batches survive tick boundaries (backlog from a flash crowd
   spills into the next tick, exactly like a real engine).

Each tick emits a :class:`TickReport` (realized QoS, deadline misses,
queue depth, in-flight count, model loads, requeued backlog); requests
are *attributed to their arrival tick* even when they finish later, and
dropped requests (OMS returns −1: no placed implementation of the
requested service) score 0 QoS — so ``per_tick[t].mean_realized_qos`` is
an unconditional per-tick service-quality number and conservation holds
exactly (``served + dropped == submitted``). Backlog queued on an
implementation that a re-placement *evicts* never executes on the evicted
model: it is pulled off the executor and re-routed through OMS against
the new placement (:func:`_requeue_evicted`), or dropped when the new
placement no longer serves it.

Two closed data paths feed placement from measurement
(:mod:`repro.tuning`): ``HorizonConfig.from_overrides`` consults the
fitted per-scenario knob lookup table for unset placer knobs, and
``policy="feedback"`` swaps the open-loop ``DynamicPlacer`` for the
:class:`~repro.tuning.controller.FeedbackPlacer`, which adapts the
stickiness bonus online from each tick's realized completions.

Everything is a pure function of ``(config, seed)``: same seed →
byte-identical per-request finish times, which is what lets
``repro.sweeps`` (kind ``"serving"``) resume killed sweeps item-granularly
by replaying a seed's horizon.

The per-tick body lives in :class:`TickController` so two drivers can
share it bit-for-bit: :func:`run_horizon` (offline — materializes each
tick's instance from the scenario and loops as fast as the CPU allows)
and the live asyncio gateway (:mod:`repro.gateway` — rebuilds each
tick's instance from requests that physically arrived over a socket,
paced by a wall or virtual clock). The gateway's determinism invariant
is exactly this factoring: on a virtual clock with a seeded load
generator it performs the same controller calls in the same order, so
its ``TickReport``\\ s are byte-identical to the offline horizon's.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro import obs
from repro.obs import ledger as _obs_ledger
from repro.obs import reqtrace as _reqtrace
from repro.core.dynamic import DynamicPlacer
from repro.core.instance import PIESInstance
from repro.core.qos import qos_matrix_np
from repro.core.scheduling import oms_np

from .scheduler import (ArrivingRequest, ContinuousScheduler,
                        ExecutorProfile, realized_qos_np)

__all__ = ["SERVING_PARAM_KEYS", "HorizonConfig", "TickReport",
           "HorizonResult", "TickController", "run_horizon",
           "split_serving_overrides"]

#: Override keys consumed by the serving driver (everything else is a
#: scenario/instance override). The sweep spec routes a flat override
#: mapping through :func:`split_serving_overrides` so one ``--override``
#: grammar covers both layers.
SERVING_PARAM_KEYS = ("switching_cost", "stickiness", "tick_duration",
                      "prompt_tokens", "new_tokens", "max_batch",
                      "feedback_gain", "feedback_ewma",
                      "feedback_target_miss")


def split_serving_overrides(
        overrides: Mapping[str, Any] | Tuple[Tuple[str, Any], ...]
) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Split a flat override mapping into (scenario, serving) key sets."""
    items = dict(overrides)
    serving = {k: v for k, v in items.items() if k in SERVING_PARAM_KEYS}
    scenario = {k: v for k, v in items.items() if k not in SERVING_PARAM_KEYS}
    return scenario, serving


@dataclasses.dataclass(frozen=True)
class HorizonConfig:
    """One serving-horizon run = (scenario, policy, placer knobs, seed)."""

    scenario: str = "steady"
    overrides: Tuple[Tuple[str, Any], ...] = ()   # scenario-level overrides
    #: ``"edf"`` / ``"fcfs"`` — continuous-batching queue policy — or
    #: ``"feedback"``: EDF queueing with the closed-loop
    #: :class:`~repro.tuning.controller.FeedbackPlacer` adapting the
    #: stickiness bonus online from realized per-tick QoS/miss-rate.
    policy: str = "edf"
    #: DynamicPlacer's QoS-units switching cost — and, *realized*, the
    #: model-load latency in seconds: a newly placed implementation cannot
    #: serve until ``switching_cost`` seconds into its tick (arrivals
    #: queue meanwhile), so churny placements pay a cold-start penalty in
    #: realized QoS and the (switching_cost × stickiness) sweep grid
    #: measures a real trade-off, not a bookkeeping discount.
    switching_cost: float = 2.0
    stickiness: float = 3.0         # DynamicPlacer: resident benefit bonus
    seed: int = 0
    n_ticks: Optional[int] = None   # default: the scenario's horizon
    tick_duration: float = 1.0      # seconds of serving time per tick
    prompt_tokens: int = 128
    new_tokens: int = 32
    max_batch: int = 8
    # policy="feedback" controller knobs (see repro.tuning.controller)
    feedback_gain: float = 1.5
    feedback_ewma: float = 0.5
    feedback_target_miss: float = 0.05

    @classmethod
    def from_overrides(cls, scenario: str, overrides, policy: str,
                       seed: int, n_ticks: Optional[int] = None
                       ) -> "HorizonConfig":
        """Build a config from a flat sweep-style override mapping.

        Placer knobs the mapping leaves unset are looked up in the fitted
        per-scenario table (:func:`repro.tuning.fit.recommend`) when one
        ships for this scenario — the auto-tuner's closed data path from
        sweep grids back into the serving engine. Explicit overrides
        always win, and direct ``HorizonConfig(...)`` construction keeps
        the plain dataclass defaults.
        """
        scen_ov, serving = split_serving_overrides(overrides)
        missing = [k for k in ("switching_cost", "stickiness")
                   if k not in serving]
        if missing:
            from repro.tuning.fit import recommend  # deferred: no cycle
            rec = recommend(scenario)
            if rec:
                for k in missing:
                    serving[k] = rec[k]
        return cls(scenario=scenario,
                   overrides=tuple(sorted(scen_ov.items())),
                   policy=policy, seed=int(seed), n_ticks=n_ticks,
                   **serving)


@dataclasses.dataclass
class TickReport:
    """Realized serving statistics of one control tick (arrival-attributed)."""

    tick: int
    submitted: int            # requests arriving this tick (inst.U)
    served: int               # submitted − dropped (all eventually finish)
    dropped: int              # OMS −1: no placed impl of the service
    mean_realized_qos: float  # over ALL submitted (dropped score 0)
    deadline_misses: int
    mean_latency_s: float     # over served requests (NaN if none)
    queue_depth: int          # backlog queued at the tick boundary
    in_flight: int            # sequences still running at the boundary
    model_loads: int          # newly loaded implementations this tick
    placement_value: float    # DynamicPlacer value (σ − switching·loads)
    #: backlog requests pulled off implementations this tick's re-placement
    #: evicted and pushed back through OMS re-routing (they never execute
    #: on an evicted model; unroutable ones count as dropped at their
    #: arrival tick)
    requeued: int = 0
    #: stickiness bonus the placer applied this tick (config value for
    #: open-loop policies; the adapted value under policy="feedback")
    stickiness: float = float("nan")
    #: mean A_sm of the implementations that served this tick's requests
    #: (NaN if none served) — persisted per item by the sweep engine so
    #: accuracy/latency frontiers are a pure store read
    mean_accuracy: float = float("nan")


@dataclasses.dataclass
class HorizonResult:
    config: HorizonConfig
    per_tick: List[TickReport]
    requests: List[ArrivingRequest]   # every served request, finish set

    # -- horizon-level aggregates -----------------------------------------
    @property
    def submitted(self) -> int:
        return sum(t.submitted for t in self.per_tick)

    @property
    def served(self) -> int:
        return sum(t.served for t in self.per_tick)

    @property
    def dropped(self) -> int:
        return sum(t.dropped for t in self.per_tick)

    @property
    def deadline_misses(self) -> int:
        return sum(t.deadline_misses for t in self.per_tick)

    @property
    def mean_realized_qos(self) -> float:
        """Submission-weighted mean over the whole horizon."""
        n = self.submitted
        return float(sum(t.mean_realized_qos * t.submitted
                         for t in self.per_tick) / n) if n else 0.0

    @property
    def miss_rate(self) -> float:
        return self.deadline_misses / self.served if self.served else 0.0

    def tick_values(self) -> np.ndarray:
        """[T] per-tick mean realized QoS — the sweep-item values."""
        return np.array([t.mean_realized_qos for t in self.per_tick],
                        np.float64)


def _arrival_times(scenario, seed: int, tick: int, n: int,
                   tick_duration: float) -> np.ndarray:
    """``n`` arrival timestamps inside tick ``tick``'s window.

    The scenario's arrival process supplies the offsets; the active
    population is its count clipped to the slot pool, so surplus arrivals
    are truncated and a shortfall (count 0 → the 1-user floor) is padded
    with deterministic mid-tick timestamps.
    """
    times = np.asarray(scenario.arrivals.times_in_tick(
        seed, tick, tick_duration), np.float64)
    if times.size < n:
        pad = (tick + (np.arange(times.size, n) + 0.5) / n) * tick_duration
        times = np.sort(np.concatenate([times, pad]))
    return times[:n]


def _requeue_evicted(sched: ContinuousScheduler, evicted: np.ndarray,
                     inst: PIESInstance, x: np.ndarray,
                     config: HorizonConfig,
                     tick_reqs: List[List[ArrivingRequest]],
                     meta: List[Dict[str, Any]]) -> int:
    """Pull backlog off evicted implementations, re-route it through OMS.

    A re-placement that drops a resident implementation mid-horizon must
    not leave queued (not in-flight) requests to execute on the evicted
    model. They are pulled off the executor and pushed through OMS (Alg. 1)
    against the *new* placement, as a mini-instance whose user set is
    exactly the displaced requests (their real edge/service/α/δ attributes
    against the tick's infrastructure and catalog). Re-routed requests keep
    their true arrival time (latency still counts the wait so far) but
    cannot be admitted in the past; unroutable ones (−1: the new placement
    holds no implementation of their service on their edge) are dropped
    and re-attributed as such to their arrival tick. Returns the number of
    requests pulled.
    """
    pulled: List[ArrivingRequest] = []
    for e, p in np.argwhere(evicted):
        pulled.extend(sched.evict_queued((int(e), int(p))))
    if not pulled:
        return 0
    bad = [r.uid for r in pulled if r.service < 0]
    if bad:
        # a silently-vanishing request would break conservation; every
        # horizon-submitted request carries its service, so this only
        # fires on a foreign driver that must opt into re-routing
        raise ValueError(f"cannot re-route requests with no service "
                         f"attribute (uids {bad[:5]}...)")
    mini = PIESInstance(
        K=inst.K, W=inst.W, R=inst.R,
        sm_service=inst.sm_service, sm_acc=inst.sm_acc,
        sm_k=inst.sm_k, sm_w=inst.sm_w, sm_r=inst.sm_r,
        u_edge=np.array([r.edge for r in pulled], dtype=inst.u_edge.dtype),
        u_service=np.array([r.service for r in pulled],
                           dtype=inst.u_service.dtype),
        u_alpha=np.array([r.alpha for r in pulled], np.float64),
        u_delta=np.array([r.delta for r in pulled], np.float64),
        delta_max=inst.delta_max)
    y, _ = oms_np(mini, x, qos_matrix_np(mini))
    rt = _reqtrace._REQTRACER
    # re-routing happens at the current tick's placement epoch; one meta
    # entry exists per already-completed tick, so this *is* tick len(meta)
    t_now = len(meta) * config.tick_duration
    for r, p2 in zip(pulled, y):
        p2 = int(p2)
        if p2 < 0:
            t0 = int(r.arrival // config.tick_duration)
            tick_reqs[t0] = [q for q in tick_reqs[t0] if q.uid != r.uid]
            meta[t0]["dropped"] += 1
            sched.unsubmit(r)   # keeps backlog() exact: it never completes
            if rt is not None:
                rt.drop(r.uid, t_now, reason="evicted_unroutable")
            continue
        if rt is not None:
            rt.requeue(r.uid, t_now, impl=p2)
        r.impl = p2
        r.accuracy = float(inst.sm_acc[p2])
        key = (r.edge, p2)
        if key not in sched.executors:
            sched.add_executor(key, ExecutorProfile.from_comp_cost(
                float(inst.sm_w[p2]), config.max_batch))
        sched.requeue([r])
    return len(pulled)


def run_horizon(config: HorizonConfig) -> HorizonResult:
    """Drive one scenario horizon through placement → routing → serving.

    Instrumented with :mod:`repro.obs` (off by default, observational
    only — a traced run produces byte-identical ``TickReport``\\ s and
    per-request finish times): per-tick ``tick.materialize`` /
    ``tick.place`` / ``tick.route`` / ``tick.execute`` spans, a
    ``kernel.qos_matrix_np`` span inside placement, queue-depth and
    in-flight gauge samples at every tick boundary, realized-QoS gauge
    samples, and per-request latency histograms labeled by (scenario,
    policy). When a live stream publisher is installed
    (:mod:`repro.obs.stream`, ``REPRO_OBS_STREAM``), each tick also
    emits a ``tick`` frame (provisional completed-window QoS/miss rate,
    queue depth) and the run ends with a ``horizon`` summary frame —
    same invariant: stream-on runs are byte-identical to stream-off.
    """
    with obs.span("horizon.run", scenario=config.scenario,
                  policy=config.policy, seed=config.seed):
        return _run_horizon(config)


class TickController:
    """The stateful per-tick serving control loop, driver-agnostic.

    One instance owns everything a control plane carries across ticks:
    the placer (open-loop :class:`~repro.core.dynamic.DynamicPlacer` or
    closed-loop feedback), the stateful
    :class:`~repro.serving.scheduler.ContinuousScheduler`, per-tick
    request/meta bookkeeping, and the live-stream / feedback completion
    pointers. Drivers differ only in *where each tick's instance comes
    from* and *when* :meth:`step` runs:

    * the offline horizon calls :meth:`materialize` (scenario-derived
      instance) and steps in a tight loop;
    * the live gateway (:mod:`repro.gateway`) rebuilds the instance from
      requests that arrived over its ingest socket and steps at
      clock-paced tick boundaries, passing the requests' carried arrival
      timestamps via ``times``.

    Identical call sequences produce byte-identical results — the
    gateway's virtual-clock parity guarantee rests on this class.
    """

    def __init__(self, config: HorizonConfig):
        from repro.workloads import get_scenario  # deferred: uses core

        self.config = config
        self.scenario = get_scenario(config.scenario,
                                     **dict(config.overrides))
        self.n_ticks = int(config.n_ticks or self.scenario.n_ticks)
        self.feedback = config.policy == "feedback"
        if self.feedback:
            # deferred import: repro.tuning imports serving at top level
            from repro.tuning.controller import FeedbackPlacer
            self.placer = FeedbackPlacer(
                config.switching_cost, config.stickiness,
                gain=config.feedback_gain, ewma=config.feedback_ewma,
                target_miss=config.feedback_target_miss)
        else:
            self.placer = DynamicPlacer(config.switching_cost,
                                        config.stickiness)
        # the feedback policy adapts the *placer*; queue stays QoS-aware
        self.sched = ContinuousScheduler(
            policy="edf" if self.feedback else config.policy)
        self.mobility_cache = self.scenario.mobility_trajectory(
            config.seed, self.n_ticks)

        self.tick_reqs: List[List[ArrivingRequest]] = []
        self.meta: List[Dict[str, Any]] = []
        #: (queue_depth, in_flight) at each tick boundary
        self.boundary: List[Tuple[int, int]] = []
        self.uid = 0
        self._done_ptr = 0    # completions already fed to the controller
        self._stream_ptr = 0  # completions already published to the stream

    # -- tick inputs -------------------------------------------------------
    def materialize(self, t: int) -> PIESInstance:
        """The offline path: tick ``t``'s instance from the scenario."""
        with obs.span("tick.materialize", tick=t):
            return self.scenario.instance_at(
                self.config.seed, t, mobility_cache=self.mobility_cache)

    # -- the control step --------------------------------------------------
    def step(self, t: int, inst: PIESInstance,
             times: Optional[np.ndarray] = None) -> None:
        """Place → route → execute one control tick.

        ``times`` (sorted [U] arrival timestamps) defaults to the
        scenario's arrival process — the offline path; the gateway passes
        the timestamps its admitted requests actually carried.
        """
        config, sc, placer, sched = (self.config, self.scenario,
                                     self.placer, self.sched)
        # request tracing + decision ledger: off by default, one global
        # load + None check each; observational only (byte-identity of
        # TickReports / digests is tested per policy)
        rt = _reqtrace._REQTRACER
        led = _obs_ledger._LEDGER
        if rt is not None:
            rt.set_context(config.seed)
        with obs.span("tick.place", tick=t):
            with obs.kernel_span("qos_matrix_np", U=inst.U, P=inst.P):
                Q = qos_matrix_np(inst)
            if led is not None:
                led.begin(tick=t, seed=config.seed,
                          algo="egp_feedback" if self.feedback
                          else "egp_hysteresis")
            x, value, loads = placer.step(inst, Q)
            applied_stickiness = placer.current_stickiness \
                if self.feedback else config.stickiness
            if rt is not None:
                rt.epoch(t, value=float(value), loads=int(loads),
                         n_placed=int(x.sum()),
                         stickiness=float(applied_stickiness))
            # cold starts: every implementation the placer just loaded
            # spends the first switching_cost seconds of the tick loading
            # and serves nothing until then — gated up front, so an impl
            # placed now but first routed to next tick still queues
            # through its load window
            if config.switching_cost > 0.0:
                ready_at = t * config.tick_duration + config.switching_cost
                for e, p in np.argwhere(placer.new_loads):
                    key = (int(e), int(p))
                    sched.add_executor(key, ExecutorProfile.from_comp_cost(
                        float(inst.sm_w[p]), config.max_batch))
                    sched.delay_executor(key, ready_at)
        with obs.span("tick.route", tick=t):
            # backlog queued on implementations this re-placement evicted
            # is re-routed (or dropped) before any of it can execute
            n_requeued = 0
            if placer.evicted is not None and placer.evicted.any():
                n_requeued = _requeue_evicted(sched, placer.evicted, inst,
                                              x, config, self.tick_reqs,
                                              self.meta)
            y, _ = oms_np(inst, x, Q)

            if times is None:
                times = _arrival_times(sc, config.seed, t, inst.U,
                                       config.tick_duration)
            reqs: List[ArrivingRequest] = []
            for u in range(inst.U):
                p = int(y[u])
                e = int(inst.u_edge[u])
                if rt is not None:
                    rt.admit(self.uid + u, t, edge=e,
                             service=int(inst.u_service[u]),
                             alpha=float(inst.u_alpha[u]),
                             delta=float(inst.u_delta[u]),
                             arrival=float(times[u]))
                if p < 0:
                    if rt is not None:
                        rt.drop(self.uid + u, float(times[u]),
                                reason="no_placed_impl")
                    continue
                if rt is not None:
                    # chosen vs rejected: the other *placed* impls OMS
                    # could have routed this user to (Q > 0 ⇔ eligible)
                    opts = np.nonzero(x[e] & (Q[u] > 0.0))[0]
                    rej = sorted(((int(pp), float(Q[u, pp]))
                                  for pp in opts if int(pp) != p),
                                 key=lambda z: -z[1])[:4]
                    rt.route(self.uid + u, float(times[u]), impl=p,
                             q=float(Q[u, p]), candidates=rej)
                if (e, p) not in sched.executors:
                    sched.add_executor(
                        (e, p), ExecutorProfile.from_comp_cost(
                            float(inst.sm_w[p]), config.max_batch))
                reqs.append(ArrivingRequest(
                    uid=self.uid + u, impl=p, edge=e,
                    arrival=float(times[u]),
                    prompt_tokens=config.prompt_tokens,
                    new_tokens=config.new_tokens,
                    alpha=float(inst.u_alpha[u]),
                    delta=float(inst.u_delta[u]),
                    accuracy=float(inst.sm_acc[p]),
                    service=int(inst.u_service[u])))
            self.uid += inst.U
        with obs.span("tick.execute", tick=t):
            sched.submit(reqs)
            sched.run_until((t + 1) * config.tick_duration)

        self.tick_reqs.append(reqs)
        self.boundary.append((sched.queue_depth(), sched.in_flight()))
        obs.sample("serving.queue_depth", self.boundary[-1][0])
        obs.sample("serving.in_flight", self.boundary[-1][1])
        self.meta.append({"submitted": inst.U,
                          "dropped": int((y < 0).sum()),
                          "loads": loads, "value": float(value),
                          "delta_max": float(inst.delta_max),
                          "requeued": n_requeued,
                          "stickiness": float(applied_stickiness)})

        pub = obs.get_publisher()
        if pub is not None:
            # live tick frame: provisional stats over what *completed*
            # this tick (final arrival-attributed reports only exist
            # after the drain) — a pure read of scheduler state, so the
            # stream-on run stays byte-identical to stream-off
            window = sched.completed[self._stream_ptr:]
            self._stream_ptr = len(sched.completed)
            window_qos = window_miss = None
            if window:
                w_lats = np.maximum(np.array(
                    [r.finish - r.arrival for r in window]), 0.0)
                w_qos, w_miss = realized_qos_np(
                    w_lats, np.array([r.delta for r in window]),
                    np.array([r.accuracy for r in window]),
                    np.array([r.alpha for r in window]),
                    float(inst.delta_max))
                window_qos = float(w_qos.mean())
                window_miss = float(w_miss.mean())
            pub.emit("tick", {
                "scenario": config.scenario, "seed": config.seed,
                "policy": config.policy, "tick": t,
                "submitted": int(inst.U),
                "dropped": self.meta[-1]["dropped"],
                "queue_depth": self.boundary[-1][0],
                "in_flight": self.boundary[-1][1],
                "completed": len(window), "window_qos": window_qos,
                "miss_rate": window_miss, "requeued": n_requeued,
                "model_loads": loads})
            # kept request traces + the tick's decision-ledger record
            # ride the same wire (unknown types are ignored by old
            # readers, so the stream schema version stays put)
            if rt is not None:
                for rec in rt.drain_emits():
                    pub.emit("reqtrace", rec)
            if led is not None:
                for rec in led.drain_emits():
                    pub.emit("ledger", rec)

        if self.feedback:
            # close the loop on what actually *completed* this tick — the
            # only signal a real controller has mid-run
            window = sched.completed[self._done_ptr:]
            self._done_ptr = len(sched.completed)
            if window:
                w_lats = np.maximum(
                    np.array([r.finish - r.arrival for r in window]), 0.0)
                w_qos, w_miss = realized_qos_np(
                    w_lats, np.array([r.delta for r in window]),
                    np.array([r.accuracy for r in window]),
                    np.array([r.alpha for r in window]),
                    float(inst.delta_max))
                placer.observe(float(w_qos.mean()), float(w_miss.mean()),
                               len(window))

    def step_idle(self, t: int) -> None:
        """Advance one tick boundary with no admitted requests.

        Gateway-only resilience path: a wall-clock gateway can hit a
        tick boundary before any of the tick's requests physically
        arrived (a stalled load generator). The offline horizon never
        produces an empty tick (the population floor is one user), so
        the placement is simply left untouched, the scheduler still runs
        to the boundary (in-flight work completes), and the tick reports
        zero submissions.
        """
        config, sched = self.config, self.sched
        with obs.span("tick.execute", tick=t):
            sched.run_until((t + 1) * config.tick_duration)
        self.tick_reqs.append([])
        self.boundary.append((sched.queue_depth(), sched.in_flight()))
        obs.sample("serving.queue_depth", self.boundary[-1][0])
        obs.sample("serving.in_flight", self.boundary[-1][1])
        self.meta.append({"submitted": 0, "dropped": 0, "loads": 0,
                          "value": 0.0, "delta_max": 0.0, "requeued": 0,
                          "stickiness": float(config.stickiness)})
        pub = obs.get_publisher()
        if pub is not None:
            window = sched.completed[self._stream_ptr:]
            self._stream_ptr = len(sched.completed)
            pub.emit("tick", {
                "scenario": config.scenario, "seed": config.seed,
                "policy": config.policy, "tick": t, "submitted": 0,
                "dropped": 0, "queue_depth": self.boundary[-1][0],
                "in_flight": self.boundary[-1][1],
                "completed": len(window), "window_qos": None,
                "miss_rate": None, "requeued": 0, "model_loads": 0})

    # -- shutdown ----------------------------------------------------------
    def finalize(self) -> HorizonResult:
        """Drain the backlog and build the arrival-attributed result."""
        config, sched = self.config, self.sched
        tick_reqs, meta, boundary = self.tick_reqs, self.meta, self.boundary
        T = len(tick_reqs)
        # Backlog left at the horizon end drains to completion (graceful
        # shutdown); its requests stay attributed to their arrival ticks.
        with obs.span("horizon.drain"):
            sched.drain()

        tracer = obs.get_tracer()
        rt = _reqtrace._REQTRACER
        # exemplars must point at traces `obs explain` can resolve —
        # only kept (sampled-in) uids qualify
        kept_uids = set(rt.kept_uids()) if rt is not None else set()
        lat_hist = tracer.metrics.histogram(
            "serving.latency_s", scenario=config.scenario,
            policy=config.policy) if tracer is not None else None
        per_tick: List[TickReport] = []
        for t in range(T):
            reqs, m = tick_reqs[t], meta[t]
            if reqs:
                lats = np.maximum(
                    np.array([r.finish - r.arrival for r in reqs]), 0.0)
                qos, missed = realized_qos_np(
                    lats, np.array([r.delta for r in reqs]),
                    np.array([r.accuracy for r in reqs]),
                    np.array([r.alpha for r in reqs]), m["delta_max"])
            else:
                lats, qos, missed = (np.zeros(0), np.zeros(0),
                                     np.zeros(0, bool))
            if lat_hist is not None:
                if rt is not None:
                    # exemplars: each latency bucket links up to N
                    # concrete request traces (bucket counts are
                    # identical to the observe_many path)
                    for r, lat in zip(reqs, lats):
                        lat_hist.observe(
                            float(lat),
                            exemplar=rt.exemplar(r.uid, t)
                            if r.uid in kept_uids else None)
                else:
                    lat_hist.observe_many(lats)
            per_tick.append(TickReport(
                tick=t, submitted=m["submitted"], served=len(reqs),
                dropped=m["dropped"],
                # dropped requests contribute 0 — divide by ALL submitted
                mean_realized_qos=float(qos.sum() / m["submitted"])
                if m["submitted"] else 0.0,
                deadline_misses=int(missed.sum()),
                mean_latency_s=float(lats.mean()) if reqs
                else float("nan"),
                queue_depth=boundary[t][0], in_flight=boundary[t][1],
                model_loads=m["loads"], placement_value=m["value"],
                requeued=m["requeued"], stickiness=m["stickiness"],
                mean_accuracy=float(np.mean([r.accuracy for r in reqs]))
                if reqs else float("nan")))

        if tracer is not None:
            for rep in per_tick:
                obs.sample("serving.realized_qos", rep.mean_realized_qos)
            tracer.metrics.gauge(
                "serving.realized_qos", scenario=config.scenario,
                policy=config.policy).set(
                    float(sum(r.mean_realized_qos * r.submitted
                              for r in per_tick) /
                          max(sum(r.submitted for r in per_tick), 1)))
            obs.count("serving.submitted",
                      sum(r.submitted for r in per_tick))
            obs.count("serving.deadline_misses",
                      sum(r.deadline_misses for r in per_tick))
            obs.count("serving.requeued",
                      sum(r.requeued for r in per_tick))

        result = HorizonResult(
            config=config, per_tick=per_tick,
            requests=[r for reqs in tick_reqs for r in reqs])
        pub = obs.get_publisher()
        if pub is not None:
            # end-of-run summary: the *final* arrival-attributed numbers
            # the provisional tick frames converged toward
            pub.emit("horizon", {
                "scenario": config.scenario, "seed": config.seed,
                "policy": config.policy, "n_ticks": T,
                "submitted": result.submitted, "served": result.served,
                "dropped": result.dropped,
                "deadline_misses": result.deadline_misses,
                "mean_realized_qos": result.mean_realized_qos,
                "miss_rate": result.miss_rate})
            if rt is not None:
                for rec in rt.drain_emits():
                    pub.emit("reqtrace", rec)
            led = _obs_ledger._LEDGER
            if led is not None:
                for rec in led.drain_emits():
                    pub.emit("ledger", rec)
            if tracer is not None:
                pub.emit_metrics(tracer)
        return result


def _run_horizon(config: HorizonConfig) -> HorizonResult:
    """The offline driver: a tight loop over :class:`TickController`."""
    ctl = TickController(config)
    for t in range(ctl.n_ticks):
        ctl.step(t, ctl.materialize(t))
    return ctl.finalize()
