"""Edge cluster simulation: PIES placement driving a real serving data plane.

Each :class:`EdgeGroup` models one edge cloud of the paper's 3-tier
architecture (in production: one pod slice of the mesh). The cluster
(1) builds a PIES instance from the catalog + request population,
(2) runs EGP placement, (3) loads the placed implementations (reduced
configs on CPU; full configs on the production mesh), (4) routes each
request with OMS and executes it batched, (5) scores *realized* QoS from
measured wall-clock latency via Eq. (1)–(3) — the paper's real-world
experiment (§VI-C) as a reusable harness.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs import get_smoke_config
from repro.core import PIESInstance
from .catalog import Catalog
from .engine import ModelServer, Request
from .router import Router, RoutingDecision
from .scheduler import realized_qos_np

__all__ = ["EdgeCluster", "ServeReport"]


@dataclasses.dataclass
class ServeReport:
    served: int
    dropped: int
    skipped: int                # assigned but never executed (no resident
                                # server for the impl on the user's edge)
    mean_expected_qos: float    # from the QoS model (router view)
    mean_realized_qos: float    # from measured latency + catalog accuracy
    per_model_counts: Dict[str, int]
    placement: np.ndarray
    total_wall_s: float


class EdgeGroup:
    def __init__(self, gid: int, smoke: bool = True, bucket_batch: int = 4,
                 bucket_seq: int = 64):
        self.gid = gid
        self.smoke = smoke
        self.bucket_batch = bucket_batch
        self.bucket_seq = bucket_seq
        self.resident: Dict[int, ModelServer] = {}

    def load_placement(self, x_row: np.ndarray, catalog: Catalog):
        wanted = set(np.nonzero(x_row)[0].tolist())
        for p in list(self.resident):
            if p not in wanted:
                del self.resident[p]          # evict
        for p in wanted:
            if p not in self.resident:
                arch = catalog.models[p].arch
                cfg = get_smoke_config(arch)
                if cfg.encoder_only or cfg.frontend != "none":
                    # modality stubs serve via their LM/encoder backbone;
                    # the cluster demo feeds token ids either way
                    cfg = get_smoke_config("smollm_360m")
                self.resident[p] = ModelServer(
                    cfg, bucket_batch=self.bucket_batch,
                    bucket_seq=self.bucket_seq, seed=p)


class EdgeCluster:
    def __init__(self, catalog: Catalog, n_edges: int = 2,
                 placement_algo: str = "egp", bucket_batch: int = 4,
                 bucket_seq: int = 64):
        self.catalog = catalog
        self.router = Router(placement_algo)
        self.groups = [EdgeGroup(g, bucket_batch=bucket_batch,
                                 bucket_seq=bucket_seq)
                       for g in range(n_edges)]

    def serve(self, inst: PIESInstance, prompts: np.ndarray,
              max_new_tokens: int = 4) -> ServeReport:
        """inst: PIES instance whose users are the requests; prompts:
        [U, s] token prompts. Runs placement + routing + execution."""
        t0 = time.perf_counter()
        x = self.router.place(inst)
        decision = self.router.route(inst)
        for g in self.groups:
            g.load_placement(x[g.gid], self.catalog)

        realized = np.zeros(inst.U)
        executed = np.zeros(inst.U, dtype=bool)
        counts: Dict[str, int] = {}
        served = 0
        for e, group in enumerate(self.groups):
            for p in sorted(group.resident):
                uids = np.nonzero((decision.assignment == p)
                                  & (inst.u_edge == e))[0]
                if uids.size == 0:
                    continue
                server = group.resident[p]
                bb = server.bucket_batch
                for i in range(0, uids.size, bb):
                    batch_uids = uids[i:i + bb]
                    batch_prompts = prompts[batch_uids]
                    t_b = time.perf_counter()
                    _, t_pre, t_dec = server.generate(
                        batch_prompts, n_steps=max_new_tokens)
                    latency = time.perf_counter() - t_b
                    # realized QoS: Eq. (1) with measured latency
                    acc = self.catalog.models[p].accuracy
                    realized[batch_uids], _ = realized_qos_np(
                        latency, inst.u_delta[batch_uids], acc,
                        inst.u_alpha[batch_uids], inst.delta_max)
                    executed[batch_uids] = True
                    served += batch_uids.size
                name = self.catalog.models[p].arch
                counts[name] = counts.get(name, 0) + int(uids.size)
        dropped = int((decision.assignment < 0).sum())
        # a user can be assigned an implementation whose server is not
        # resident on its edge (placement row loaded elsewhere): it never
        # executed, so its zero entry must not deflate the realized mean
        skipped = int(((decision.assignment >= 0) & ~executed).sum())
        return ServeReport(
            served=served, dropped=dropped, skipped=skipped,
            mean_expected_qos=float(decision.expected_qos.mean()),
            mean_realized_qos=float(realized[executed].mean())
            if served else 0.0,
            per_model_counts=counts, placement=x,
            total_wall_s=time.perf_counter() - t0)
