"""Serving data plane: batched prefill+decode executors per implementation.

One :class:`ModelServer` wraps a loaded architecture (params + jitted
prefill/decode at fixed batch/seq buckets — shapes are bucketed so the jit
cache stays small). The engine measures wall-clock latency per batch; the
cluster layer (cluster.py) converts measured latency + catalog accuracy
into realized QoS via the paper's Eq. (1)–(3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig

__all__ = ["Request", "BatchResult", "ModelServer"]


@dataclasses.dataclass
class Request:
    uid: int
    service: str
    tokens: np.ndarray           # prompt tokens (LM) / frames (audio)
    max_new_tokens: int = 8
    alpha: float = 0.0           # accuracy threshold
    delta: float = 1.0           # delay threshold (seconds)
    submitted_at: float = 0.0


@dataclasses.dataclass
class BatchResult:
    uids: List[int]
    outputs: np.ndarray          # [b, new_tokens]
    latency_s: float             # wall time for the whole batch
    prefill_s: float
    decode_s: float


class ModelServer:
    """A resident service implementation: params + compiled step functions."""

    def __init__(self, cfg: ModelConfig, params=None, *, bucket_batch: int = 4,
                 bucket_seq: int = 64, seed: int = 0):
        self.cfg = cfg
        self.bucket_batch = bucket_batch
        self.bucket_seq = bucket_seq
        self.params = params if params is not None else T.init_params(
            cfg, jax.random.PRNGKey(seed))
        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)
        self._cache_shape: Optional[Tuple[int, int]] = None

    # --- jitted step functions -------------------------------------------
    def _prefill_impl(self, params, tokens, cache):
        return T.prefill(params, self.cfg, {"tokens": tokens}, cache,
                         self._ring)

    def _decode_impl(self, params, tok, cache):
        return T.decode_step(params, self.cfg, tok, cache, self._ring)

    # --- public API ---------------------------------------------------------
    def warmup(self):
        toks = np.zeros((self.bucket_batch, self.bucket_seq // 2), np.int32)
        self.generate(toks, n_steps=1)

    def generate(self, prompts: np.ndarray, n_steps: int = 8) -> Tuple[np.ndarray, float, float]:
        """prompts: [b, s] int32 (padded to bucket); returns
        (new_tokens [b, n_steps], prefill_seconds, decode_seconds)."""
        b, s = prompts.shape
        bb = self.bucket_batch
        assert b <= bb
        pad_b, pad_s = bb - b, 0
        toks = np.pad(prompts, ((0, pad_b), (0, pad_s))).astype(np.int32)

        cache, ring = T.init_cache(self.cfg, bb, self.bucket_seq)
        self._ring = ring
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(toks), cache)
        logits.block_until_ready()
        t1 = time.perf_counter()
        outs = []
        tok = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1)
        for _ in range(n_steps):
            outs.append(np.asarray(tok))
            logits, cache = self._decode(self.params, tok.astype(jnp.int32),
                                         cache)
            tok = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1)
        tok.block_until_ready()
        t2 = time.perf_counter()
        new_tokens = np.stack(outs, axis=1)[:b]
        return new_tokens, t1 - t0, t2 - t1
