"""Continuous batching with QoS-aware admission — the serving fast path.

The paper schedules each request once (OMS); a production engine must also
decide *when* requests run: they arrive over time, batch slots free up as
sequences finish, and delay satisfaction (Eq. 3) decays while a request
queues. This module adds an event-driven continuous-batching simulator on
top of the PIES assignment:

* requests are routed to an implementation by OMS (the paper's Alg. 1);
* each (edge, implementation) executor runs a rolling batch: finished
  sequences release their slot immediately (continuous batching, vLLM
  style) instead of waiting for the whole batch (static batching);
* the queue is ordered by an **earliest-deadline-first** key derived from
  the request's delay threshold δ_u — the QoS-aware policy — or FCFS for
  the baseline;
* per-implementation latency comes from the catalog profile
  (prefill ∝ prompt tokens, decode ∝ steps, both scaled by comp_cost).

The simulation is a **single global event heap** over all executors:
arrivals and request completions are explicit events ordered by
``(time, seq)`` where ``seq`` is a monotone submission counter, so equal
timestamps resolve deterministically and request objects are never
compared. Executors only hold state (a policy-ordered queue plus the
in-flight set); all timing flows through the scheduler's heap. This is
what makes the scheduler *incremental*: :meth:`ContinuousScheduler
.run_until` advances the clock to a tick boundary and returns with queues
and in-flight batches intact, so a multi-tick driver
(:mod:`repro.serving.horizon`) can interleave re-placement and routing
with serving without losing backlog. Everything is a deterministic
discrete-event simulation (no wall clock), so policies are comparable,
resumable sweeps get byte-identical replays, and unit tests can pin exact
finish times.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.instance import PIESInstance
from repro.core.qos import (accuracy_satisfaction_elem_np,
                            delay_satisfaction_elem_np)
# request-lifecycle tracing hook: the hot loop reads one module global
# (reqtrace._REQTRACER) per run_until/_admit call — disabled cost is a
# load + None check; enabled hooks are observational only
from repro.obs import reqtrace as _reqtrace

__all__ = ["ArrivingRequest", "ExecutorProfile", "ContinuousScheduler",
           "realized_qos_np", "simulate"]


def realized_qos_np(latency, delta, accuracy, alpha, delta_max: float):
    """Eq. (1) scored with *realized* latency, elementwise.

    The single source of the realized-QoS blend for every serving surface
    (``simulate``, the horizon driver, the edge-cluster harness): accuracy
    satisfaction (Eq. 2) against the request's α, delay satisfaction
    (Eq. 3) against measured latency, averaged. Returns ``(qos, missed)``
    where ``missed`` marks deadline overruns.
    """
    latency = np.maximum(np.asarray(latency, np.float64), 0.0)
    a_hat = accuracy_satisfaction_elem_np(accuracy, alpha)
    d_hat = delay_satisfaction_elem_np(latency, delta, delta_max)
    missed = latency > np.asarray(delta, np.float64)
    return 0.5 * (a_hat + d_hat), missed

#: Occupancy slowdown per already-running sequence in the batch.
_CONTENTION = 0.15

_ARRIVE, _FINISH, _KICK = 0, 1, 2


@dataclasses.dataclass
class ArrivingRequest:
    uid: int
    impl: int                 # service model index (from OMS routing)
    edge: int
    arrival: float            # seconds
    prompt_tokens: int
    new_tokens: int
    alpha: float
    delta: float              # delay threshold (seconds)
    accuracy: float           # A_sm of the scheduled implementation
    service: int = -1         # requested service (enables re-routing when
                              # the scheduled impl is evicted mid-horizon)

    # simulation state
    start: float = -1.0
    finish: float = -1.0


@dataclasses.dataclass(frozen=True)
class ExecutorProfile:
    """Latency model of one implementation on one edge group."""
    prefill_per_token_s: float    # seconds per prompt token (batched)
    decode_per_step_s: float      # seconds per generated token (batched)
    max_batch: int = 8

    @classmethod
    def from_comp_cost(cls, comp_cost: float, max_batch: int = 8):
        # comp_cost ≈ active GFLOPs/token; v5e-ish effective 50 GFLOP/s/req
        per_tok = comp_cost / 50.0 * 1e-3
        return cls(prefill_per_token_s=per_tok,
                   decode_per_step_s=per_tok * 3.0, max_batch=max_batch)


class _Executor:
    """Queue + in-flight set of one (edge, impl) pair.

    Pure state: admission computes start/finish times, but *when* a slot
    frees is decided by the scheduler's global event heap — the executor
    never filters or re-orders an implicit timing structure (the old
    design kept a per-executor `(finish, request)` heap and rebuilt it
    with a list comprehension, which silently broke the heap invariant
    and crashed on equal finish times).
    """

    def __init__(self, profile: ExecutorProfile, policy: str):
        self.profile = profile
        self.policy = policy
        self.queue: List[Tuple[float, int, ArrivingRequest]] = []
        self.running: Dict[int, ArrivingRequest] = {}   # uid -> in-flight
        self.available_from = 0.0   # model-load gate (see delay_executor)

    def _key(self, r: ArrivingRequest) -> float:
        if self.policy == "edf":
            return r.arrival + r.delta     # absolute deadline
        return r.arrival                   # FCFS

    def submit(self, r: ArrivingRequest) -> None:
        heapq.heappush(self.queue, (self._key(r), r.uid, r))

    def admit(self, now: float) -> List[ArrivingRequest]:
        """Start queued work in free slots; returns newly started requests."""
        if now < self.available_from:
            return []                # model still loading; work queues
        started = []
        while self.queue and len(self.running) < self.profile.max_batch:
            _, _, r = heapq.heappop(self.queue)
            dur = (r.prompt_tokens * self.profile.prefill_per_token_s
                   + r.new_tokens * self.profile.decode_per_step_s)
            # batch contention: effective slowdown grows with occupancy
            dur *= 1.0 + _CONTENTION * len(self.running)
            r.start = now
            r.finish = now + dur
            self.running[r.uid] = r
            started.append(r)
        return started

    def complete(self, r: ArrivingRequest) -> None:
        del self.running[r.uid]


class ContinuousScheduler:
    """Event-driven continuous batching over a set of executors.

    Stateful by design: ``submit`` + ``run_until(t)`` advance the event
    clock to ``t`` and leave queued/in-flight requests in place, so ticks
    of a control horizon share one scheduler. ``run`` keeps the one-shot
    batch interface (submit everything, drain, return).
    """

    def __init__(self,
                 profiles: Optional[Dict[Tuple[int, int],
                                         ExecutorProfile]] = None,
                 policy: str = "edf"):
        if policy not in ("edf", "fcfs"):
            raise ValueError(f"unknown policy {policy!r}; use 'edf'|'fcfs'")
        self.policy = policy
        self.executors: Dict[Tuple[int, int], _Executor] = {}
        #: (time, seq, kind, key, request|None) — the single global event
        #: heap; seq breaks timestamp ties so payloads are never compared
        self._events: List[Tuple[float, int, int, Tuple[int, int],
                                 Optional[ArrivingRequest]]] = []
        self._seq = 0
        self.now = 0.0
        self.n_submitted = 0
        self.completed: List[ArrivingRequest] = []
        for key, p in (profiles or {}).items():
            self.add_executor(key, p)

    # -- executor registry (placements appear mid-horizon) -----------------
    def add_executor(self, key: Tuple[int, int],
                     profile: ExecutorProfile) -> None:
        """Register (edge, impl); idempotent — live queues are kept."""
        if key not in self.executors:
            self.executors[key] = _Executor(profile, self.policy)

    def delay_executor(self, key: Tuple[int, int], until: float) -> None:
        """Gate (edge, impl) behind a model load finishing at ``until``:
        nothing is admitted before then (arrivals queue), and a kick event
        re-runs admission the moment the load completes."""
        ex = self.executors[key]
        ex.available_from = max(ex.available_from, float(until))
        self._push(ex.available_from, _KICK, key, None)

    def evict_queued(self, key: Tuple[int, int]) -> List[ArrivingRequest]:
        """Pull every *queued* (not in-flight) request off (edge, impl).

        Used when re-placement evicts a resident implementation
        mid-horizon: queued work must not execute on a model that is no
        longer placed. Requests are returned in the executor's policy
        order (deterministic); in-flight batches run to completion.
        """
        ex = self.executors.get(key)
        if ex is None:
            return []
        out = []
        while ex.queue:
            _, _, r = heapq.heappop(ex.queue)
            out.append(r)
        return out

    def unsubmit(self, r: ArrivingRequest) -> None:
        """Remove one previously submitted request from the conservation
        accounting — it will neither execute nor complete (the horizon
        drops evicted backlog OMS cannot re-route). Without this,
        ``backlog()`` would stay positive forever after a drain."""
        self.n_submitted -= 1

    def requeue(self, requests: Iterable[ArrivingRequest]) -> None:
        """Re-submit previously evicted requests to their (new) executors.

        Unlike :meth:`submit`, the arrival event fires no earlier than the
        current clock (``self.now``): the original arrival time stays on
        the request (latency is still measured from true arrival), but a
        request evicted at tick *t* cannot be admitted in the past.
        """
        for r in requests:
            key = (r.edge, r.impl)
            if key not in self.executors:
                raise KeyError(f"no executor registered for (edge, impl)="
                               f"{key}; call add_executor first")
            self._push(max(r.arrival, self.now), _ARRIVE, key, r)

    # -- observability -----------------------------------------------------
    def queue_depth(self) -> int:
        return sum(len(ex.queue) for ex in self.executors.values())

    def in_flight(self) -> int:
        return sum(len(ex.running) for ex in self.executors.values())

    def backlog(self) -> int:
        """Submitted but not yet finished (queued + in-flight)."""
        return self.n_submitted - len(self.completed)

    # -- event machinery ---------------------------------------------------
    def _push(self, time: float, kind: int, key: Tuple[int, int],
              r: Optional[ArrivingRequest]) -> None:
        self._seq += 1
        heapq.heappush(self._events, (time, self._seq, kind, key, r))

    def submit(self, requests: Iterable[ArrivingRequest]) -> None:
        for r in requests:
            key = (r.edge, r.impl)
            if key not in self.executors:
                raise KeyError(f"no executor registered for (edge, impl)="
                               f"{key}; call add_executor first")
            self.n_submitted += 1
            self._push(r.arrival, _ARRIVE, key, r)

    def _admit(self, key: Tuple[int, int], now: float) -> None:
        rt = _reqtrace._REQTRACER
        for started in self.executors[key].admit(now):
            self._push(started.finish, _FINISH, key, started)
            if rt is not None:
                rt.execute(started.uid, started.start,
                           wait_s=max(started.start - started.arrival,
                                      0.0))

    def run_until(self, t_end: float) -> None:
        """Process every event with ``time ≤ t_end``; keep the rest."""
        rt = _reqtrace._REQTRACER
        while self._events and self._events[0][0] <= t_end:
            now, _, kind, key, r = heapq.heappop(self._events)
            if kind == _ARRIVE:
                self.executors[key].submit(r)
                if rt is not None:
                    rt.event(r.uid, "queue", now, edge=key[0],
                             impl=key[1])
            elif kind == _FINISH:
                self.executors[key].complete(r)
                self.completed.append(r)
                if rt is not None:
                    lat = max(r.finish - r.arrival, 0.0)
                    rt.complete(r.uid, now, latency=lat,
                                missed=lat > r.delta)
            # _KICK carries no payload — it exists to re-run admission
            self._admit(key, now)
            self.now = max(self.now, now)
        if math.isfinite(t_end):  # drain(∞) leaves the last event time
            self.now = max(self.now, t_end)

    def drain(self) -> None:
        """Run to completion (no more events)."""
        self.run_until(float("inf"))

    def run(self, requests: List[ArrivingRequest]) -> List[ArrivingRequest]:
        """One-shot: submit everything, drain, return the requests."""
        self.submit(requests)
        self.drain()
        return requests


def simulate(inst: PIESInstance, assignment: np.ndarray, comp_cost,
             *, policy: str = "edf", arrival_rate: float = 20.0,
             prompt_tokens: int = 128, new_tokens: int = 32,
             max_batch: int = 8, seed: int = 0,
             delta_max: Optional[float] = None,
             arrivals=None, tick_duration: float = 1.0) -> Dict[str, float]:
    """Simulate serving the routed requests; return realized-QoS stats.

    assignment: [U] implementation index per user (−1 = dropped).
    comp_cost: [P] per-implementation compute cost (catalog w_sm).
    arrivals: optional :class:`repro.workloads.ArrivalProcess` — when given,
      request timestamps follow the (seed, tick)-seekable process (bursty /
      diurnal traffic) instead of the i.i.d. exponential default; the first
      ``U`` arrivals of the stream are used, one per user in order.
    """
    rng = np.random.default_rng(seed)
    delta_max = delta_max or inst.delta_max
    if arrivals is not None:
        times: List[float] = []
        tick = 0
        while len(times) < inst.U:
            times.extend(arrivals.times_in_tick(seed, tick, tick_duration))
            tick += 1
            if tick > 100_000:
                raise RuntimeError("arrival process produced no requests")
        arrival_times = np.asarray(times[:inst.U])
    else:
        arrival_times = np.cumsum(
            rng.exponential(1.0 / arrival_rate, size=inst.U))
    sched = ContinuousScheduler(policy=policy)
    reqs: List[ArrivingRequest] = []
    for u in range(inst.U):
        t = float(arrival_times[u])
        p = int(assignment[u])
        if p < 0:
            continue
        e = int(inst.u_edge[u])
        if (e, p) not in sched.executors:
            sched.add_executor(
                (e, p), ExecutorProfile.from_comp_cost(float(comp_cost[p]),
                                                       max_batch))
        reqs.append(ArrivingRequest(
            uid=u, impl=p, edge=e, arrival=t,
            prompt_tokens=prompt_tokens, new_tokens=new_tokens,
            alpha=float(inst.u_alpha[u]), delta=float(inst.u_delta[u]),
            accuracy=float(inst.sm_acc[p])))

    sched.run(reqs)

    if reqs:
        qos, missed = realized_qos_np(
            np.array([r.finish - r.arrival for r in reqs]),
            np.array([r.delta for r in reqs]),
            np.array([r.accuracy for r in reqs]),
            np.array([r.alpha for r in reqs]), delta_max)
    else:
        qos, missed = np.zeros(0), np.zeros(0, bool)
    return {
        "mean_qos": float(qos.mean()) if reqs else 0.0,
        "p10_qos": float(np.percentile(qos, 10)) if reqs else 0.0,
        "deadline_misses": int(missed.sum()),
        "served": len(reqs),
    }
