"""Continuous batching with QoS-aware admission — the serving fast path.

The paper schedules each request once (OMS); a production engine must also
decide *when* requests run: they arrive over time, batch slots free up as
sequences finish, and delay satisfaction (Eq. 3) decays while a request
queues. This module adds an event-driven continuous-batching simulator on
top of the PIES assignment:

* requests are routed to an implementation by OMS (the paper's Alg. 1);
* each (edge, implementation) executor runs a rolling batch: finished
  sequences release their slot immediately (continuous batching, vLLM
  style) instead of waiting for the whole batch (static batching);
* the queue is ordered by an **earliest-deadline-first** key derived from
  the request's delay threshold δ_u — the QoS-aware policy — or FCFS for
  the baseline;
* per-implementation latency comes from the catalog profile
  (prefill ∝ prompt tokens, decode ∝ steps, both scaled by comp_cost).

Everything is a deterministic discrete-event simulation (no wall clock),
so policies are comparable and unit-testable.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.instance import PIESInstance
from repro.core.qos import accuracy_satisfaction_np

__all__ = ["ArrivingRequest", "ExecutorProfile", "ContinuousScheduler",
           "simulate"]


@dataclasses.dataclass
class ArrivingRequest:
    uid: int
    impl: int                 # service model index (from OMS routing)
    edge: int
    arrival: float            # seconds
    prompt_tokens: int
    new_tokens: int
    alpha: float
    delta: float              # delay threshold (seconds)
    accuracy: float           # A_sm of the scheduled implementation

    # simulation state
    start: float = -1.0
    finish: float = -1.0


@dataclasses.dataclass(frozen=True)
class ExecutorProfile:
    """Latency model of one implementation on one edge group."""
    prefill_per_token_s: float    # seconds per prompt token (batched)
    decode_per_step_s: float      # seconds per generated token (batched)
    max_batch: int = 8

    @classmethod
    def from_comp_cost(cls, comp_cost: float, max_batch: int = 8):
        # comp_cost ≈ active GFLOPs/token; v5e-ish effective 50 GFLOP/s/req
        per_tok = comp_cost / 50.0 * 1e-3
        return cls(prefill_per_token_s=per_tok,
                   decode_per_step_s=per_tok * 3.0, max_batch=max_batch)


class _Executor:
    """One (edge, impl) continuous-batching executor (discrete-event)."""

    def __init__(self, profile: ExecutorProfile, policy: str):
        self.profile = profile
        self.policy = policy
        self.queue: List[Tuple[float, int, ArrivingRequest]] = []
        self.running: List[Tuple[float, ArrivingRequest]] = []  # (finish, r)

    def _key(self, r: ArrivingRequest) -> float:
        if self.policy == "edf":
            return r.arrival + r.delta     # absolute deadline
        return r.arrival                   # FCFS

    def submit(self, r: ArrivingRequest):
        heapq.heappush(self.queue, (self._key(r), r.uid, r))

    def step(self, now: float) -> Optional[float]:
        """Admit queued work into free slots; return next event time."""
        self.running = [(f, r) for f, r in self.running if f > now]
        while self.queue and len(self.running) < self.profile.max_batch:
            _, _, r = heapq.heappop(self.queue)
            r.start = now
            dur = (r.prompt_tokens * self.profile.prefill_per_token_s
                   + r.new_tokens * self.profile.decode_per_step_s)
            # batch contention: effective slowdown grows with occupancy
            dur *= 1.0 + 0.15 * len(self.running)
            r.finish = now + dur
            heapq.heappush(self.running, (r.finish, r))
        if self.running:
            return self.running[0][0]
        return None


class ContinuousScheduler:
    def __init__(self, profiles: Dict[Tuple[int, int], ExecutorProfile],
                 policy: str = "edf"):
        self.executors = {key: _Executor(p, policy)
                          for key, p in profiles.items()}

    def run(self, requests: List[ArrivingRequest]) -> List[ArrivingRequest]:
        """Event loop: arrivals + completion ticks, until drained."""
        events: List[Tuple[float, int, Tuple]] = []
        seq = 0
        for r in requests:
            seq += 1
            heapq.heappush(events, (r.arrival, seq, ("arrive", r)))
        while events:
            now, _, (kind, payload) = heapq.heappop(events)
            if kind == "arrive":
                key = (payload.edge, payload.impl)
                self.executors[key].submit(payload)
            else:
                key = payload
            nxt = self.executors[key].step(now)
            if nxt is not None and nxt > now:
                seq += 1
                heapq.heappush(events, (nxt, seq, ("tick", key)))
        return requests


def simulate(inst: PIESInstance, assignment: np.ndarray, comp_cost,
             *, policy: str = "edf", arrival_rate: float = 20.0,
             prompt_tokens: int = 128, new_tokens: int = 32,
             max_batch: int = 8, seed: int = 0,
             delta_max: Optional[float] = None,
             arrivals=None, tick_duration: float = 1.0) -> Dict[str, float]:
    """Simulate serving the routed requests; return realized-QoS stats.

    assignment: [U] implementation index per user (−1 = dropped).
    comp_cost: [P] per-implementation compute cost (catalog w_sm).
    arrivals: optional :class:`repro.workloads.ArrivalProcess` — when given,
      request timestamps follow the (seed, tick)-seekable process (bursty /
      diurnal traffic) instead of the i.i.d. exponential default; the first
      ``U`` arrivals of the stream are used, one per user in order.
    """
    rng = np.random.default_rng(seed)
    delta_max = delta_max or inst.delta_max
    if arrivals is not None:
        times: List[float] = []
        tick = 0
        while len(times) < inst.U:
            times.extend(arrivals.times_in_tick(seed, tick, tick_duration))
            tick += 1
            if tick > 100_000:
                raise RuntimeError("arrival process produced no requests")
        arrival_times = np.asarray(times[:inst.U])
    else:
        arrival_times = np.cumsum(
            rng.exponential(1.0 / arrival_rate, size=inst.U))
    profiles: Dict[Tuple[int, int], ExecutorProfile] = {}
    reqs: List[ArrivingRequest] = []
    for u in range(inst.U):
        t = float(arrival_times[u])
        p = int(assignment[u])
        if p < 0:
            continue
        e = int(inst.u_edge[u])
        profiles.setdefault(
            (e, p), ExecutorProfile.from_comp_cost(float(comp_cost[p]),
                                                   max_batch))
        reqs.append(ArrivingRequest(
            uid=u, impl=p, edge=e, arrival=t,
            prompt_tokens=prompt_tokens, new_tokens=new_tokens,
            alpha=float(inst.u_alpha[u]), delta=float(inst.u_delta[u]),
            accuracy=float(inst.sm_acc[p])))

    sched = ContinuousScheduler(profiles, policy)
    sched.run(reqs)

    qos, misses = [], 0
    for r in reqs:
        latency = max(r.finish - r.arrival, 0.0)
        a_hat = float(accuracy_satisfaction_np(
            np.array([r.accuracy]), np.array([r.alpha]))[0, 0])
        over = latency - r.delta
        d_hat = 1.0 if over <= 0 else max(0.0, 1.0 - over / delta_max)
        if over > 0:
            misses += 1
        qos.append(0.5 * (a_hat + d_hat))
    return {
        "mean_qos": float(np.mean(qos)) if qos else 0.0,
        "p10_qos": float(np.percentile(qos, 10)) if qos else 0.0,
        "deadline_misses": misses,
        "served": len(reqs),
    }
