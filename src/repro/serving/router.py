"""QoS-aware request router — OMS (Alg. 1) as the serving control plane.

The router owns the current placement ``x`` and, per control tick,
(1) refreshes the QoS matrix for the live request batch (Pallas kernel
when on TPU), (2) schedules each request onto the best placed
implementation of its service, (3) reports per-request expected QoS and
drop decisions. Placement refresh (EGP) runs on a slower timer or on
topology events (see repro.distributed.elastic).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import (PIESInstance, egp_np, oms_np, qos_matrix_np,
                        sigma_np)

__all__ = ["Router", "RoutingDecision"]


@dataclasses.dataclass
class RoutingDecision:
    assignment: np.ndarray    # [U] model index (−1 ⇒ drop to central cloud)
    expected_qos: np.ndarray  # [U]
    value: float              # Eq. (7) objective
    placement: np.ndarray     # [E, P] current placement


class Router:
    """Stateful control plane: placement (slow path) + scheduling (fast)."""

    def __init__(self, placement_algo: str = "egp", use_kernel: bool = False):
        self.placement_algo = placement_algo
        self.use_kernel = use_kernel
        self._x: Optional[np.ndarray] = None

    # --- slow path -------------------------------------------------------
    def place(self, inst: PIESInstance) -> np.ndarray:
        Q = self._qos(inst)
        if self.placement_algo == "egp":
            self._x = egp_np(inst, Q)
        elif self.placement_algo == "agp":
            from repro.core import agp_np
            self._x = agp_np(inst, Q)
        elif self.placement_algo == "opt":
            from repro.core import opt_np
            self._x = opt_np(inst, Q)
        else:
            raise ValueError(self.placement_algo)
        return self._x

    # --- fast path ---------------------------------------------------------
    def route(self, inst: PIESInstance,
              placement: Optional[np.ndarray] = None) -> RoutingDecision:
        x = placement if placement is not None else self._x
        assert x is not None, "call place() first"
        Q = self._qos(inst)
        y, value = oms_np(inst, x, Q)
        served = y >= 0
        qos = np.where(served, Q[np.arange(inst.U), np.maximum(y, 0)], 0.0)
        return RoutingDecision(assignment=y, expected_qos=qos, value=value,
                               placement=x)

    def _qos(self, inst: PIESInstance) -> np.ndarray:
        if self.use_kernel:
            from repro.kernels.qos_matrix.ops import qos_matrix_from_instance
            return np.asarray(
                qos_matrix_from_instance(inst.as_jax())).astype(np.float64)
        return qos_matrix_np(inst)

    def handle_edge_failure(self, inst: PIESInstance,
                            failed_edges) -> Tuple[PIESInstance, np.ndarray]:
        """Elastic re-placement: users covered by failed edge clouds are
        re-homed to surviving edges (round-robin by load) and placement is
        recomputed on the survivors — the paper's placement problem as the
        recovery mechanism."""
        failed = set(int(e) for e in np.atleast_1d(failed_edges))
        survivors = [e for e in range(inst.E) if e not in failed]
        assert survivors, "no surviving edge clouds"
        counts = {e: int((inst.u_edge == e).sum()) for e in survivors}
        u_edge = inst.u_edge.copy()
        for u in np.nonzero(np.isin(inst.u_edge, list(failed)))[0]:
            tgt = min(counts, key=counts.get)
            u_edge[u] = tgt
            counts[tgt] += 1
        R = inst.R.copy()
        R[list(failed)] = 0.0  # nothing can be placed on a dead edge
        new = dataclasses.replace(inst, u_edge=u_edge, R=R)
        new.validate()
        x = self.place(new)
        assert not x[list(failed)].any()
        return new, x
