"""repro.sweeps — device-sharded, resumable Monte-Carlo experiment engine.

The production evaluation plane on top of :mod:`repro.workloads`:
a :class:`SweepSpec` declares a (scenario × overrides × algorithm × seed ×
tick) grid; :func:`run_sweep` expands it to a deterministic work list,
skips items already in the append-only :class:`SweepStore`, chunks the
rest to a memory budget, and evaluates accelerator chunks with
``shard_map(vmap(...))`` across the mesh batch axis (plain jitted ``vmap``
on one device — the two are bit-identical per item); :mod:`aggregate`
reduces stored values to mean/std/95%-CI approximation-ratio tables.

    python -m repro.sweeps --scenario flash_crowd --seeds 0:32
"""
from .aggregate import (fig3_table, fig4_table, frontier_table, ratio_frame,
                        summarize, table)
from .shard import (HOST_PARITY_ATOL, SERVING_METRIC_NAMES, SweepResult,
                    auto_chunk_size, bytes_per_item, run_sweep)
from .spec import (ACCEL_ALGOS, HOST_ALGOS, KINDS, SERVING_POLICIES,
                   SYNTHETIC, SweepSpec, WorkItem, envelope_for, materialize,
                   variant_key)
from .store import SweepStore

__all__ = [
    "SweepSpec", "WorkItem", "variant_key", "envelope_for", "materialize",
    "ACCEL_ALGOS", "HOST_ALGOS", "KINDS", "SERVING_POLICIES", "SYNTHETIC",
    "SweepStore",
    "SweepResult", "run_sweep", "auto_chunk_size", "bytes_per_item",
    "HOST_PARITY_ATOL", "SERVING_METRIC_NAMES",
    "summarize", "table", "ratio_frame", "fig3_table", "fig4_table",
    "frontier_table",
]
