"""Host-side reduction of sweep results.

Turns a :class:`~repro.sweeps.shard.SweepResult` into per-(scenario,
algorithm) statistics — mean/std/95%-CI of the raw σ objective and of the
*approximation ratio* against a reference:

* ``ref="auto"`` — the exact optimum (``opt``) when it was swept,
  otherwise the per-instance max across the swept algorithms (so the best
  algorithm's ratio is exactly 1.0 and the others are relative, which is
  the Fig-3 presentation without a 20-hour solver run);
* ``ref="<algo>"`` — a fixed reference algorithm (e.g. ``sck`` to get the
  paper's Fig-4 "EGP ≈ 1.5× SCK" framing).

``fig3_table``/``fig4_table`` render the classic figure-shaped text tables.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np

from .shard import SweepResult

__all__ = ["basic_stats", "summarize", "ratio_frame", "table",
           "fig3_table", "fig4_table", "frontier_table"]

#: normal-approximation 95% confidence half-width multiplier
_Z95 = 1.959963984540054


def _nan_quiet(fn, *args, **kwargs):
    """nan-reductions over partial results (all-NaN / empty cells are a
    legitimate state after --max-chunks or a killed run) without numpy's
    RuntimeWarning noise; NaN propagates and _stats handles it."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return fn(*args, **kwargs)


def basic_stats(a: np.ndarray) -> Dict[str, float]:
    """NaN-dropping mean/std/95%-CI of a value set — the single source of
    the confidence arithmetic for every consumer (these tables, the
    :mod:`repro.tuning` fit)."""
    a = np.asarray(a, np.float64).ravel()
    a = a[~np.isnan(a)]
    n = a.size
    mean = float(a.mean()) if n else float("nan")
    std = float(a.std(ddof=1)) if n > 1 else 0.0
    ci = _Z95 * std / np.sqrt(n) if n > 1 else 0.0
    return {"n": int(n), "mean": mean, "std": std, "ci95": float(ci)}


_stats = basic_stats


def ratio_frame(result: SweepResult, ref: str = "auto"
                ) -> Dict[Tuple[str, str], np.ndarray]:
    """Per-item approximation ratios, same shapes as ``result.values``."""
    variants = sorted({v for v, _ in result.values})
    out: Dict[Tuple[str, str], np.ndarray] = {}
    for variant in variants:
        algos = [a for v, a in result.values if v == variant]
        stack = np.stack([result.values[(variant, a)] for a in algos])
        if ref == "auto":
            denom = (result.values[(variant, "opt")]
                     if "opt" in algos
                     else _nan_quiet(np.nanmax, stack, axis=0))
        else:
            if ref not in algos:
                raise ValueError(f"ratio reference {ref!r} was not swept "
                                 f"for {variant!r} (have {algos})")
            denom = result.values[(variant, ref)]
        denom = np.maximum(denom, 1e-9)
        for a in algos:
            out[(variant, a)] = result.values[(variant, a)] / denom
    return out


def summarize(result: SweepResult, ref: str = "auto") -> Dict:
    """Per-(scenario, algorithm) mean/std/95%-CI of σ and of the ratio."""
    ratios = ratio_frame(result, ref=ref)
    cells = {}
    for (variant, algo), vals in result.values.items():
        cells[(variant, algo)] = {
            "sigma": _stats(vals),
            "ratio": _stats(ratios[(variant, algo)]),
            "mean_time_s": float(_nan_quiet(
                np.nanmean, result.times[(variant, algo)])),
        }
    return {
        "ref": ref,
        "cells": {f"{v}/{a}": c for (v, a), c in cells.items()},
        "execution": result.execution,
        "spec": result.spec.to_json(),
    }


def table(result: SweepResult, ref: str = "auto") -> str:
    """The default CLI table: one row per (scenario, algorithm)."""
    ratios = ratio_frame(result, ref=ref)
    lines = [f"{'scenario':<28} {'algo':<12} {'n':>5} "
             f"{'mean σ':>10} {'±95%':>8} {'ratio':>7} {'±95%':>7}"]
    for (variant, algo), vals in result.values.items():
        s, r = _stats(vals), _stats(ratios[(variant, algo)])
        lines.append(f"{variant:<28} {algo:<12} {s['n']:>5d} "
                     f"{s['mean']:>10.3f} {s['ci95']:>8.3f} "
                     f"{r['mean']:>7.4f} {r['ci95']:>7.4f}")
    return "\n".join(lines)


def fig3_table(result: SweepResult, ref: str = "auto") -> str:
    """Fig-3a-shaped: algorithms × mean approximation ratio per scenario."""
    ratios = ratio_frame(result, ref=ref)
    variants = sorted({v for v, _ in result.values})
    algos = list(dict.fromkeys(a for _, a in result.values))
    head = f"{'scenario':<28}" + "".join(f"{a:>12}" for a in algos)
    lines = [head]
    for v in variants:
        row = f"{v:<28}"
        for a in algos:
            if (v, a) in ratios:
                row += f"{_stats(ratios[(v, a)])['mean']:>12.4f}"
            else:
                row += f"{'—':>12}"
        lines.append(row)
    return "\n".join(lines)


def frontier_table(rows: "Dict[str, List[Dict]]") -> str:
    """Fig-style Pareto-frontier table (arXiv:2011.08381's accuracy/time
    view) from :func:`repro.tuning.pareto.frontier_rows` output.

    One row per stored (switching_cost × stickiness × policy) operating
    point, grouped by scenario and sorted by realized latency;
    ``QF``/``AF`` mark membership of the (QoS ↑, miss ↓) and
    (accuracy ↑, latency ↓) frontiers with a ``*``.
    """
    lines = [f"{'scenario':<22} {'sw_cost':>7} {'stick':>6} {'policy':<9} "
             f"{'qos':>7} {'miss':>6} {'acc':>6} {'lat_s':>8} "
             f"{'QF':>3} {'AF':>3}"]
    for scenario in sorted(rows):
        # NaN latency (a point that served nothing) sorts last, stably
        pts = sorted(rows[scenario],
                     key=lambda p: (np.isnan(p["mean_latency_s"]),
                                    p["mean_latency_s"], -p["mean_qos"]))
        for p in pts:
            lines.append(
                f"{scenario:<22} {p['switching_cost']:>7.2f} "
                f"{p['stickiness']:>6.2f} {p['policy']:<9} "
                f"{p['mean_qos']:>7.4f} {p['miss_rate']:>6.3f} "
                f"{p['mean_accuracy']:>6.3f} {p['mean_latency_s']:>8.4f} "
                f"{'*' if p['qos_frontier'] else '':>3} "
                f"{'*' if p['acc_lat_frontier'] else '':>3}")
    return "\n".join(lines)


def fig4_table(results: "List[Tuple[str, SweepResult]]",
               algo: str = "egp", ref: str = "sck") -> str:
    """Fig-4-shaped scaling table: one labelled sweep per row (e.g. one per
    instance size), reporting mean σ and the ``algo``/``ref`` ratio."""
    lines = [f"{'label':<16} {'mean ' + algo:>12} {'mean ' + ref:>12} "
             f"{algo + '/' + ref:>10}"]
    for label, result in results:
        va = np.concatenate([v.ravel() for (vr, a), v in
                             result.values.items() if a == algo])
        vr_ = np.concatenate([v.ravel() for (vr, a), v in
                              result.values.items() if a == ref])
        r = float(np.nanmean(va) / max(np.nanmean(vr_), 1e-9))
        lines.append(f"{label:<16} {np.nanmean(va):>12.2f} "
                     f"{np.nanmean(vr_):>12.2f} {r:>10.3f}")
    return "\n".join(lines)
