"""Append-only on-disk result store for resumable (and fleet) sweeps.

Layout under the store root::

    spec.json             # the spec that owns this store (informational)
    manifest.jsonl        # one line per completed chunk (append-only)
    manifest.lock         # advisory flock serializing manifest appends
    shards/NNNNNN_<h>.npz # values/times/keys (+ per-item metric_*) arrays

Each manifest line records the work-item keys a shard covers, so resume is
*item*-granular: chunk boundaries may change between runs (different device
count, different ``--chunk-size``) and previously computed items are still
skipped. A shard's ``.npz`` is written to a tempfile and atomically renamed
into place **before** its manifest line lands; a crash between the two
leaves an orphan shard file that the next run simply ignores and recomputes
— the manifest is always the source of truth, and no line in it ever
dangles for longer than one ``load`` (lines whose shard file is missing are
dropped defensively).

Concurrent writers (``repro.fleet`` workers on one host, or any two
processes pointed at the same store) are safe: every append takes the
advisory ``manifest.lock`` (``flock`` — released by the kernel if the
holder dies), re-reads the manifest to pick up lines other writers landed
meanwhile, and publishes the new manifest via fsync'd
tempfile-``os.replace`` — so a writer killed at *any* instruction can never
leave a torn line that poisons resume, and no writer ever clobbers
another's lines.

Store schema v3 adds optional **per-item metric arrays**: ``add_chunk``
accepts a ``metrics`` mapping of named per-row arrays (the serving path
persists ``submitted``/``served``/``misses``/``latency``/``accuracy`` per
tick), saved as ``metric_<name>`` inside the shard npz and read back via
:meth:`SweepStore.metrics` — which is what lets ``repro.tuning.pareto``
extract frontiers as a pure store read instead of replaying horizons.
"""
from __future__ import annotations

import contextlib
import io
import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, Iterable, Iterator, List, Mapping, Optional, \
    Sequence

import numpy as np

from repro import obs

try:                      # POSIX advisory locks; auto-released on death
    import fcntl
except ImportError:       # pragma: no cover - non-POSIX fallback (no lock)
    fcntl = None

__all__ = ["SweepStore", "atomic_write"]

_METRIC_PREFIX = "metric_"


def _fsync_dir(path: Path) -> None:
    """Flush a directory entry (rename durability); best-effort."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:       # pragma: no cover - exotic filesystems
        return
    try:
        os.fsync(fd)
    except OSError:       # pragma: no cover
        pass
    finally:
        os.close(fd)


def atomic_write(path: "os.PathLike | str", payload: bytes) -> None:
    """Publish ``payload`` at ``path`` via fsync'd tempfile + rename.

    The one crash-publication primitive the store *and* the fleet queue
    share: a writer killed at any instruction leaves either the old file
    or the new one, never a torn hybrid (the stray ``.tmp`` is ignored by
    every reader).
    """
    path = Path(path)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise
    _fsync_dir(path.parent)


class SweepStore:
    """Item-keyed, append-only npz/jsonl result store."""

    def __init__(self, root: os.PathLike | str):
        self.root = Path(root)
        self.shard_dir = self.root / "shards"
        self.manifest_path = self.root / "manifest.jsonl"
        self.lock_path = self.root / "manifest.lock"
        self.shard_dir.mkdir(parents=True, exist_ok=True)
        #: item key -> (shard file name, row index)
        self._index: Dict[str, tuple] = {}
        #: item key -> manifest meta of its chunk
        self._meta: Dict[str, Dict[str, Any]] = {}
        #: parsed manifest records (shard file present), in append order
        self._records: List[Dict[str, Any]] = []
        self._n_lines = 0
        self._npz_cache: Dict[str, Dict[str, np.ndarray]] = {}
        #: (size, mtime_ns) of the manifest as this handle last wrote it —
        #: lets the single-writer fast path skip the under-lock reparse
        self._publish_stat: Optional[tuple] = None
        self._load()

    # ------------------------------------------------------------------
    def _ingest_line(self, line: str) -> None:
        line = line.strip()
        if not line:
            return
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            return  # torn final line from a pre-v3 killed writer
        self._n_lines += 1
        shard = rec.get("shard", "")
        if not (self.shard_dir / shard).exists():
            return  # orphaned manifest entry; items will recompute
        self._records.append(rec)
        for row, key in enumerate(rec.get("keys", [])):
            self._index[key] = (shard, row)
            self._meta[key] = rec.get("meta", {})

    def _load(self) -> None:
        if not self.manifest_path.exists():
            return
        for line in self.manifest_path.read_text().splitlines():
            self._ingest_line(line)

    def _reload(self) -> None:
        """Drop state and re-read the manifest (used under the lock to pick
        up lines concurrent writers appended since our last read)."""
        self._index.clear()
        self._meta.clear()
        self._records.clear()
        self._n_lines = 0
        self._load()

    @contextlib.contextmanager
    def _locked(self) -> Iterator[None]:
        """Advisory exclusive lock over manifest mutation. ``flock`` is
        released by the kernel when the holder dies, so a killed writer can
        never wedge the store."""
        with open(self.lock_path, "a+b") as lf:
            if fcntl is not None:
                fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
            try:
                yield
            finally:
                if fcntl is not None:
                    fcntl.flock(lf.fileno(), fcntl.LOCK_UN)

    def __contains__(self, key: str) -> bool:
        return key in self._index

    def __len__(self) -> int:
        return len(self._index)

    def completed(self, keys: Iterable[str]) -> List[str]:
        return [k for k in keys if k in self._index]

    def keys(self) -> List[str]:
        """Every completed item key, in manifest (insertion) order —
        lets store *consumers* (e.g. :mod:`repro.tuning.fit`) walk a
        possibly-partial store without reconstructing its spec."""
        return list(self._index)

    def chunks(self) -> List[Dict[str, Any]]:
        """The parsed manifest records whose shard file exists, in append
        order — the chunk-granular walk ``repro.fleet``'s merge uses."""
        return [dict(rec) for rec in self._records]

    # ------------------------------------------------------------------
    def write_spec(self, spec_json: Mapping[str, Any]) -> None:
        path = self.root / "spec.json"
        if not path.exists():
            path.write_text(json.dumps(spec_json, indent=1))

    def _manifest_stat(self) -> Optional[tuple]:
        try:
            st = self.manifest_path.stat()
        except OSError:
            return None
        return (st.st_size, st.st_mtime_ns)

    def add_chunk(self, keys: Sequence[str], values: np.ndarray,
                  times: np.ndarray,
                  meta: Optional[Mapping[str, Any]] = None,
                  metrics: Optional[Mapping[str, Any]] = None) -> str:
        """Persist one evaluated chunk; returns the shard file name.

        ``metrics`` optionally carries named per-row float arrays (same
        length as ``keys``) stored alongside ``values`` in the shard npz —
        the schema-v3 per-item serving metrics.

        Durability over append speed: the manifest is *republished whole*
        (atomic rename — no torn line is ever possible), so each append
        writes O(chunks-so-far) bytes. Manifest lines are per-*chunk*
        (coarse — a chunk is seconds of compute), and the single-writer
        fast path below skips the under-lock reparse when nobody else
        touched the file, so the rewrite stays noise next to evaluation.
        """
        assert len(keys) == len(values) == len(times)
        with obs.span("store.add_chunk", rows=len(keys)):
            return self._add_chunk(keys, values, times, meta, metrics)

    def _add_chunk(self, keys, values, times, meta, metrics) -> str:
        arrays = {"values": np.asarray(values, np.float64),
                  "times": np.asarray(times, np.float64),
                  "keys": np.asarray(list(keys))}
        metric_names: List[str] = []
        for name, arr in sorted((metrics or {}).items()):
            arr = np.asarray(arr, np.float64)
            assert arr.shape == (len(keys),), \
                f"metric {name!r} must be one value per key"
            arrays[_METRIC_PREFIX + str(name)] = arr
            metric_names.append(str(name))

        with self._locked():
            # pick up chunks concurrent writers appended since our last
            # read — both for shard-name allocation and so the rewritten
            # manifest below keeps their lines. Fast path: if the manifest
            # is exactly as this handle last published it, our in-memory
            # state IS the file and the reparse is skipped.
            if self._manifest_stat() != self._publish_stat or \
                    self._publish_stat is None:
                self._reload()
            name = f"{self._n_lines:06d}_{keys[0][:8]}.npz"
            while (self.shard_dir / name).exists():
                self._n_lines += 1
                name = f"{self._n_lines:06d}_{keys[0][:8]}.npz"
            buf = io.BytesIO()
            np.savez(buf, **arrays)
            atomic_write(self.shard_dir / name, buf.getvalue())

            rec = {"shard": name, "keys": list(keys),
                   "meta": dict(meta or {})}
            if metric_names:
                rec["metrics"] = metric_names
            # full-content republish via tempfile + atomic rename: a killed
            # writer can never leave a torn line, and valid lines (ours and
            # other writers') survive verbatim
            lines = [json.dumps(r, separators=(",", ":"))
                     for r in self._records] + \
                    [json.dumps(rec, separators=(",", ":"))]
            atomic_write(self.manifest_path,
                         ("\n".join(lines) + "\n").encode())
            self._publish_stat = self._manifest_stat()
            self._records.append(rec)
            self._n_lines += 1
            for row, key in enumerate(keys):
                self._index[key] = (name, row)
                self._meta[key] = rec["meta"]
        return name

    # ------------------------------------------------------------------
    def _shard(self, name: str) -> Dict[str, np.ndarray]:
        if name not in self._npz_cache:
            with np.load(self.shard_dir / name) as z:
                self._npz_cache[name] = {k: z[k] for k in z.files
                                         if k != "keys"}
        return self._npz_cache[name]

    def value(self, key: str) -> float:
        shard, row = self._index[key]
        return float(self._shard(shard)["values"][row])

    def time(self, key: str) -> float:
        shard, row = self._index[key]
        return float(self._shard(shard)["times"][row])

    def meta(self, key: str) -> Dict[str, Any]:
        return dict(self._meta.get(key, {}))

    def metrics(self, key: str) -> Dict[str, float]:
        """The item's named per-row metrics (schema v3); ``{}`` when its
        chunk predates metric persistence."""
        shard, row = self._index[key]
        return {name[len(_METRIC_PREFIX):]: float(arr[row])
                for name, arr in self._shard(shard).items()
                if name.startswith(_METRIC_PREFIX)}

    def chunk_data(self, shard: str) -> Dict[str, np.ndarray]:
        """All row arrays of one shard (``values``/``times``/``metric_*``)
        — the bulk read behind ``repro.fleet``'s chunk-granular merge."""
        return {name: arr.copy() for name, arr in self._shard(shard).items()}
