"""Append-only on-disk result store for resumable sweeps.

Layout under the store root::

    spec.json             # the spec that owns this store (informational)
    manifest.jsonl        # one line per completed chunk (append-only)
    shards/NNNNNN_<h>.npz # values/times/keys arrays for that chunk

Each manifest line records the work-item keys a shard covers, so resume is
*item*-granular: chunk boundaries may change between runs (different device
count, different ``--chunk-size``) and previously computed items are still
skipped. A shard's ``.npz`` is written and flushed **before** its manifest
line is appended; a crash between the two leaves an orphan shard file that
the next run simply ignores and recomputes — the manifest is always the
source of truth, and no line in it ever dangles for longer than one
``load`` (lines whose shard file is missing are dropped defensively).
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence

import numpy as np

__all__ = ["SweepStore"]


class SweepStore:
    """Item-keyed, append-only npz/jsonl result store."""

    def __init__(self, root: os.PathLike | str):
        self.root = Path(root)
        self.shard_dir = self.root / "shards"
        self.manifest_path = self.root / "manifest.jsonl"
        self.shard_dir.mkdir(parents=True, exist_ok=True)
        #: item key -> (shard file name, row index)
        self._index: Dict[str, tuple] = {}
        #: item key -> manifest meta of its chunk
        self._meta: Dict[str, Dict[str, Any]] = {}
        self._n_lines = 0
        self._npz_cache: Dict[str, Dict[str, np.ndarray]] = {}
        self._load()

    # ------------------------------------------------------------------
    def _load(self) -> None:
        if not self.manifest_path.exists():
            return
        for line in self.manifest_path.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue  # torn final line from a killed writer
            self._n_lines += 1
            shard = rec.get("shard", "")
            if not (self.shard_dir / shard).exists():
                continue  # orphaned manifest entry; items will recompute
            for row, key in enumerate(rec.get("keys", [])):
                self._index[key] = (shard, row)
                self._meta[key] = rec.get("meta", {})

    def __contains__(self, key: str) -> bool:
        return key in self._index

    def __len__(self) -> int:
        return len(self._index)

    def completed(self, keys: Iterable[str]) -> List[str]:
        return [k for k in keys if k in self._index]

    def keys(self) -> List[str]:
        """Every completed item key, in manifest (insertion) order —
        lets store *consumers* (e.g. :mod:`repro.tuning.fit`) walk a
        possibly-partial store without reconstructing its spec."""
        return list(self._index)

    # ------------------------------------------------------------------
    def write_spec(self, spec_json: Mapping[str, Any]) -> None:
        path = self.root / "spec.json"
        if not path.exists():
            path.write_text(json.dumps(spec_json, indent=1))

    def add_chunk(self, keys: Sequence[str], values: np.ndarray,
                  times: np.ndarray,
                  meta: Optional[Mapping[str, Any]] = None) -> str:
        """Persist one evaluated chunk; returns the shard file name."""
        assert len(keys) == len(values) == len(times)
        name = f"{self._n_lines:06d}_{keys[0][:8]}.npz"
        while (self.shard_dir / name).exists():  # torn-line index reuse
            self._n_lines += 1
            name = f"{self._n_lines:06d}_{keys[0][:8]}.npz"
        path = self.shard_dir / name
        with open(path, "wb") as f:
            np.savez(f, values=np.asarray(values, np.float64),
                     times=np.asarray(times, np.float64),
                     keys=np.asarray(list(keys)))
            f.flush()
            os.fsync(f.fileno())
        rec = {"shard": name, "keys": list(keys), "meta": dict(meta or {})}
        with open(self.manifest_path, "a+b") as f:
            # a writer killed mid-append can leave a torn final line with
            # no newline; start on a fresh line so this record is not
            # glued to (and lost with) the torn one
            f.seek(0, os.SEEK_END)
            if f.tell() > 0:
                f.seek(-1, os.SEEK_END)
                if f.read(1) != b"\n":
                    f.write(b"\n")
            f.write((json.dumps(rec, separators=(",", ":")) + "\n").encode())
            f.flush()
            os.fsync(f.fileno())
        self._n_lines += 1
        for row, key in enumerate(keys):
            self._index[key] = (name, row)
            self._meta[key] = rec["meta"]
        return name

    # ------------------------------------------------------------------
    def _shard(self, name: str) -> Dict[str, np.ndarray]:
        if name not in self._npz_cache:
            with np.load(self.shard_dir / name) as z:
                self._npz_cache[name] = {k: z[k] for k in ("values", "times")}
        return self._npz_cache[name]

    def value(self, key: str) -> float:
        shard, row = self._index[key]
        return float(self._shard(shard)["values"][row])

    def time(self, key: str) -> float:
        shard, row = self._index[key]
        return float(self._shard(shard)["times"][row])

    def meta(self, key: str) -> Dict[str, Any]:
        return dict(self._meta.get(key, {}))
