"""Declarative sweep specifications.

A :class:`SweepSpec` describes a Monte-Carlo experiment grid — scenarios ×
instance-size overrides × algorithms × seeds × ticks — and expands it into a
deterministic, stably-ordered list of :class:`WorkItem`\\ s. Every item hashes
to a stable key (:meth:`WorkItem.key`) derived from exactly the inputs that
determine its value (scenario + overrides + seed + tick + algorithm +
executor + engine schema version), which is what makes sweeps resumable:
the on-disk store skips items whose key it has already seen, and re-running
an identical spec is a no-op.

Two instance sources are supported per grid row:

* any scenario registered in :mod:`repro.workloads.scenarios` (``steady``,
  ``flash_crowd``, …), with arbitrary field overrides
  (``n_user_slots=64``, ``mobility_p_move=0.5``, …);
* the pseudo-scenario ``"synthetic"`` — the paper's §VI-B numerical setup
  via :func:`repro.core.instance.synthetic_instance`, with overrides
  (``n_users``, ``n_edges``, ``n_services``, ``max_impls``, …). This is how
  the Fig-3/Fig-4 benchmarks route their classic instance streams through
  the engine.

The padding envelope of every grid row is *derived statically* from the
scenario configuration (:func:`envelope_for`) — not from materialized
instances — so all chunks of a row share one compiled evaluator and chunk
boundaries never affect results.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.instance import PIESInstance, synthetic_instance

__all__ = [
    "SCHEMA_VERSION",
    "ACCEL_ALGOS",
    "HOST_ALGOS",
    "SERVING_POLICIES",
    "KINDS",
    "SYNTHETIC",
    "WorkItem",
    "SweepSpec",
    "variant_key",
    "envelope_for",
    "materialize",
]

#: Bump when the evaluator semantics change — invalidates stored results.
#: v2: the serving horizon re-routes backlog off evicted implementations
#: (TickReport.requeued) and unset placer knobs resolve through the
#: fitted repro.tuning lookup table — both change realized serving
#: values. Table refreshes need no bump: the resolved knobs are baked
#: into every serving item's overrides at expansion, so its keys change
#: by themselves (see SweepSpec._resolve_serving_knobs).
#: v3: serving items persist per-item serving metrics (submitted/served/
#: misses/latency/accuracy) alongside QoS, and ``repro.tuning.pareto``
#: reads frontiers straight from the store — a store without metrics must
#: recompute rather than silently mix metric-less items into frontier
#: extraction, so the bump re-keys every serving item.
SCHEMA_VERSION = 3

#: Algorithms with a batched accelerator implementation (vmap / shard_map).
ACCEL_ALGOS = ("egp", "agp")

#: Host-only algorithms (NumPy reference implementations in repro.core).
HOST_ALGOS = ("egp", "agp", "agp_literal", "opt", "sck", "rnd")

#: The ``algos`` axis of a serving-kind sweep: the continuous-batching
#: queue policies of :mod:`repro.serving.scheduler`, plus ``"feedback"``
#: — EDF queueing under the closed-loop
#: :class:`repro.tuning.controller.FeedbackPlacer`, so open-loop vs
#: closed-loop placement sweeps ride the same resumable engine.
SERVING_POLICIES = ("edf", "fcfs", "feedback")

#: Sweep kinds: ``"sigma"`` scores placements with the analytic objective
#: σ; ``"serving"`` drives scenario traffic through the full serving
#: engine (:mod:`repro.serving.horizon`) and scores *realized* QoS.
KINDS = ("sigma", "serving")

#: The pseudo-scenario name backed by ``synthetic_instance`` (§VI-B setup).
SYNTHETIC = "synthetic"

_SYNTH_DEFAULTS: Dict[str, Any] = dict(
    n_users=100, n_edges=10, n_services=100, max_impls=10,
    delta_max=10.0, alpha_scale=0.125, delta_scale=1.5,
)
#: Tick mixing stride for synthetic instance seeds (distinct instances per
#: tick while tick 0 reproduces ``synthetic_instance(seed=seed)`` exactly).
_SYNTH_TICK_STRIDE = 1_000_003


def _canon_overrides(overrides: Mapping[str, Any] | Sequence[Tuple[str, Any]]
                     ) -> Tuple[Tuple[str, Any], ...]:
    items = overrides.items() if isinstance(overrides, Mapping) else overrides
    out = []
    for k, v in items:
        if isinstance(v, (np.integer,)):
            v = int(v)
        elif isinstance(v, (np.floating,)):
            v = float(v)
        out.append((str(k), v))
    return tuple(sorted(out))


def variant_key(scenario: str,
                overrides: Tuple[Tuple[str, Any], ...]) -> str:
    """Human-readable key for a (scenario, overrides) grid row."""
    if not overrides:
        return scenario
    return scenario + "[" + ",".join(f"{k}={v}" for k, v in overrides) + "]"


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One evaluation: σ(algo placement) on instance(scenario, seed, tick),
    or one serving-horizon tick for ``executor == "serving"``.

    ``max_iters`` is the accelerator greedy-loop cap (0 for host items,
    whose reference implementations always run to completion).
    ``horizon`` is the total tick count of a serving item's horizon run
    (0 for sigma items, whose per-tick values are horizon-independent).
    """

    scenario: str
    overrides: Tuple[Tuple[str, Any], ...]
    algo: str
    executor: str          # "accel" | "host" | "serving"
    seed: int
    tick: int
    max_iters: int = 0
    horizon: int = 0

    def key(self) -> str:
        """Stable content hash — the resume/store key.

        Depends on everything that determines the value — including the
        accelerator iteration cap — and nothing else (in particular not on
        ``n_ticks``, chunk boundaries, or the device count), so extending
        a sweep or re-sharding it reuses results, while a store written
        under a different ``max_iters`` is never silently reused.

        Exception that proves the rule: a *serving* item's tick value IS a
        function of the whole horizon length (earlier-tick backlog is
        re-ordered by later arrivals under EDF), so serving keys append
        ``horizon`` — extending ``--ticks`` recomputes rather than mixing
        values from different horizons. Sigma payloads are unchanged, so
        pre-existing sigma stores stay valid.
        """
        payload = json.dumps(
            [SCHEMA_VERSION, self.scenario, list(map(list, self.overrides)),
             self.algo, self.executor, self.seed, self.tick,
             self.max_iters]
            + ([self.horizon] if self.executor == "serving" else []),
            separators=(",", ":"))
        return hashlib.sha256(payload.encode()).hexdigest()[:24]

    @property
    def variant(self) -> str:
        return variant_key(self.scenario, self.overrides)


@dataclasses.dataclass
class SweepSpec:
    """The declarative grid: scenarios × overrides × algos × seeds × ticks.

    ``override_grid`` is an axis of override *sets* — each entry yields one
    grid row per scenario (e.g. sweeping ``n_user_slots`` over sizes).
    ``force_host`` routes accelerator-capable algorithms through the NumPy
    host path instead (float64 reference semantics).
    """

    scenarios: Tuple[str, ...] = ("steady",)
    seeds: Tuple[int, ...] = (0,)
    n_ticks: Optional[int] = None
    algos: Tuple[str, ...] = ("egp",)
    override_grid: Tuple[Tuple[Tuple[str, Any], ...], ...] = ((),)
    force_host: Tuple[str, ...] = ()
    #: accelerator greedy-loop iteration cap (part of every accel item key)
    max_iters: int = 512
    #: "sigma" (analytic σ objective) or "serving" (realized QoS through
    #: the full serving engine; ``algos`` are then queue policies and
    #: ``override_grid`` may carry serving knobs like ``switching_cost``)
    kind: str = "sigma"

    def __post_init__(self):
        # order-preserving dedup on every axis: duplicates would collapse
        # into one (scenario, overrides, algo) group and break the
        # [n_seeds, n_ticks] result shapes
        self.scenarios = tuple(dict.fromkeys(str(s) for s in self.scenarios))
        self.seeds = tuple(dict.fromkeys(int(s) for s in self.seeds))
        self.algos = tuple(dict.fromkeys(str(a) for a in self.algos))
        self.force_host = tuple(dict.fromkeys(str(a)
                                              for a in self.force_host))
        self.override_grid = tuple(dict.fromkeys(
            _canon_overrides(ov) for ov in (self.override_grid or ((),))))
        self.max_iters = int(self.max_iters)
        self.kind = str(self.kind)
        if self.kind not in KINDS:
            raise ValueError(f"unknown sweep kind {self.kind!r}; "
                             f"have {KINDS}")
        if self.kind == "serving":
            if SYNTHETIC in self.scenarios:
                raise ValueError(
                    "kind='serving' needs a registered scenario (an arrival "
                    "process drives the engine); 'synthetic' has none")
            for algo in self.algos:
                if algo not in SERVING_POLICIES:
                    raise ValueError(
                        f"kind='serving' sweeps queue policies "
                        f"{SERVING_POLICIES}, got algo {algo!r}")
        else:
            for algo in self.algos:
                if algo not in set(ACCEL_ALGOS) | set(HOST_ALGOS):
                    raise ValueError(
                        f"unknown algorithm {algo!r}; accelerator algos: "
                        f"{ACCEL_ALGOS}, host algos: {HOST_ALGOS}")

    # ------------------------------------------------------------------
    def executor_of(self, algo: str) -> str:
        if self.kind == "serving":
            return "serving"
        if algo in ACCEL_ALGOS and algo not in self.force_host:
            return "accel"
        return "host"

    def _resolve_serving_knobs(self, scenario: str,
                               overrides: Tuple[Tuple[str, Any], ...]
                               ) -> Tuple[Tuple[str, Any], ...]:
        """Bake the fitted placer knobs into a serving grid row's
        overrides at *expansion* time. A serving value genuinely depends
        on the knobs the tuning table recommends for unset keys, so they
        must be part of the item (key, stored meta): a later table
        refresh (or ``$REPRO_TUNING_TABLE`` change) then yields new keys
        — the store recomputes instead of silently mixing results from
        two operating points — and fits can read the actual knobs back
        from any store, pinned or not."""
        have = dict(overrides)
        missing = [k for k in ("switching_cost", "stickiness")
                   if k not in have]
        if not missing:
            return overrides
        from repro.tuning.fit import recommend  # deferred: no cycle
        rec = recommend(scenario)
        if not rec:
            return overrides
        for k in missing:
            have[k] = rec[k]
        return _canon_overrides(have)

    def scenario_overrides(self, overrides: Tuple[Tuple[str, Any], ...]
                           ) -> Dict[str, Any]:
        """Overrides that apply to the *scenario* (serving-kind grids may
        also carry serving-engine knobs — see repro.serving.horizon)."""
        if self.kind != "serving":
            return dict(overrides)
        from repro.serving.horizon import split_serving_overrides
        scen, _ = split_serving_overrides(overrides)
        return scen

    def ticks_for(self, scenario: str,
                  overrides: Tuple[Tuple[str, Any], ...] = ()) -> int:
        if self.n_ticks is not None:
            return int(self.n_ticks)
        if scenario == SYNTHETIC:
            return 1
        from repro.workloads import get_scenario
        return int(get_scenario(
            scenario, **self.scenario_overrides(overrides)).n_ticks)

    def expand(self) -> List[WorkItem]:
        """The full, stably-ordered work list (the resume unit is one item)."""
        items: List[WorkItem] = []
        for scenario in self.scenarios:
            for overrides in self.override_grid:
                T = self.ticks_for(scenario, overrides)
                if self.kind == "serving":
                    overrides = self._resolve_serving_knobs(scenario,
                                                            overrides)
                for algo in self.algos:
                    ex = self.executor_of(algo)
                    mi = self.max_iters if ex == "accel" else 0
                    hz = T if ex == "serving" else 0
                    for seed in self.seeds:
                        for tick in range(T):
                            items.append(WorkItem(scenario, overrides, algo,
                                                  ex, seed, tick, mi, hz))
        return items

    def groups(self) -> "List[Tuple[Tuple[str, Tuple, str], List[WorkItem]]]":
        """Work list grouped by (scenario, overrides, algo) — the unit that
        shares an envelope, an executor, and a compiled evaluator."""
        grouped: Dict[Tuple[str, Tuple, str], List[WorkItem]] = {}
        for item in self.expand():
            grouped.setdefault(
                (item.scenario, item.overrides, item.algo), []).append(item)
        return list(grouped.items())

    def fingerprint(self) -> str:
        """Hash of the whole spec (recorded in the store's spec.json)."""
        payload = json.dumps(
            [SCHEMA_VERSION, list(self.scenarios), list(self.seeds),
             self.n_ticks, list(self.algos),
             [list(map(list, ov)) for ov in self.override_grid],
             sorted(self.force_host), self.max_iters]
            # sigma payload unchanged: pre-`kind` fingerprints stay valid
            + ([self.kind] if self.kind != "sigma" else []),
            separators=(",", ":"))
        return hashlib.sha256(payload.encode()).hexdigest()[:12]

    def store_key(self) -> str:
        """Hash over the *reuse-stable* axes only (no seeds, no ticks) —
        the default store-directory name, so extending a sweep to more
        seeds or a longer horizon lands in the same store and resumes
        item-granularly instead of recomputing from scratch.

        Serving sweeps additionally pin the *resolved* horizon length per
        grid row: their per-tick values depend on it (see
        :meth:`WorkItem.key`), so a ``--ticks`` change lands in a fresh
        store and recomputes — extending ``--seeds`` still reuses, and an
        explicit ``--ticks`` equal to the scenario default keys the same
        store as the default."""
        extra = []
        if self.kind != "sigma":
            extra = [self.kind, [self.ticks_for(s, ov)
                                 for s in self.scenarios
                                 for ov in self.override_grid]]
        payload = json.dumps(
            [SCHEMA_VERSION, list(self.scenarios), list(self.algos),
             [list(map(list, ov)) for ov in self.override_grid],
             sorted(self.force_host)] + extra,
            separators=(",", ":"))
        return hashlib.sha256(payload.encode()).hexdigest()[:12]

    def to_json(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "scenarios": list(self.scenarios),
            "seeds": list(self.seeds),
            "n_ticks": self.n_ticks,
            "algos": list(self.algos),
            "override_grid": [dict(ov) for ov in self.override_grid],
            "force_host": list(self.force_host),
            "max_iters": self.max_iters,
            "schema_version": SCHEMA_VERSION,
            "fingerprint": self.fingerprint(),
        }

    @classmethod
    def from_json(cls, doc: Mapping[str, Any]) -> "SweepSpec":
        """Reconstruct a spec from :meth:`to_json` output — the queue
        export ``repro.fleet`` ships to workers. A document written under
        a different engine schema version is rejected: its item keys would
        silently never match this engine's, and a fleet must fail loudly
        on version skew rather than recompute everything into limbo."""
        have = int(doc.get("schema_version", SCHEMA_VERSION))
        if have != SCHEMA_VERSION:
            raise ValueError(
                f"spec document has sweep schema v{have}, this engine is "
                f"v{SCHEMA_VERSION} — re-plan the fleet with the current "
                f"code (item keys are schema-versioned)")
        return cls(
            scenarios=tuple(doc.get("scenarios", ("steady",))),
            seeds=tuple(doc.get("seeds", (0,))),
            n_ticks=doc.get("n_ticks"),
            algos=tuple(doc.get("algos", ("egp",))),
            override_grid=tuple(_canon_overrides(ov)
                                for ov in doc.get("override_grid", [{}])),
            force_host=tuple(doc.get("force_host", ())),
            max_iters=doc.get("max_iters", 512),
            kind=doc.get("kind", "sigma"),
        )


# ===========================================================================
# Static envelopes + instance materialization
# ===========================================================================

def _synth_params(overrides: Tuple[Tuple[str, Any], ...]) -> Dict[str, Any]:
    params = dict(_SYNTH_DEFAULTS)
    unknown = [k for k, _ in overrides if k not in params]
    if unknown:
        raise ValueError(f"unknown synthetic override(s) {unknown}; "
                         f"have {sorted(params)}")
    params.update(dict(overrides))
    return params


def envelope_for(scenario: str,
                 overrides: Tuple[Tuple[str, Any], ...] = ()
                 ) -> Tuple[int, int, int]:
    """Static padding envelope ``(U_pad, P_pad, E_pad)`` for a grid row.

    Derived from the scenario *configuration* (slot pool, catalog bounds),
    never from materialized instances, so it is identical across chunks,
    runs, and device counts. ``E_pad`` includes the +1 padded edge that
    hosts padded users (see :mod:`repro.workloads.batched`).
    """
    if scenario == SYNTHETIC:
        p = _synth_params(overrides)
        return (int(p["n_users"]), int(p["n_services"]) * int(p["max_impls"]),
                int(p["n_edges"]) + 1)
    from repro.workloads import get_scenario
    sc = get_scenario(scenario, **dict(overrides))
    return (int(sc.n_user_slots), int(sc.n_services) * int(sc.max_impls),
            int(sc.n_edges) + 1)


def materialize(scenario: str, overrides: Tuple[Tuple[str, Any], ...],
                pairs: Iterable[Tuple[int, int]]) -> List[PIESInstance]:
    """Instances for ``(seed, tick)`` pairs of one grid row, in order.

    Mobility trajectories are cached per seed so a chunk of T ticks costs
    O(T·U) rather than O(T²·U).
    """
    pairs = list(pairs)
    if scenario == SYNTHETIC:
        p = _synth_params(overrides)
        return [synthetic_instance(seed=int(s) + _SYNTH_TICK_STRIDE * int(t),
                                   **p) for s, t in pairs]

    from repro.workloads import get_scenario

    sc = get_scenario(scenario, **dict(overrides))
    caches: Dict[int, np.ndarray] = {}
    if sc.mobility_p_move > 0.0:
        max_tick: Dict[int, int] = {}
        for s, t in pairs:
            max_tick[int(s)] = max(max_tick.get(int(s), 0), int(t))
        for s, mt in max_tick.items():
            caches[s] = sc.mobility_trajectory(s, mt + 1)
    return [sc.instance_at(int(s), int(t),
                           mobility_cache=caches.get(int(s)))
            for s, t in pairs]
