"""Command-line entry point: ``python -m repro.sweeps``.

Examples::

    # 32-seed flash-crowd sweep, resumable under experiments/sweeps/
    python -m repro.sweeps --scenario flash_crowd --seeds 0:32

    # two scenarios × 8 seeds, EGP vs AGP, with host-path validation
    python -m repro.sweeps --scenario steady,flash_crowd --seeds 0:8 \\
        --algos egp,agp --validate

    # paper §VI-B synthetic instances at two sizes, ratios vs exact OPT
    python -m repro.sweeps --scenario synthetic --override n_users=50 \\
        --override n_users=100 --algos egp,agp,sck,opt --seeds 0:10

    # realized QoS through the full serving engine: EDF vs FCFS over a
    # (switching_cost × stickiness) grid of the hysteresis placer
    python -m repro.sweeps --kind serving --scenario flash_crowd \\
        --seeds 0:8 --override switching_cost=0 --override \\
        switching_cost=2 --override stickiness=3

    # same grid drained by 4 forked local workers through repro.fleet
    # (plan -> claim/execute/merge), then aggregated from the store
    python -m repro.sweeps --kind serving --scenario flash_crowd \\
        --seeds 0:8 --override switching_cost=0 --fleet 4

Interrupting a stored run and re-invoking the same command resumes it:
completed chunks are read back from the manifest, not recomputed.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .aggregate import summarize, table
from .shard import DEFAULT_MEMORY_BUDGET_MB, HOST_PARITY_ATOL, run_sweep
from .spec import KINDS, SweepSpec

__all__ = ["main", "parse_seeds", "build_spec", "add_spec_arguments"]

_DEFAULT_STORE_ROOT = Path("experiments") / "sweeps"

#: tolerance for --validate (float32 batched vs float64 host path)
VALIDATE_ATOL = HOST_PARITY_ATOL


def parse_seeds(text: str) -> Tuple[int, ...]:
    """``"0:32"`` → range(0, 32); ``"0,3,7"`` → (0, 3, 7); ``"5"`` → (5,)."""
    text = text.strip()
    if ":" in text:
        lo, hi = text.split(":", 1)
        lo, hi = int(lo or 0), int(hi)
        if hi <= lo:
            raise argparse.ArgumentTypeError(f"empty seed range {text!r}")
        return tuple(range(lo, hi))
    return tuple(int(s) for s in text.split(",") if s.strip())


def _parse_override(text: str) -> Tuple[str, Any]:
    if "=" not in text:
        raise argparse.ArgumentTypeError(
            f"--override expects key=value, got {text!r}")
    k, v = text.split("=", 1)
    for conv in (int, float):
        try:
            return k.strip(), conv(v)
        except ValueError:
            continue
    return k.strip(), v.strip()


def _split_csv(values: List[str]) -> List[str]:
    out: List[str] = []
    for v in values:
        out.extend(s.strip() for s in v.split(",") if s.strip())
    return out


def add_spec_arguments(ap: argparse.ArgumentParser) -> None:
    """The sweep-grid flags shared by ``repro.sweeps`` and the
    ``repro.fleet plan`` coordinator (one --override grammar everywhere)."""
    ap.add_argument("--scenario", action="append", required=True,
                    help="scenario name(s); repeat or comma-separate "
                         "(registered scenarios or 'synthetic')")
    ap.add_argument("--kind", choices=list(KINDS), default="sigma",
                    help="sigma: analytic objective (default); serving: "
                         "realized QoS through the full serving engine "
                         "(algos become queue policies edf/fcfs, or "
                         "'feedback' for the closed-loop repro.tuning "
                         "placer; --override also accepts switching_cost, "
                         "stickiness, max_batch, ...)")
    ap.add_argument("--seeds", type=parse_seeds, default=(0,),
                    help="'a:b' range or comma list (default: 0)")
    ap.add_argument("--ticks", type=int, default=None,
                    help="horizon length (default: scenario's n_ticks)")
    ap.add_argument("--algos", action="append", default=None,
                    help="algorithms to sweep (default: egp; serving "
                         "kind: edf,fcfs)")
    ap.add_argument("--override", action="append", metavar="K=V",
                    help="scenario/instance-size override; repeating the "
                         "same key forms a grid axis")
    ap.add_argument("--force-host", action="append", default=None,
                    help="run these accel-capable algos on the host path")
    ap.add_argument("--max-iters", type=int, default=512,
                    help="accelerator greedy-loop iteration cap (part of "
                         "every work-item hash)")


def build_spec(args: argparse.Namespace) -> SweepSpec:
    if args.algos is None:
        # serving kind sweeps queue policies, not placement algorithms
        args.algos = ["edf", "fcfs"] if args.kind == "serving" else ["egp"]
    overrides = [_parse_override(o) for o in (args.override or [])]
    # repeated overrides of the same key form a grid axis; distinct keys
    # combine into every grid point
    grid: List[Tuple[Tuple[str, Any], ...]] = [()]
    by_key: Dict[str, List[Any]] = {}
    for k, v in overrides:
        by_key.setdefault(k, []).append(v)
    for k, vals in by_key.items():
        grid = [g + ((k, v),) for v in vals for g in grid]
    return SweepSpec(
        scenarios=tuple(_split_csv(args.scenario)),
        seeds=args.seeds,
        n_ticks=args.ticks,
        algos=tuple(_split_csv(args.algos)),
        override_grid=tuple(grid),
        force_host=tuple(_split_csv(args.force_host or [])),
        max_iters=args.max_iters,
        kind=getattr(args, "kind", "sigma"),
    )


def _validate(spec: SweepSpec, result) -> float:
    """Max |batched − host| σ over every accelerator-evaluated item.

    Never-computed (NaN) cells count as infinite divergence — a partial
    run must not report a vacuous validation success.
    """
    from repro.sweeps.spec import materialize, variant_key
    from repro.workloads import evaluate_host

    worst = 0.0
    for (scenario, overrides, algo), items in spec.groups():
        if spec.executor_of(algo) != "accel":
            continue
        insts = materialize(scenario, overrides,
                            [(it.seed, it.tick) for it in items])
        host = evaluate_host(insts, algo=algo)
        got = result.values[(variant_key(scenario, overrides), algo)].ravel()
        diff = np.nan_to_num(np.abs(got - host), nan=np.inf)
        worst = max(worst, float(diff.max()) if diff.size else 0.0)
    return worst


def _run_fleet(spec: SweepSpec, store_dir: Path, n_workers: int, *,
               memory_budget_mb: float, quiet: bool) -> None:
    """The ``--fleet N`` convenience path: plan under ``<store>/fleet``,
    fork N local workers, wait, reap stragglers, merge into the store.
    The subsequent ``run_sweep`` call resumes from the merged store —
    normally a pure read, and the single-process safety net for any chunk
    a crashed worker left behind.

    The fleet root is keyed by the spec *fingerprint*: the store is
    deliberately shared across ``--seeds``/``--ticks`` extensions (that
    is what makes them resume), but one queue serves one exact spec — an
    extended grid plans a fresh queue whose already-complete seeds are
    skipped against the shared store."""
    from repro.fleet.coordinator import merge, plan, reap
    from repro.fleet.worker import spawn_local_workers

    fleet_root = store_dir / "fleet" / spec.fingerprint()
    pl = plan(spec, fleet_root, target_store=store_dir)
    if not quiet:
        print(f"[fleet] planned {pl['n_tasks']} task(s) "
              f"({pl['n_items']} item(s), {pl['skipped_items']} already "
              f"stored) under {fleet_root}")
    if pl["n_tasks"] or pl["skipped_tasks"]:
        procs = spawn_local_workers(fleet_root, n_workers, quiet=quiet,
                                    silence=quiet,
                                    memory_budget_mb=memory_budget_mb)
        rcs = [p.wait() for p in procs]
        if any(rcs) and not quiet:
            print(f"[fleet] worker exit codes {rcs} — the final "
                  f"single-process pass will cover any gap",
                  file=sys.stderr)
        reap(fleet_root)
        mg = merge(fleet_root, store_dir)
        if not quiet:
            print(f"[fleet] merged {mg['merged_items']} item(s) from "
                  f"{len(mg['workers'])} worker store(s) "
                  f"({mg['duplicate_items']} duplicate(s) verified "
                  f"bit-for-bit); store now holds "
                  f"{mg['target_items']} item(s)")
        if mg.get("missing_items") == 0:
            # everything is in the merged store: the fleet root (queue +
            # a second copy of every result shard in the worker stores)
            # is redundant — prune it so resume-with-extended-seeds runs
            # don't accumulate fingerprint-keyed roots of duplicate data.
            # A partial merge keeps the root: it IS the recovery state.
            shutil.rmtree(fleet_root, ignore_errors=True)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sweeps",
        description="Device-sharded, resumable Monte-Carlo sweeps over the "
                    "PIES scenario registry.")
    add_spec_arguments(ap)
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="drain the sweep with N forked local worker "
                         "processes through repro.fleet (plan -> workers "
                         "-> crash-safe merge) before aggregating; "
                         "requires a store")
    ap.add_argument("--out", default=None,
                    help="store directory (default: experiments/sweeps/"
                         "<store-key>, stable across --seeds/--ticks "
                         "extensions — serving-kind values depend on the "
                         "horizon, so there --ticks changes get a fresh "
                         "store); use --no-store to disable")
    ap.add_argument("--no-store", action="store_true",
                    help="run fully in memory (no resume)")
    ap.add_argument("--chunk-size", type=int, default=None)
    ap.add_argument("--memory-budget-mb", type=float,
                    default=DEFAULT_MEMORY_BUDGET_MB)
    ap.add_argument("--max-chunks", type=int, default=None,
                    help="stop after N computed chunks (smoke/testing)")
    ap.add_argument("--ref", default="auto",
                    help="ratio reference algorithm (default: auto = opt "
                         "if swept, else per-item best)")
    ap.add_argument("--validate", action="store_true",
                    help="check accelerator values against the NumPy host "
                         f"path (atol {VALIDATE_ATOL})")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the aggregate summary as JSON")
    ap.add_argument("--obs", default=None, metavar="PATH",
                    help="enable repro.obs tracing and save the raw "
                         "artifact at PATH (inspect with python -m "
                         "repro.obs report/export)")
    ap.add_argument("--stream", default=None, metavar="SPEC",
                    help="publish live telemetry frames while the sweep "
                         "runs: a JSONL file path, unix:/path, or "
                         "tcp:host:port (watch with python -m repro.obs "
                         "dash --stream SPEC); equivalent to setting "
                         "REPRO_OBS_STREAM=SPEC")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)
    if args.kind == "serving" and args.validate:
        ap.error("--validate compares the batched accelerator path against "
                 "the NumPy host path; kind='serving' has neither")

    from repro import obs
    if args.obs:
        obs.enable()
    else:
        obs.enable_from_env()  # REPRO_OBS=1 — same switch workers use
    if args.stream:
        obs.enable_stream(args.stream, source="sweeps")
    else:
        obs.enable_stream_from_env(source="sweeps")  # REPRO_OBS_STREAM

    spec = build_spec(args)
    store_dir = None
    if not args.no_store:
        # keyed on the seed/tick-independent axes: extending --seeds or
        # --ticks reuses the same store and resumes instead of recomputing
        store_dir = Path(args.out) if args.out else \
            _DEFAULT_STORE_ROOT / spec.store_key()

    if args.fleet and args.fleet > 0:
        if store_dir is None:
            ap.error("--fleet dispatches through a shared store; drop "
                     "--no-store")
        _run_fleet(spec, store_dir, args.fleet,
                   memory_budget_mb=args.memory_budget_mb,
                   quiet=args.quiet)

    result = run_sweep(spec, store_dir=store_dir,
                       chunk_size=args.chunk_size,
                       memory_budget_mb=args.memory_budget_mb,
                       max_chunks=args.max_chunks,
                       verbose=not args.quiet)

    summary = summarize(result, ref=args.ref)
    validate_failed = False
    if args.validate:
        worst = _validate(spec, result)
        summary["validate_max_abs_diff"] = worst
        validate_failed = not (worst <= VALIDATE_ATOL)  # NaN/inf fail too

    # always show the table and persist --json — a validation failure must
    # not throw away an otherwise-complete sweep's aggregate
    if not args.quiet:
        ex = result.execution
        where = f"{ex['n_devices']} device(s) via {ex['path']}" \
            if ex["path"] != "host" else "host path"
        print(f"[sweeps] {ex['chunks_computed']} chunk(s) computed, "
              f"{ex['items_skipped']} item(s) resumed from store; {where}"
              + (f"; store: {ex['store']}" if ex["store"] else ""))
    print(table(result, ref=args.ref))
    if args.validate:
        if validate_failed:
            print(f"VALIDATION FAILED: max|batched − host| = "
                  f"{summary['validate_max_abs_diff']:.2e} > "
                  f"{VALIDATE_ATOL}", file=sys.stderr)
        else:
            print(f"validated against host path: max|Δσ| = "
                  f"{summary['validate_max_abs_diff']:.2e} <= "
                  f"{VALIDATE_ATOL}")

    if args.json:
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(summary, indent=1))
    if args.obs:
        Path(args.obs).parent.mkdir(parents=True, exist_ok=True)
        obs.save(args.obs)
        if not args.quiet:
            tr = obs.get_tracer()
            print(f"[obs] saved {tr.n_spans} span(s) to {args.obs}")
    if validate_failed:
        return 1
    return 0 if result.complete or args.max_chunks is not None else 2


if __name__ == "__main__":
    sys.exit(main())
