"""Device-sharded, chunked, resumable sweep execution.

The execution core behind ``python -m repro.sweeps``. For every
(scenario, overrides, algorithm) group of a :class:`~repro.sweeps.spec
.SweepSpec`:

1. work items already present in the :class:`~repro.sweeps.store.SweepStore`
   are skipped (resume is item-granular — chunk boundaries can change
   between runs without losing work);
2. pending items are split into chunks whose size is auto-tuned to bound
   peak accelerator memory (:func:`auto_chunk_size`) and rounded to the
   mesh size;
3. each accelerator chunk is padded to the group's *static* envelope
   (derived from scenario config, so all chunks share one compiled
   evaluator), padded along the batch axis up to a multiple of the device
   count, and evaluated either by the plain jitted ``vmap`` on one device
   or by ``shard_map(vmap(...))`` over the mesh batch axis — with input
   buffers donated on accelerator backends. The per-item results are
   bit-identical between the two paths (each item's computation is
   independent; no cross-batch collectives exist to reassociate);
4. results are appended to the store (npz shard + manifest line) as soon
   as the chunk completes, so a killed sweep resumes mid-group.

Host-only algorithms (``opt``, ``sck``, ``rnd``, ``agp_literal`` — and any
algorithm listed in ``spec.force_host``) run through the NumPy reference
implementations, one instance at a time, through the *same* chunk/store
pipeline, which is how the Fig-3 benchmark keeps its exact host-path
validation while sharing the engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

from .spec import SweepSpec, WorkItem, envelope_for, materialize, variant_key
from .store import SweepStore

__all__ = [
    "SERVING_METRIC_NAMES",
    "SweepResult",
    "auto_chunk_size",
    "bytes_per_item",
    "run_sweep",
]

#: Default accelerator-memory budget per in-flight chunk.
DEFAULT_MEMORY_BUDGET_MB = 512.0

#: Acceptance tolerance between float32 batched and float64 host-path σ —
#: the single source for the CLI's --validate and the benchmark checks.
HOST_PARITY_ATOL = 1e-4

_EVALUATOR_CACHE: Dict[Tuple, Any] = {}

#: (path, algo, envelope, padded-B, n_dev, max_iters) combos already
#: compiled — lets per-item timings exclude the one-off XLA compile.
_WARMED: set = set()

#: Largest chunk worth re-running once for a compile-free timing.
_RETIME_MAX_B = 64


# ===========================================================================
# Chunk sizing
# ===========================================================================

def bytes_per_item(envelope: Tuple[int, int, int]) -> int:
    """Peak working-set estimate for one padded instance.

    Dominated by the per-edge masked QoS tensor the greedy placement
    vmaps over (``[E, U, P]`` f32), plus the QoS/eligibility matrices and
    placement state.
    """
    U, P, E = envelope
    return 4 * (U * P * (E + 4) + 4 * E * P + 8 * (U + P + E))


def auto_chunk_size(envelope: Tuple[int, int, int], n_devices: int = 1,
                    memory_budget_mb: float = DEFAULT_MEMORY_BUDGET_MB,
                    n_items: Optional[int] = None) -> int:
    """Largest chunk that fits the memory budget, rounded to the mesh.

    Chunks are rounded *down* to a multiple of ``n_devices`` (so shards are
    even and no batch-padding is wasted) except when the budget admits
    fewer items than devices, where the chunk pads up instead.
    """
    fit = max(1, int(memory_budget_mb * 2**20) // bytes_per_item(envelope))
    if n_devices > 1 and fit >= n_devices:
        fit -= fit % n_devices
    if n_items is not None:
        fit = min(fit, max(1, int(n_items)))
    return fit


# ===========================================================================
# Accelerator path
# ===========================================================================

def _mesh_n_devices(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


def _sharded_evaluator(mesh, algo: str, n_services: int, max_iters: int):
    """``jit(shard_map(vmap(one)))`` over the mesh's 1-D batch axis."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from repro.workloads.batched import single_evaluator

    key = (mesh, algo, n_services, max_iters)
    if key not in _EVALUATOR_CACHE:
        bad = [a for a in mesh.axis_names if a not in ("data", "pod")
               and mesh.shape[a] > 1]
        if bad:
            raise ValueError(
                f"sweep sharding needs a pure batch mesh; axis(es) {bad} "
                f"are not batch axes (use launch.mesh.make_sweep_mesh)")
        spec = PartitionSpec(tuple(a for a in mesh.axis_names
                                   if mesh.shape[a] > 1))
        one = single_evaluator(algo, n_services, max_iters)
        fn = shard_map(jax.vmap(one), mesh=mesh, in_specs=(spec,),
                       out_specs=(spec, spec), check_rep=False)
        donate = () if jax.default_backend() == "cpu" else (0,)
        _EVALUATOR_CACHE[key] = jax.jit(fn, donate_argnums=donate)
    return _EVALUATOR_CACHE[key]


def _eval_accel_chunk(instances: List, algo: str,
                      envelope: Tuple[int, int, int], mesh,
                      max_iters: int, bucketed: bool = True
                      ) -> Tuple[np.ndarray, str, float]:
    """Evaluate one chunk; returns (values [B], path, exec_seconds).

    With ``bucketed=True`` (the default) the chunk's instances are grouped
    into geometric size classes (:func:`repro.workloads.batched
    .bucket_envelope`, capped by the group's static ``envelope``) and each
    bucket is padded and evaluated at its own envelope — one outlier no
    longer inflates every instance's pad. Because the bucket envelope is a
    pure function of each instance's own dims, per-item results are
    independent of chunk composition, exactly as on the global-pad path —
    resume, re-chunk, and fleet-merge byte-identity are preserved.
    ``bucketed=False`` keeps the legacy single-envelope pad.

    ``exec_seconds`` is the steady-state execution wall time: the first
    call per (path, shape) triggers the XLA compile, so that chunk is
    re-padded and re-run once and the re-run is what gets timed —
    otherwise a 3-item benchmark chunk would report seconds-per-item of
    compiler, not evaluator (input donation means the first batch may be
    consumed, hence the re-pad rather than a re-call).
    """
    from repro.workloads.batched import (bucket_indices, evaluate_batch,
                                         pad_instances)

    B = len(instances)
    n_dev = 1 if mesh is None else _mesh_n_devices(mesh)
    if bucketed:
        groups = bucket_indices(instances, cap=envelope)
    else:
        groups = [(tuple(envelope), list(range(B)))]
    path = "vmap" if n_dev <= 1 else "shard_map"

    def call():
        out = np.empty(B, dtype=np.float64)
        for benv, idx in groups:
            members = [instances[i] for i in idx]
            if n_dev > 1:
                members = members + [members[0]] * ((-len(idx)) % n_dev)
            batch = pad_instances(members, *benv)
            if n_dev <= 1:
                values, _ = evaluate_batch(batch, algo=algo,
                                           max_iters=max_iters)
            else:
                fn = _sharded_evaluator(mesh, algo, batch.n_services,
                                        max_iters)
                values, _ = fn(batch.jax_instance)
            out[idx] = np.asarray(values, np.float64)[:len(idx)]
        return out

    t0 = time.perf_counter()
    values = call()
    exec_s = time.perf_counter() - t0
    # Benchmark-scale chunks get compile-free timings via one re-run; for
    # production-scale chunks (> _RETIME_MAX_B items) the 2x compute to
    # refine a timing nobody is bottlenecked on is not worth it — their
    # wall clock amortizes the one-off compile anyway.
    warm_key = (path, algo, tuple((benv, len(idx)) for benv, idx in groups),
                n_dev, max_iters)
    if B <= _RETIME_MAX_B and warm_key not in _WARMED:
        _WARMED.add(warm_key)
        t0 = time.perf_counter()
        values = call()
        exec_s = time.perf_counter() - t0
    return values, path, exec_s


# ===========================================================================
# Host path
# ===========================================================================

#: Decorrelates the RND baseline's draws from the instance-generation
#: stream (the work-item seed is also the synthetic instance's rng seed;
#: reusing it verbatim would make the "random" baseline a function of the
#: same stream that drew the instance).
_RND_SEED_SALT = 0x5EED_BA5E


def _host_value(inst, algo: str, seed: int, tick: int) -> Tuple[float, float]:
    """(value, placement-time) via the NumPy reference implementations."""
    from repro.core import (agp_literal_np, agp_np, egp_np, opt_np,
                            qos_matrix_np, rnd_np, sck_np,
                            schedule_value_np, sigma_np)

    # instances are shared across algo groups via run_sweep's inst_cache;
    # stash the QoS matrix on the instance so a 6-algorithm grid builds
    # Q once per instance, not once per (instance, algorithm)
    Q = getattr(inst, "_sweeps_qos_cache", None)
    if Q is None:
        Q = qos_matrix_np(inst)
        inst._sweeps_qos_cache = Q
    if algo == "rnd":
        t0 = time.perf_counter()
        _, y = rnd_np(inst, seed=(seed * 1_000_003 + tick) ^ _RND_SEED_SALT)
        dt = time.perf_counter() - t0
        return float(schedule_value_np(inst, y, Q)), dt
    fn = {"egp": egp_np, "agp": agp_np, "agp_literal": agp_literal_np,
          "opt": opt_np, "sck": sck_np}[algo]
    t0 = time.perf_counter()
    x = fn(inst, Q)
    dt = time.perf_counter() - t0
    return float(sigma_np(inst, x, Q)), dt


# ===========================================================================
# Serving path (kind="serving": realized QoS through the full engine)
# ===========================================================================

#: Per-item metric arrays persisted for ``kind="serving"`` chunks (store
#: schema v3): per-tick request counts plus mean latency/accuracy of the
#: tick's served requests — exactly what :mod:`repro.tuning.pareto` needs
#: to reconstruct horizon-level miss-rate / latency / accuracy frontiers
#: as a pure store read (no horizon replay).
SERVING_METRIC_NAMES = ("submitted", "served", "misses", "latency",
                        "accuracy")


def _serving_horizon(scenario: str, overrides, policy: str, seed: int,
                     n_ticks: int):
    """One seed's full :class:`~repro.serving.horizon.HorizonResult`.

    One call drives the whole placement → routing → continuous-batching
    pipeline (:func:`repro.serving.horizon.run_horizon`); the scheduler is
    stateful across ticks, so a seed's horizon is the atomic computation —
    the *store* stays item-granular per (seed, tick), and a partially
    stored seed is replayed deterministically on resume (byte-identical,
    so already-stored ticks are simply skipped, never rewritten).
    """
    from repro.serving.horizon import HorizonConfig, run_horizon

    cfg = HorizonConfig.from_overrides(scenario, dict(overrides), policy,
                                       seed, n_ticks=n_ticks)
    return run_horizon(cfg)


def _serving_metrics(per_tick, ticks: Sequence[int]
                     ) -> Dict[str, np.ndarray]:
    """The :data:`SERVING_METRIC_NAMES` rows for the given tick items."""
    by_name = {
        "submitted": [per_tick[t].submitted for t in ticks],
        "served": [per_tick[t].served for t in ticks],
        "misses": [per_tick[t].deadline_misses for t in ticks],
        "latency": [per_tick[t].mean_latency_s for t in ticks],
        "accuracy": [per_tick[t].mean_accuracy for t in ticks],
    }
    return {name: np.asarray(by_name[name], np.float64)
            for name in SERVING_METRIC_NAMES}


def _note_chunk(executor: str, n_items: int, wall_s: float) -> None:
    """Feed chunk throughput into the active tracer and the live stream
    (each a no-op when its half is off)."""
    rate = n_items / wall_s if wall_s > 0 else None
    tracer = obs.get_tracer()
    if tracer is not None:
        tracer.metrics.counter("sweep.items", executor=executor).inc(n_items)
        tracer.metrics.counter("sweep.chunks", executor=executor).inc()
        if rate is not None:
            tracer.metrics.histogram("sweep.items_per_s",
                                     executor=executor).observe(rate)
            tracer.sample("sweep.items_per_s", rate)
    obs.publish("chunk", executor=executor, items=int(n_items),
                wall_s=round(float(wall_s), 6),
                items_per_s=None if rate is None else round(rate, 6))


# ===========================================================================
# The engine
# ===========================================================================

@dataclasses.dataclass
class SweepResult:
    """Collected sweep output, shaped for aggregation.

    ``values[(variant, algo)]`` and ``times[(variant, algo)]`` are
    ``[n_seeds, n_ticks]`` float64 arrays in the spec's seed/tick order;
    incomplete cells (``max_chunks`` stopped the run early) are NaN.
    """

    spec: SweepSpec
    values: Dict[Tuple[str, str], np.ndarray]
    times: Dict[Tuple[str, str], np.ndarray]
    execution: Dict[str, Any]

    @property
    def complete(self) -> bool:
        return all(not np.isnan(v).any() for v in self.values.values())

    def rows(self) -> List[Dict[str, Any]]:
        """Flat per-item records (scenario, algo, seed, tick, value, time)."""
        out = []
        for (variant, algo), vals in self.values.items():
            ts = self.times[(variant, algo)]
            seeds = self.spec.seeds
            for i, seed in enumerate(seeds):
                for t in range(vals.shape[1]):
                    out.append({"scenario": variant, "algo": algo,
                                "seed": int(seed), "tick": t,
                                "value": float(vals[i, t]),
                                "time_s": float(ts[i, t])})
        return out


def run_sweep(spec: SweepSpec, store_dir=None, *,
              chunk_size: Optional[int] = None,
              memory_budget_mb: float = DEFAULT_MEMORY_BUDGET_MB,
              mesh=None,
              max_chunks: Optional[int] = None,
              bucketed: bool = True,
              verbose: bool = False) -> SweepResult:
    """Run (or resume) a sweep; returns the collected :class:`SweepResult`.

    ``store_dir=None`` runs fully in memory (no resume). With a store,
    completed items are skipped and newly computed chunks are persisted as
    soon as they finish. ``max_chunks`` stops after that many computed
    chunks (testing / incremental smoke runs) — the result is then partial
    (NaN cells) but everything computed is durable. ``bucketed`` pads each
    accelerator chunk per geometric size class instead of one global
    envelope (item keys, store bytes, and resume semantics are identical
    either way — see :func:`_eval_accel_chunk`).
    """
    store = SweepStore(store_dir) if store_dir is not None else None
    if store is not None:
        store.write_spec(spec.to_json())
    memory: Dict[str, Tuple[float, float]] = {}  # key -> (value, time)

    groups = spec.groups()
    needs_accel = any(spec.executor_of(a) == "accel" for _, _, a in
                      (g for g, _ in groups))
    n_devices, backend = 1, "host"
    if needs_accel:
        import jax
        backend = jax.default_backend()
        if mesh is None:
            from repro.launch.mesh import make_sweep_mesh
            if len(jax.devices()) > 1:
                mesh = make_sweep_mesh()
        n_devices = 1 if mesh is None else _mesh_n_devices(mesh)

    # several algorithms sweep the same (scenario, overrides, seed, tick)
    # items — cache materialized instances across algo groups so e.g. the
    # 6-algorithm Fig-3 grid builds each instance once, not 6 times
    inst_cache: Dict[Tuple, Any] = {}

    def get_instances(scenario, overrides, pairs):
        if len(spec.algos) == 1:
            return materialize(scenario, overrides, pairs)
        row = (scenario, overrides)
        missing = [p for p in pairs if (row, p) not in inst_cache]
        if missing:
            for p, inst in zip(missing,
                               materialize(scenario, overrides, missing)):
                inst_cache[(row, p)] = inst
        return [inst_cache[(row, p)] for p in pairs]

    computed = skipped = 0
    paths = set()
    stopped = False
    for (scenario, overrides, algo), items in groups:
        executor = spec.executor_of(algo)
        keys = [it.key() for it in items]
        pending = [(it, k) for it, k in zip(items, keys)
                   if not (store is not None and k in store) and
                   k not in memory]
        skipped += len(items) - len(pending)
        if not pending:
            continue

        if executor == "serving":
            # one seed's horizon = one chunk: ticks share scheduler state,
            # so they are computed together; pending (seed, tick) items are
            # still stored individually (resume granularity is unchanged)
            T = spec.ticks_for(scenario, overrides)
            by_seed: Dict[int, List[Tuple[WorkItem, str]]] = {}
            for it, k in pending:
                by_seed.setdefault(it.seed, []).append((it, k))
            for seed, chunk in by_seed.items():
                if max_chunks is not None and computed >= max_chunks:
                    stopped = True
                    break
                t0 = time.perf_counter()
                with obs.span("sweep.chunk", executor="serving",
                              scenario=scenario, algo=algo, seed=int(seed),
                              items=len(chunk)):
                    res = _serving_horizon(scenario, overrides, algo,
                                           seed, T)
                wall = time.perf_counter() - t0
                _note_chunk(executor, len(chunk), wall)
                chunk_keys = [k for _, k in chunk]
                chunk_ticks = [it.tick for it, _ in chunk]
                vals = res.tick_values()[chunk_ticks]
                times = np.full(len(chunk), wall / len(chunk))
                paths.add("serving")
                meta = {"scenario": scenario, "overrides": dict(overrides),
                        "algo": algo, "executor": executor,
                        "path": "serving", "seed": int(seed),
                        "horizon": int(T),   # lets repro.tuning replay runs
                        "n_devices": 1, "wall_s": round(wall, 6),
                        "B": len(chunk)}
                if store is not None:
                    store.add_chunk(chunk_keys, vals, times, meta,
                                    metrics=_serving_metrics(res.per_tick,
                                                             chunk_ticks))
                for k, v, dt in zip(chunk_keys, vals, times):
                    memory[k] = (float(v), float(dt))
                computed += 1
                if verbose:
                    print(f"[sweeps] {variant_key(scenario, overrides)}/"
                          f"{algo} seed {seed}: {len(chunk):4d} items via "
                          f"serving ({wall:.3f}s)", flush=True)
            if stopped:
                break
            continue

        envelope = envelope_for(scenario, overrides)
        group_dev = n_devices if executor == "accel" else 1
        cs = chunk_size or auto_chunk_size(envelope, group_dev,
                                           memory_budget_mb, len(pending))
        for lo in range(0, len(pending), cs):
            if max_chunks is not None and computed >= max_chunks:
                stopped = True
                break
            chunk = pending[lo:lo + cs]
            chunk_items = [it for it, _ in chunk]
            chunk_keys = [k for _, k in chunk]
            with obs.span("sweep.materialize", items=len(chunk)):
                insts = get_instances(
                    scenario, overrides,
                    [(it.seed, it.tick) for it in chunk_items])
            t0 = time.perf_counter()
            with obs.span("sweep.chunk", executor=executor,
                          scenario=scenario, algo=algo, items=len(chunk)):
                if executor == "accel":
                    vals, path, exec_s = _eval_accel_chunk(
                        insts, algo, envelope, mesh, spec.max_iters,
                        bucketed=bucketed)
                    wall = time.perf_counter() - t0
                    # per-item time is steady-state execution, not compile
                    times = np.full(len(chunk), exec_s / len(chunk))
                else:
                    path = "host"
                    vt = [_host_value(inst, algo, it.seed, it.tick)
                          for inst, it in zip(insts, chunk_items)]
                    wall = time.perf_counter() - t0
                    vals = np.array([v for v, _ in vt])
                    times = np.array([t for _, t in vt])
            _note_chunk(executor, len(chunk), wall)
            paths.add(path)
            meta = {"scenario": scenario, "overrides": dict(overrides),
                    "algo": algo, "executor": executor, "path": path,
                    "envelope": list(envelope), "n_devices": group_dev,
                    "bucketed": bool(bucketed and executor == "accel"),
                    "wall_s": round(wall, 6), "B": len(chunk)}
            if store is not None:
                store.add_chunk(chunk_keys, vals, times, meta)
            for k, v, dt in zip(chunk_keys, vals, times):
                memory[k] = (float(v), float(dt))
            computed += 1
            if verbose:
                print(f"[sweeps] {variant_key(scenario, overrides)}/{algo} "
                      f"chunk {len(chunk):4d} items via {path} "
                      f"({wall:.3f}s)", flush=True)
        if stopped:
            break

    # ---- collect --------------------------------------------------------
    def lookup(key: str) -> Tuple[float, float]:
        if key in memory:
            return memory[key]
        if store is not None and key in store:
            return store.value(key), store.time(key)
        return float("nan"), float("nan")

    values: Dict[Tuple[str, str], np.ndarray] = {}
    times_out: Dict[Tuple[str, str], np.ndarray] = {}
    for (scenario, overrides, algo), items in groups:
        T = spec.ticks_for(scenario, overrides)
        vk = variant_key(scenario, overrides)
        pairs = [lookup(it.key()) for it in items]
        arr = np.array([v for v, _ in pairs], np.float64)
        ts = np.array([t for _, t in pairs], np.float64)
        values[(vk, algo)] = arr.reshape(len(spec.seeds), T)
        times_out[(vk, algo)] = ts.reshape(len(spec.seeds), T)

    execution = {
        "backend": backend,
        "n_devices": n_devices,
        "path": ("shard_map" if "shard_map" in paths else
                 "vmap" if "vmap" in paths else
                 "serving" if "serving" in paths else
                 "host" if "host" in paths else "cached"),
        "paths": sorted(paths),
        "chunks_computed": computed,
        "items_skipped": skipped,
        "store": None if store is None else str(store.root),
    }
    return SweepResult(spec=spec, values=values, times=times_out,
                       execution=execution)
