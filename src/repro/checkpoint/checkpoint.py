"""Fault-tolerant checkpointing: atomic, sharded, resharding-on-restore.

Layout (one step):

    <dir>/step_000123.tmp-<nonce>/     — written here first
        manifest.json                  — tree structure, shapes, dtypes,
                                         sha256 per leaf, mesh/pspec note
        leaf_00000.npy …               — one .npy per pytree leaf
    <dir>/step_000123/                 — atomic rename on completion

Properties required at 1000+ nodes, all implemented here single-process
(the multi-host variant shards leaves by process index — the manifest
format already records per-leaf paths so that is a writer-policy change):

* **atomicity** — a crash mid-write never corrupts the latest checkpoint
  (tmp dir + rename; restore only considers dirs with a manifest).
* **integrity** — sha256 per leaf, verified on restore.
* **keep-last-k GC** + auto-resume from the newest valid step.
* **async save** — a background thread serializes device arrays after
  they are fetched, so the train loop blocks only for the device→host copy.
* **resharding restore** — restore takes a target mesh + pspec tree and
  ``jax.device_put``s each leaf to its new sharding: a checkpoint written
  on 512 chips restores onto a 256-chip survivor mesh (elastic scaling).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]


def _tree_leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
           "int8", "uint64", "uint32", "uint16", "uint8", "bool",
           "complex64", "complex128"}


def _encode_leaf(arr: np.ndarray):
    """np.save silently voids ml_dtypes (bfloat16, fp8): store those as raw
    uint8 bytes and record the logical dtype in the manifest."""
    if arr.dtype.name in _NATIVE:
        return arr, arr.dtype.name, False
    raw = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
    return raw, arr.dtype.name, True


def _decode_leaf(raw: np.ndarray, dtype_name: str, shape, encoded: bool):
    if not encoded:
        return raw
    import ml_dtypes  # jax dependency, always present
    dt = np.dtype(getattr(ml_dtypes, dtype_name))
    return raw.view(dt).reshape(shape)


def save_checkpoint(directory, step: int, tree, *, keep: int = 3) -> Path:
    """Blocking save. Returns the final checkpoint path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _tree_leaves_with_paths(tree)
    host_leaves = [np.asarray(l) for l in leaves]

    nonce = os.urandom(4).hex()
    tmp = directory / f"step_{step:09d}.tmp-{nonce}"
    tmp.mkdir()
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "time": time.time(),
        "leaves": [],
    }
    for i, arr in enumerate(host_leaves):
        name = f"leaf_{i:05d}.npy"
        stored, dtype_name, encoded = _encode_leaf(arr)
        with open(tmp / name, "wb") as f:
            np.save(f, stored)
        manifest["leaves"].append({
            "name": name,
            "shape": list(arr.shape),
            "dtype": dtype_name,
            "raw_encoded": encoded,
            "sha256": hashlib.sha256(stored.tobytes()).hexdigest(),
        })
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    final = directory / f"step_{step:09d}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _gc(directory, keep)
    return final


def _gc(directory: Path, keep: int):
    steps = sorted(p for p in directory.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and ".tmp-" not in p.name)
    for p in steps[:-keep] if keep else []:
        shutil.rmtree(p, ignore_errors=True)
    # orphaned tmp dirs from crashes
    for p in directory.iterdir():
        if ".tmp-" in p.name and time.time() - p.stat().st_mtime > 3600:
            shutil.rmtree(p, ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    best = None
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and ".tmp-" not in p.name \
                and (p / "manifest.json").exists():
            best = max(best or -1, int(p.name.split("_")[1]))
    return best


def restore_checkpoint(directory, step: int, tree_like, *, mesh=None,
                       pspecs=None, verify: bool = True):
    """Restore into the structure of ``tree_like``. With ``mesh``+``pspecs``
    each leaf is device_put with its target NamedSharding (resharding)."""
    path = Path(directory) / f"step_{step:09d}"
    manifest = json.loads((path / "manifest.json").read_text())
    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(manifest["leaves"]) == len(leaves_like), \
        f"leaf count mismatch: {len(manifest['leaves'])} vs {len(leaves_like)}"
    spec_leaves = None
    if pspecs is not None:
        spec_leaves = jax.tree_util.tree_leaves(
            pspecs, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec))
        assert len(spec_leaves) == len(leaves_like)

    out = []
    for i, (meta, like) in enumerate(zip(manifest["leaves"], leaves_like)):
        arr = np.load(path / meta["name"])
        if verify:
            digest = hashlib.sha256(arr.tobytes()).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(f"checksum mismatch in {meta['name']}")
        arr = _decode_leaf(arr, meta["dtype"], meta["shape"],
                           meta.get("raw_encoded", False))
        if mesh is not None and spec_leaves is not None:
            sharding = jax.sharding.NamedSharding(mesh, spec_leaves[i])
            arr = jax.device_put(arr, sharding)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async keep-k manager with auto-resume."""

    def __init__(self, directory, *, keep: int = 3, every: int = 100):
        self.directory = Path(directory)
        self.keep = keep
        self.every = every
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def maybe_save(self, step: int, tree) -> bool:
        if step % self.every:
            return False
        self.wait()
        # fetch to host synchronously (cheap vs serialization), write async
        host = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host, keep=self.keep)
            except BaseException as e:  # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error

    def restore_latest(self, tree_like, *, mesh=None, pspecs=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.directory, step, tree_like,
                                        mesh=mesh, pspecs=pspecs)
