"""``python -m repro.obs dash`` — ANSI terminal dashboard over the live
telemetry stream.

Consumes the :mod:`repro.obs.stream` wire protocol (file tails and/or
sockets, any number of streams — e.g. one per fleet worker) and renders
a refreshing text dashboard: per-scenario tick rate, realized QoS,
deadline-miss rate, queue depth and in-flight count from ``tick``
frames; per-worker items/s and pending-task ETA from ``worker`` frames;
sweep chunk throughput from ``chunk`` frames; the most recent sampled
request traces from ``reqtrace`` frames (uid/edge/impl/latency/flags —
feed a uid to ``python -m repro.obs explain``); and the live SLO pane
(:mod:`repro.obs.slo` burn rates) evaluated over the same frames.

Everything is pure functions over accumulated frames
(:class:`DashState` → :func:`render`), so the dashboard is testable
without a terminal and the CI smoke can assert a frame rendered.
"""
from __future__ import annotations

import math
import queue
import sys
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Mapping, Optional

from .slo import DEFAULT_SLOS, SLO, evaluate_slos
from .stream import read_stream

__all__ = ["DashState", "render", "run_dash"]

_CLEAR = "\x1b[H\x1b[2J"


class DashState:
    """Accumulated view of one or more telemetry streams."""

    def __init__(self):
        self.n_frames = 0
        self.sources: Dict[str, Mapping[str, Any]] = {}   # hello payloads
        self.frames: List[Mapping[str, Any]] = []          # for SLO window
        #: (scenario, seed, policy) -> latest tick payload + timing
        self.ticks: Dict[tuple, Dict[str, Any]] = {}
        self.workers: Dict[str, Mapping[str, Any]] = {}
        #: (scenario, seed, policy) -> latest gateway payload (live
        #: control plane: repro.gateway per-tick operational frames)
        self.gateways: Dict[tuple, Dict[str, Any]] = {}
        self.chunks = {"n": 0, "items": 0}
        self.counters: Dict[str, float] = {}
        #: most recent sampled request traces (reqtrace frames)
        self.requests: "deque" = deque(maxlen=8)
        self.n_requests = 0
        self.last_t: Optional[float] = None

    def update(self, frame: Mapping[str, Any]) -> None:
        self.n_frames += 1
        self.frames.append(frame)
        if len(self.frames) > 4096:         # bound memory on long runs
            del self.frames[:2048]
        t = float(frame.get("t", 0.0))
        self.last_t = t if self.last_t is None else max(self.last_t, t)
        kind = frame.get("type")
        payload = frame.get("payload", {})
        if kind == "hello":
            self.sources[f"{payload.get('source')}:{payload.get('pid')}"] \
                = payload
        elif kind == "tick":
            key = (payload.get("scenario"), payload.get("seed"),
                   payload.get("policy"))
            cell = self.ticks.setdefault(
                key, {"first_t": t, "n_ticks": 0})
            cell.update(payload)
            cell["n_ticks"] += 1
            cell["last_t"] = t
        elif kind == "gateway":
            key = (payload.get("scenario"), payload.get("seed"),
                   payload.get("policy"))
            cell = self.gateways.setdefault(
                key, {"first_t": t, "n_ticks": 0})
            cell.update(payload)
            cell["n_ticks"] += 1
            cell["last_t"] = t
        elif kind == "worker":
            self.workers[str(payload.get("owner"))] = payload
        elif kind == "chunk":
            self.chunks["n"] += 1
            self.chunks["items"] += int(payload.get("items", 0))
        elif kind == "metrics":
            self.counters.update(payload.get("counters", {}))
        elif kind == "reqtrace":
            self.requests.append(payload)
            self.n_requests += 1

    def tick_rate(self, cell: Mapping[str, Any]) -> float:
        span = cell.get("last_t", 0.0) - cell.get("first_t", 0.0)
        n = cell.get("n_ticks", 0)
        return (n - 1) / span if n > 1 and span > 0 else float("nan")


def _fmt(v, spec: str = ".3f", width: int = 7) -> str:
    if v is None or (isinstance(v, float) and math.isnan(v)):
        return " " * (width - 3) + "n/a"
    return f"{v:{spec}}".rjust(width)


def render(state: DashState, *, slos: Iterable[SLO] = DEFAULT_SLOS,
           width: int = 100) -> str:
    """One dashboard screen as a plain string (no cursor control)."""
    bar = "=" * min(width, 100)
    when = time.strftime("%H:%M:%S", time.localtime(state.last_t)) \
        if state.last_t else "--:--:--"
    out = [bar,
           f" repro.obs dash   {len(state.sources)} source(s)   "
           f"{state.n_frames} frame(s)   last {when}",
           bar]

    if state.ticks:
        out.append(f" {'scenario':<20} {'seed':>4} {'pol':>4} {'tick':>5} "
                   f"{'tick/s':>7} {'qos':>7} {'miss':>7} {'queue':>6} "
                   f"{'infl':>5} {'drop':>5}")
        for (scenario, seed, policy), cell in sorted(
                state.ticks.items(), key=lambda kv: str(kv[0])):
            out.append(
                f" {str(scenario):<20} {str(seed):>4} "
                f"{str(policy)[:4]:>4} {cell.get('tick', 0):>5} "
                f"{_fmt(state.tick_rate(cell), '.2f')} "
                f"{_fmt(cell.get('window_qos'))} "
                f"{_fmt(cell.get('miss_rate'))} "
                f"{cell.get('queue_depth', 0):>6} "
                f"{cell.get('in_flight', 0):>5} "
                f"{cell.get('dropped', 0):>5}")
    else:
        out.append(" (no tick frames yet)")

    if state.gateways:
        out.append("")
        out.append(f" {'gateway':<20} {'mode':>5} {'spd':>5} {'tick':>5} "
                   f"{'adm':>6} {'ingr':>5} {'lag ms':>7} {'drop':>5} "
                   f"{'late':>5}")
        for (scenario, seed, policy), cell in sorted(
                state.gateways.items(), key=lambda kv: str(kv[0])):
            out.append(
                f" {f'{scenario}/s{seed}':<20} "
                f"{str(cell.get('mode', '?'))[:5]:>5} "
                f"{_fmt(cell.get('speed'), '.3g', 5)} "
                f"{cell.get('tick', 0):>5} "
                f"{cell.get('requests', 0):>6} "
                f"{cell.get('ingress_depth', 0):>5} "
                f"{_fmt(cell.get('loop_lag_ms'), '.2f')} "
                f"{cell.get('dropped_ingress', 0):>5} "
                f"{cell.get('late', 0):>5}")

    if state.workers:
        out.append("")
        out.append(f" {'worker':<20} {'tasks':>6} {'items':>7} "
                   f"{'items/s':>8} {'pending':>8} {'eta':>8}")
        for owner, w in sorted(state.workers.items()):
            rate = float(w.get("items_per_s") or 0.0)
            pending = w.get("queue_pending_items")
            eta = pending / rate if pending and rate > 0 else None
            out.append(f" {owner:<20} {w.get('tasks_done', 0):>6} "
                       f"{w.get('items_done', 0):>7} "
                       f"{_fmt(rate, '.2f', 8)} "
                       f"{pending if pending is not None else 'n/a':>8} "
                       f"{_fmt(eta, '.0f', 7) + 's' if eta is not None else '     n/a'}")

    if state.requests:
        out.append("")
        out.append(f" requests ({state.n_requests} sampled)"
                   f"{'':<7} {'uid':>6} {'tick':>5} {'edge':>5} "
                   f"{'impl':>5} {'lat ms':>8} {'kept for':>13} flags")
        for rec in state.requests:
            impl = next((ev.get("impl") for ev in rec.get("events", [])
                         if ev.get("stage") == "route"), None)
            lat = rec.get("latency_s")
            flags = ",".join(f for f in ("dropped", "missed", "requeued")
                             if rec.get(f)) or "-"
            out.append(
                f" {'':<20} {rec.get('uid', '?'):>6} "
                f"{rec.get('tick', '?'):>5} {rec.get('edge', '?'):>5} "
                f"{impl if impl is not None else '-':>5} "
                f"{_fmt(lat * 1e3 if lat is not None else None, '.2f', 8)} "
                f"{str(rec.get('keep_reason', '?')):>13} {flags}")

    if state.chunks["n"]:
        out.append("")
        out.append(f" sweep chunks: {state.chunks['n']} "
                   f"({state.chunks['items']} item(s))")

    reports = [r for r in evaluate_slos(slos, frames=state.frames)
               if r.n_samples > 0]
    if reports:
        out.append("")
        out.append(" SLO")
        for r in reports:
            out.append(" " + r.line())
    out.append(bar)
    return "\n".join(out)


def _pump(spec: str, sink: "queue.Queue", timeout_s: float) -> None:
    try:
        for frame in read_stream(spec, follow=True, timeout_s=timeout_s):
            sink.put(frame)
    except Exception as e:  # surfaced by the main loop, never lost
        sink.put({"type": "_error", "payload": {"spec": spec,
                                                "error": str(e)}})
    finally:
        sink.put({"type": "_eof", "payload": {"spec": spec}})


def run_dash(specs: List[str], *, interval: float = 1.0,
             timeout_s: float = 10.0, once: bool = False,
             max_frames: Optional[int] = None,
             slos: Iterable[SLO] = DEFAULT_SLOS,
             out=None, clear: bool = True) -> int:
    """Tail the given streams and render until they end.

    ``once`` drains what is currently available, renders a single screen,
    and exits (0 when at least one frame arrived, 2 otherwise — the CI
    smoke contract). Returns a process exit code.
    """
    out = out or sys.stdout
    state = DashState()
    frames: "queue.Queue" = queue.Queue()
    threads = []
    for spec in specs:
        th = threading.Thread(
            target=_pump, args=(spec, frames, 0.5 if once else timeout_s),
            daemon=True)
        th.start()
        threads.append(th)
    live = len(threads)
    errors: List[str] = []
    last_render = 0.0
    while live > 0:
        try:
            frame = frames.get(timeout=0.2)
        except queue.Empty:
            frame = None
        if frame is not None:
            if frame.get("type") == "_eof":
                live -= 1
            elif frame.get("type") == "_error":
                errors.append(f"{frame['payload']['spec']}: "
                              f"{frame['payload']['error']}")
                live -= 1
            else:
                state.update(frame)
        if not once and time.monotonic() - last_render >= interval:
            screen = render(state, slos=slos)
            out.write((_CLEAR if clear else "") + screen + "\n")
            out.flush()
            last_render = time.monotonic()
        if max_frames is not None and state.n_frames >= max_frames:
            break
    screen = render(state, slos=slos)
    out.write((_CLEAR if clear and not once else "") + screen + "\n")
    for err in errors:
        out.write(f" [dash] stream error: {err}\n")
    out.flush()
    if errors:
        return 1
    return 0 if state.n_frames > 0 else 2
