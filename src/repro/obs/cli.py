"""Command-line entry point: ``python -m repro.obs``.

Operates on the artifacts the rest of the repo produces::

    # summarize a saved obs artifact (spans, counters, histograms)
    python -m repro.obs report experiments/obs/trace.json

    # convert to Chrome-trace / Perfetto JSON (open in ui.perfetto.dev)
    python -m repro.obs export experiments/obs/trace.json \\
        --format chrome-trace --out /tmp/trace_chrome.json

    # metrics snapshot as versioned JSONL
    python -m repro.obs export experiments/obs/trace.json \\
        --format jsonl --out /tmp/metrics.jsonl

    # live rate/ETA of a running fleet (worker telemetry + queue)
    python -m repro.obs tail --root experiments/fleet/demo --interval 2

Artifacts come from ``python -m repro.sweeps ... --obs PATH``, from
``REPRO_OBS=1 REPRO_OBS_DIR=...`` in any instrumented process (fleet
workers inherit it), or from ``Tracer.save`` directly.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

from .metrics import METRICS_SCHEMA_VERSION
from .trace import load_artifact, to_chrome_trace, validate_chrome_trace

__all__ = ["main", "report_text", "span_summaries"]


def span_summaries(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Per-span-name aggregate rows from a raw artifact (exact
    percentiles — the artifact carries every live span)."""
    names = doc.get("names", [])
    spans = doc.get("spans", {})
    ids = np.asarray(spans.get("name", []), np.int64)
    t0 = np.asarray(spans.get("t0_ns", []), np.float64)
    t1 = np.asarray(spans.get("t1_ns", []), np.float64)
    rows = []
    for nid in sorted(set(ids.tolist())):
        dur_ms = (t1[ids == nid] - t0[ids == nid]) / 1e6
        rows.append({
            "name": names[nid], "count": int(dur_ms.size),
            "total_ms": float(dur_ms.sum()),
            "mean_ms": float(dur_ms.mean()),
            "p50_ms": float(np.percentile(dur_ms, 50)),
            "p95_ms": float(np.percentile(dur_ms, 95)),
            "p99_ms": float(np.percentile(dur_ms, 99)),
        })
    rows.sort(key=lambda r: -r["total_ms"])
    return rows


def report_text(doc: Dict[str, Any]) -> str:
    """The ``obs report`` table: spans by total time, then counters and
    histogram digests."""
    out = []
    rows = span_summaries(doc)
    if rows:
        out.append(f"{'span':<32} {'count':>7} {'total_ms':>10} "
                   f"{'mean_ms':>9} {'p50_ms':>9} {'p95_ms':>9} "
                   f"{'p99_ms':>9}")
        for r in rows:
            out.append(f"{r['name']:<32} {r['count']:>7d} "
                       f"{r['total_ms']:>10.3f} {r['mean_ms']:>9.3f} "
                       f"{r['p50_ms']:>9.3f} {r['p95_ms']:>9.3f} "
                       f"{r['p99_ms']:>9.3f}")
    else:
        out.append("(no spans recorded)")
    dropped = doc.get("dropped_spans", 0)
    if dropped:
        out.append(f"! ring wrapped: {dropped} oldest span(s) dropped")
    counters = doc.get("counters", {})
    if counters:
        out.append("")
        out.append("counters:")
        for name, v in sorted(counters.items()):
            out.append(f"  {name:<38} {v:g}")
    hists = [m for m in doc.get("metrics", [])
             if m.get("kind") == "histogram"]
    if hists:
        out.append("")
        out.append(f"{'histogram':<38} {'count':>7} {'mean':>10} "
                   f"{'p50':>10} {'p95':>10} {'p99':>10}")
        for m in hists:
            labels = ",".join(f"{k}={v}"
                              for k, v in sorted(m["labels"].items()))
            label = m["name"] + ("{" + labels + "}" if labels else "")
            out.append(f"{label:<38} {m['count']:>7d} {m['mean']:>10.4g} "
                       f"{m['p50']:>10.4g} {m['p95']:>10.4g} "
                       f"{m['p99']:>10.4g}")
    return "\n".join(out)


def _cmd_report(args: argparse.Namespace) -> int:
    print(report_text(load_artifact(args.artifact)))
    return 0


def _cmd_export(args: argparse.Namespace) -> int:
    doc = load_artifact(args.artifact)
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    if args.format == "chrome-trace":
        chrome = to_chrome_trace(doc)
        n = validate_chrome_trace(chrome)
        out.write_text(json.dumps(chrome))
        print(f"[obs] wrote {n} duration event(s) "
              f"({len(chrome['traceEvents'])} total) to {out} — load in "
              f"ui.perfetto.dev or chrome://tracing")
    else:  # jsonl
        lines = [json.dumps(rec, separators=(",", ":"))
                 for rec in doc.get("metrics", [])]
        for name, value in sorted(doc.get("counters", {}).items()):
            lines.append(json.dumps(
                {"metrics_schema": METRICS_SCHEMA_VERSION,
                 "kind": "counter", "name": name, "labels": {},
                 "value": value}, separators=(",", ":")))
        for row in span_summaries(doc):
            lines.append(json.dumps(
                {"metrics_schema": METRICS_SCHEMA_VERSION,
                 "kind": "span_summary", "labels": {}, **row},
                separators=(",", ":")))
        out.write_text("".join(line + "\n" for line in lines))
        print(f"[obs] wrote {len(lines)} metric record(s) to {out}")
    return 0


def _fleet_line(status: Dict[str, Any]) -> str:
    q = status["queue"]
    parts = [f"pending {q['pending']}", f"leased {q['leased']}",
             f"done {q['done']}"]
    if status.get("remaining_items") is not None:
        parts.append(f"remaining {status['remaining_items']} item(s)")
    rate = status.get("rate_items_per_s")
    if rate:
        parts.append(f"{rate:.2f} items/s")
    eta = status.get("eta_s")
    if eta is not None:
        parts.append(f"ETA {eta:.0f}s")
    return "[obs tail] " + ", ".join(parts)


def _cmd_tail(args: argparse.Namespace) -> int:
    from repro.fleet.coordinator import status  # deferred: heavy import

    while True:
        out = status(args.root)
        print(_fleet_line(out), flush=True)
        for name, w in sorted(out.get("telemetry", {}).items()):
            wall = w.get("last_task_wall_s")
            print(f"  {name:<24} {w.get('items_done', 0):>6} item(s) "
                  f"{w.get('items_per_s', 0.0):>7.2f} items/s"
                  + (f"  last chunk {wall:.2f}s" if wall else ""),
                  flush=True)
        if args.once:
            return 0
        q = out["queue"]
        if q["pending"] == 0 and q["leased"] == 0:
            print("[obs tail] queue drained", flush=True)
            return 0
        time.sleep(args.interval)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Inspect and export repro.obs trace artifacts; tail "
                    "a running fleet's telemetry.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("report", help="text summary of a saved artifact")
    rp.add_argument("artifact")
    rp.set_defaults(fn=_cmd_report)

    ex = sub.add_parser("export", help="convert an artifact to "
                                       "chrome-trace or metrics JSONL")
    ex.add_argument("artifact")
    ex.add_argument("--format", choices=("chrome-trace", "jsonl"),
                    default="chrome-trace")
    ex.add_argument("--out", required=True)
    ex.set_defaults(fn=_cmd_export)

    tl = sub.add_parser("tail", help="live fleet rate/ETA from worker "
                                     "telemetry")
    tl.add_argument("--root", required=True, help="fleet root directory")
    tl.add_argument("--interval", type=float, default=2.0)
    tl.add_argument("--once", action="store_true",
                    help="print one status line and exit")
    tl.set_defaults(fn=_cmd_tail)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except (ValueError, OSError) as e:
        print(f"[obs] error: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
