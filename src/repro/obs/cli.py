"""Command-line entry point: ``python -m repro.obs``.

Operates on the artifacts the rest of the repo produces::

    # summarize a saved obs artifact (spans, counters, histograms)
    python -m repro.obs report experiments/obs/trace.json

    # convert to Chrome-trace / Perfetto JSON (open in ui.perfetto.dev)
    python -m repro.obs export experiments/obs/trace.json \\
        --format chrome-trace --out /tmp/trace_chrome.json

    # metrics snapshot as versioned JSONL
    python -m repro.obs export experiments/obs/trace.json \\
        --format jsonl --out /tmp/metrics.jsonl

    # live rate/ETA of a running fleet (worker telemetry + queue)
    python -m repro.obs tail --root experiments/fleet/demo --interval 2

    # live terminal dashboard over telemetry streams (REPRO_OBS_STREAM)
    python -m repro.obs dash --stream /tmp/stream.jsonl
    python -m repro.obs dash --root experiments/fleet/demo   # all workers

    # stitch every worker artifact of a fleet run into ONE Chrome trace
    python -m repro.obs stitch --root experiments/fleet/demo \\
        --out /tmp/fleet_chrome.json

    # evaluate SLOs against a stream / artifact / benchmark JSON
    python -m repro.obs slo --stream /tmp/stream.jsonl
    python -m repro.obs slo --bench BENCH_latest.json --spec slos.json

    # causal chain of one sampled request (reqtrace export or stream)
    python -m repro.obs explain --uid 1234 --trace /tmp/reqtrace.json

    # greedy decision provenance for a placement epoch
    python -m repro.obs why --tick 3 --ledger /tmp/ledger.jsonl

Artifacts come from ``python -m repro.sweeps ... --obs PATH``, from
``REPRO_OBS=1 REPRO_OBS_DIR=...`` in any instrumented process (fleet
workers inherit it), or from ``Tracer.save`` directly. Streams come from
``REPRO_OBS_STREAM`` / ``--stream`` (see :mod:`repro.obs.stream`).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import numpy as np

from .metrics import METRICS_SCHEMA_VERSION
from .trace import load_artifact, to_chrome_trace, validate_chrome_trace

__all__ = ["main", "report_text", "span_summaries"]


def span_summaries(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Per-span-name aggregate rows from a raw artifact (exact
    percentiles — the artifact carries every live span)."""
    names = doc.get("names", [])
    spans = doc.get("spans", {})
    ids = np.asarray(spans.get("name", []), np.int64)
    t0 = np.asarray(spans.get("t0_ns", []), np.float64)
    t1 = np.asarray(spans.get("t1_ns", []), np.float64)
    rows = []
    for nid in sorted(set(ids.tolist())):
        dur_ms = (t1[ids == nid] - t0[ids == nid]) / 1e6
        rows.append({
            "name": names[nid], "count": int(dur_ms.size),
            "total_ms": float(dur_ms.sum()),
            "mean_ms": float(dur_ms.mean()),
            "p50_ms": float(np.percentile(dur_ms, 50)),
            "p95_ms": float(np.percentile(dur_ms, 95)),
            "p99_ms": float(np.percentile(dur_ms, 99)),
        })
    rows.sort(key=lambda r: -r["total_ms"])
    return rows


def report_text(doc: Dict[str, Any]) -> str:
    """The ``obs report`` table: spans by total time, then counters and
    histogram digests."""
    out = []
    rows = span_summaries(doc)
    if rows:
        out.append(f"{'span':<32} {'count':>7} {'total_ms':>10} "
                   f"{'mean_ms':>9} {'p50_ms':>9} {'p95_ms':>9} "
                   f"{'p99_ms':>9}")
        for r in rows:
            out.append(f"{r['name']:<32} {r['count']:>7d} "
                       f"{r['total_ms']:>10.3f} {r['mean_ms']:>9.3f} "
                       f"{r['p50_ms']:>9.3f} {r['p95_ms']:>9.3f} "
                       f"{r['p99_ms']:>9.3f}")
    else:
        out.append("(no spans recorded)")
    dropped = doc.get("dropped_spans", 0)
    if dropped:
        out.append(f"! ring wrapped: {dropped} oldest span(s) dropped")
    counters = doc.get("counters", {})
    if counters:
        out.append("")
        out.append("counters:")
        for name, v in sorted(counters.items()):
            out.append(f"  {name:<38} {v:g}")
    hists = [m for m in doc.get("metrics", [])
             if m.get("kind") == "histogram"]
    if hists:
        out.append("")
        out.append(f"{'histogram':<38} {'count':>7} {'mean':>10} "
                   f"{'p50':>10} {'p95':>10} {'p99':>10}")
        for m in hists:
            labels = ",".join(f"{k}={v}"
                              for k, v in sorted(m["labels"].items()))
            label = m["name"] + ("{" + labels + "}" if labels else "")
            out.append(f"{label:<38} {m['count']:>7d} {m['mean']:>10.4g} "
                       f"{m['p50']:>10.4g} {m['p95']:>10.4g} "
                       f"{m['p99']:>10.4g}")
    return "\n".join(out)


def _cmd_report(args: argparse.Namespace) -> int:
    print(report_text(load_artifact(args.artifact)))
    return 0


def _cmd_export(args: argparse.Namespace) -> int:
    doc = load_artifact(args.artifact)
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    if args.format == "chrome-trace":
        chrome = to_chrome_trace(doc)
        n = validate_chrome_trace(chrome)
        out.write_text(json.dumps(chrome))
        print(f"[obs] wrote {n} duration event(s) "
              f"({len(chrome['traceEvents'])} total) to {out} — load in "
              f"ui.perfetto.dev or chrome://tracing")
    else:  # jsonl
        lines = [json.dumps(rec, separators=(",", ":"))
                 for rec in doc.get("metrics", [])]
        for name, value in sorted(doc.get("counters", {}).items()):
            lines.append(json.dumps(
                {"metrics_schema": METRICS_SCHEMA_VERSION,
                 "kind": "counter", "name": name, "labels": {},
                 "value": value}, separators=(",", ":")))
        for row in span_summaries(doc):
            lines.append(json.dumps(
                {"metrics_schema": METRICS_SCHEMA_VERSION,
                 "kind": "span_summary", "labels": {}, **row},
                separators=(",", ":")))
        out.write_text("".join(line + "\n" for line in lines))
        print(f"[obs] wrote {len(lines)} metric record(s) to {out}")
    return 0


def _fleet_line(status: Dict[str, Any]) -> str:
    q = status["queue"]
    parts = [f"pending {q['pending']}", f"leased {q['leased']}",
             f"done {q['done']}"]
    if status.get("remaining_items") is not None:
        parts.append(f"remaining {status['remaining_items']} item(s)")
    rate = status.get("rate_items_per_s")
    if rate:
        parts.append(f"{rate:.2f} items/s")
    eta = status.get("eta_s")
    if eta is not None:
        parts.append(f"ETA {eta:.0f}s")
    return "[obs tail] " + ", ".join(parts)


def _cmd_tail(args: argparse.Namespace) -> int:
    from repro.fleet.coordinator import status  # deferred: heavy import

    while True:
        out = status(args.root)
        print(_fleet_line(out), flush=True)
        for name, w in sorted(out.get("telemetry", {}).items()):
            wall = w.get("last_task_wall_s")
            print(f"  {name:<24} {w.get('items_done', 0):>6} item(s) "
                  f"{w.get('items_per_s', 0.0):>7.2f} items/s"
                  + (f"  last chunk {wall:.2f}s" if wall else ""),
                  flush=True)
        if args.once:
            return 0
        q = out["queue"]
        if q["pending"] == 0 and q["leased"] == 0:
            print("[obs tail] queue drained", flush=True)
            return 0
        time.sleep(args.interval)


def _dash_specs(args: argparse.Namespace) -> List[str]:
    """--stream specs plus every per-worker stream under --root."""
    specs = list(args.stream or [])
    if getattr(args, "root", None):
        specs += [str(p) for p in
                  sorted((Path(args.root) / "stream").glob("*.jsonl"))]
    return specs


def _cmd_dash(args: argparse.Namespace) -> int:
    from .dash import run_dash
    from .slo import DEFAULT_SLOS, load_slos

    specs = _dash_specs(args)
    if not specs:
        print("[obs] dash: no streams — pass --stream SPEC and/or --root "
              "FLEET_ROOT (workers publish streams when REPRO_OBS_STREAM "
              "is set)", file=sys.stderr)
        return 2
    slos = load_slos(args.spec) if args.spec else DEFAULT_SLOS
    return run_dash(specs, interval=args.interval, timeout_s=args.timeout,
                    once=args.once, max_frames=args.max_frames, slos=slos,
                    clear=not args.no_clear and sys.stdout.isatty())


def _cmd_stitch(args: argparse.Namespace) -> int:
    from .aggregate import stitch_fleet

    summary = stitch_fleet(args.root, out=args.out)
    print(f"[obs] stitched {summary['n_artifacts']} worker artifact(s) "
          f"into {summary['n_events']} validated event(s)"
          + (f" -> {args.out}" if args.out else ""))
    for label, pid in sorted(summary["workers"].items()):
        print(f"  {label:<28} pid {pid}")
    hists = [m for m in summary["metrics"] if m.get("kind") == "histogram"]
    if hists:
        print(f"  rolled-up histograms: "
              + ", ".join(sorted({m['name'] for m in hists})))
    if args.json:
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        doc = {k: v for k, v in summary.items() if k != "chrome_trace"}
        Path(args.json).write_text(json.dumps(doc, indent=1))
    return 0


def _cmd_explain(args: argparse.Namespace) -> int:
    from .reqtrace import explain_uid, load_reqtrace

    doc = load_reqtrace(args.trace)
    print(explain_uid(doc, args.uid, tick=args.tick))
    return 0


def _cmd_why(args: argparse.Namespace) -> int:
    from .ledger import load_ledger, why_text

    recs = load_ledger(args.ledger)
    if args.tick is not None:
        recs = [r for r in recs if r.get("tick") == args.tick]
    if not recs:
        have = sorted({r.get("tick") for r in load_ledger(args.ledger)})
        raise ValueError(
            f"no decision record for tick {args.tick} in {args.ledger}"
            f" (ticks with records: {have})" if args.tick is not None
            else f"no decision records in {args.ledger}")
    for rec in recs[-1:] if args.tick is None else recs:
        print(why_text(rec, edge=args.edge))
    return 0


def _cmd_slo(args: argparse.Namespace) -> int:
    from .slo import DEFAULT_SLOS, evaluate_slos, load_slos
    from .stream import read_stream

    slos = load_slos(args.spec) if args.spec else list(DEFAULT_SLOS)
    frames: List[Dict[str, Any]] = []
    for spec in args.stream or []:
        frames.extend(read_stream(spec, follow=False))
    metrics = counters = None
    if args.artifact:
        doc = load_artifact(args.artifact)
        metrics = doc.get("metrics", [])
        counters = doc.get("counters", {})
    bench = json.loads(Path(args.bench).read_text()) if args.bench else None
    reports = evaluate_slos(slos, frames=frames, metrics=metrics,
                            counters=counters, bench=bench)
    for r in reports:
        print(r.line())
    failed = [r for r in reports if not r.ok]
    if failed:
        print(f"[obs] {len(failed)} SLO(s) violated", file=sys.stderr)
        return 1
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Inspect and export repro.obs trace artifacts; tail "
                    "a running fleet's telemetry.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("report", help="text summary of a saved artifact")
    rp.add_argument("artifact")
    rp.set_defaults(fn=_cmd_report)

    ex = sub.add_parser("export", help="convert an artifact to "
                                       "chrome-trace or metrics JSONL")
    ex.add_argument("artifact")
    ex.add_argument("--format", choices=("chrome-trace", "jsonl"),
                    default="chrome-trace")
    ex.add_argument("--out", required=True)
    ex.set_defaults(fn=_cmd_export)

    tl = sub.add_parser("tail", help="live fleet rate/ETA from worker "
                                     "telemetry")
    tl.add_argument("--root", required=True, help="fleet root directory")
    tl.add_argument("--interval", type=float, default=2.0)
    tl.add_argument("--once", action="store_true",
                    help="print one status line and exit")
    tl.set_defaults(fn=_cmd_tail)

    da = sub.add_parser("dash", help="live terminal dashboard over "
                                     "telemetry streams")
    da.add_argument("--stream", action="append", metavar="SPEC",
                    help="stream to tail: JSONL path, unix:/path, or "
                         "tcp:host:port; repeatable")
    da.add_argument("--root", default=None,
                    help="fleet root — tails every <root>/stream/*.jsonl")
    da.add_argument("--spec", default=None, metavar="PATH",
                    help="SLO spec JSON (default: built-in serving SLOs)")
    da.add_argument("--interval", type=float, default=1.0)
    da.add_argument("--timeout", type=float, default=10.0,
                    help="idle seconds before a stream is considered over")
    da.add_argument("--once", action="store_true",
                    help="drain what is buffered, render one screen, exit "
                         "(exit 2 when no frames arrived — the CI smoke)")
    da.add_argument("--max-frames", type=int, default=None)
    da.add_argument("--no-clear", action="store_true")
    da.set_defaults(fn=_cmd_dash)

    sti = sub.add_parser("stitch", help="merge a fleet's per-worker obs "
                                        "artifacts into one Chrome trace")
    sti.add_argument("--root", required=True, help="fleet root directory")
    sti.add_argument("--out", default=None, metavar="PATH",
                     help="write the stitched Chrome trace JSON here")
    sti.add_argument("--json", default=None, metavar="PATH",
                     help="write the stitch summary (workers, counters, "
                          "rolled-up metrics) here")
    sti.set_defaults(fn=_cmd_stitch)

    exp = sub.add_parser("explain", help="reconstruct one request's "
                                         "causal chain from a reqtrace "
                                         "export or stream")
    exp.add_argument("--uid", type=int, required=True,
                     help="request uid (see `obs dash` requests pane or "
                          "the reqtrace export's kept uids)")
    exp.add_argument("--tick", type=int, default=None,
                     help="disambiguate when the uid appears in several "
                          "ticks (uids are unique per run; optional)")
    exp.add_argument("--trace", required=True, metavar="PATH",
                     help="reqtrace snapshot JSON or stream JSONL")
    exp.set_defaults(fn=_cmd_explain)

    wh = sub.add_parser("why", help="greedy decision provenance for one "
                                    "placement epoch: per-pick marginal "
                                    "gains, gain curve, (1-1/e) "
                                    "certificate")
    wh.add_argument("--tick", type=int, default=None,
                    help="placement epoch to explain (default: latest)")
    wh.add_argument("--edge", type=int, default=None,
                    help="only show picks for this edge")
    wh.add_argument("--ledger", required=True, metavar="PATH",
                    help="decision-ledger JSONL or stream JSONL")
    wh.set_defaults(fn=_cmd_why)

    sl = sub.add_parser("slo", help="evaluate SLOs against streams, an "
                                    "artifact, or a benchmark JSON; exit "
                                    "1 on violation")
    sl.add_argument("--spec", default=None, metavar="PATH",
                    help="SLO spec JSON (default: built-in serving SLOs)")
    sl.add_argument("--stream", action="append", metavar="SPEC",
                    help="stream(s) to evaluate tick/metrics frames from")
    sl.add_argument("--artifact", default=None, metavar="PATH",
                    help="saved obs artifact for hist./counter. metrics")
    sl.add_argument("--bench", default=None, metavar="PATH",
                    help="benchmarks/run.py --json document for bench.*")
    sl.set_defaults(fn=_cmd_slo)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except (ValueError, OSError) as e:
        print(f"[obs] error: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
