"""Adapter between :mod:`repro.obs` and the JAX profiler.

Two directions of the same timeline:

* **obs spans → JAX profiler**: a :class:`~repro.obs.trace.Tracer`
  enabled with ``jax_annotations=True`` mirrors every span into a
  ``jax.profiler.TraceAnnotation``, so the driver-level structure
  (``tick.place``, ``sweep.chunk``, ...) shows up inside the JAX/XLA
  profile next to the kernels it wraps.
* **kernel time → obs**: :func:`kernel_span` is the host-side wrapper
  the kernel dispatchers (``repro.kernels.qos_matrix``,
  ``flash_attention``) use — an obs span named ``kernel.<x>`` (so the
  Chrome-trace export carries kernel annotations on the same timeline as
  the tick spans) plus, inside traced code, ``jax.named_scope`` tags the
  emitted HLO so Pallas kernel time is attributable in ``jax.profiler``
  dumps too.

:func:`profile_trace` wraps ``jax.profiler.trace`` (TensorBoard /
Perfetto-loadable ``plugins/profile`` dumps); everything degrades to a
no-op when JAX or its profiler is unavailable, so obs never adds a hard
dependency.
"""
from __future__ import annotations

import contextlib
from typing import Any, Iterator, Optional

from . import trace as _trace

__all__ = ["kernel_span", "named_scope", "profile_trace",
           "have_jax_profiler"]


def have_jax_profiler() -> bool:
    try:
        import jax.profiler  # noqa: F401
        return True
    except Exception:  # pragma: no cover - jax-less install
        return False


def kernel_span(name: str, **args: Any):
    """An obs span in the ``kernel`` category (``kernel.<name>``) —
    recorded on the obs timeline and, when the tracer runs with JAX
    annotations, on the JAX profiler timeline as well. No-op (the shared
    null span) when tracing is disabled."""
    full = name if name.startswith("kernel.") else "kernel." + name
    return _trace.span(full, **args)


def named_scope(name: str):
    """``jax.named_scope`` when JAX is importable, else a null context —
    tags HLO emitted under it so kernel time is attributable in profiler
    dumps. Safe inside jitted code (it is a trace-time annotation)."""
    try:
        import jax
        return jax.named_scope(name)
    except Exception:  # pragma: no cover - jax-less install
        return contextlib.nullcontext()


@contextlib.contextmanager
def profile_trace(log_dir, *, create_perfetto_link: bool = False
                  ) -> Iterator[Optional[str]]:
    """Run the body under ``jax.profiler.trace(log_dir)``.

    Yields the log dir on success or ``None`` when the profiler is
    unavailable (the body still runs). Combine with an obs tracer enabled
    with ``jax_annotations=True`` to see driver spans inside the dump::

        obs.enable(jax_annotations=True)
        with profile_trace("/tmp/jaxprof"):
            run_sweep(spec)
    """
    try:
        import jax.profiler as prof
    except Exception:  # pragma: no cover - jax-less install
        yield None
        return
    prof.start_trace(str(log_dir),
                     create_perfetto_link=create_perfetto_link)
    try:
        yield str(log_dir)
    finally:
        prof.stop_trace()
