"""Metrics registry: counters, gauges, log-bucketed latency histograms.

The aggregate half of :mod:`repro.obs` (the tracer is the timeline half).
A :class:`MetricsRegistry` hands out labeled series —

    reg.counter("sweep.items", scenario="steady").inc(64)
    reg.gauge("serving.queue_depth", scenario="steady").set(12)
    reg.histogram("serving.latency_s", scenario="steady").observe(0.031)

— keyed by ``(name, sorted labels)``, so the same call site yields the
same series object every time. Histograms are **log-bucketed**: bucket
``i`` covers ``(growth^(i-1)·min_value, growth^i·min_value]`` with the
default growth of ``2**(1/8)`` ≈ 9.05 % per bucket, which bounds any
quantile estimate's relative error by ``sqrt(growth) − 1`` ≈ 4.4 % while
storing a 9-decade latency range in ~240 sparse buckets. Quantiles
(p50/p95/p99) come straight from the cumulative bucket counts — no raw
samples are kept, so memory is O(buckets), not O(observations).

Snapshots serialize to a versioned JSONL format
(:data:`METRICS_SCHEMA_VERSION`): one self-describing JSON object per
line, ``kind`` ∈ {counter, gauge, histogram}. ``benchmarks/run.py
--json`` embeds the same records, and ``python -m repro.obs export
--format jsonl`` emits them from any saved obs artifact.

Like everything in :mod:`repro.obs`, metrics are observational only:
nothing reads them back into placement or scheduling decisions.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

__all__ = [
    "METRICS_SCHEMA_VERSION",
    "DEFAULT_GROWTH",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
]

#: Version stamp of the JSONL snapshot records.
METRICS_SCHEMA_VERSION = 1

#: Default histogram bucket growth factor: 2**(1/8) per bucket ⇒ 8
#: buckets per octave, ≤ ~4.4 % relative quantile error.
DEFAULT_GROWTH = 2.0 ** 0.125

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Mapping[str, Any]) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonic counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1) -> None:
        self.value += n

    def record(self) -> Dict[str, Any]:
        return {"value": self.value}


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)

    def record(self) -> Dict[str, Any]:
        return {"value": self.value}


class Histogram:
    """Sparse log-bucketed histogram with quantile estimation.

    Values ≤ ``min_value`` collapse into one underflow bucket (index
    ``None`` conceptually; stored as the smallest index − 1) whose
    representative value is ``min_value`` — fine for latencies, where
    anything below a nanosecond is measurement noise anyway.
    """

    __slots__ = ("growth", "min_value", "_log_growth", "_buckets",
                 "count", "sum", "min", "max", "exemplar_cap",
                 "_exemplars")

    def __init__(self, growth: float = DEFAULT_GROWTH,
                 min_value: float = 1e-9, exemplar_cap: int = 2):
        if not growth > 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        self.growth = float(growth)
        self.min_value = float(min_value)
        self._log_growth = math.log(self.growth)
        self._buckets: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        # bucket index -> up to exemplar_cap concrete exemplars (e.g.
        # {"uid", "tick"} request-trace links). First-N retention keeps
        # the exemplar set deterministic under identical input order.
        self.exemplar_cap = int(exemplar_cap)
        self._exemplars: Dict[int, List[Any]] = {}

    def _index(self, v: float) -> int:
        """Smallest ``i`` with ``min_value * growth**i >= v``."""
        if v <= self.min_value:
            return 0
        return max(0, math.ceil(
            math.log(v / self.min_value) / self._log_growth - 1e-12))

    def _upper_edge(self, i: int) -> float:
        return self.min_value * self.growth ** i

    def observe(self, v: float, exemplar: Any = None) -> None:
        v = float(v)
        if math.isnan(v):
            return  # a tick that served nothing has NaN mean latency
        i = self._index(v)
        self._buckets[i] = self._buckets.get(i, 0) + 1
        self.count += 1
        self.sum += v
        self.min = v if v < self.min else self.min
        self.max = v if v > self.max else self.max
        if exemplar is not None:
            ex = self._exemplars.setdefault(i, [])
            if len(ex) < self.exemplar_cap:
                ex.append(exemplar)

    def observe_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.observe(v)

    def quantile(self, q: float) -> float:
        """Estimated ``q``-quantile (0 ≤ q ≤ 1): the geometric midpoint of
        the bucket holding the q·count-th observation, clamped to the
        exact observed [min, max]."""
        if self.count == 0:
            return float("nan")
        rank = q * self.count
        seen = 0
        for i in sorted(self._buckets):
            seen += self._buckets[i]
            if seen >= rank:
                hi = self._upper_edge(i)
                lo = hi / self.growth
                mid = math.sqrt(lo * hi) if lo > 0 else hi
                return min(max(mid, self.min), self.max)
        return self.max  # pragma: no cover - guarded by count above

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def summary(self) -> Dict[str, float]:
        """The p50/p95/p99 digest the benchmarks and reports print."""
        empty = self.count == 0
        return {
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": float("nan") if empty else self.min,
            "max": float("nan") if empty else self.max,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }

    def record(self) -> Dict[str, Any]:
        rec = {
            "growth": self.growth,
            "min_value": self.min_value,
            "buckets": {str(i): n for i, n in sorted(self._buckets.items())},
            **self.summary(),
        }
        # additive-optional field: absent when no exemplars were ever
        # attached, so METRICS_SCHEMA_VERSION stays 1 and old readers
        # (which ignore unknown keys) keep working
        if self._exemplars:
            rec["exemplars"] = {str(i): ex for i, ex
                                in sorted(self._exemplars.items())}
        return rec

    @classmethod
    def from_record(cls, rec: Mapping[str, Any]) -> "Histogram":
        """Rebuild a histogram from its :meth:`record` dict — the inverse
        the cross-worker rollup needs (bucket counts are exact; ``sum`` is
        the stored float)."""
        h = cls(growth=float(rec.get("growth", DEFAULT_GROWTH)),
                min_value=float(rec.get("min_value", 1e-9)))
        h._buckets = {int(i): int(n)
                      for i, n in rec.get("buckets", {}).items()}
        h.count = int(rec.get("count", sum(h._buckets.values())))
        h.sum = float(rec.get("sum", 0.0))
        if h.count:
            h.min = float(rec["min"])
            h.max = float(rec["max"])
        h._exemplars = {int(i): list(ex)
                        for i, ex in rec.get("exemplars", {}).items()}
        return h

    def merge(self, other: "Histogram") -> "Histogram":
        """Bucket-wise sum of ``other`` into ``self`` (min/max union).

        Exact in bucket arithmetic: merging per-worker histograms yields
        byte-identical bucket counts, count, min, and max to histogramming
        the concatenated samples in one process (``sum`` is float addition
        and may differ in the last ulp). Bucket layouts must match.
        """
        if (other.growth, other.min_value) != (self.growth, self.min_value):
            raise ValueError(
                f"cannot merge histograms with different bucket layouts: "
                f"(growth={self.growth}, min_value={self.min_value}) vs "
                f"(growth={other.growth}, min_value={other.min_value})")
        for i, n in other._buckets.items():
            self._buckets[i] = self._buckets.get(i, 0) + n
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        for i, ex in other._exemplars.items():
            mine = self._exemplars.setdefault(i, [])
            mine.extend(ex[: max(0, self.exemplar_cap - len(mine))])
        return self


class MetricsRegistry:
    """Labeled series factory + versioned snapshot/JSONL export."""

    def __init__(self):
        self._series: Dict[Tuple[str, str, _LabelKey], Any] = {}

    def _get(self, kind: str, name: str, labels: Mapping[str, Any],
             factory) -> Any:
        key = (kind, str(name), _label_key(labels))
        series = self._series.get(key)
        if series is None:
            series = self._series[key] = factory()
        return series

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, growth: float = DEFAULT_GROWTH,
                  min_value: float = 1e-9, **labels: Any) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(growth, min_value))

    def __len__(self) -> int:
        return len(self._series)

    def snapshot(self) -> List[Dict[str, Any]]:
        """One self-describing record per series, stably ordered."""
        out = []
        for (kind, name, labels), series in sorted(
                self._series.items(), key=lambda kv: kv[0]):
            out.append({
                "metrics_schema": METRICS_SCHEMA_VERSION,
                "kind": kind,
                "name": name,
                "labels": dict(labels),
                **series.record(),
            })
        return out

    def to_jsonl(self) -> str:
        return "".join(json.dumps(rec, separators=(",", ":")) + "\n"
                       for rec in self.snapshot())

    @classmethod
    def from_snapshot(cls, records: Iterable[Mapping[str, Any]]
                      ) -> "MetricsRegistry":
        """Rebuild a registry from :meth:`snapshot` records (version-checked
        per record) — what lets the fleet stitcher roll up the ``metrics``
        section of saved per-worker obs artifacts."""
        reg = cls()
        for rec in records:
            have = int(rec.get("metrics_schema", -1))
            if have != METRICS_SCHEMA_VERSION:
                raise ValueError(f"metrics record schema v{have}, this "
                                 f"code reads v{METRICS_SCHEMA_VERSION}")
            kind, name = rec["kind"], rec["name"]
            labels = dict(rec.get("labels", {}))
            if kind == "counter":
                reg.counter(name, **labels).inc(float(rec["value"]))
            elif kind == "gauge":
                reg.gauge(name, **labels).set(float(rec["value"]))
            elif kind == "histogram":
                key = ("histogram", str(name), _label_key(labels))
                reg._series[key] = Histogram.from_record(rec)
            else:
                raise ValueError(f"unknown metrics record kind {kind!r}")
        return reg

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Roll ``other`` into ``self``: counters add, histograms merge
        bucket-wise (exact — see :meth:`Histogram.merge`), gauges keep
        ``other``'s value when it is set (last-writer-wins across the
        merge order the caller chooses)."""
        for key, series in other._series.items():
            kind = key[0]
            mine = self._series.get(key)
            if mine is None:
                if kind == "counter":
                    mine = self._series[key] = Counter()
                elif kind == "gauge":
                    mine = self._series[key] = Gauge()
                else:
                    mine = self._series[key] = Histogram(
                        series.growth, series.min_value)
            if kind == "counter":
                mine.inc(series.value)
            elif kind == "gauge":
                if not math.isnan(series.value):
                    mine.set(series.value)
            else:
                mine.merge(series)
        return self

    def histograms(self, name: Optional[str] = None
                   ) -> Dict[str, Dict[str, float]]:
        """``{"name{labels}": summary}`` for every (matching) histogram —
        the digest ``benchmarks/run.py --json`` embeds."""
        out = {}
        for (kind, nm, labels), series in sorted(
                self._series.items(), key=lambda kv: kv[0]):
            if kind != "histogram" or (name is not None and nm != name):
                continue
            suffix = ",".join(f"{k}={v}" for k, v in labels)
            out[nm + ("{" + suffix + "}" if suffix else "")] = \
                series.summary()
        return out
