"""repro.obs — determinism-safe tracing, metrics, and trace export.

The observability layer for the whole repo: span tracing with a
preallocated ring buffer (:mod:`~repro.obs.trace`), a counters/gauges/
histograms metrics registry (:mod:`~repro.obs.metrics`), a JAX-profiler
adapter (:mod:`~repro.obs.jaxprof`), and a CLI
(``python -m repro.obs report|export|tail``).

Everything is **off by default** and strictly observational: enabling
tracing changes no stored sweep byte and no ``TickReport`` field (tested
— see ``tests/test_obs.py``). Opt in with::

    from repro import obs
    obs.enable()                      # or REPRO_OBS=1 in the environment
    with obs.span("tick.place"):
        ...
    obs.save("trace.json")            # raw artifact; export via the CLI

Instrumented hot paths: :mod:`repro.serving.horizon` (per-tick
materialize/place/route/execute spans, queue-depth + realized-QoS
gauges, per-request latency histograms), :mod:`repro.sweeps`
(per-chunk spans, items/s, store I/O timing), :mod:`repro.fleet`
(worker telemetry files behind ``fleet status`` rate/ETA), and the
Pallas kernel dispatchers (``kernel.*`` annotations).
"""
from .metrics import (METRICS_SCHEMA_VERSION, Counter, Gauge, Histogram,
                      MetricsRegistry)
from .trace import (DEFAULT_CAPACITY, OBS_SCHEMA_VERSION,
                    READABLE_OBS_SCHEMAS, Tracer, count, disable, enable,
                    enable_from_env, enabled, get_tracer, load_artifact,
                    sample, save, span, to_chrome_trace,
                    validate_chrome_trace)
from .jaxprof import (have_jax_profiler, kernel_span, named_scope,
                      profile_trace)
from .stream import (STREAM_SCHEMA_VERSION, StreamPublisher, disable_stream,
                     enable_stream, enable_stream_from_env, get_publisher,
                     publish, read_stream, stream_active)
from .aggregate import (rollup_counters, rollup_metrics, stitch_fleet,
                        stitch_traces)
from .slo import (DEFAULT_SLOS, SLO, SLO_SCHEMA_VERSION, SLOReport,
                  compare_bench, evaluate_slos, load_slos)
from .reqtrace import (REQTRACE_SCHEMA_VERSION, RequestTracer,
                       disable_request_tracing, enable_request_tracing,
                       enable_reqtrace_from_env, explain_uid,
                       get_request_tracer, load_reqtrace)
from .ledger import (LEDGER_SCHEMA_VERSION, DecisionLedger, disable_ledger,
                     enable_ledger, enable_ledger_from_env, get_ledger,
                     ingest_sparse_trace, load_ledger, why_text)

__all__ = [
    "OBS_SCHEMA_VERSION", "METRICS_SCHEMA_VERSION", "DEFAULT_CAPACITY",
    "READABLE_OBS_SCHEMAS", "STREAM_SCHEMA_VERSION", "SLO_SCHEMA_VERSION",
    "Tracer", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "enable", "disable", "enabled", "get_tracer", "enable_from_env",
    "span", "count", "sample", "save",
    "load_artifact", "to_chrome_trace", "validate_chrome_trace",
    "kernel_span", "named_scope", "profile_trace", "have_jax_profiler",
    "StreamPublisher", "enable_stream", "disable_stream", "stream_active",
    "get_publisher", "publish", "read_stream", "enable_stream_from_env",
    "stitch_traces", "stitch_fleet", "rollup_metrics", "rollup_counters",
    "SLO", "SLOReport", "DEFAULT_SLOS", "load_slos", "evaluate_slos",
    "compare_bench",
    "REQTRACE_SCHEMA_VERSION", "RequestTracer", "enable_request_tracing",
    "disable_request_tracing", "get_request_tracer",
    "enable_reqtrace_from_env", "load_reqtrace", "explain_uid",
    "LEDGER_SCHEMA_VERSION", "DecisionLedger", "enable_ledger",
    "disable_ledger", "get_ledger", "enable_ledger_from_env",
    "ingest_sparse_trace", "load_ledger", "why_text",
]
