"""Live telemetry streaming: a versioned JSONL wire protocol over a
unix/TCP socket or an append-only file tail.

``repro.obs`` (PR 6) made the hot paths *record* — spans, counters,
gauges, histograms — into process-local buffers that are only visible
once the process saves an artifact. This module makes that telemetry
*flow* while the process runs: a :class:`StreamPublisher` tails the live
:class:`~repro.obs.metrics.MetricsRegistry` and the per-tick gauges and
pushes versioned frames to whoever is watching (``python -m repro.obs
dash``, ``python -m repro.fleet status --watch``, or any ``tail -f`` +
``jq`` pipeline).

Wire protocol (``stream_schema`` :data:`STREAM_SCHEMA_VERSION`): one JSON
object per ``\\n``-terminated line. The first frame is a **handshake**::

    {"stream_schema": 1, "seq": 0, "type": "hello",
     "t": <wall s>, "payload": {"source": ..., "pid": ...}}

Every subsequent frame carries a strictly increasing ``seq``; readers
(:func:`read_stream` / :class:`FrameValidator`) reject streams with a
missing or version-mismatched handshake, non-monotonic ``seq`` (an
out-of-order or replayed frame), and complete lines that fail to parse
(a torn write). An *incomplete* trailing line — a frame still being
written — is never parsed: file readers buffer until the newline lands,
so tailing a live stream can't see a half-frame. Frame types in use:
``hello``, ``tick`` (per serving-horizon tick), ``horizon`` (end-of-run
summary), ``chunk`` (sweep chunk completions), ``worker`` (fleet task
completions), ``metrics`` (a full registry snapshot), ``bye``.

Transports: ``unix:<path>`` binds a unix-domain socket and broadcasts to
every connected client (slow or dead clients are dropped, never waited
on); ``tcp:<host>:<port>`` does the same over TCP; anything else is a
file path appended to — the fallback that works across any shared
filesystem, which is what the fleet uses (one file per worker under
``<fleet_root>/stream/``).

Opt-in mirrors the tracer: ``REPRO_OBS_STREAM=<spec>`` in the
environment (``1`` means "the default file sink"), or an explicit
:func:`enable_stream` / CLI flag. The hard invariant of PR 6 carries
over unchanged and is tested: streaming is observational only — stores
and ``TickReport``\\ s are byte-identical stream-on vs stream-off, and a
publisher failure (full disk, dead socket) disables the stream rather
than failing the serving path.
"""
from __future__ import annotations

import json
import os
import socket
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "STREAM_SCHEMA_VERSION",
    "StreamError",
    "FileSink",
    "SocketSink",
    "StreamPublisher",
    "FrameValidator",
    "parse_stream_spec",
    "read_stream",
    "enable_stream",
    "disable_stream",
    "stream_active",
    "get_publisher",
    "publish",
    "enable_stream_from_env",
]

#: Version stamp of the wire protocol (the handshake frame carries it).
STREAM_SCHEMA_VERSION = 1

_ENV_STREAM = "REPRO_OBS_STREAM"

_TRUTHY = ("1", "true", "on")


class StreamError(ValueError):
    """A malformed stream: bad handshake, torn frame, out-of-order seq."""


# ===========================================================================
# Sinks (publisher side)
# ===========================================================================

class FileSink:
    """Append frames to a JSONL file — the lowest-common-denominator
    transport: works over any shared filesystem, readable with ``tail -f``.
    One publisher per file (the seq contiguity contract is per-writer)."""

    def __init__(self, path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f = open(self.path, "a", buffering=1)  # line-buffered

    def write(self, line: str) -> None:
        self._f.write(line)
        self._f.flush()

    def close(self) -> None:
        try:
            self._f.close()
        except OSError:
            pass

    def describe(self) -> str:
        return str(self.path)


class SocketSink:
    """Bind a unix/TCP socket and broadcast every frame to all connected
    clients. Strictly best-effort: a slow or dead client is dropped (the
    publisher never blocks on a reader), and a late joiner is replayed
    the handshake frame so validation still works mid-run."""

    def __init__(self, kind: str, address):
        self.kind = kind
        self.address = address
        if kind == "unix":
            self._srv = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            try:
                os.unlink(address)
            except OSError:
                pass
            Path(address).parent.mkdir(parents=True, exist_ok=True)
            self._srv.bind(address)
        elif kind == "tcp":
            self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self._srv.bind(address)
            self.address = self._srv.getsockname()  # resolved port 0
        else:
            raise ValueError(f"unknown socket kind {kind!r}")
        self._srv.listen(8)
        self._lock = threading.Lock()
        self._clients: List[socket.socket] = []
        self._hello: Optional[str] = None
        self._closed = False
        self._thread = threading.Thread(target=self._accept_loop,
                                        daemon=True)
        self._thread.start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return  # closed underneath us
            conn.setblocking(False)
            with self._lock:
                if self._hello is not None:
                    try:
                        conn.sendall(self._hello.encode())
                    except OSError:
                        conn.close()
                        continue
                self._clients.append(conn)

    def write(self, line: str) -> None:
        data = line.encode()
        with self._lock:
            if self._hello is None:
                self._hello = line
            dead = []
            for conn in self._clients:
                try:
                    conn.sendall(data)
                except OSError:  # includes EWOULDBLOCK: drop slow readers
                    dead.append(conn)
            for conn in dead:
                self._clients.remove(conn)
                conn.close()

    def close(self) -> None:
        self._closed = True
        try:
            self._srv.close()
        except OSError:
            pass
        with self._lock:
            for conn in self._clients:
                try:
                    conn.close()
                except OSError:
                    pass
            self._clients.clear()
        if self.kind == "unix":
            try:
                os.unlink(self.address)
            except OSError:
                pass

    def describe(self) -> str:
        if self.kind == "unix":
            return f"unix:{self.address}"
        host, port = self.address
        return f"tcp:{host}:{port}"


def parse_stream_spec(spec: str, default_path: Optional[str] = None
                      ) -> Tuple[str, Any]:
    """``unix:/path`` / ``tcp:host:port`` / file path → (kind, address).

    A bare truthy value (``1``/``true``/``on``) selects the default file
    sink — ``default_path`` or ``obs_stream.jsonl`` in the cwd.
    """
    spec = str(spec).strip()
    if spec.lower() in _TRUTHY:
        return "file", str(default_path or "obs_stream.jsonl")
    if spec.startswith("unix:"):
        return "unix", spec[len("unix:"):]
    if spec.startswith("tcp:"):
        rest = spec[len("tcp:"):]
        host, _, port = rest.rpartition(":")
        return "tcp", (host or "127.0.0.1", int(port))
    return "file", spec


def _open_sink(spec: str, default_path: Optional[str] = None):
    kind, address = parse_stream_spec(spec, default_path)
    if kind == "file":
        return FileSink(address)
    return SocketSink(kind, address)


# ===========================================================================
# Publisher
# ===========================================================================

class StreamPublisher:
    """Frame writer over one sink; thread-safe, best-effort, versioned.

    Emits the handshake at construction. ``emit`` never raises into the
    instrumented caller: a sink failure closes the stream and subsequent
    emits are dropped (``self.failed`` flips so callers can report it).
    """

    def __init__(self, sink, *, source: str = "repro",
                 clock: Callable[[], float] = time.time):
        self._sink = sink
        self._clock = clock
        self._lock = threading.Lock()
        self._seq = 0
        self.failed = False
        self.n_frames = 0
        self.emit("hello", {
            "stream_schema": STREAM_SCHEMA_VERSION,
            "source": str(source),
            "pid": os.getpid(),
        })

    def emit(self, type_: str, payload: Dict[str, Any]) -> bool:
        """Write one frame; returns False when the stream is dead."""
        if self.failed:
            return False
        with self._lock:
            frame = {
                "stream_schema": STREAM_SCHEMA_VERSION,
                "seq": self._seq,
                "t": round(float(self._clock()), 6),
                "type": str(type_),
                "payload": payload,
            }
            line = json.dumps(frame, separators=(",", ":"),
                              sort_keys=True) + "\n"
            try:
                self._sink.write(line)
            except (OSError, ValueError):
                # ValueError covers writes to an already-closed file —
                # streaming must degrade, never raise into the hot path
                self.failed = True
                try:
                    self._sink.close()
                except (OSError, ValueError):
                    pass
                return False
            self._seq += 1
            self.n_frames += 1
            return True

    def emit_metrics(self, tracer) -> bool:
        """One ``metrics`` frame: the registry snapshot + counters of a
        live :class:`~repro.obs.trace.Tracer` — the "tail the registry"
        half of the stream."""
        return self.emit("metrics", {
            "metrics": tracer.metrics.snapshot(),
            "counters": dict(tracer.counters),
            "n_spans": tracer.n_spans,
        })

    def close(self) -> None:
        if not self.failed:
            self.emit("bye", {"n_frames": self.n_frames})
        try:
            self._sink.close()
        except OSError:
            pass

    def describe(self) -> str:
        return self._sink.describe()


# ===========================================================================
# Reader / validator
# ===========================================================================

class FrameValidator:
    """Stateful frame checker shared by every consumer.

    Rules (violations raise :class:`StreamError`):

    - the first frame must be a ``hello`` whose ``stream_schema`` matches
      :data:`STREAM_SCHEMA_VERSION` (the versioned handshake);
    - ``seq`` must be strictly increasing — an out-of-order or replayed
      frame is rejected; with ``contiguous=True`` (file streams, where
      no frame can be legitimately dropped) any gap is also rejected;
    - every frame must be a complete, parseable JSON object (a complete
      line that fails to parse is a torn write, not a partial tail).
    """

    def __init__(self, *, contiguous: bool = True):
        self.contiguous = contiguous
        self.last_seq: Optional[int] = None
        self.hello: Optional[Dict[str, Any]] = None

    def reset(self) -> None:
        """Forget all state — the stream restarted (file truncated or
        rotated), so the next frame must be a fresh hello handshake."""
        self.last_seq = None
        self.hello = None

    def feed_line(self, line: str) -> Dict[str, Any]:
        try:
            frame = json.loads(line)
        except json.JSONDecodeError as e:
            raise StreamError(f"truncated/corrupt frame: {line!r:.80}") \
                from e
        if not isinstance(frame, dict):
            raise StreamError(f"frame is not an object: {line!r:.80}")
        return self.feed(frame)

    def feed(self, frame: Dict[str, Any]) -> Dict[str, Any]:
        seq = frame.get("seq")
        if not isinstance(seq, int):
            raise StreamError(f"frame without integer seq: {frame!r:.120}")
        if self.hello is None:
            if frame.get("type") != "hello":
                raise StreamError(
                    f"stream does not start with a hello handshake "
                    f"(got type={frame.get('type')!r})")
            have = frame.get("payload", {}).get("stream_schema",
                                                frame.get("stream_schema"))
            if have != STREAM_SCHEMA_VERSION:
                raise StreamError(
                    f"stream handshake schema v{have}, this code reads "
                    f"v{STREAM_SCHEMA_VERSION}")
            self.hello = frame
        if self.last_seq is not None:
            if seq <= self.last_seq:
                raise StreamError(f"out-of-order frame: seq {seq} after "
                                  f"{self.last_seq}")
            if self.contiguous and seq != self.last_seq + 1:
                raise StreamError(f"missing frame(s): seq jumped "
                                  f"{self.last_seq} -> {seq}")
        self.last_seq = seq
        return frame


def read_stream(spec: str, *, follow: bool = False,
                timeout_s: float = 5.0, poll_s: float = 0.05,
                validator: Optional[FrameValidator] = None
                ) -> Iterator[Dict[str, Any]]:
    """Yield validated frames from a stream spec (file path or socket).

    File mode buffers partial lines (a frame mid-write is invisible, not
    an error) and, with ``follow=True``, keeps polling for new frames
    until ``timeout_s`` passes with no progress or a ``bye`` frame
    arrives. Socket mode connects as a client; socket streams validate
    non-contiguously (a broadcaster drops frames for slow clients).
    """
    kind, address = parse_stream_spec(spec)
    if kind == "file":
        validator = validator or FrameValidator(contiguous=True)
        yield from _read_file(Path(address), follow, timeout_s, poll_s,
                              validator)
    else:
        validator = validator or FrameValidator(contiguous=False)
        yield from _read_socket(kind, address, timeout_s, validator)


def _read_file(path: Path, follow: bool, timeout_s: float, poll_s: float,
               validator: FrameValidator) -> Iterator[Dict[str, Any]]:
    buf = ""
    pos = 0
    deadline = time.monotonic() + timeout_s
    while True:
        try:
            # A shrunken file means the writer truncated or rotated the
            # stream in place: restart from offset 0 with fresh validator
            # state (the new stream begins with its own hello handshake).
            if pos and os.stat(path).st_size < pos:
                pos = 0
                buf = ""
                validator.reset()
            with open(path) as f:
                f.seek(pos)
                chunk = f.read()
                pos = f.tell()
        except FileNotFoundError:
            chunk = ""
        if chunk:
            buf += chunk
            deadline = time.monotonic() + timeout_s
            while "\n" in buf:
                line, buf = buf.split("\n", 1)
                if not line.strip():
                    continue
                frame = validator.feed_line(line)
                yield frame
                if frame.get("type") == "bye":
                    return
        if not follow:
            return
        if time.monotonic() >= deadline:
            return
        time.sleep(poll_s)


def _read_socket(kind: str, address, timeout_s: float,
                 validator: FrameValidator) -> Iterator[Dict[str, Any]]:
    family = socket.AF_UNIX if kind == "unix" else socket.AF_INET
    with socket.socket(family, socket.SOCK_STREAM) as conn:
        conn.settimeout(timeout_s)
        conn.connect(address)
        buf = b""
        while True:
            try:
                chunk = conn.recv(65536)
            except socket.timeout:
                return
            if not chunk:
                return
            buf += chunk
            while b"\n" in buf:
                line, buf = buf.split(b"\n", 1)
                if not line.strip():
                    continue
                frame = validator.feed_line(line.decode())
                yield frame
                if frame.get("type") == "bye":
                    return


# ===========================================================================
# Module-level switch (mirrors trace.enable/disable)
# ===========================================================================

_PUBLISHER: Optional[StreamPublisher] = None


def enable_stream(spec: str, *, source: str = "repro",
                  default_path: Optional[str] = None) -> StreamPublisher:
    """Install (and return) the process-global stream publisher."""
    global _PUBLISHER
    if _PUBLISHER is not None:
        _PUBLISHER.close()
    _PUBLISHER = StreamPublisher(_open_sink(spec, default_path),
                                 source=source)
    return _PUBLISHER


def disable_stream() -> Optional[StreamPublisher]:
    """Close and uninstall the global publisher (emits the bye frame)."""
    global _PUBLISHER
    pub, _PUBLISHER = _PUBLISHER, None
    if pub is not None:
        pub.close()
    return pub


def stream_active() -> bool:
    return _PUBLISHER is not None and not _PUBLISHER.failed


def get_publisher() -> Optional[StreamPublisher]:
    return _PUBLISHER


def publish(type_: str, **payload: Any) -> bool:
    """The one hot-path hook: a no-op (one global load + ``None`` check)
    unless a publisher is installed."""
    pub = _PUBLISHER
    if pub is None:
        return False
    return pub.emit(type_, payload)


def enable_stream_from_env(default_path: Optional[str] = None,
                           source: str = "repro"
                           ) -> Optional[StreamPublisher]:
    """Opt-in via ``REPRO_OBS_STREAM`` — how forked fleet workers inherit
    streaming. The value is a stream spec (``unix:...``, ``tcp:...``, a
    file path) or a bare ``1`` for the default file sink; anything else
    leaves streaming off. Registers an :mod:`atexit` close so the bye
    frame lands on clean exit."""
    spec = os.environ.get(_ENV_STREAM, "").strip()
    if not spec or spec.lower() in ("0", "false", "off"):
        return None
    pub = enable_stream(spec, source=source, default_path=default_path)
    import atexit
    atexit.register(disable_stream)
    return pub
