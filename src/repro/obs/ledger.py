"""Greedy decision ledger — the "explain this pick" layer.

The paper's central algorithmic claim (Theorem 2 of arXiv:2104.15094)
is that the efficient greedy placement (EGP) achieves
``σ(greedy) ≥ (1 − 1/e) · OPT`` for the submodular objective of
Eq. (1). The sweeps verify that in aggregate; this module makes it
checkable **per placement**: every EGP / sparse-EGP pick is recorded as

    (edge, impl, benefit, marginal gain, remaining storage budget,
     #candidates considered, rank of chosen candidate, placed?)

so a tick's ledger exposes the live submodular gain curve (cumulative
marginal gains, concave by submodularity) and a certificate

    σ(greedy) ≥ (1 − 1/e) · σ̄     with σ̄ = sigma_upper_bound_np(...)

where σ̄ is the per-user relaxation bound (each user served by its best
individually-feasible implementation, budgets ignored) — an efficiently
computable upper bound on the LP optimum, so ``ratio ≥ 1 − 1/e``
*against σ̄* is strictly stronger than the guarantee. A ratio below the
line does **not** refute Theorem 2 (σ̄ overshoots OPT); it flags a
placement worth a closer look, which is exactly what a ledger is for.

Marginal gains are exact by construction: the ledger tracks each
user's best placed QoS (``best_u``) and books
``gain = Σ_u max(0, Q[u, p★] − best_u)`` per placed pick, so the gains
telescope — their sum equals the realized ``σ(x)`` of the placement to
float64 summation order (≤ 1e-6; the sparse top-k path books gains in
f32 inside the kernel's lock-step loop, documented tolerance ~1e-3
relative).

Hook protocol: :func:`enable_ledger` installs the ledger as
``repro.core.placement._DECISION_SINK`` (and mirrors it into
``repro.core.dynamic``), so the core never imports :mod:`repro.obs` —
the disabled hot path in the pick loops is one module-attribute load +
``is None``. Everything here is observational: picks are recorded, not
influenced, and stores/TickReports/digests stay byte-identical.

Exports are JSONL (one record per placement instance), versioned by
:data:`LEDGER_SCHEMA_VERSION`, and ride the PR-7 stream protocol as
``ledger`` frames.
"""
from __future__ import annotations

import atexit
import json
import math
import os
from typing import Any, Dict, List, Optional

__all__ = [
    "LEDGER_SCHEMA_VERSION",
    "ONE_MINUS_INV_E",
    "CERT_TOL",
    "DecisionLedger",
    "enable_ledger",
    "disable_ledger",
    "get_ledger",
    "enable_ledger_from_env",
    "ingest_sparse_trace",
    "load_ledger",
    "why_text",
]

#: Version stamp of the decision-ledger JSONL records.
LEDGER_SCHEMA_VERSION = 1

#: The Theorem-2 guarantee line: 1 − 1/e ≈ 0.6321.
ONE_MINUS_INV_E = 1.0 - 1.0 / math.e

#: Slack on the certificate comparison (float summation order).
CERT_TOL = 1e-9

_LEDGER: Optional["DecisionLedger"] = None


class DecisionLedger:
    """Ring of per-placement-instance pick records.

    One *record* covers one greedy placement run (one serving tick, or
    one standalone ``egp_np`` call). Within it, every candidate the
    greedy considered becomes a *pick* entry; ``placed`` distinguishes
    actual placements from infeasible/zero-benefit rejections.
    """

    def __init__(self, *, capacity: int = 1024) -> None:
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._ring: List[Optional[Dict[str, Any]]] = [None] * self.capacity
        self._n = 0               # monotone; slot = n % capacity
        self.evicted_records = 0
        self._open: Optional[Dict[str, Any]] = None
        self._emit_queue: List[Dict[str, Any]] = []

    # ------------------------------------------------------------------
    # record lifecycle (called from core pick loops / controllers)
    # ------------------------------------------------------------------
    def begin(self, *, tick: int = -1, seed: Optional[int] = None,
              algo: str = "egp") -> None:
        """Open a record; an already-open record is closed uncertified
        (standalone ``egp_np`` calls never see an explicit ``end``)."""
        if self._open is not None:
            self._commit(self._open)
        self._open = {
            "ledger_schema": LEDGER_SCHEMA_VERSION,
            "tick": int(tick), "seed": seed, "algo": algo,
            "picks": [],
        }

    def pick(self, *, edge: int, impl: int, benefit: float, gain: float,
             remaining: float, n_candidates: int, rank: int,
             placed: bool, bias: float = 0.0) -> None:
        """Book one greedy consideration (auto-opens a record)."""
        if self._open is None:
            self.begin()
        p: Dict[str, Any] = {
            "seq": len(self._open["picks"]),
            "edge": int(edge), "impl": int(impl),
            "benefit": float(benefit), "gain": float(gain),
            "remaining": float(remaining),
            "n_candidates": int(n_candidates), "rank": int(rank),
            "placed": bool(placed),
        }
        if bias:
            p["bias"] = float(bias)
        self._open["picks"].append(p)

    def end(self, *, sigma: Optional[float] = None,
            sigma_bound: Optional[float] = None) -> Dict[str, Any]:
        """Close the open record, attaching the certificate."""
        rec = self._open if self._open is not None else {
            "ledger_schema": LEDGER_SCHEMA_VERSION,
            "tick": -1, "seed": None, "algo": "egp", "picks": []}
        self._open = None
        self._commit(rec, sigma=sigma, sigma_bound=sigma_bound)
        return rec

    def _commit(self, rec: Dict[str, Any], *,
                sigma: Optional[float] = None,
                sigma_bound: Optional[float] = None) -> None:
        gains = [p["gain"] for p in rec["picks"] if p["placed"]]
        rec["n_picks"] = len(rec["picks"])
        rec["n_placed"] = len(gains)
        rec["gain_sum"] = float(sum(gains))
        # the live submodular gain curve: cumulative gain after each
        # placed pick — concave (non-increasing increments per edge)
        curve, acc = [], 0.0
        for g in gains:
            acc += g
            curve.append(acc)
        rec["gain_curve"] = curve
        if sigma is not None:
            rec["sigma"] = float(sigma)
        if sigma_bound is not None:
            rec["sigma_bound"] = float(sigma_bound)
            if sigma is not None:
                bound = float(sigma_bound)
                ratio = (float(sigma) / bound) if bound > 0 else 1.0
                rec["ratio"] = ratio
                rec["cert_ok"] = ratio >= ONE_MINUS_INV_E - CERT_TOL
        slot = self._n % self.capacity
        if self._ring[slot] is not None:
            self.evicted_records += 1
        self._ring[slot] = rec
        self._n += 1
        if len(self._emit_queue) >= self.capacity:
            self._emit_queue.pop(0)
        self._emit_queue.append(rec)

    # ------------------------------------------------------------------
    # reads / export
    # ------------------------------------------------------------------
    def records(self) -> List[Dict[str, Any]]:
        """Committed records, oldest first."""
        n = min(self._n, self.capacity)
        start = self._n - n
        return [self._ring[i % self.capacity]
                for i in range(start, self._n)]

    def record_for(self, tick: int) -> Optional[Dict[str, Any]]:
        """Latest committed record for ``tick``."""
        for rec in reversed(self.records()):
            if rec["tick"] == tick:
                return rec
        return None

    def drain_emits(self) -> List[Dict[str, Any]]:
        out, self._emit_queue = self._emit_queue, []
        return out

    def to_jsonl(self) -> str:
        return "".join(json.dumps(rec, sort_keys=True) + "\n"
                       for rec in self.records())

    def save(self, path: str) -> None:
        from .trace import _atomic_write_text
        _atomic_write_text(path, self.to_jsonl())


# ----------------------------------------------------------------------
# install / uninstall (wires the core's sink attribute)
# ----------------------------------------------------------------------
def _set_core_sink(led: Optional[DecisionLedger]) -> None:
    # obs → core import happens here, at enable time, never at import
    # time — the core stays free of any obs dependency and its
    # disabled-path cost is one attribute load + `is None`.
    from repro.core import placement
    placement._DECISION_SINK = led


def enable_ledger(*, capacity: int = 1024) -> DecisionLedger:
    """Install a fresh global :class:`DecisionLedger` and return it."""
    global _LEDGER
    _LEDGER = DecisionLedger(capacity=capacity)
    _set_core_sink(_LEDGER)
    return _LEDGER


def disable_ledger() -> Optional[DecisionLedger]:
    """Remove the global ledger; returns it for final export."""
    global _LEDGER
    led, _LEDGER = _LEDGER, None
    _set_core_sink(None)
    return led


def get_ledger() -> Optional[DecisionLedger]:
    return _LEDGER


def enable_ledger_from_env() -> Optional[DecisionLedger]:
    """``REPRO_OBS_LEDGER=<path>`` → ledger on, JSONL saved on exit."""
    path = os.environ.get("REPRO_OBS_LEDGER")
    if not path or _LEDGER is not None:
        return _LEDGER
    led = enable_ledger()

    def _save() -> None:
        if get_ledger() is led:
            led.save(path)

    atexit.register(_save)
    return led


def ingest_sparse_trace(led: DecisionLedger, trace: Dict[str, Any], *,
                        tick: int = -1, seed: Optional[int] = None,
                        sigma: Optional[float] = None,
                        sigma_bound: Optional[float] = None
                        ) -> Dict[str, Any]:
    """Convert an ``egp_place_sparse_jax(..., with_trace=True)`` trace
    into one ledger record. Picks are booked in lock-step order
    (iteration-major, then edge), rank 0 by construction (the sparse
    loop takes the per-edge benefit argmax). Gains were accumulated in
    f32 inside the kernel loop — their sum matches ``sigma_sparse_jnp``
    to f32 summation order (documented tolerance ~1e-3 relative)."""
    import numpy as np
    pick = np.asarray(trace["pick"])
    placed = np.asarray(trace["placed"])
    benefit = np.asarray(trace["benefit"])
    gain = np.asarray(trace["gain"])
    remaining = np.asarray(trace["remaining"])
    ncand = np.asarray(trace["n_candidates"])
    n_iters = int(trace.get("n_iters", pick.shape[0]))
    led.begin(tick=tick, seed=seed, algo="egp_sparse")
    E = pick.shape[1]
    for it in range(min(n_iters, pick.shape[0])):
        for e in range(E):
            p = int(pick[it, e])
            if p < 0:
                continue
            led.pick(edge=e, impl=p, benefit=float(benefit[it, e]),
                     gain=float(gain[it, e]),
                     remaining=float(remaining[it, e]),
                     n_candidates=int(ncand[it, e]), rank=0,
                     placed=bool(placed[it, e]))
    return led.end(sigma=sigma, sigma_bound=sigma_bound)


# ----------------------------------------------------------------------
# offline readers (CLI `why`)
# ----------------------------------------------------------------------
def load_ledger(path: str) -> List[Dict[str, Any]]:
    """Load ledger records — from a :meth:`DecisionLedger.save` JSONL
    file or a PR-7 stream file carrying ``ledger`` frames."""
    records: List[Dict[str, Any]] = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                continue
            if obj.get("type") == "ledger":       # stream frame
                obj = obj["payload"]
            if "ledger_schema" not in obj:
                continue
            have = obj["ledger_schema"]
            if have != LEDGER_SCHEMA_VERSION:
                raise ValueError(
                    f"unreadable ledger schema v{have} (this reader "
                    f"understands v{LEDGER_SCHEMA_VERSION})")
            records.append(obj)
    return records


def why_text(rec: Dict[str, Any], edge: Optional[int] = None) -> str:
    """Render one ledger record as the ``why`` pick table + gain curve."""
    picks = rec.get("picks", [])
    if edge is not None:
        picks = [p for p in picks if p["edge"] == edge]
    head = (f"placement tick={rec.get('tick')} algo={rec.get('algo')} "
            f"picks={rec.get('n_picks')} placed={rec.get('n_placed')}")
    if edge is not None:
        head += f" (edge {edge}: {len(picks)} pick(s))"
    lines = [head,
             f"  {'seq':>4} {'edge':>4} {'impl':>4} {'benefit':>10} "
             f"{'gain':>10} {'remaining':>10} {'cands':>5} {'rank':>4} "
             f"placed"]
    acc = 0.0
    for p in picks:
        if p["placed"]:
            acc += p["gain"]
        bias = f" bias={p['bias']:.3g}" if "bias" in p else ""
        lines.append(
            f"  {p['seq']:>4} {p['edge']:>4} {p['impl']:>4} "
            f"{p['benefit']:>10.4f} {p['gain']:>10.4f} "
            f"{p['remaining']:>10.3f} {p['n_candidates']:>5} "
            f"{p['rank']:>4} {'yes' if p['placed'] else 'no '}{bias}")
    curve = rec.get("gain_curve", [])
    if curve:
        lines.append("  gain curve: "
                     + " → ".join(f"{g:.4f}" for g in curve[:16])
                     + (" …" if len(curve) > 16 else ""))
    if "sigma" in rec:
        lines.append(f"  sigma(greedy) = {rec['sigma']:.6f}   "
                     f"gain_sum = {rec['gain_sum']:.6f}")
    if "sigma_bound" in rec and "ratio" in rec:
        ok = bool(rec.get("cert_ok"))
        verdict = ("OK" if ok else
                   "BELOW LINE (bound is a relaxation — investigate, "
                   "not necessarily a violation)")
        lines.append(
            f"  certificate: sigma/bound = {rec['sigma']:.4f}/"
            f"{rec['sigma_bound']:.4f} = {rec['ratio']:.4f} "
            f"{'≥' if ok else '<'} 1−1/e = {ONE_MINUS_INV_E:.4f} → "
            f"{verdict}")
    return "\n".join(lines)
