"""Declarative SLOs over live telemetry + the benchmark regression gate.

The paper's objective (Eq. 1) is a QoS target; this module makes targets
*explicit and enforceable*: an :class:`SLO` declares a bound on a metric
(deadline-miss rate, p99 latency, queue depth, obs overhead), and
:func:`evaluate_slos` checks it over a sliding window of live stream
frames (:mod:`repro.obs.stream`), a saved metrics snapshot, or a
``benchmarks/run.py --json`` document — emitting a **burn rate** (the
fraction of the error budget the observed value consumes; > 1 means the
SLO is burning) rather than a bare pass/fail, so dashboards can show
*how close* the system is running to its bounds.

Metric selectors (the ``metric`` field):

- ``tick.<field>`` — a field of ``tick`` stream frames (``miss_rate``,
  ``queue_depth``, ``window_qos``, ...), aggregated over the sliding
  ``window_s`` by ``agg`` (mean/max/min/last);
- ``gateway.<field>`` — same windowed aggregation over ``gateway``
  frames (the live control plane's per-tick operational stats:
  ``ingress_depth``, ``loop_lag_ms``, ``admitted``, ...);
- ``hist.<name>.<pXX|mean|count>`` — a digest of the named histogram,
  merged across label sets, from a ``metrics`` frame or snapshot records;
- ``counter.<name>`` — a tracer counter value;
- ``bench.<row>.<field>`` — a field of a benchmark row (``bench.
  obs_overhead.disabled_pct`` is the obs-overhead budget gate).

The second half is the regression gate: :func:`compare_bench` diffs two
``benchmarks/run.py --json`` documents row by row — quality fields
(ratios, QoS, miss rates; anything not timing-suffixed) within
``atol + rtol·|base|``, timings within a ``max_slowdown`` factor — and
``benchmarks/run.py --compare BENCH_baseline.json`` exits nonzero on any
violation, which is what turns the committed baseline into CI's closed
regression loop over the accuracy/latency trade-off axis
(arXiv:2011.08381).
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence

from .metrics import Histogram, MetricsRegistry

__all__ = [
    "SLO_SCHEMA_VERSION",
    "SLO",
    "SLOReport",
    "DEFAULT_SLOS",
    "load_slos",
    "evaluate_slos",
    "compare_bench",
]

#: Version stamp of the SLO spec file format.
SLO_SCHEMA_VERSION = 1

#: Field-name suffixes treated as machine-dependent timings/throughputs in
#: :func:`compare_bench` — bounded by ``max_slowdown``, never by the tight
#: quality tolerance. Everything else in a row's ``fields`` is a quality
#: number (ratio, QoS, count) and must reproduce within tolerance.
TIMING_SUFFIXES = ("_us", "_ns", "_ms", "_per_s", "_pct")


@dataclasses.dataclass(frozen=True)
class SLO:
    """One declarative objective: a bound on a metric over a window."""

    name: str
    metric: str
    max_value: Optional[float] = None
    min_value: Optional[float] = None
    #: sliding window (seconds of frame wall time) for ``tick.*`` metrics
    window_s: float = 60.0
    #: aggregation over windowed samples: mean / max / min / last
    agg: str = "mean"

    def __post_init__(self):
        if (self.max_value is None) == (self.min_value is None):
            raise ValueError(f"SLO {self.name!r}: exactly one of "
                             f"max_value/min_value must be set")
        if self.agg not in ("mean", "max", "min", "last"):
            raise ValueError(f"SLO {self.name!r}: unknown agg {self.agg!r}")

    def to_json(self) -> Dict[str, Any]:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}


@dataclasses.dataclass
class SLOReport:
    """One evaluated SLO: observed value, verdict, burn rate."""

    slo: SLO
    value: float          # NaN when the metric had no samples
    n_samples: int
    ok: bool              # vacuously True on no data (reported as n=0)
    #: budget consumption: observed/bound for max-SLOs, bound/observed
    #: for min-SLOs — 1.0 is exactly at the objective, > 1 is violating
    burn_rate: float

    def line(self) -> str:
        state = "OK " if self.ok else "FAIL"
        val = "n/a" if math.isnan(self.value) else f"{self.value:.4g}"
        bound = (f"<= {self.slo.max_value:g}"
                 if self.slo.max_value is not None
                 else f">= {self.slo.min_value:g}")
        burn = "" if math.isnan(self.burn_rate) \
            else f"  burn {self.burn_rate:.2f}"
        return (f"[{state}] {self.slo.name:<24} {self.slo.metric:<32} "
                f"{val:>10} {bound:>12}{burn}  (n={self.n_samples})")


#: The serving defaults: explicit versions of what the README promises.
DEFAULT_SLOS = (
    SLO("deadline-miss-rate", "tick.miss_rate", max_value=0.75),
    SLO("queue-depth", "tick.queue_depth", max_value=4096, agg="max"),
    SLO("p99-latency", "hist.serving.latency_s.p99", max_value=30.0),
    SLO("obs-overhead", "bench.obs_overhead.disabled_pct", max_value=3.0),
    # Sparse-placement guarantees: the top-k candidate path must match the
    # float64 host evaluator on paper-scale instances, and the candidate
    # representation must actually buy its claimed memory headroom.
    SLO("placement-parity", "bench.placement_scale.rel_diff_paper",
        max_value=1e-4),
    SLO("placement-mem-ratio", "bench.placement_scale.mem_ratio_u1k",
        min_value=10.0),
    # Live control plane (repro.gateway): the event loop must hit its
    # tick deadlines, requests must clear ingest promptly, and the
    # ingress queue must stay bounded. Histogram selectors read the
    # gateway's periodic ``metrics`` frames; the depth bound reads the
    # per-tick ``gateway`` frames. All three are vacuously ok (n=0)
    # when no gateway is running.
    SLO("gateway-loop-lag-p99", "hist.gateway.loop_lag_ms.p99",
        max_value=250.0),
    SLO("gateway-admission-p99", "hist.gateway.admission_ms.p99",
        max_value=500.0),
    SLO("gateway-ingress-depth", "gateway.ingress_depth",
        max_value=4096, agg="max"),
)


def load_slos(path) -> List[SLO]:
    """Load a versioned SLO spec file: ``{"slo_schema": 1, "slos": [...]}``."""
    doc = json.loads(Path(path).read_text())
    have = int(doc.get("slo_schema", -1))
    if have != SLO_SCHEMA_VERSION:
        raise ValueError(f"{path}: slo spec schema v{have}, this code "
                         f"reads v{SLO_SCHEMA_VERSION}")
    return [SLO(**spec) for spec in doc.get("slos", [])]


def _windowed(frames: Sequence[Mapping[str, Any]], field: str,
              window_s: float, type_: str = "tick") -> List[float]:
    ticks = [f for f in frames if f.get("type") == type_
             and field in f.get("payload", {})]
    if not ticks:
        return []
    latest = max(float(f.get("t", 0.0)) for f in ticks)
    out = []
    for f in ticks:
        if latest - float(f.get("t", 0.0)) <= window_s:
            v = f["payload"][field]
            if v is not None and not (isinstance(v, float) and math.isnan(v)):
                out.append(float(v))
    return out


def _merged_histogram(metrics_records: Iterable[Mapping[str, Any]],
                      name: str) -> Optional[Histogram]:
    merged: Optional[Histogram] = None
    for rec in metrics_records:
        if rec.get("kind") != "histogram" or rec.get("name") != name:
            continue
        h = Histogram.from_record(rec)
        merged = h if merged is None else merged.merge(h)
    return merged


def _latest_metrics(frames: Sequence[Mapping[str, Any]]
                    ) -> Mapping[str, Any]:
    for f in reversed(frames):
        if f.get("type") == "metrics":
            return f.get("payload", {})
    return {}


def _resolve(slo: SLO, frames: Sequence[Mapping[str, Any]],
             metrics: Iterable[Mapping[str, Any]],
             counters: Mapping[str, float],
             bench: Optional[Mapping[str, Any]]
             ) -> tuple:
    """(value, n_samples) for one SLO against the supplied sources."""
    metric = slo.metric
    for prefix in ("tick.", "gateway."):
        if metric.startswith(prefix):
            samples = _windowed(frames, metric[len(prefix):],
                                slo.window_s, type_=prefix[:-1])
            if not samples:
                return float("nan"), 0
            agg = {"mean": lambda s: sum(s) / len(s), "max": max,
                   "min": min, "last": lambda s: s[-1]}[slo.agg]
            return float(agg(samples)), len(samples)
    if metric.startswith("hist."):
        name, _, digest = metric[len("hist."):].rpartition(".")
        h = _merged_histogram(metrics, name)
        if h is None or h.count == 0:
            return float("nan"), 0
        if digest.startswith("p"):
            return h.quantile(int(digest[1:]) / 100.0), h.count
        return float(getattr(h, digest)), h.count
    if metric.startswith("counter."):
        name = metric[len("counter."):]
        if name not in counters:
            return float("nan"), 0
        return float(counters[name]), 1
    if metric.startswith("bench."):
        if bench is None:
            return float("nan"), 0
        row_name, _, field = metric[len("bench."):].rpartition(".")
        for row in bench.get("rows", []):
            if row.get("name") == row_name:
                v = row["fields"].get(field) if field != "us_per_call" \
                    else row.get("us_per_call")
                if isinstance(v, (int, float)):
                    return float(v), 1
                return float("nan"), 0
        return float("nan"), 0
    raise ValueError(f"SLO {slo.name!r}: unknown metric selector "
                     f"{metric!r}")


def evaluate_slos(slos: Iterable[SLO], *,
                  frames: Sequence[Mapping[str, Any]] = (),
                  metrics: Optional[Iterable[Mapping[str, Any]]] = None,
                  counters: Optional[Mapping[str, float]] = None,
                  bench: Optional[Mapping[str, Any]] = None
                  ) -> List[SLOReport]:
    """Evaluate SLOs against stream frames / metric records / bench JSON.

    When ``metrics``/``counters`` aren't passed explicitly they are taken
    from the latest ``metrics`` frame in ``frames`` — the live-stream
    path. An SLO whose metric has no data reports ``n_samples == 0`` and
    stays ``ok`` (absence of traffic is not a violation; the dashboard
    shows the n=0 so it is never silent).
    """
    frames = list(frames)
    latest = _latest_metrics(frames)
    metric_records = list(metrics) if metrics is not None \
        else list(latest.get("metrics", []))
    counter_map = dict(counters) if counters is not None \
        else dict(latest.get("counters", {}))
    out: List[SLOReport] = []
    for slo in slos:
        value, n = _resolve(slo, frames, metric_records, counter_map,
                            bench)
        if n == 0 or math.isnan(value):
            out.append(SLOReport(slo, float("nan"), 0, True, float("nan")))
            continue
        if slo.max_value is not None:
            ok = value <= slo.max_value
            burn = value / slo.max_value if slo.max_value != 0 \
                else math.inf * (1 if value > 0 else 0)
        else:
            ok = value >= slo.min_value
            burn = slo.min_value / value if value != 0 else math.inf
        out.append(SLOReport(slo, value, n, bool(ok), float(burn)))
    return out


# ===========================================================================
# Benchmark regression gate
# ===========================================================================

def _is_timing_field(name: str) -> bool:
    return name.endswith(TIMING_SUFFIXES)


def compare_bench(new: Mapping[str, Any], base: Mapping[str, Any], *,
                  max_slowdown: float = 4.0, rtol: float = 0.12,
                  atol: float = 0.02,
                  rows: Optional[Iterable[str]] = None) -> Dict[str, Any]:
    """Diff two ``benchmarks/run.py --json`` documents row by row.

    For every row name present in both documents (restricted to ``rows``
    when given): ``us_per_call`` and timing-suffixed fields may not exceed
    ``max_slowdown ×`` the baseline (machine variance is expected; an
    order-of-magnitude cliff is not); every other shared numeric field is
    a quality number and must satisfy ``|new − base| ≤ atol + rtol·|base|``
    in *both* directions — a "better" ratio that moved outside tolerance
    still fails, because it means the benchmark no longer measures the
    same thing. Returns ``{"violations": [...], "rows_checked": [...],
    "fields_checked": n}``; an empty violation list is a pass.
    """
    want = set(rows) if rows is not None else None
    base_rows = {r["name"]: r for r in base.get("rows", [])}
    violations: List[str] = []
    checked_rows: List[str] = []
    n_fields = 0
    for row in new.get("rows", []):
        name = row["name"]
        if want is not None and name not in want:
            continue
        ref = base_rows.get(name)
        if ref is None:
            continue
        checked_rows.append(name)
        b_us, n_us = float(ref["us_per_call"]), float(row["us_per_call"])
        n_fields += 1
        if b_us > 0 and n_us > b_us * max_slowdown:
            violations.append(
                f"{name}: us_per_call {n_us:.1f} > {max_slowdown:g}x "
                f"baseline {b_us:.1f}")
        ref_fields = ref.get("fields", {})
        for field, new_v in row.get("fields", {}).items():
            base_v = ref_fields.get(field)
            if not isinstance(new_v, (int, float)) or \
                    not isinstance(base_v, (int, float)):
                continue
            n_fields += 1
            if _is_timing_field(field):
                if base_v > 0 and new_v > base_v * max_slowdown:
                    violations.append(
                        f"{name}.{field}: {new_v:.4g} > {max_slowdown:g}x "
                        f"baseline {base_v:.4g}")
                continue
            if abs(new_v - base_v) > atol + rtol * abs(base_v):
                violations.append(
                    f"{name}.{field}: {new_v:.4g} vs baseline "
                    f"{base_v:.4g} (tol {atol + rtol * abs(base_v):.4g})")
    if want is not None:
        missing = sorted(want - set(checked_rows))
        for name in missing:
            violations.append(f"row {name}: requested for comparison but "
                              f"missing from new run or baseline")
    return {"violations": violations, "rows_checked": checked_rows,
            "fields_checked": n_fields}
