"""Per-request causal tracing — the "explain this request" layer.

A :class:`RequestTrace` record follows one user request through its
whole life on the control plane::

    receipt → admit → place → route → queue → execute
                                    ↘ requeue ↗   ↘ drop
                                            complete

Requests are keyed by ``(seed, tick, uid)`` — ``uid`` is the
horizon-global request id assigned by
:class:`~repro.serving.horizon.TickController` (globally unique and
advanced even for dropped users, so the key is stable between the live
gateway and an offline replay of the same seeded trace).

Design rules, inherited from the PR-6 tracer:

* **Off by default, observational always.** Every hook site reads one
  module global (``_REQTRACER``) and bails on ``None`` — the disabled
  path is a single load + identity check, within the existing ~0.25 µs
  span budget. Enabled or not, hooks only *read* control-plane state;
  stores, TickReports, and gateway digests stay byte-identical.
* **Preallocated ring storage.** Finished traces land in a
  fixed-capacity ring (oldest evicted, eviction counted) so a long
  soak cannot grow memory without bound.
* **Deterministic tail-based sampling.** At completion a trace is kept
  iff it is *special* — deadline miss, drop, requeue, or a latency at
  or above the tracer's own running p99 — or its uid survives a seeded
  multiplicative hash (``sample_every`` knob). No wall clock, no RNG:
  the same (config, seed, trace) keeps the identical uid set across
  runs and across gateway-vs-offline replay.

Exported artifacts are JSON documents versioned by
:data:`REQTRACE_SCHEMA_VERSION`; kept traces also ride the PR-7 stream
protocol as ``reqtrace`` frames (unknown frame types are ignored by
older readers, so the wire version does not move).
"""
from __future__ import annotations

import atexit
import json
import os
from typing import Any, Dict, Iterable, List, Optional, Tuple

from .metrics import Histogram

__all__ = [
    "REQTRACE_SCHEMA_VERSION",
    "STAGES",
    "RequestTracer",
    "enable_request_tracing",
    "disable_request_tracing",
    "get_request_tracer",
    "enable_reqtrace_from_env",
    "load_reqtrace",
    "explain_uid",
]

#: Version stamp of the request-trace export document.
REQTRACE_SCHEMA_VERSION = 1

#: Canonical lifecycle stages, in causal order. ``receipt`` only exists
#: for wall-clock gateway runs (socket receipt time); offline horizons
#: start at ``admit``.
STAGES = ("receipt", "admit", "place", "route", "queue",
          "execute", "requeue", "drop", "complete")

# Fibonacci-hashing multiplier (golden-ratio constant) — uid bits are
# sequential, so plain modulo would sample one contiguous block per
# tick; the multiply decorrelates uid from keep decision.
_HASH_MULT = 0x9E3779B97F4A7C15
_HASH_MASK = (1 << 64) - 1

#: The one module-global hook target. Hot paths read this directly
#: (``rt = reqtrace._REQTRACER``) so the disabled cost is one global
#: load + ``is None``.
_REQTRACER: Optional["RequestTracer"] = None


class RequestTracer:
    """Collects per-request lifecycle events with tail-based sampling.

    Parameters
    ----------
    capacity:
        Ring size for *kept* (finished, sampled-in) traces.
    sample_every:
        Keep roughly 1-in-N of the non-special tail by seeded uid
        hash. ``1`` keeps everything, ``0`` keeps only special traces
        (misses / drops / requeues / p99 outliers).
    salt:
        Seed folded into the hash — set from the horizon seed so
        replaying the same trace reproduces the same sampled uid set.
    exemplars_per_bucket:
        How many uids a histogram bucket links to (first-N, see
        :meth:`repro.obs.metrics.Histogram.observe`).
    """

    def __init__(self, *, capacity: int = 4096, sample_every: int = 16,
                 salt: int = 0, exemplars_per_bucket: int = 2) -> None:
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        if sample_every < 0:
            raise ValueError(
                f"sample_every must be >= 0, got {sample_every}")
        self.capacity = int(capacity)
        self.sample_every = int(sample_every)
        self.salt = int(salt)
        self.exemplars_per_bucket = int(exemplars_per_bucket)
        self.seed: Optional[int] = None
        # in-flight traces: uid -> mutable record
        self._pending: Dict[int, Dict[str, Any]] = {}
        # finished + kept traces on a preallocated ring
        self._ring: List[Optional[Dict[str, Any]]] = [None] * self.capacity
        self._n_kept = 0          # monotone; slot = n % capacity
        self.evicted_records = 0  # kept traces overwritten by the ring
        self.discarded = 0        # finished traces sampled out
        # the tracer's own latency view — drives the p99-outlier rule
        self._lat_hist = Histogram()
        # per-tick placement-epoch context for `explain`
        self._epochs: Dict[int, Dict[str, Any]] = {}
        # kept-trace queue for per-tick stream emission (drained by the
        # controller; bounded by the same capacity)
        self._emit_queue: List[Dict[str, Any]] = []

    # ------------------------------------------------------------------
    # lifecycle hooks (all observational)
    # ------------------------------------------------------------------
    def set_context(self, seed: int) -> None:
        """Bind the horizon seed (also folds it into the sample hash)."""
        if self.seed is None:
            self.seed = int(seed)
            self.salt = (self.salt + int(seed)) & _HASH_MASK

    def _rec(self, uid: int) -> Dict[str, Any]:
        rec = self._pending.get(uid)
        if rec is None:
            rec = {"uid": int(uid), "tick": -1, "events": [],
                   "missed": False, "dropped": False, "requeued": False}
            self._pending[uid] = rec
        return rec

    def event(self, uid: int, stage: str, t: float,
              **detail: Any) -> None:
        """Record one lifecycle event at simulation time ``t``."""
        ev: Dict[str, Any] = {"stage": stage, "t": float(t)}
        if detail:
            ev.update(detail)
        self._rec(uid)["events"].append(ev)

    def admit(self, uid: int, tick: int, *, edge: int, service: int,
              alpha: float, delta: float, arrival: float) -> None:
        rec = self._rec(uid)
        rec["tick"] = int(tick)
        rec["edge"] = int(edge)
        rec["service"] = int(service)
        rec["alpha"] = float(alpha)
        rec["delta"] = float(delta)
        rec["events"].append({"stage": "admit", "t": float(arrival),
                              "tick": int(tick)})

    def route(self, uid: int, t: float, *, impl: int, q: float,
              candidates: Iterable[Tuple[int, float]] = ()) -> None:
        """OMS picked ``impl``; ``candidates`` are the rejected
        runners-up as ``(impl, qos)`` pairs, best first."""
        self.event(uid, "route", t, impl=int(impl), q=float(q),
                   rejected=[[int(p), float(v)] for p, v in candidates])

    def requeue(self, uid: int, t: float, *, impl: int) -> None:
        rec = self._rec(uid)
        rec["requeued"] = True
        rec["events"].append({"stage": "requeue", "t": float(t),
                              "impl": int(impl)})

    def execute(self, uid: int, t: float, *, wait_s: float) -> None:
        self.event(uid, "execute", t, wait_s=float(wait_s))

    def drop(self, uid: int, t: float, *, reason: str) -> None:
        """Terminal: the request could not be served. Always kept."""
        rec = self._rec(uid)
        rec["dropped"] = True
        rec["events"].append({"stage": "drop", "t": float(t),
                              "reason": reason})
        self._finish(uid, rec)

    def complete(self, uid: int, t: float, *, latency: float,
                 missed: bool) -> None:
        """Terminal: the request finished executing."""
        rec = self._rec(uid)
        rec["missed"] = bool(missed)
        rec["latency_s"] = float(latency)
        rec["events"].append({"stage": "complete", "t": float(t),
                              "latency_s": float(latency),
                              "missed": bool(missed)})
        # observe-then-test: with one sample the p99 is that sample, so
        # early completions over-keep — deterministic, and exactly what
        # a tail sampler warming up should do.
        self._lat_hist.observe(latency)
        self._finish(uid, rec)

    def epoch(self, tick: int, **info: Any) -> None:
        """Record placement-epoch context (σ, loads, …) for a tick."""
        self._epochs[int(tick)] = {k: v for k, v in info.items()}

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def _hash_keep(self, uid: int) -> bool:
        if self.sample_every == 0:
            return False
        if self.sample_every == 1:
            return True
        h = ((uid * _HASH_MULT) + self.salt) & _HASH_MASK
        return (h >> 32) % self.sample_every == 0

    def keep_reason(self, rec: Dict[str, Any]) -> Optional[str]:
        """Why this finished trace is kept (``None`` → sampled out)."""
        if rec.get("dropped"):
            return "dropped"
        if rec.get("missed"):
            return "deadline_miss"
        if rec.get("requeued"):
            return "requeued"
        lat = rec.get("latency_s")
        if lat is not None and self._lat_hist.count > 0:
            if lat >= self._lat_hist.quantile(0.99):
                return "p99_outlier"
        if self._hash_keep(rec["uid"]):
            return "sampled"
        return None

    def _finish(self, uid: int, rec: Dict[str, Any]) -> None:
        self._pending.pop(uid, None)
        reason = self.keep_reason(rec)
        if reason is None:
            self.discarded += 1
            return
        rec["keep_reason"] = reason
        slot = self._n_kept % self.capacity
        if self._ring[slot] is not None:
            self.evicted_records += 1
        self._ring[slot] = rec
        self._n_kept += 1
        if len(self._emit_queue) >= self.capacity:
            self._emit_queue.pop(0)
        self._emit_queue.append(rec)

    # ------------------------------------------------------------------
    # reads / export
    # ------------------------------------------------------------------
    def kept(self) -> List[Dict[str, Any]]:
        """Kept traces, oldest first."""
        n = min(self._n_kept, self.capacity)
        start = self._n_kept - n
        return [self._ring[i % self.capacity]
                for i in range(start, self._n_kept)]

    def kept_uids(self) -> List[int]:
        return [rec["uid"] for rec in self.kept()]

    def trace(self, uid: int) -> Optional[Dict[str, Any]]:
        """Look up one trace by uid (kept ring, then in-flight)."""
        for rec in self.kept():
            if rec["uid"] == uid:
                return rec
        return self._pending.get(uid)

    def drain_emits(self) -> List[Dict[str, Any]]:
        """Kept traces since the last drain (for stream emission)."""
        out, self._emit_queue = self._emit_queue, []
        return out

    def exemplar(self, uid: int, tick: int) -> Dict[str, int]:
        """The histogram-exemplar payload linking a bucket to a trace."""
        return {"uid": int(uid), "tick": int(tick)}

    def snapshot(self) -> Dict[str, Any]:
        return {
            "reqtrace_schema": REQTRACE_SCHEMA_VERSION,
            "seed": self.seed,
            "sample_every": self.sample_every,
            "capacity": self.capacity,
            "kept": self._n_kept,
            "discarded": self.discarded,
            "evicted_records": self.evicted_records,
            "pending": len(self._pending),
            "records": self.kept(),
            "epochs": {str(t): info
                       for t, info in sorted(self._epochs.items())},
        }

    def save(self, path: str) -> None:
        from .trace import _atomic_write_text
        _atomic_write_text(
            path, json.dumps(self.snapshot(), sort_keys=True))


# ----------------------------------------------------------------------
# module-level enable/disable (mirrors repro.obs.trace)
# ----------------------------------------------------------------------
def enable_request_tracing(*, capacity: int = 4096, sample_every: int = 16,
                           salt: int = 0,
                           exemplars_per_bucket: int = 2) -> RequestTracer:
    """Install a fresh global :class:`RequestTracer` and return it."""
    global _REQTRACER
    _REQTRACER = RequestTracer(capacity=capacity,
                               sample_every=sample_every, salt=salt,
                               exemplars_per_bucket=exemplars_per_bucket)
    return _REQTRACER


def disable_request_tracing() -> Optional[RequestTracer]:
    """Remove the global tracer; returns it for final export."""
    global _REQTRACER
    rt, _REQTRACER = _REQTRACER, None
    return rt


def get_request_tracer() -> Optional[RequestTracer]:
    return _REQTRACER


def enable_reqtrace_from_env() -> Optional[RequestTracer]:
    """``REPRO_OBS_REQTRACE=<path>`` → trace and save on exit.

    ``REPRO_OBS_REQTRACE_SAMPLE`` overrides ``sample_every``.
    """
    path = os.environ.get("REPRO_OBS_REQTRACE")
    if not path or _REQTRACER is not None:
        return _REQTRACER
    sample = int(os.environ.get("REPRO_OBS_REQTRACE_SAMPLE", "16"))
    rt = enable_request_tracing(sample_every=sample)

    def _save() -> None:
        if get_request_tracer() is rt:
            rt.save(path)

    atexit.register(_save)
    return rt


# ----------------------------------------------------------------------
# offline readers (CLI `explain`)
# ----------------------------------------------------------------------
def load_reqtrace(path: str) -> Dict[str, Any]:
    """Load a request-trace artifact — either a :meth:`snapshot` JSON
    document or a PR-7 stream file carrying ``reqtrace`` frames."""
    with open(path, "r", encoding="utf-8") as f:
        head = f.read(1)
        f.seek(0)
        if head == "{":
            first = json.loads(f.readline())
            if "reqtrace_schema" in first:
                have = first["reqtrace_schema"]
                if have != REQTRACE_SCHEMA_VERSION:
                    raise ValueError(
                        f"unreadable reqtrace schema v{have} "
                        f"(this reader understands "
                        f"v{REQTRACE_SCHEMA_VERSION})")
                return first
            # else: fall through to stream parsing (first line was a
            # stream frame, also a JSON object)
        records: List[Dict[str, Any]] = []
        epochs: Dict[str, Any] = {}
        seed = None
        f.seek(0)
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                frame = json.loads(line)
            except json.JSONDecodeError:
                continue
            if frame.get("type") == "reqtrace":
                records.append(frame["payload"])
            elif frame.get("type") == "ledger":
                pass  # ledger frames live in repro.obs.ledger
            elif frame.get("type") == "hello":
                seed = frame.get("payload", {}).get("seed", seed)
    return {"reqtrace_schema": REQTRACE_SCHEMA_VERSION, "seed": seed,
            "records": records, "epochs": epochs}


def explain_uid(doc: Dict[str, Any], uid: int,
                tick: Optional[int] = None) -> str:
    """Render the full causal chain of one sampled uid as text."""
    recs = [r for r in doc.get("records", []) if r.get("uid") == uid]
    if tick is not None:
        recs = [r for r in recs if r.get("tick") == tick]
    if not recs:
        where = f"uid {uid}" + (f" tick {tick}" if tick is not None
                                else "")
        raise ValueError(
            f"no sampled trace for {where} — it may have been sampled "
            f"out (raise the keep rate with sample_every=1) or never "
            f"admitted")
    rec = recs[-1]
    order = {s: i for i, s in enumerate(STAGES)}
    events = sorted(rec.get("events", []),
                    key=lambda e: (e["t"], order.get(e["stage"], 99)))
    lines = [f"request uid={rec['uid']} tick={rec.get('tick')} "
             f"edge={rec.get('edge', '?')} "
             f"service={rec.get('service', '?')} "
             f"alpha={rec.get('alpha', float('nan')):.3f} "
             f"delta={rec.get('delta', float('nan')):.3f}s "
             f"[kept: {rec.get('keep_reason', '?')}]"]
    epoch = doc.get("epochs", {}).get(str(rec.get("tick")))
    if epoch:
        lines.append(
            f"  placement epoch t={rec.get('tick')}: "
            + " ".join(f"{k}={v}" for k, v in sorted(epoch.items())))
    t0 = events[0]["t"] if events else 0.0
    for ev in events:
        extra = {k: v for k, v in ev.items()
                 if k not in ("stage", "t")}
        parts = [f"  {ev['t']:12.6f}s  +{ev['t'] - t0:8.6f}s  "
                 f"{ev['stage']:<8}"]
        if ev["stage"] == "route" and "rejected" in extra:
            rej = extra.pop("rejected")
            parts.append(f"impl={extra.pop('impl')} "
                         f"q={extra.pop('q'):.4f} rejected=["
                         + ", ".join(f"impl {p} (q={v:.4f})"
                                     for p, v in rej) + "]")
        if extra:
            parts.append(" ".join(
                f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in sorted(extra.items())))
        lines.append(" ".join(parts))
    flags = [f for f in ("missed", "dropped", "requeued")
             if rec.get(f)]
    if flags:
        lines.append(f"  flags: {', '.join(flags)}")
    if "latency_s" in rec:
        lines.append(f"  latency: {rec['latency_s'] * 1e3:.3f} ms "
                     f"(deadline {rec.get('delta', 0.0) * 1e3:.1f} ms)")
    return "\n".join(lines)
