"""Determinism-safe span/event tracer with a preallocated ring buffer.

The tracer is the timing half of :mod:`repro.obs`: nestable
``span("tick.place")`` context managers, monotonic counters, and
timestamped gauge samples, all recorded into preallocated NumPy ring
buffers so the *enabled* hot path allocates nothing but one small span
handle and the *disabled* path is a single module-global load, a ``None``
check, and a slotted no-op context manager — measured in the tens of
nanoseconds per span (see ``tests/test_obs.py`` and the
``benchmarks/serving_horizon.py`` overhead row).

Hard invariant (the reason this module exists at all): tracing is
**observational only**. Nothing here feeds back into placement, routing,
scheduling, or sweep values — enabling the tracer changes no stored byte
of any :class:`~repro.sweeps.store.SweepStore` and no field of any
``TickReport``. Everything is **off by default**; a process opts in via
:func:`enable`, a CLI ``--obs`` flag, or the ``REPRO_OBS`` environment
variable (see :func:`enable_from_env`).

Artifacts: :meth:`Tracer.snapshot` serializes the buffers into a
versioned JSON document (``obs_schema`` :data:`OBS_SCHEMA_VERSION`);
:func:`to_chrome_trace` converts any such document into Chrome-trace /
Perfetto JSON (open ``chrome://tracing`` or https://ui.perfetto.dev and
load the file). ``python -m repro.obs`` wraps report/export/tail around
the same documents.

When the owning :class:`Tracer` was enabled with ``jax_annotations=True``
every span additionally enters a ``jax.profiler.TraceAnnotation`` of the
same name, so obs spans appear on the JAX profiler / XLA timeline too
(see :mod:`repro.obs.jaxprof`).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Mapping, Optional

import numpy as np

from .metrics import MetricsRegistry

__all__ = [
    "OBS_SCHEMA_VERSION",
    "READABLE_OBS_SCHEMAS",
    "DEFAULT_CAPACITY",
    "Tracer",
    "enable",
    "disable",
    "enabled",
    "get_tracer",
    "span",
    "count",
    "sample",
    "save",
    "enable_from_env",
    "load_artifact",
    "to_chrome_trace",
    "validate_chrome_trace",
]

#: Version stamp of the raw obs artifact (``Tracer.snapshot()`` output).
#: v2 added the ``anchor`` wall/monotonic clock pair that lets
#: :mod:`repro.obs.aggregate` align traces from different processes onto
#: one timeline; v1 artifacts still load (stitching then falls back to
#: fleet telemetry heartbeat anchors, or start-alignment).
OBS_SCHEMA_VERSION = 2

#: Artifact schema versions :func:`load_artifact` accepts.
READABLE_OBS_SCHEMAS = (1, 2)

#: Default ring-buffer capacity (spans and gauge samples each). At ~26
#: bytes/span this is ~1.7 MB of preallocated buffer — hours of per-tick
#: serving spans before the ring wraps (wraps drop the *oldest* records
#: and are counted, never silently).
DEFAULT_CAPACITY = 65536

_ENV_FLAG = "REPRO_OBS"
_ENV_DIR = "REPRO_OBS_DIR"


class _NullSpan:
    """The disabled-path span: one shared, stateless, slotted no-op."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Live span handle; records into the tracer's ring on ``__exit__``."""

    __slots__ = ("_tracer", "_name_id", "_args", "_t0", "_jax_ctx")

    def __init__(self, tracer: "Tracer", name_id: int,
                 args: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self._name_id = name_id
        self._args = args
        self._t0 = 0
        self._jax_ctx = None

    def __enter__(self) -> "_Span":
        tr = self._tracer
        if tr._jax_ann is not None:
            self._jax_ctx = tr._jax_ann(tr._names[self._name_id])
            self._jax_ctx.__enter__()
        tr._depth_of(threading.get_ident())  # ensure tid registered
        local = tr._local
        local.depth = getattr(local, "depth", 0) + 1
        self._t0 = tr._clock()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self._tracer._clock()
        tr = self._tracer
        local = tr._local
        depth = getattr(local, "depth", 1)
        local.depth = depth - 1
        tr._record(self._name_id, self._t0, t1, depth - 1, self._args)
        if self._jax_ctx is not None:
            self._jax_ctx.__exit__(*(exc or (None, None, None)))
        return False


class Tracer:
    """Span/counter/gauge recorder over preallocated ring buffers.

    ``clock`` is injectable (defaults to :func:`time.perf_counter_ns`) so
    tests can drive a deterministic fake clock and golden-test the export
    byte-for-byte. ``jax_annotations=True`` mirrors every span into a
    ``jax.profiler.TraceAnnotation`` (no-op when JAX's profiler isn't
    collecting), putting obs spans on the JAX/Perfetto timeline next to
    Pallas kernel time.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY, *,
                 clock: Optional[Callable[[], int]] = None,
                 jax_annotations: bool = False):
        self.capacity = int(capacity)
        if self.capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self._clock = clock or time.perf_counter_ns
        self._lock = threading.Lock()
        self._local = threading.local()
        # interned span/gauge names
        self._names: List[str] = []
        self._name_ids: Dict[str, int] = {}
        # span ring: parallel preallocated arrays, slot = n % capacity
        self._s_name = np.zeros(self.capacity, np.int32)
        self._s_t0 = np.zeros(self.capacity, np.int64)
        self._s_t1 = np.zeros(self.capacity, np.int64)
        self._s_tid = np.zeros(self.capacity, np.int32)
        self._s_depth = np.zeros(self.capacity, np.int16)
        self._s_args: Dict[int, Dict[str, Any]] = {}  # slot -> args
        self._n_spans = 0   # total ever recorded (>= capacity ⇒ wrapped)
        # gauge-sample ring (timeline counters: queue depth, QoS, ...)
        self._g_name = np.zeros(self.capacity, np.int32)
        self._g_t = np.zeros(self.capacity, np.int64)
        self._g_val = np.zeros(self.capacity, np.float64)
        self._n_gauges = 0
        # monotonic counters + the metrics registry (histograms/gauges)
        self.counters: Dict[str, float] = {}
        self.metrics = MetricsRegistry()
        # small-int thread ids, stable within this tracer
        self._tids: Dict[int, int] = {}
        self._jax_ann = None
        if jax_annotations:
            try:
                from jax.profiler import TraceAnnotation
                self._jax_ann = TraceAnnotation
            except Exception:  # pragma: no cover - jax-less install
                self._jax_ann = None

    # -- recording ---------------------------------------------------------
    def _depth_of(self, ident: int) -> int:
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _intern(self, name: str) -> int:
        name_id = self._name_ids.get(name)
        if name_id is None:
            with self._lock:
                name_id = self._name_ids.get(name)
                if name_id is None:
                    name_id = len(self._names)
                    self._names.append(name)
                    self._name_ids[name] = name_id
        return name_id

    def span(self, name: str, args: Optional[Dict[str, Any]] = None
             ) -> _Span:
        return _Span(self, self._intern(name), args)

    def _record(self, name_id: int, t0: int, t1: int, depth: int,
                args: Optional[Dict[str, Any]]) -> None:
        with self._lock:
            slot = self._n_spans % self.capacity
            self._s_name[slot] = name_id
            self._s_t0[slot] = t0
            self._s_t1[slot] = t1
            self._s_tid[slot] = self._tids.get(threading.get_ident(), 0)
            self._s_depth[slot] = depth
            if args is not None:
                self._s_args[slot] = args
            else:
                self._s_args.pop(slot, None)  # slot reuse after a wrap
            self._n_spans += 1

    def count(self, name: str, n: float = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def sample(self, name: str, value: float) -> None:
        """Record a timestamped gauge sample (a Chrome-trace ``C`` event:
        queue depth, realized QoS, ... over the span timeline)."""
        name_id = self._intern(name)  # gauge names share the intern table
        with self._lock:
            slot = self._n_gauges % self.capacity
            self._g_name[slot] = name_id
            self._g_t[slot] = self._clock()
            self._g_val[slot] = value
            self._n_gauges += 1

    # -- export ------------------------------------------------------------
    @property
    def n_spans(self) -> int:
        return self._n_spans

    @property
    def dropped_spans(self) -> int:
        return max(0, self._n_spans - self.capacity)

    def _ring_view(self, arrays: List[np.ndarray], n_total: int
                   ) -> List[np.ndarray]:
        """Live records of one ring, oldest → newest."""
        n = min(n_total, self.capacity)
        if n_total <= self.capacity:
            return [a[:n].copy() for a in arrays]
        head = n_total % self.capacity
        return [np.concatenate([a[head:], a[:head]]) for a in arrays]

    def snapshot(self) -> Dict[str, Any]:
        """The versioned raw artifact (JSON-serializable)."""
        with self._lock:
            s_name, s_t0, s_t1, s_tid, s_depth = self._ring_view(
                [self._s_name, self._s_t0, self._s_t1, self._s_tid,
                 self._s_depth], self._n_spans)
            g_name, g_t, g_val = self._ring_view(
                [self._g_name, self._g_t, self._g_val], self._n_gauges)
            # args are keyed by slot; map them back to snapshot row order
            n = min(self._n_spans, self.capacity)
            base = self._n_spans - n
            args = {}
            for row in range(n):
                slot = (base + row) % self.capacity
                if slot in self._s_args:
                    args[str(row)] = self._s_args[slot]
            # wall/monotonic pair sampled under the same lock: both clocks
            # advance at wall rate, so the offset (wall_ns − mono_ns) is a
            # process constant and any capture time yields the same
            # cross-process alignment (to clock-sync precision)
            anchor = {"wall_ns": time.time_ns(), "mono_ns": self._clock()}
            return {
                "obs_schema": OBS_SCHEMA_VERSION,
                "clock": "perf_counter_ns",
                "anchor": anchor,
                "names": list(self._names),
                "spans": {
                    "name": s_name.tolist(), "t0_ns": s_t0.tolist(),
                    "t1_ns": s_t1.tolist(), "tid": s_tid.tolist(),
                    "depth": s_depth.tolist(),
                },
                "span_args": args,
                "gauges": {
                    "name": g_name.tolist(), "t_ns": g_t.tolist(),
                    "value": g_val.tolist(),
                },
                "counters": dict(self.counters),
                "metrics": self.metrics.snapshot(),
                "dropped_spans": self.dropped_spans,
                "dropped_gauges": max(0, self._n_gauges - self.capacity),
                "pid": os.getpid(),
            }

    def save(self, path) -> None:
        """Atomically publish the snapshot as JSON at ``path``."""
        _atomic_write_text(path, json.dumps(self.snapshot()))

    def chrome_trace(self) -> Dict[str, Any]:
        return to_chrome_trace(self.snapshot())

    def span_durations_s(self, name: str) -> np.ndarray:
        """Recorded durations (seconds) of every live span named ``name``
        — what :mod:`benchmarks.kernels_micro` times kernels with."""
        name_id = self._name_ids.get(name)
        if name_id is None:
            return np.zeros(0, np.float64)
        with self._lock:
            s_name, s_t0, s_t1 = self._ring_view(
                [self._s_name, self._s_t0, self._s_t1], self._n_spans)
        mask = s_name == name_id
        return (s_t1[mask] - s_t0[mask]).astype(np.float64) / 1e9


# ===========================================================================
# Module-level switch (the fast path lives here)
# ===========================================================================

_TRACER: Optional[Tracer] = None


def enable(capacity: int = DEFAULT_CAPACITY, *,
           clock: Optional[Callable[[], int]] = None,
           jax_annotations: bool = False) -> Tracer:
    """Install (and return) the process-global tracer. Idempotent-ish:
    enabling over a live tracer replaces it (the old one keeps working
    for code still holding a reference)."""
    global _TRACER
    _TRACER = Tracer(capacity, clock=clock,
                     jax_annotations=jax_annotations)
    return _TRACER


def disable() -> Optional[Tracer]:
    """Uninstall the global tracer; returns it so callers can still
    snapshot/save what was recorded."""
    global _TRACER
    tr, _TRACER = _TRACER, None
    return tr


def enabled() -> bool:
    return _TRACER is not None


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def span(name: str, **args: Any):
    """``with span("tick.place"): ...`` — the one instrumentation
    primitive on every hot path. Disabled cost: one global load, one
    ``None`` check, one shared no-op context manager."""
    tr = _TRACER
    if tr is None:
        return _NULL_SPAN
    return tr.span(name, args or None)


def count(name: str, n: float = 1) -> None:
    tr = _TRACER
    if tr is not None:
        tr.count(name, n)


def sample(name: str, value: float) -> None:
    tr = _TRACER
    if tr is not None:
        tr.sample(name, value)


def save(path) -> bool:
    """Save the global tracer's snapshot; False when tracing is off."""
    tr = _TRACER
    if tr is None:
        return False
    tr.save(path)
    return True


def enable_from_env(default_name: str = "obs") -> Optional[Tracer]:
    """Opt-in via environment — how forked fleet workers inherit tracing.

    ``REPRO_OBS=1`` enables the tracer; if ``REPRO_OBS_DIR`` is also set,
    an :mod:`atexit` hook saves ``<dir>/<default_name>_<pid>.json`` on
    clean exit. Anything else leaves observability off (the default).
    """
    if os.environ.get(_ENV_FLAG, "").strip() not in ("1", "true", "on"):
        return None
    tr = enable()
    out_dir = os.environ.get(_ENV_DIR, "").strip()
    if out_dir:
        import atexit

        path = os.path.join(out_dir, f"{default_name}_{os.getpid()}.json")

        def _save(tracer=tr, path=path):
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tracer.save(path)

        atexit.register(_save)
    return tr


# ===========================================================================
# Artifact I/O + Chrome-trace conversion
# ===========================================================================

def _atomic_write_text(path, text: str) -> None:
    """Tempfile + rename publish (obs depends on nothing else in repro,
    so it carries its own copy of the crash-safe write)."""
    import tempfile

    path = os.fspath(path)
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def load_artifact(path) -> Dict[str, Any]:
    """Load + version-check a raw obs artifact."""
    with open(path) as f:
        doc = json.load(f)
    have = int(doc.get("obs_schema", -1))
    if have not in READABLE_OBS_SCHEMAS:
        raise ValueError(f"{path}: obs artifact schema v{have}, this code "
                         f"reads v{list(READABLE_OBS_SCHEMAS)}")
    return doc


def _cat_of(name: str) -> str:
    """Chrome-trace category = the name's first dotted component
    (``kernel.qos_matrix`` → ``kernel``)."""
    return name.split(".", 1)[0]


def to_chrome_trace(doc: Mapping[str, Any]) -> Dict[str, Any]:
    """Convert a raw artifact into Chrome-trace / Perfetto JSON.

    Timestamps are rebased so the earliest record sits at t=0 (µs), which
    also makes the export a pure function of the recorded deltas — the
    golden-export test relies on that.
    """
    names = list(doc.get("names", []))
    spans = doc.get("spans", {})
    gauges = doc.get("gauges", {})
    s_t0 = spans.get("t0_ns", [])
    g_t = gauges.get("t_ns", [])
    base = min([*s_t0, *g_t], default=0)
    pid = int(doc.get("pid", 0))
    events: List[Dict[str, Any]] = [
        {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
         "args": {"name": "repro.obs"}},
    ]
    span_args = doc.get("span_args", {})
    for row, (nid, t0, t1, tid, _depth) in enumerate(zip(
            spans.get("name", []), s_t0, spans.get("t1_ns", []),
            spans.get("tid", []), spans.get("depth", []))):
        name = names[nid]
        ev: Dict[str, Any] = {
            "ph": "X", "name": name, "cat": _cat_of(name), "pid": pid,
            "tid": int(tid), "ts": (t0 - base) / 1e3,
            "dur": (t1 - t0) / 1e3,
        }
        args = span_args.get(str(row))
        if args:
            ev["args"] = args
        events.append(ev)
    for nid, t, v in zip(gauges.get("name", []), g_t,
                         gauges.get("value", [])):
        name = names[nid]
        events.append({"ph": "C", "name": name, "cat": _cat_of(name),
                       "pid": pid, "tid": 0, "ts": (t - base) / 1e3,
                       "args": {"value": v}})
    return {
        "displayTimeUnit": "ms",
        "otherData": {
            "obs_schema": doc.get("obs_schema", OBS_SCHEMA_VERSION),
            "dropped_spans": doc.get("dropped_spans", 0),
            "counters": doc.get("counters", {}),
        },
        "traceEvents": events,
    }


def validate_chrome_trace(doc: Mapping[str, Any]) -> int:
    """Structural validation of a Chrome-trace document; returns the
    number of duration (``X``) events. Raises ``ValueError`` on malformed
    documents — shared by the tests and the CI smoke step."""
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("chrome trace has no traceEvents")
    n_x = 0
    for ev in events:
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"malformed trace event: {ev!r}")
        if ev["ph"] == "X":
            for field in ("name", "ts", "dur", "pid", "tid"):
                if field not in ev:
                    raise ValueError(f"X event missing {field!r}: {ev!r}")
            if ev["dur"] <= 0:
                raise ValueError(
                    f"non-positive duration ({ev['dur']}): span "
                    f"{ev.get('name')!r} must close strictly after it "
                    f"opens — zero-length spans indicate a clock that "
                    f"did not advance: {ev!r}")
            n_x += 1
    return n_x
