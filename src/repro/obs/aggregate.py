"""Fleet-wide trace stitching and cross-worker metric rollups.

A fleet run (``repro.fleet``) leaves one obs artifact per worker process
(``REPRO_OBS=1 REPRO_OBS_DIR=<fleet_root>/obs`` — each worker's atexit
save). Each artifact's timestamps come from that process's *own*
monotonic clock (``time.perf_counter_ns``), whose zero point is
arbitrary per process — concatenating them naively would overlay every
worker at t=0. This module merges them into **one** coherent
Chrome/Perfetto trace:

- **worker → pid mapping**: every artifact keeps its recording process's
  pid as the Chrome-trace ``pid`` (collisions — pid reuse across hosts —
  are remapped deterministically), with a ``process_name`` metadata event
  carrying the worker label, so Perfetto shows one swimlane group per
  worker;
- **monotonic-clock alignment**: artifacts are shifted onto a common
  wall-clock timeline using each artifact's ``anchor`` (a wall/monotonic
  pair sampled at snapshot time, obs schema v2); artifacts that predate
  the anchor fall back to the fleet telemetry heartbeats
  (:mod:`repro.fleet.telemetry` v2 records carry the same pair, keyed by
  pid), and failing both are aligned at their start;
- **metric rollup**: counters sum, histograms merge bucket-wise
  (:meth:`~repro.obs.metrics.Histogram.merge` — exact bucket arithmetic,
  so the fleet rollup equals the single-process run's histograms), and
  gauges keep the last writer in label order.

``python -m repro.obs stitch`` wraps :func:`stitch_fleet` for the CLI.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from .metrics import MetricsRegistry
from .trace import load_artifact, validate_chrome_trace

__all__ = [
    "stitch_traces",
    "rollup_metrics",
    "rollup_counters",
    "load_fleet_artifacts",
    "telemetry_anchors",
    "stitch_fleet",
]

#: Subdirectory of a fleet root where worker obs artifacts land
#: (``REPRO_OBS_DIR`` — see :func:`repro.obs.trace.enable_from_env`).
FLEET_OBS_DIR = "obs"


def _doc_offset_ns(doc: Mapping[str, Any],
                   anchors_by_pid: Mapping[int, Tuple[int, int]]
                   ) -> Optional[int]:
    """monotonic → wall offset (ns) for one artifact, or None."""
    anchor = doc.get("anchor")
    if anchor and "wall_ns" in anchor and "mono_ns" in anchor:
        return int(anchor["wall_ns"]) - int(anchor["mono_ns"])
    tele = anchors_by_pid.get(int(doc.get("pid", -1)))
    if tele is not None:
        wall_ns, mono_ns = tele
        return int(wall_ns) - int(mono_ns)
    return None


def _cat_of(name: str) -> str:
    return name.split(".", 1)[0]


def stitch_traces(docs: Sequence[Mapping[str, Any]],
                  labels: Optional[Sequence[str]] = None,
                  anchors_by_pid: Optional[Mapping[int, Tuple[int, int]]]
                  = None) -> Dict[str, Any]:
    """Merge raw obs artifacts into one Chrome-trace document.

    ``labels`` names each artifact's process swimlane (worker owner, file
    stem, ...). ``anchors_by_pid`` supplies telemetry-heartbeat fallback
    anchors ``{pid: (wall_ns, mono_ns)}`` for pre-v2 artifacts. The
    earliest aligned record sits at ts=0 µs.
    """
    if not docs:
        raise ValueError("no artifacts to stitch")
    labels = list(labels) if labels is not None else \
        [f"pid {doc.get('pid', i)}" for i, doc in enumerate(docs)]
    if len(labels) != len(docs):
        raise ValueError(f"{len(docs)} artifact(s) but {len(labels)} "
                         f"label(s)")
    anchors_by_pid = anchors_by_pid or {}

    # Anchored docs share a wall timeline; unanchored docs are aligned at
    # their start (their own min lands at the stitched t=0).
    offsets: List[Optional[int]] = [
        _doc_offset_ns(doc, anchors_by_pid) for doc in docs]
    mins: List[int] = []
    for doc in docs:
        t0 = doc.get("spans", {}).get("t0_ns", [])
        gt = doc.get("gauges", {}).get("t_ns", [])
        mins.append(min([*t0, *gt], default=0))
    anchored = [m + off for m, off in zip(mins, offsets) if off is not None]
    base = min(anchored) if anchored else 0
    for i, off in enumerate(offsets):
        if off is None:
            offsets[i] = base - mins[i]  # start-aligned fallback

    # worker → pid: keep the recording pid, remap collisions
    pids: List[int] = []
    used: set = set()
    for i, doc in enumerate(docs):
        pid = int(doc.get("pid", 0))
        while pid in used:
            pid += 100000
        used.add(pid)
        pids.append(pid)

    events: List[Dict[str, Any]] = []
    dropped: Dict[str, int] = {}
    for doc, label, pid, off in zip(docs, labels, pids, offsets):
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name", "args": {"name": label}})
        names = list(doc.get("names", []))
        spans = doc.get("spans", {})
        span_args = doc.get("span_args", {})
        for row, (nid, t0, t1, tid, _depth) in enumerate(zip(
                spans.get("name", []), spans.get("t0_ns", []),
                spans.get("t1_ns", []), spans.get("tid", []),
                spans.get("depth", []))):
            name = names[nid]
            ev: Dict[str, Any] = {
                "ph": "X", "name": name, "cat": _cat_of(name), "pid": pid,
                "tid": int(tid), "ts": (t0 + off - base) / 1e3,
                "dur": (t1 - t0) / 1e3,
            }
            args = span_args.get(str(row))
            if args:
                ev["args"] = args
            events.append(ev)
        gauges = doc.get("gauges", {})
        for nid, t, v in zip(gauges.get("name", []),
                             gauges.get("t_ns", []),
                             gauges.get("value", [])):
            name = names[nid]
            events.append({"ph": "C", "name": name, "cat": _cat_of(name),
                           "pid": pid, "tid": 0,
                           "ts": (t + off - base) / 1e3,
                           "args": {"value": v}})
        if doc.get("dropped_spans"):
            dropped[label] = int(doc["dropped_spans"])

    return {
        "displayTimeUnit": "ms",
        "otherData": {
            "stitched_from": {label: pid
                              for label, pid in zip(labels, pids)},
            "dropped_spans": dropped,
            "counters": rollup_counters(docs),
        },
        "traceEvents": events,
    }


def rollup_counters(docs: Sequence[Mapping[str, Any]]) -> Dict[str, float]:
    """Sum the plain tracer counters across artifacts."""
    out: Dict[str, float] = {}
    for doc in docs:
        for name, v in doc.get("counters", {}).items():
            out[name] = out.get(name, 0) + v
    return out


def rollup_metrics(docs: Sequence[Mapping[str, Any]]) -> MetricsRegistry:
    """Merge the ``metrics`` sections of artifacts into one registry —
    counters add, histograms merge bucket-exactly, gauges last-write-win
    in artifact order."""
    reg = MetricsRegistry()
    for doc in docs:
        reg.merge(MetricsRegistry.from_snapshot(doc.get("metrics", [])))
    return reg


def load_fleet_artifacts(fleet_root
                         ) -> Tuple[List[str], List[Dict[str, Any]]]:
    """Every worker obs artifact under ``<fleet_root>/obs/``, sorted by
    filename (labels are the file stems, e.g. ``obs_12345``)."""
    d = Path(fleet_root) / FLEET_OBS_DIR
    labels, docs = [], []
    if d.is_dir():
        for p in sorted(d.glob("*.json")):
            try:
                docs.append(load_artifact(p))
            except (ValueError, OSError):
                continue  # torn write or foreign file; skip, don't fail
            labels.append(p.stem)
    return labels, docs


def telemetry_anchors(fleet_root) -> Dict[int, Tuple[int, int]]:
    """Heartbeat fallback anchors ``{pid: (wall_ns, mono_ns)}`` from the
    fleet telemetry records (v2 records publish the pair)."""
    from repro.fleet.telemetry import read_telemetry  # deferred: no cycle

    out: Dict[int, Tuple[int, int]] = {}
    for rec in read_telemetry(fleet_root).get("workers", {}).values():
        pid, mono = rec.get("pid"), rec.get("anchor_mono_ns")
        wall = rec.get("updated_at")
        if pid is not None and mono is not None and wall is not None:
            out[int(pid)] = (int(float(wall) * 1e9), int(mono))
    return out


def stitch_fleet(fleet_root, out: Optional[Path] = None) -> Dict[str, Any]:
    """Stitch every worker artifact of a fleet run; returns a summary.

    Writes the stitched Chrome trace to ``out`` when given. The summary
    carries the validated event count, per-worker pids, and the rolled-up
    metric snapshot (exact bucket arithmetic across workers).
    """
    labels, docs = load_fleet_artifacts(fleet_root)
    if not docs:
        raise ValueError(f"no obs artifacts under "
                         f"{Path(fleet_root) / FLEET_OBS_DIR} — run the "
                         f"fleet with REPRO_OBS=1 REPRO_OBS_DIR set")
    chrome = stitch_traces(docs, labels,
                           anchors_by_pid=telemetry_anchors(fleet_root))
    n_events = validate_chrome_trace(chrome)
    if out is not None:
        out = Path(out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(chrome))
    reg = rollup_metrics(docs)
    return {
        "workers": chrome["otherData"]["stitched_from"],
        "n_artifacts": len(docs),
        "n_events": n_events,
        "counters": chrome["otherData"]["counters"],
        "metrics": reg.snapshot(),
        "chrome_trace": chrome,
    }
