"""gemma2-27b — local/global alternating attention, softcaps [arXiv:2408.00118]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b", family="dense", n_layers=46, d_model=4608,
        n_heads=32, n_kv_heads=16, d_ff=36864, vocab_size=256000,
        head_dim=128, block_pattern=("swa", "full"), window=4096,
        logit_softcap=30.0, attn_softcap=50.0, scale_embed=True,
        post_norms=True, act="gelu", tie_embeddings=True,
        rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b-smoke", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512, head_dim=32,
        block_pattern=("swa", "full"), window=16, logit_softcap=30.0,
        attn_softcap=50.0, scale_embed=True, post_norms=True, act="gelu",
        tie_embeddings=True, rope_theta=10_000.0,
    )
