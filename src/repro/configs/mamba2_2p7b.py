"""mamba2-2.7b — attention-free SSD stack [arXiv:2405.21060]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=50280,
        block_pattern=("mamba",), ssm_state=128, ssm_head_dim=64,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=256,
        block_pattern=("mamba",), ssm_state=16, ssm_head_dim=16,
        ssm_chunk=8, tie_embeddings=True,
    )
