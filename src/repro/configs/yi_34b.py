"""yi-34b — llama-arch dense GQA [arXiv:2403.04652; hf]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=20480, vocab_size=64000,
        head_dim=128, rope_theta=5_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=8, n_kv_heads=2, d_ff=256, vocab_size=512, head_dim=16,
    )
