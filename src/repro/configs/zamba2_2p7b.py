"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

Simplification vs the released model (documented in DESIGN.md): the two
alternating shared transformer blocks take the residual stream directly
(no concatenated original-embedding input, no LoRA projectors)."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_ff=10240, vocab_size=32000,
        head_dim=80, block_pattern=("mamba",), ssm_state=64,
        ssm_head_dim=64, shared_attn_every=6, n_shared_blocks=2,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16,
        block_pattern=("mamba",), ssm_state=16, ssm_head_dim=16,
        ssm_chunk=8, shared_attn_every=2, n_shared_blocks=2,
    )
