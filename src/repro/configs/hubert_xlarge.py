"""hubert-xlarge — encoder-only audio backbone [arXiv:2106.07447].

Modality frontend is a STUB: input_specs provide precomputed frame
embeddings at the backbone width (per assignment rules)."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
        n_heads=16, n_kv_heads=16, d_ff=5120, vocab_size=504,
        head_dim=80, encoder_only=True, causal=False, frontend="audio",
        act="gelu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=63, head_dim=16,
        encoder_only=True, causal=False, frontend="audio", act="gelu",
    )
