"""The paper's own experimental configurations as selectable configs.

* ``numerical()``   — the §VI-B synthetic setup (|E|=10, |S|=100, impls
  ~U{1..10}, the exact capacity/cost/threshold distributions).
* ``realworld()``   — the §VI-C Table-I setup (six ImageNet classifiers,
  one edge cloud, R=1 placement slot).
* ``zoo_catalog()`` — the beyond-paper catalog mapping the 10 assigned
  architectures onto multi-implementation services.
"""
from __future__ import annotations

from repro.core.instance import (PIESInstance, realworld_instance,
                                 synthetic_instance, REALWORLD_CATALOG)


def numerical(n_users: int = 250, seed: int = 0) -> PIESInstance:
    return synthetic_instance(n_users, n_edges=10, n_services=100,
                              max_impls=10, seed=seed)


def realworld(seed: int = 0) -> PIESInstance:
    return realworld_instance(seed=seed)


def zoo_catalog():
    from repro.serving.catalog import default_catalog
    return default_catalog()


TABLE_I = REALWORLD_CATALOG
