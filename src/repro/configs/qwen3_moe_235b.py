"""qwen3-moe-235b-a22b — 128-expert top-8 MoE [hf:Qwen/Qwen3 family]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
        n_heads=64, n_kv_heads=4, d_ff=1536, vocab_size=151936,
        head_dim=128, n_experts=128, top_k=8, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=512, head_dim=16,
        n_experts=8, top_k=2,
    )
