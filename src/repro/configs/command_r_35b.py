"""command-r-35b — GQA, no-bias dense [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense", n_layers=40, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=22528, vocab_size=256000,
        head_dim=128, rope_theta=8_000_000.0, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=8, n_kv_heads=1, d_ff=256, vocab_size=512, head_dim=16,
        tie_embeddings=True,
    )
