"""internvl2-1b — InternViT + Qwen2-0.5B backbone [arXiv:2404.16821].

VLM frontend is a STUB: input_specs provide precomputed patch embeddings
at the backbone width; a learned adapter projects them in."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm", n_layers=24, d_model=896,
        n_heads=14, n_kv_heads=2, d_ff=4864, vocab_size=151655,
        head_dim=64, frontend="vision", n_vision_tokens=1024,
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=1, d_ff=128, vocab_size=512, head_dim=32,
        frontend="vision", n_vision_tokens=8,
    )
