"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=32000,
        head_dim=128, n_experts=8, top_k=2, block_pattern=("swa",),
        window=4096, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=512, head_dim=16,
        n_experts=4, top_k=2, block_pattern=("swa",), window=16,
    )
