"""smollm-360m — llama-arch small dense GQA [hf:HuggingFaceTB/SmolLM]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense", n_layers=32, d_model=960,
        n_heads=15, n_kv_heads=5, d_ff=2560, vocab_size=49152,
        head_dim=64, rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m-smoke", family="dense", n_layers=2, d_model=96,
        n_heads=3, n_kv_heads=1, d_ff=192, vocab_size=512, head_dim=32,
        rope_theta=10_000.0,
    )
