"""Assigned architecture configs (exact published shapes) + registry.

Every architecture is selectable via ``--arch <id>`` in the launchers.
``full()`` returns the exact published config; ``smoke()`` returns a
reduced same-family config for CPU tests. Shape-cell skip rules (which
(arch × input-shape) dry-run cells apply) live in :mod:`repro.launch.shapes`.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "yi_34b",
    "smollm_360m",
    "gemma2_27b",
    "command_r_35b",
    "hubert_xlarge",
    "zamba2_2p7b",
    "internvl2_1b",
    "qwen3_moe_235b",
    "mixtral_8x7b",
    "mamba2_2p7b",
]

#: dashes-to-underscores aliases matching the assignment sheet names
ALIASES: Dict[str, str] = {
    "yi-34b": "yi_34b",
    "smollm-360m": "smollm_360m",
    "gemma2-27b": "gemma2_27b",
    "command-r-35b": "command_r_35b",
    "hubert-xlarge": "hubert_xlarge",
    "zamba2-2.7b": "zamba2_2p7b",
    "internvl2-1b": "internvl2_1b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-2.7b": "mamba2_2p7b",
}


def _module(arch: str):
    arch = ALIASES.get(arch, arch).replace("-", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str, *, tp_shards: int = 1, **overrides) -> ModelConfig:
    cfg = _module(arch).full()
    return cfg.with_(tp_shards=tp_shards, **overrides)


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).smoke()
    return cfg.with_(**overrides) if overrides else cfg


def all_configs(tp_shards: int = 1) -> Dict[str, ModelConfig]:
    return {a: get_config(a, tp_shards=tp_shards) for a in ARCH_IDS}
