"""Trip-count-aware cost model over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies **once**, but our
models scan over layers (and flash-attention scans over chunks), so raw
numbers under-count FLOPs/bytes by ~n_layers×. The CPU backend annotates
every while with ``backend_config={"known_trip_count":{"n": ...}}`` — this
module walks the call graph multiplying by trip counts and derives:

* ``flops``        — 2·M·N·K for every dot (from result shape × contracting
                     dims), conv similarly, + 1 flop/element for elementwise
                     and reduce ops (transcendentals counted 1).
* ``hbm_bytes``    — consumer-side bytes-accessed: Σ operand sizes + result
                     size per instruction, fusion boundaries only (reads and
                     writes inside a fusion stay in registers/VMEM).
                     ``dynamic-update-slice`` roots count the *update* slice
                     (in-place aliasing), not the full destination buffer.
* ``collectives``  — per-type counts + operand/result bytes, trip-scaled.

All values are **per device** (the module is post-partitioning).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

#: ops that neither read nor write HBM themselves (aliases / metadata)
_TRANSPARENT = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "domain", "opt-barrier",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z0-9\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems_total, bytes_total = 0, 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems_total += n
        bytes_total += n * _DTYPE_BYTES[dt]
    return elems_total, bytes_total


@dataclasses.dataclass
class Instr:
    name: str
    rtype: str
    op: str
    args_str: str
    raw: str
    operands: List[str]
    attrs: Dict[str, str]


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "HloCost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.dot_flops += other.dot_flops * scale
        self.hbm_bytes += other.hbm_bytes * scale
        for k, v in other.collective.items():
            slot = self.collective.setdefault(
                k, {"count": 0.0, "operand_bytes": 0.0, "result_bytes": 0.0})
            for kk in slot:
                slot[kk] += v.get(kk, 0.0) * scale

    def as_dict(self) -> dict:
        total_ob = sum(v["operand_bytes"] for v in self.collective.values())
        total_rb = sum(v["result_bytes"] for v in self.collective.values())
        return {
            "flops": self.flops, "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "collectives": {**{k: dict(v) for k, v in self.collective.items()},
                            "total_operand_bytes": total_ob,
                            "total_result_bytes": total_rb},
        }


def _parse_operands(args_str: str) -> List[str]:
    """Operand names up to the matching close-paren of the op call."""
    depth = 1
    out, cur = [], []
    for ch in args_str:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if ch == "," and depth == 1:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    names = []
    for tok in out:
        tok = tok.strip().lstrip("%")
        tok = tok.split(" ")[0].split("=")[0].strip()
        if tok:
            names.append(tok)
    return names


def _parse_attrs(raw: str) -> Dict[str, str]:
    attrs = {}
    for m in re.finditer(r"([a-z_]+)=(\{[^{}]*(?:\{[^{}]*\})?[^{}]*\}|%[\w.\-]+|\"[^\"]*\"|[\w.\-]+)", raw):
        attrs[m.group(1)] = m.group(2)
    return attrs


def parse_module(text: str):
    comps: Dict[str, List[Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", line)  # strip /*index=N*/ comments
        if not line.strip():
            cur = None
            continue
        mc = _COMP_RE.match(line)
        if mc and "=" not in line.split("->")[0]:
            cur = mc.group(2)
            comps[cur] = []
            if mc.group(1):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if mi and cur is not None:
            name, rtype, op, rest = mi.groups()
            comps[cur].append(Instr(
                name=name, rtype=rtype, op=op, args_str=rest, raw=line,
                operands=_parse_operands(rest), attrs=_parse_attrs(rest)))
    return comps, entry


def _dims_product(type_str: str, dims: List[int]) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 1
    shape = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    p = 1
    for d in dims:
        if d < len(shape):
            p *= shape[d]
    return p


def _dot_flops(instr: Instr, types: Dict[str, str]) -> float:
    relems, _ = _shape_elems_bytes(instr.rtype)
    lhs = instr.operands[0] if instr.operands else None
    lhs_type = types.get(lhs, "")
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.raw)
    cdims = [int(x) for x in m.group(1).split(",")] if (m and m.group(1)) else []
    k = _dims_product(lhs_type, cdims) if lhs_type else 1
    return 2.0 * relems * max(k, 1)


def _trip_count(instr: Instr) -> float:
    m = re.search(r"known_trip_count[^0-9]*([0-9]+)", instr.raw)
    return float(m.group(1)) if m else 1.0


def _fusion_root(comp: List[Instr]) -> Optional[Instr]:
    for ins in comp:
        if "ROOT" in ins.raw.split("=")[0]:
            return ins
    return comp[-1] if comp else None


def _comp_cost(comp_name: str, comps, types_cache, memo,
               trace=None, mult=1.0) -> HloCost:
    if trace is None and comp_name in memo:
        return memo[comp_name]
    memo[comp_name] = HloCost()  # cycle guard
    instrs = comps.get(comp_name, [])
    types = {i.name: i.rtype for i in instrs}
    types_cache[comp_name] = types
    cost = HloCost()

    def log(ins, bytes_, kind="hbm"):
        if trace is not None and bytes_ * mult > 0:
            m = re.search(r'op_name="([^"]+)"', ins.raw)
            trace.append((bytes_ * mult, kind, ins.op,
                          ins.rtype.split("{")[0][:48],
                          (m.group(1) if m else "?")[-80:]))

    for ins in instrs:
        op = ins.op
        _, rbytes = _shape_elems_bytes(ins.rtype)
        relems, _ = _shape_elems_bytes(ins.rtype)

        if op in _TRANSPARENT:
            continue
        if op == "while":
            trips = _trip_count(ins)
            body = ins.attrs.get("body", "").lstrip("%")
            cond = ins.attrs.get("condition", "").lstrip("%")
            if body in comps:
                cost.add(_comp_cost(body, comps, types_cache, memo,
                                    trace, mult * trips), trips)
            if cond in comps:
                cost.add(_comp_cost(cond, comps, types_cache, memo,
                                    trace, mult * trips), trips)
            continue
        if op in ("call", "conditional", "async-start"):
            for key in ("to_apply", "true_computation", "false_computation",
                        "called_computations", "calls"):
                sub = ins.attrs.get(key, "").lstrip("%")
                if sub in comps:
                    cost.add(_comp_cost(sub, comps, types_cache, memo,
                                        trace, mult))
            continue

        kind = next((c for c in _COLLECTIVES
                     if op in (c, c + "-start")), None)
        if op.endswith("-done"):
            continue
        obytes = sum(_shape_elems_bytes(types.get(o, ""))[1]
                     for o in ins.operands)
        if kind:
            slot = cost.collective.setdefault(
                kind, {"count": 0.0, "operand_bytes": 0.0,
                       "result_bytes": 0.0})
            slot["count"] += 1
            slot["operand_bytes"] += obytes
            slot["result_bytes"] += rbytes
            cost.hbm_bytes += obytes + rbytes
            log(ins, obytes + rbytes, "coll")
            continue

        if op == "fusion":
            sub = ins.attrs.get("calls", "").lstrip("%")
            root = _fusion_root(comps.get(sub, []))
            # flops from all dots/elementwise inside the fused computation
            inner = _comp_cost(sub, comps, types_cache, memo)
            cost.flops += inner.flops
            cost.dot_flops += inner.dot_flops
            # pure-convert fusions: see the `convert` normalization below
            body_ops = {i.op for i in comps.get(sub, [])} - _TRANSPARENT
            if body_ops <= {"convert"}:
                continue
            # in-place update fusions: a contained dynamic-update-slice
            # whose result is buffer-sized (root may be a convert wrapped
            # around the DUS by CPU float normalization)
            dus = next((i for i in comps.get(sub, [])
                        if i.op in ("dynamic-update-slice", "scatter")), None)
            # bytes at the fusion boundary only
            wbytes = rbytes
            if dus is not None and root is not None and root.op in (
                    "dynamic-update-slice", "scatter", "convert", "copy"):
                root = dus
                # in-place update fusion: writes = update slice; the aliased
                # base operand (≈ result-sized) is neither read nor written
                # in full — drop the largest operand from the read count.
                sub_types = types_cache.get(sub, {})
                upd = root.operands[1] if len(root.operands) > 1 else None
                ub = _shape_elems_bytes(sub_types.get(upd, ""))[1]
                wbytes = ub or rbytes
                op_sizes = sorted(
                    (_shape_elems_bytes(types.get(o, ""))[1]
                     for o in ins.operands), reverse=True)
                if op_sizes and op_sizes[0] >= rbytes // 2:
                    obytes -= op_sizes[0]
                obytes = max(obytes, wbytes)  # the update data is read
            elif any(i.op in ("dynamic-slice", "gather")
                     for i in comps.get(sub, [])):
                # slice-extraction fusion (root may be transpose/convert
                # around the slice): only the extracted region of the big
                # operand is read
                op_sizes = sorted(
                    (_shape_elems_bytes(types.get(o, ""))[1]
                     for o in ins.operands), reverse=True)
                if op_sizes and op_sizes[0] > 4 * rbytes:
                    obytes = obytes - op_sizes[0] + rbytes
            cost.hbm_bytes += obytes + wbytes
            log(ins, obytes + wbytes)
            continue

        if op in ("dynamic-slice", "gather"):
            # reads only the extracted region (+ tiny indices), writes result
            cost.hbm_bytes += 2 * rbytes
            cost.flops += relems
            log(ins, 2 * rbytes)
            continue
        if op == "convert":
            # TARGET-HARDWARE NORMALIZATION (documented in EXPERIMENTS.md):
            # XLA-CPU FloatNormalization legalizes every bf16 op through
            # f32, materializing f32 shadow copies of bf16 buffers (e.g.
            # the full KV cache per decode step). TPUs compute bf16
            # natively — these converts do not exist in the TPU HLO — so
            # dtype converts are costed as fused (zero HBM traffic).
            cost.flops += relems
            continue

        if op == "dot":
            cost.dot_flops += _dot_flops(ins, types)
            cost.flops += _dot_flops(ins, types)
            cost.hbm_bytes += obytes + rbytes
            log(ins, obytes + rbytes)
            continue
        if op == "convolution":
            # approximate: 2 × result elems × (kernel elems / output feature)
            kern = ins.operands[1] if len(ins.operands) > 1 else None
            kelems, _ = _shape_elems_bytes(types.get(kern, ""))
            cost.flops += 2.0 * relems * max(kelems, 1) ** 0.5
            cost.hbm_bytes += obytes + rbytes
            continue
        if op == "dynamic-update-slice":
            upd = ins.operands[1] if len(ins.operands) > 1 else None
            ub = _shape_elems_bytes(types.get(upd, ""))[1]
            cost.hbm_bytes += 2 * ub
            log(ins, 2 * ub)
            continue

        # generic elementwise / reduce / data movement
        cost.flops += relems  # ~1 flop per output element
        cost.hbm_bytes += obytes + rbytes
        log(ins, obytes + rbytes)

    memo[comp_name] = cost
    return cost


def analyze_hlo(text: str, trace: bool = False):
    comps, entry = parse_module(text)
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    if entry is None:
        return HloCost().as_dict()
    memo: Dict[str, HloCost] = {}
    tr = [] if trace else None
    cost = _comp_cost(entry, comps, {}, memo, tr, 1.0)
    out = cost.as_dict()
    out["entry_computation"] = entry
    out["n_computations"] = len(comps)
    if trace:
        tr.sort(reverse=True)
        return out, tr
    return out
