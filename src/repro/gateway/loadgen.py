"""Open-loop wall-clock load generator replaying scenario traces.

The generator owns the *demand* side of the live control plane: it
materializes each tick's user set from the same seeded scenario machinery
the offline horizon uses (``Scenario.instance_at`` + the serving
driver's arrival-time padding), serializes every request as a wire
envelope (:mod:`repro.gateway.control`), and delivers it **open-loop**:
each envelope is sent at its scheduled wall time ``arrival / speed``
regardless of whether the gateway has kept up. Open-loop is the honest
load model — a closed-loop generator silently self-throttles against a
slow server and hides exactly the overload the soak test exists to
measure (cf. the coordinated-omission literature).

``speed`` is the RPS multiplier: ``speed=10`` replays the trace at 10×
its native rate (one simulated tick every ``tick_duration / 10`` wall
seconds). The virtual mode sends every envelope back-to-back with no
pacing at all — the ``eot`` sentinels alone define tick boundaries, so
a virtual replay is deterministic and as fast as the CPU allows.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Awaitable, Callable, List, Optional

import numpy as np

from repro.serving.horizon import HorizonConfig, _arrival_times

from .control import RequestEnvelope, eos_frame, eot_frame

__all__ = ["LoadgenReport", "tick_envelopes", "run_loadgen",
           "tcp_loadgen"]

#: async callable delivering one wire line to the gateway
SendFn = Callable[[str], Awaitable[None]]


@dataclasses.dataclass
class LoadgenReport:
    """What one load-generation run actually delivered."""

    ticks: int
    sent: int               # request envelopes delivered
    wall_s: float           # wall-clock duration of the run
    target_rps: float       # scheduled request rate (speed-scaled)
    achieved_rps: float     # sent / wall_s

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def tick_envelopes(scenario, config: HorizonConfig, tick: int,
                   mobility_cache: Optional[np.ndarray] = None
                   ) -> List[RequestEnvelope]:
    """Tick ``tick``'s request envelopes from the seeded scenario.

    Uses the exact same generator calls as the offline horizon
    (``instance_at`` + ``_arrival_times``), so a lossless delivery of
    these envelopes reconstructs, on the gateway side, instances
    byte-identical to what ``run_horizon`` would have materialized.
    """
    inst = scenario.instance_at(config.seed, tick,
                                mobility_cache=mobility_cache)
    times = _arrival_times(scenario, config.seed, tick, inst.U,
                           config.tick_duration)
    return [RequestEnvelope(tick=tick, u=u, edge=int(inst.u_edge[u]),
                            service=int(inst.u_service[u]),
                            alpha=float(inst.u_alpha[u]),
                            delta=float(inst.u_delta[u]),
                            arrival=float(times[u]))
            for u in range(inst.U)]


async def run_loadgen(send: SendFn, config: HorizonConfig, *,
                      speed: float = 1.0, n_ticks: Optional[int] = None,
                      wall: bool = True,
                      max_wall_s: Optional[float] = None,
                      send_eos: bool = True) -> LoadgenReport:
    """Replay the configured scenario into ``send``, one line at a time.

    ``wall=True`` paces each envelope to its scheduled wall time
    ``arrival / speed`` (open-loop; a late generator sends immediately
    and never skips); ``wall=False`` streams everything back-to-back
    for deterministic virtual-clock replay. ``max_wall_s`` stops the
    replay at a wall-clock budget (soak runs), always finishing the
    current tick + its ``eot`` so the gateway never sees a torn tick.
    """
    import asyncio

    from repro.workloads import get_scenario

    scenario = get_scenario(config.scenario, **dict(config.overrides))
    T = int(n_ticks or config.n_ticks or scenario.n_ticks)
    cache = scenario.mobility_trajectory(config.seed, T)
    t0 = time.monotonic()
    sent = 0
    ticks = 0
    for t in range(T):
        envs = tick_envelopes(scenario, config, t, mobility_cache=cache)
        for env in envs:
            if wall:
                due = t0 + env.arrival / speed
                delay = due - time.monotonic()
                if delay > 0:
                    await asyncio.sleep(delay)
            await send(env.to_line())
            sent += 1
        await send(eot_frame(t, len(envs)))
        ticks += 1
        if max_wall_s is not None and time.monotonic() - t0 >= max_wall_s:
            break
    if send_eos:
        await send(eos_frame())
    wall_s = time.monotonic() - t0
    native_rps = sent / (ticks * config.tick_duration) if ticks else 0.0
    return LoadgenReport(
        ticks=ticks, sent=sent, wall_s=wall_s,
        target_rps=native_rps * speed if wall else float("inf"),
        achieved_rps=sent / wall_s if wall_s > 0 else float("inf"))


async def tcp_loadgen(host: str, port: int, config: HorizonConfig,
                      **kwargs: Any) -> LoadgenReport:
    """Aim :func:`run_loadgen` at a gateway's TCP ingest socket."""
    import asyncio

    reader, writer = await asyncio.open_connection(host, port)
    del reader  # ingest is one-way; the gateway never writes back

    async def send(line: str) -> None:
        writer.write(line.encode())
        await writer.drain()

    try:
        return await run_loadgen(send, config, **kwargs)
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass
