"""The asyncio request gateway: live driver of the serving control loop.

``serving.horizon`` runs the paper's control loop offline — materialize
tick, place (EGP + hysteresis / feedback), route (OMS), execute
(continuous batching) — as fast as the CPU allows. This module runs the
*same* loop (literally the same
:class:`~repro.serving.horizon.TickController`) against requests that
physically arrive over an asyncio ingest path, paced by a pluggable
clock:

* **wall mode** — tick boundaries fire at real deadlines
  ``t0 + (t+1) · tick_duration / speed``; whatever envelopes arrived by
  the deadline are admitted as tick ``t``'s instance
  (:func:`~repro.gateway.control.instance_from_requests`), an empty
  window degrades to
  :meth:`~repro.serving.horizon.TickController.step_idle`, and the
  gateway measures *event-loop lag* (how late each boundary actually
  ran) and *admission latency* (socket receipt → control-loop
  admission) on log-bucketed histograms.
* **virtual mode** — no wall pacing at all: tick ``t`` steps exactly
  when its ``eot`` sentinel is ingested, so the boundary is a property
  of the byte stream, not of task scheduling, and a seeded replay
  produces ``TickReport``\\ s byte-identical to the offline horizon on
  the same ``(config, seed)`` (tested).

Simulation time stays virtual throughout: the scheduler still runs on
simulation seconds, the wall clock only decides *when* control steps
fire. Telemetry flows out through the PR-7 stream protocol — per-tick
``gateway`` frames plus periodic ``metrics`` frames carrying the
gateway histograms — so ``python -m repro.obs dash`` renders a live
server with zero changes to stored artifacts.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Any, Dict, List, Optional

from repro import obs
from repro.obs import reqtrace as _reqtrace
from repro.obs.metrics import MetricsRegistry
from repro.serving.horizon import (HorizonConfig, HorizonResult,
                                   TickController)

from .control import RequestEnvelope, instance_from_requests, parse_frame

__all__ = ["WallClock", "VirtualClock", "GatewayConfig", "Gateway"]


class WallClock:
    """Real time: ``now()`` is monotonic seconds since construction."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    async def sleep(self, dt: float) -> None:
        if dt > 0:
            await asyncio.sleep(dt)


class VirtualClock:
    """Simulated time: ``sleep`` advances instantly, ``now`` follows.

    Yields to the event loop once per sleep so concurrently scheduled
    tasks still interleave — but nothing in the deterministic replay
    path depends on *how* they interleave (tick boundaries are
    ``eot``-driven, see the module docstring).
    """

    def __init__(self):
        self._t = 0.0

    def now(self) -> float:
        return self._t

    async def sleep(self, dt: float) -> None:
        if dt > 0:
            self._t += dt
        await asyncio.sleep(0)


@dataclasses.dataclass
class GatewayConfig:
    """One gateway deployment = a horizon config + live-serving knobs."""

    horizon: HorizonConfig = dataclasses.field(
        default_factory=HorizonConfig)
    #: ``"wall"`` (real deadlines) or ``"virtual"`` (eot-driven replay)
    mode: str = "wall"
    #: RPS multiplier: one control tick every ``tick_duration/speed``
    #: wall seconds (wall mode only)
    speed: float = 1.0
    #: ingress queue bound — ``req`` frames beyond it are dropped and
    #: counted, sentinels are always accepted (backpressure must never
    #: wedge shutdown)
    max_ingress: int = 65536
    #: emit a ``metrics`` stream frame every N ticks
    metrics_every: int = 10
    #: wall mode: give the first frame this long to arrive before
    #: declaring the run empty
    start_timeout_s: float = 30.0

    def __post_init__(self):
        if self.mode not in ("wall", "virtual"):
            raise ValueError(f"unknown gateway mode {self.mode!r}")
        if self.speed <= 0:
            raise ValueError(f"speed must be > 0, got {self.speed}")


class Gateway:
    """Asyncio ingest + the shared serving control loop.

    One instance is single-use: feed it lines (:meth:`submit_line` from
    any reader task, or point :meth:`serve` at a TCP port) and await
    :meth:`run` for the :class:`~repro.serving.horizon.HorizonResult` —
    the same result type, with the same semantics, as the offline
    driver.
    """

    def __init__(self, config: GatewayConfig):
        self.config = config
        self.ctl = TickController(config.horizon)
        self.clock = VirtualClock() if config.mode == "virtual" \
            else WallClock()
        self.queue: "asyncio.Queue[Dict[str, Any]]" = asyncio.Queue()
        self.registry = MetricsRegistry()
        self._lag_hist = self.registry.histogram("gateway.loop_lag_ms")
        self._adm_hist = self.registry.histogram("gateway.admission_ms")
        self.counters: Dict[str, float] = {
            "gateway.requests": 0, "gateway.admitted": 0,
            "gateway.dropped_ingress": 0, "gateway.late": 0,
            "gateway.malformed": 0, "gateway.ticks": 0,
        }
        self.max_ingress_depth = 0
        #: per-tick operational log (what the soak report aggregates)
        self.tick_log: List[Dict[str, Any]] = []
        self._t0: Optional[float] = None   # wall origin: first frame
        self.bound_port: Optional[int] = None

    # -- ingest ------------------------------------------------------------
    def submit_line(self, line: str) -> None:
        obj = parse_frame(line)
        if obj is None:
            self.counters["gateway.malformed"] += 1
            return
        self.submit(obj)

    def submit(self, obj: Dict[str, Any]) -> None:
        """Enqueue one parsed frame (thread of the event loop only)."""
        if obj.get("type") == "req":
            self.counters["gateway.requests"] += 1
            if self.queue.qsize() >= self.config.max_ingress:
                self.counters["gateway.dropped_ingress"] += 1
                return
            obj["_recv"] = self.clock.now()
        self.queue.put_nowait(obj)
        depth = self.queue.qsize()
        if depth > self.max_ingress_depth:
            self.max_ingress_depth = depth

    async def _on_client(self, reader: asyncio.StreamReader,
                         writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                self.submit_line(line.decode("utf-8", errors="replace"))
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    # -- the control loop --------------------------------------------------
    def _step_tick(self, t: int, envs: List[RequestEnvelope],
                   lag_ms: float, admission_ms: List[float]) -> None:
        cfg = self.config
        rt = _reqtrace._REQTRACER
        # the controller assigns tick t's uids as ctl.uid + env.u —
        # capture the base before step() advances it
        uid_base = self.ctl.uid
        if rt is not None and cfg.mode == "wall":
            for env in sorted(envs, key=lambda e: e.u):
                recv = getattr(env, "_recv", None)
                if recv is not None:
                    # socket-receipt time on the wall clock (simulation
                    # timestamps follow at admit)
                    rt.event(uid_base + env.u, "receipt", float(recv),
                             clock="wall", tick=t)
        if envs:
            inst, times = instance_from_requests(
                self.ctl.scenario, cfg.horizon.seed, t, envs)
            self.ctl.step(t, inst, times)
            self.counters["gateway.admitted"] += len(envs)
        else:
            self.ctl.step_idle(t)
        self.counters["gateway.ticks"] += 1
        if cfg.mode == "wall":
            self._lag_hist.observe(lag_ms)
            if rt is not None and len(admission_ms) == len(envs):
                # admission histogram exemplars link buckets to uids
                # (bucket counts identical to the observe_many path).
                # Kept-status is unknowable at admission time, so only
                # hash-sampled uids — which always survive to the kept
                # ring — get an exemplar; tail-kept specials don't.
                for env, ms in zip(envs, admission_ms):
                    uid = uid_base + env.u
                    self._adm_hist.observe(
                        ms, exemplar=rt.exemplar(uid, t)
                        if rt._hash_keep(uid) else None)
            else:
                self._adm_hist.observe_many(admission_ms)
        entry = {
            "tick": t, "admitted": len(envs),
            "ingress_depth": self.queue.qsize(),
            "queue_depth": self.ctl.boundary[-1][0],
            "in_flight": self.ctl.boundary[-1][1],
            "loop_lag_ms": round(lag_ms, 3),
        }
        self.tick_log.append(entry)
        pub = obs.get_publisher()
        if pub is not None:
            pub.emit("gateway", {
                "scenario": cfg.horizon.scenario,
                "seed": cfg.horizon.seed,
                "policy": cfg.horizon.policy,
                "mode": cfg.mode, "speed": cfg.speed, **entry,
                "requests": int(self.counters["gateway.requests"]),
                "dropped_ingress":
                    int(self.counters["gateway.dropped_ingress"]),
                "late": int(self.counters["gateway.late"]),
            })
            if (t + 1) % cfg.metrics_every == 0:
                self._emit_metrics(pub)

    def _emit_metrics(self, pub) -> None:
        pub.emit("metrics", {
            "metrics": self.registry.snapshot(),
            "counters": {k: float(v) for k, v in self.counters.items()},
            "n_spans": 0,
        })

    async def _run_virtual(self) -> None:
        pend: Dict[int, List[RequestEnvelope]] = {}
        t = 0
        while t < self.ctl.n_ticks:
            obj = await self.queue.get()
            kind = obj.get("type")
            if kind == "req":
                pend.setdefault(int(obj["tick"]), []).append(
                    RequestEnvelope.from_wire(obj))
            elif kind == "eot":
                # the determinism hinge: the boundary is this frame
                k = int(obj["tick"])
                while t <= min(k, self.ctl.n_ticks - 1):
                    self._step_tick(t, pend.pop(t, []), 0.0, [])
                    t += 1
            elif kind == "eos":
                break

    async def _run_wall(self) -> None:
        cfg = self.config
        tick_wall = cfg.horizon.tick_duration / cfg.speed
        pend: Dict[int, List[RequestEnvelope]] = {}
        eos = False
        try:
            first = await asyncio.wait_for(self.queue.get(),
                                           cfg.start_timeout_s)
        except asyncio.TimeoutError:
            return  # no traffic ever arrived: an empty, clean run
        # the wall origin is first-byte time, so gateway and generator
        # agree on tick phase regardless of who started first
        self._t0 = self.clock.now()
        eos = self._ingest_wall(first, pend, 0)
        t = 0
        while t < self.ctl.n_ticks:
            deadline = self._t0 + (t + 1) * tick_wall
            while not eos:
                remain = deadline - self.clock.now()
                if remain <= 0:
                    break
                try:
                    obj = await asyncio.wait_for(self.queue.get(), remain)
                except asyncio.TimeoutError:
                    break
                eos = self._ingest_wall(obj, pend, t)
            now = self.clock.now()
            lag_ms = max(0.0, (now - deadline) * 1e3)
            envs = pend.pop(t, [])
            admission_ms = [(now - e._recv) * 1e3 for e in envs]  # type: ignore[attr-defined]
            self._step_tick(t, envs, lag_ms, admission_ms)
            t += 1
            if eos and not any(k >= t for k in pend):
                break

    def _ingest_wall(self, obj: Dict[str, Any],
                     pend: Dict[int, List[RequestEnvelope]],
                     current_tick: int) -> bool:
        """Route one frame into the pending-tick buffers; True on eos."""
        kind = obj.get("type")
        if kind == "eos":
            return True
        if kind == "req":
            k = int(obj["tick"])
            if k < current_tick:
                # its control tick already stepped; admitting it into a
                # later tick would corrupt that tick's user indexing
                self.counters["gateway.late"] += 1
                return False
            env = RequestEnvelope.from_wire(obj)
            env._recv = float(obj.get("_recv", self.clock.now()))  # type: ignore[attr-defined]
            pend.setdefault(k, []).append(env)
        return False  # eot is advisory in wall mode: deadlines rule

    async def run(self) -> HorizonResult:
        """Drive the control loop to completion and finalize."""
        cfg = self.config
        with obs.span("gateway.run", scenario=cfg.horizon.scenario,
                      policy=cfg.horizon.policy, mode=cfg.mode,
                      seed=cfg.horizon.seed):
            if cfg.mode == "virtual":
                await self._run_virtual()
            else:
                await self._run_wall()
            result = self.ctl.finalize()
        pub = obs.get_publisher()
        if pub is not None:
            self._emit_metrics(pub)
        return result

    async def serve(self, host: str = "127.0.0.1",
                    port: int = 0) -> HorizonResult:
        """Bind a TCP ingest socket, run to completion, tear down."""
        server = await asyncio.start_server(self._on_client, host, port)
        self.bound_port = server.sockets[0].getsockname()[1]
        try:
            return await self.run()
        finally:
            server.close()
            await server.wait_closed()
