"""repro.gateway — the live serving control plane.

Promotes :mod:`repro.serving.horizon` from an offline driver to a real
async service: an asyncio gateway (:mod:`~repro.gateway.server`) ingests
request envelopes over a one-line-per-frame JSON protocol
(:mod:`~repro.gateway.control`), batches them into control ticks, and
runs the placement → routing → execution loop — the *same*
:class:`~repro.serving.horizon.TickController` the offline horizon
uses — paced by a wall or virtual clock. An open-loop load generator
(:mod:`~repro.gateway.loadgen`) replays the seeded scenario traces at
configurable RPS multipliers, and the soak harness
(:mod:`~repro.gateway.soak`) judges sustained high-RPS runs for bounded
backlog and honest event-loop latency.

Determinism invariant (tested): on the virtual clock, a seeded replay
produces ``TickReport``\\ s byte-identical to ``run_horizon`` on the
same ``(config, seed)``. Telemetry rides the PR-7 stream protocol, so
``python -m repro.obs dash`` works against a live gateway unchanged.

CLI: ``python -m repro.gateway serve|loadgen|replay|soak``.
"""
from .control import (GATEWAY_PROTOCOL_VERSION, RequestEnvelope,
                      eos_frame, eot_frame, instance_from_requests,
                      parse_frame, result_digest)
from .loadgen import LoadgenReport, run_loadgen, tcp_loadgen, tick_envelopes
from .server import Gateway, GatewayConfig, VirtualClock, WallClock
from .soak import SoakReport, run_soak

__all__ = [
    "GATEWAY_PROTOCOL_VERSION",
    "RequestEnvelope",
    "eot_frame",
    "eos_frame",
    "parse_frame",
    "instance_from_requests",
    "result_digest",
    "LoadgenReport",
    "tick_envelopes",
    "run_loadgen",
    "tcp_loadgen",
    "Gateway",
    "GatewayConfig",
    "WallClock",
    "VirtualClock",
    "SoakReport",
    "run_soak",
]
