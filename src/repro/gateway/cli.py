"""``python -m repro.gateway`` — serve / loadgen / replay / soak.

The operational entry points of the live control plane:

* ``serve`` — bind the TCP ingest socket and run the gateway until the
  stream ends (``eos``) or the horizon completes; mount a telemetry
  stream with ``--stream`` (or ``REPRO_OBS_STREAM``) and watch it live
  with ``python -m repro.obs dash``.
* ``loadgen`` — aim the open-loop trace replayer at a running gateway.
* ``replay`` — the determinism check, in-process: run the same seeded
  trace through the virtual-clock gateway *and* the offline horizon and
  compare result digests byte-for-byte (exit 1 on divergence).
* ``soak`` — the judged wall-clock soak (exit 1 when the run is not
  bounded / clean); ``--json`` prints the full report.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import sys
from typing import List, Optional

__all__ = ["main"]


def _hconfig(args: argparse.Namespace):
    from repro.serving.horizon import (HorizonConfig,
                                       split_serving_overrides)
    overrides = {}
    for item in args.override or []:
        k, _, v = item.partition("=")
        try:
            overrides[k] = json.loads(v)
        except json.JSONDecodeError:
            overrides[k] = v
    scen_ov, serving = split_serving_overrides(overrides)
    return HorizonConfig(scenario=args.scenario, policy=args.policy,
                         seed=args.seed, n_ticks=args.n_ticks,
                         overrides=tuple(sorted(scen_ov.items())),
                         **serving)


def _add_run_args(p: argparse.ArgumentParser) -> None:
    p.add_argument("--scenario", default="trace_replay_bursty")
    p.add_argument("--policy", default="feedback")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--n-ticks", type=int, default=None)
    p.add_argument("--override", action="append", metavar="K=V",
                   help="scenario/serving override (repeatable)")
    p.add_argument("--speed", type=float, default=1.0,
                   help="RPS multiplier over the trace's native rate")
    p.add_argument("--reqtrace", default=None, metavar="PATH",
                   help="enable per-request causal tracing and save the "
                        "sampled traces here (feed to `repro.obs "
                        "explain`)")
    p.add_argument("--reqtrace-sample", type=int, default=16,
                   metavar="N", help="hash-sample 1-in-N ordinary "
                                     "requests (misses/drops/requeues "
                                     "are always kept; default 16)")
    p.add_argument("--ledger", default=None, metavar="PATH",
                   help="enable the greedy decision ledger and save the "
                        "JSONL here (feed to `repro.obs why`)")


def _enable_v3(args: argparse.Namespace):
    """Turn on reqtrace/ledger per the flags; return the saver."""
    from repro.obs import ledger as _ledger
    from repro.obs import reqtrace as _reqtrace

    if getattr(args, "reqtrace", None):
        _reqtrace.enable_request_tracing(sample_every=args.reqtrace_sample)
    if getattr(args, "ledger", None):
        _ledger.enable_ledger()

    def _save() -> None:
        if getattr(args, "reqtrace", None):
            rt = _reqtrace.disable_request_tracing()
            if rt is not None:
                rt.save(args.reqtrace)
                print(f"[gateway] reqtrace: {len(rt.kept())} sampled "
                      f"trace(s) -> {args.reqtrace}", flush=True)
        if getattr(args, "ledger", None):
            led = _ledger.disable_ledger()
            if led is not None:
                led.save(args.ledger)
                print(f"[gateway] ledger: {len(led.records())} epoch "
                      f"record(s) -> {args.ledger}", flush=True)

    return _save


def _cmd_serve(args: argparse.Namespace) -> int:
    from repro import obs
    from .server import Gateway, GatewayConfig

    if args.stream:
        obs.enable_stream(args.stream, source="gateway")
    else:
        obs.enable_stream_from_env()
    save_v3 = _enable_v3(args)
    host, _, port = args.listen.rpartition(":")
    gw = Gateway(GatewayConfig(
        horizon=_hconfig(args),
        mode="virtual" if args.virtual else "wall",
        speed=args.speed, max_ingress=args.max_ingress))

    async def _serve():
        task = asyncio.ensure_future(gw.serve(host or "127.0.0.1",
                                              int(port)))
        while gw.bound_port is None and not task.done():
            await asyncio.sleep(0.01)
        if gw.bound_port is not None:
            print(f"[gateway] ingest on {host or '127.0.0.1'}:"
                  f"{gw.bound_port} ({gw.config.mode} mode, "
                  f"x{gw.config.speed:g})", flush=True)
        return await task

    result = asyncio.run(_serve())
    save_v3()
    print(f"[gateway] done: {len(result.per_tick)} tick(s), "
          f"{result.served}/{result.submitted} served, "
          f"qos {result.mean_realized_qos:.4f}, "
          f"miss {result.miss_rate:.4f}", flush=True)
    return 0


def _cmd_loadgen(args: argparse.Namespace) -> int:
    from .loadgen import tcp_loadgen

    host, _, port = args.connect.rpartition(":")
    report = asyncio.run(tcp_loadgen(
        host or "127.0.0.1", int(port), _hconfig(args),
        speed=args.speed, n_ticks=args.n_ticks,
        max_wall_s=args.max_wall_s))
    print(json.dumps(report.to_json()), flush=True)
    return 0


def _cmd_replay(args: argparse.Namespace) -> int:
    from repro.serving.horizon import run_horizon
    from .control import result_digest
    from .loadgen import run_loadgen
    from .server import Gateway, GatewayConfig

    hconfig = _hconfig(args)
    save_v3 = _enable_v3(args)
    gw = Gateway(GatewayConfig(horizon=hconfig, mode="virtual"))

    async def _replay():
        async def send(line: str) -> None:
            gw.submit_line(line)

        task = asyncio.ensure_future(gw.run())
        await run_loadgen(send, hconfig, wall=False)
        return await task

    live = asyncio.run(_replay())
    save_v3()   # live-run traces only — the offline half runs untraced
    offline = run_horizon(hconfig)
    d_live, d_off = result_digest(live), result_digest(offline)
    match = d_live == d_off
    print(f"live    {d_live}\noffline {d_off}\n"
          f"parity: {'OK — byte-identical' if match else 'FAIL'}",
          flush=True)
    return 0 if match else 1


def _cmd_soak(args: argparse.Namespace) -> int:
    from repro import obs
    from .soak import run_soak

    # REPRO_OBS_STREAM=<spec> → per-tick gateway frames stream live
    # during the soak (the CI smoke tails them with `repro.obs dash`)
    obs.enable_stream_from_env(source="gateway")
    save_v3 = _enable_v3(args)
    overrides = {}
    for item in args.override or []:
        k, _, v = item.partition("=")
        try:
            overrides[k] = json.loads(v)
        except json.JSONDecodeError:
            overrides[k] = v
    report = run_soak(args.scenario, seed=args.seed, policy=args.policy,
                      speed=args.speed, duration_s=args.duration,
                      tcp=args.tcp, max_ingress=args.max_ingress,
                      overrides=overrides)
    save_v3()
    if args.json:
        print(json.dumps(report.to_json(), indent=2), flush=True)
    else:
        print(report.line(), flush=True)
    return 0 if report.ok else 1


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.gateway",
        description="live serving control plane")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("serve", help="run the asyncio gateway")
    _add_run_args(p)
    p.add_argument("--listen", default="127.0.0.1:0",
                   metavar="HOST:PORT")
    p.add_argument("--virtual", action="store_true",
                   help="eot-driven virtual clock (deterministic replay)")
    p.add_argument("--max-ingress", type=int, default=65536)
    p.add_argument("--stream", default=None,
                   help="telemetry stream spec (file / unix:… / tcp:…)")
    p.set_defaults(fn=_cmd_serve)

    p = sub.add_parser("loadgen", help="replay a trace at a gateway")
    _add_run_args(p)
    p.add_argument("--connect", required=True, metavar="HOST:PORT")
    p.add_argument("--max-wall-s", type=float, default=None)
    p.set_defaults(fn=_cmd_loadgen)

    p = sub.add_parser("replay",
                       help="virtual-clock parity check vs offline")
    _add_run_args(p)
    p.set_defaults(fn=_cmd_replay)

    p = sub.add_parser("soak", help="judged wall-clock soak run")
    _add_run_args(p)
    p.add_argument("--duration", type=float, default=30.0)
    p.add_argument("--tcp", action="store_true",
                   help="route ingest over a real TCP socket")
    p.add_argument("--max-ingress", type=int, default=65536)
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_soak)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
