"""Gateway wire protocol and request → instance reconstruction.

The gateway's ingest speaks a one-line-per-frame JSON protocol
(:data:`GATEWAY_PROTOCOL_VERSION`), deliberately shaped like the
:mod:`repro.obs.stream` wire format but in the *opposite* direction —
requests flow in, telemetry flows out::

    {"v": 1, "type": "req", "tick": 3, "u": 0, "edge": 2, "service": 7,
     "alpha": 0.42, "delta": 1.9, "arrival": 3.125}
    {"v": 1, "type": "eot", "tick": 3, "n": 41}
    {"v": 1, "type": "eos"}

``req`` carries everything the control plane needs to know about one
user request: its service, QoS attributes (α, δ), home edge, and the
absolute *virtual* arrival timestamp (simulation seconds — the wall
clock only paces delivery, it never enters the control state). ``eot``
(end-of-tick) is the determinism hinge: in virtual-clock mode the
gateway steps tick ``t`` exactly when ``eot(t)`` is ingested, so tick
boundaries are a property of the byte stream, not of asyncio task
scheduling. ``eos`` requests a graceful shutdown (drain + finalize).

:func:`instance_from_requests` is the inverse of
``Scenario.instance_at``: it rebuilds the tick's
:class:`~repro.core.instance.PIESInstance` from the request envelopes
that physically arrived, against the same per-seed infrastructure and
catalog draws and the same dead-edge capacity zeroing. Because JSON
floats round-trip binary64 exactly (``repr`` is shortest-roundtrip) and
the envelopes are re-sorted into user order, a lossless replay of a
seeded trace reconstructs instances bit-identical to the offline
generator — which is what makes gateway-vs-horizon byte parity possible
at all.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.instance import PIESInstance
from repro.serving.horizon import HorizonResult

__all__ = [
    "GATEWAY_PROTOCOL_VERSION",
    "RequestEnvelope",
    "eot_frame",
    "eos_frame",
    "parse_frame",
    "instance_from_requests",
    "result_digest",
]

#: Version stamp of the ingest wire protocol (every frame carries it).
GATEWAY_PROTOCOL_VERSION = 1


@dataclasses.dataclass
class RequestEnvelope:
    """One user request on the wire — the gateway's unit of ingest."""

    tick: int        # control tick the request belongs to
    u: int           # user index within the tick (canonical ordering)
    edge: int        # home edge (post-rehoming: where the user *is*)
    service: int     # requested service
    alpha: float     # QoS accuracy weight α_i
    delta: float     # deadline δ_i (seconds)
    arrival: float   # absolute virtual arrival time (simulation seconds)

    def to_wire(self) -> Dict[str, Any]:
        return {"v": GATEWAY_PROTOCOL_VERSION, "type": "req",
                "tick": self.tick, "u": self.u, "edge": self.edge,
                "service": self.service, "alpha": self.alpha,
                "delta": self.delta, "arrival": self.arrival}

    def to_line(self) -> str:
        return json.dumps(self.to_wire(), separators=(",", ":"),
                          sort_keys=True) + "\n"

    @classmethod
    def from_wire(cls, obj: Dict[str, Any]) -> "RequestEnvelope":
        return cls(tick=int(obj["tick"]), u=int(obj["u"]),
                   edge=int(obj["edge"]), service=int(obj["service"]),
                   alpha=float(obj["alpha"]), delta=float(obj["delta"]),
                   arrival=float(obj["arrival"]))


def eot_frame(tick: int, n: int) -> str:
    """End-of-tick sentinel: all ``n`` of tick ``tick``'s requests sent."""
    return json.dumps({"v": GATEWAY_PROTOCOL_VERSION, "type": "eot",
                       "tick": int(tick), "n": int(n)},
                      separators=(",", ":"), sort_keys=True) + "\n"


def eos_frame() -> str:
    """End-of-stream sentinel: drain and shut down gracefully."""
    return json.dumps({"v": GATEWAY_PROTOCOL_VERSION, "type": "eos"},
                      separators=(",", ":"), sort_keys=True) + "\n"


def parse_frame(line: str) -> Optional[Dict[str, Any]]:
    """Parse one wire line; ``None`` on a torn/foreign/blank line.

    A live ingest socket must degrade on garbage, not crash the control
    loop — the caller counts rejects on a ``gateway.malformed`` counter.
    """
    line = line.strip()
    if not line:
        return None
    try:
        obj = json.loads(line)
    except json.JSONDecodeError:
        return None
    if not isinstance(obj, dict):
        return None
    if int(obj.get("v", -1)) != GATEWAY_PROTOCOL_VERSION:
        return None
    if obj.get("type") not in ("req", "eot", "eos"):
        return None
    return obj


def instance_from_requests(scenario, seed: int, tick: int,
                           envelopes: Sequence[RequestEnvelope]
                           ) -> Tuple[PIESInstance, np.ndarray]:
    """Rebuild tick ``tick``'s PIES instance from arrived envelopes.

    The inverse of ``Scenario.instance_at``: per-seed infrastructure and
    catalog come from the scenario's memoized draws (they are static
    across the horizon, so the gateway need not trust the wire for
    them); the user set — edges, services, α, δ — comes entirely from
    the envelopes, re-sorted into canonical user order; dead edges at
    ``tick`` have their deployment capacity zeroed exactly like the
    offline generator. Returns ``(instance, times)`` where ``times`` is
    the [U] float64 array of carried arrival timestamps, ready to pass
    to :meth:`~repro.serving.horizon.TickController.step`.
    """
    if not envelopes:
        raise ValueError(f"tick {tick}: cannot build an instance from "
                         f"zero envelopes (use step_idle)")
    envs = sorted(envelopes, key=lambda e: e.u)
    if [e.u for e in envs] != list(range(len(envs))):
        raise ValueError(
            f"tick {tick}: envelope user indices are not the contiguous "
            f"range 0..{len(envs) - 1} — lost or duplicated requests "
            f"cannot be admitted as a coherent control tick")
    K, W, R = scenario.infrastructure(seed)
    sm_service, sm_acc, sm_k, sm_w, sm_r = scenario.catalog(seed)
    dead = scenario.dead_edges_at(tick)
    R = R.copy()
    if dead:
        R[np.asarray(dead)] = 0.0
    inst = PIESInstance(
        K=K, W=W, R=R,
        sm_service=sm_service, sm_acc=sm_acc,
        sm_k=sm_k, sm_w=sm_w, sm_r=sm_r,
        u_edge=np.array([e.edge for e in envs], np.int64),
        u_service=np.array([e.service for e in envs], np.int64),
        u_alpha=np.array([e.alpha for e in envs], np.float64),
        u_delta=np.array([e.delta for e in envs], np.float64),
        delta_max=scenario.delta_max,
    )
    inst.validate()
    times = np.array([e.arrival for e in envs], np.float64)
    return inst, times


def result_digest(result: HorizonResult) -> str:
    """SHA-256 over the byte-exact content of a horizon result.

    Covers every per-request (uid, impl, arrival, finish) tuple and
    every per-tick report field — the parity test's one-line equality
    check between the live gateway and the offline horizon.
    """
    h = hashlib.sha256()
    reqs = result.requests
    h.update(np.array([r.uid for r in reqs], np.int64).tobytes())
    h.update(np.array([r.impl for r in reqs], np.int64).tobytes())
    h.update(np.array([r.arrival for r in reqs], np.float64).tobytes())
    h.update(np.array([r.finish for r in reqs], np.float64).tobytes())
    for rep in result.per_tick:
        h.update(repr(dataclasses.astuple(rep)).encode())
    return h.hexdigest()
