"""Soak harness: sustained high-RPS wall-clock runs, judged.

The acceptance bar for the live control plane is operational, not just
statistical: at ``speed×`` the trace's native request rate, sustained
for a wall-clock duration, the gateway must (a) keep its backlog
**bounded** — no monotonic queue growth, which is the signature of a
control loop that has fallen behind its arrival process — and (b) keep
its event loop honest: tick boundaries fire close to their deadlines
(p99 loop lag) and requests clear ingest quickly (p99 admission
latency). :func:`run_soak` wires an open-loop generator
(:mod:`repro.gateway.loadgen`) straight into a wall-mode
:class:`~repro.gateway.server.Gateway` inside one event loop — or over
a real TCP socket — runs for the requested duration, and renders a
pass/fail :class:`SoakReport` whose fields feed the ``gateway_soak``
benchmark row and the CI smoke.

Boundedness test: the scheduler-backlog trajectory at tick boundaries
is split in half; the run is *bounded* when the later half's mean depth
is no worse than the earlier half's mean plus one tick's worth of
arrivals (steady state or draining — growth slower than that cannot
compound), and the maximum never hits the ingress bound.
"""
from __future__ import annotations

import asyncio
import dataclasses
import math
import time
from typing import Any, Dict, Optional

from repro.serving.horizon import HorizonConfig

from .loadgen import run_loadgen, tcp_loadgen
from .server import Gateway, GatewayConfig

__all__ = ["SoakReport", "run_soak"]


@dataclasses.dataclass
class SoakReport:
    """One judged soak run (all latencies wall-clock milliseconds)."""

    scenario: str
    seed: int
    policy: str
    speed: float
    requested_s: float       # wall budget asked for
    wall_s: float            # wall actually spent
    ticks: int
    sent: int                # envelopes the generator delivered
    admitted: int            # envelopes the control loop admitted
    dropped_ingress: int
    late: int
    sustained_rps: float     # admitted / wall_s
    p99_admission_ms: float
    p99_loop_lag_ms: float
    max_queue_depth: int     # scheduler backlog, max over boundaries
    final_queue_depth: int
    max_ingress_depth: int
    bounded: bool            # no monotonic backlog growth (see module doc)

    @property
    def ok(self) -> bool:
        return (self.bounded and self.ticks > 0
                and self.dropped_ingress == 0)

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["ok"] = self.ok
        return d

    def line(self) -> str:
        state = "OK " if self.ok else "FAIL"
        return (f"[{state}] soak {self.scenario}/s{self.seed} "
                f"x{self.speed:g}: {self.sustained_rps:.1f} req/s over "
                f"{self.wall_s:.1f}s ({self.ticks} ticks, "
                f"{self.admitted} admitted), p99 admission "
                f"{self.p99_admission_ms:.1f} ms, p99 lag "
                f"{self.p99_loop_lag_ms:.1f} ms, queue max/final "
                f"{self.max_queue_depth}/{self.final_queue_depth}"
                f"{'' if self.bounded else ' UNBOUNDED'}")


def _bounded(depths, per_tick_arrivals: float, max_ingress: int,
             max_depth: int) -> bool:
    if len(depths) < 2:
        return True
    if max_depth >= max_ingress:
        return False
    half = len(depths) // 2
    early = sum(depths[:half]) / half
    late = sum(depths[half:]) / (len(depths) - half)
    return late <= early + per_tick_arrivals


async def _soak(hconfig: HorizonConfig, *, speed: float,
                duration_s: float, tcp: bool,
                max_ingress: int) -> SoakReport:
    n_ticks = max(1, math.ceil(duration_s * speed
                               / hconfig.tick_duration))
    hconfig = dataclasses.replace(hconfig, n_ticks=n_ticks)
    gw = Gateway(GatewayConfig(horizon=hconfig, mode="wall", speed=speed,
                               max_ingress=max_ingress))
    t0 = time.monotonic()
    if tcp:
        server_task = asyncio.ensure_future(gw.serve())
        while gw.bound_port is None:      # bind races the first connect
            await asyncio.sleep(0.005)
        lg_task = asyncio.ensure_future(tcp_loadgen(
            "127.0.0.1", gw.bound_port, hconfig, speed=speed,
            n_ticks=n_ticks, max_wall_s=duration_s))
    else:
        async def send(line: str) -> None:
            gw.submit_line(line)

        server_task = asyncio.ensure_future(gw.run())
        lg_task = asyncio.ensure_future(run_loadgen(
            send, hconfig, speed=speed, n_ticks=n_ticks,
            max_wall_s=duration_s))
    lg = await lg_task
    await server_task
    wall_s = time.monotonic() - t0

    depths = [e["queue_depth"] for e in gw.tick_log]
    admitted = int(gw.counters["gateway.admitted"])
    per_tick = lg.sent / max(lg.ticks, 1)
    return SoakReport(
        scenario=hconfig.scenario, seed=hconfig.seed,
        policy=hconfig.policy, speed=speed, requested_s=duration_s,
        wall_s=wall_s, ticks=len(gw.tick_log), sent=lg.sent,
        admitted=admitted,
        dropped_ingress=int(gw.counters["gateway.dropped_ingress"]),
        late=int(gw.counters["gateway.late"]),
        sustained_rps=admitted / wall_s if wall_s > 0 else 0.0,
        p99_admission_ms=gw.registry.histogram(
            "gateway.admission_ms").quantile(0.99),
        p99_loop_lag_ms=gw.registry.histogram(
            "gateway.loop_lag_ms").quantile(0.99),
        max_queue_depth=max(depths, default=0),
        final_queue_depth=depths[-1] if depths else 0,
        max_ingress_depth=gw.max_ingress_depth,
        bounded=_bounded(depths, per_tick, max_ingress,
                         max(depths, default=0)))


def run_soak(scenario: str = "trace_replay_bursty", *, seed: int = 0,
             policy: str = "feedback", speed: float = 10.0,
             duration_s: float = 30.0, tcp: bool = False,
             max_ingress: int = 65536,
             overrides: Optional[Dict[str, Any]] = None) -> SoakReport:
    """Run one judged wall-clock soak (see module docstring)."""
    from repro.serving.horizon import split_serving_overrides

    scen_ov, serving = split_serving_overrides(overrides or {})
    hconfig = HorizonConfig(scenario=scenario, policy=policy,
                            seed=int(seed),
                            overrides=tuple(sorted(scen_ov.items())),
                            **serving)
    return asyncio.run(_soak(hconfig, speed=speed, duration_s=duration_s,
                             tcp=tcp, max_ingress=max_ingress))
