from .pipeline import TokenPipeline, RequestPipeline
