"""Deterministic, seekable data pipelines.

Restart/elastic requirements drive the design: ``batch_at(step)`` is a pure
function of ``(seed, step)`` — a replacement worker that joins at step N
produces byte-identical batches without replaying the stream, and a resume
from checkpoint continues exactly where training left off. Sharding is by
slicing the *global* batch, so a re-meshed (smaller-DP) cluster reading the
same steps sees the same global data in more accumulation slices.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.models.config import ModelConfig

__all__ = ["TokenPipeline", "RequestPipeline"]


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    """Synthetic LM token stream (markov-ish structure so loss can fall)."""

    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        B, S, V = self.global_batch, self.seq_len, self.cfg.vocab_size
        # structured stream: noisy arithmetic sequences mod V — learnable
        start = rng.integers(0, V, size=(B, 1))
        stride = rng.integers(1, 7, size=(B, 1))
        toks = (start + stride * np.arange(S + 1)[None, :]) % V
        noise = rng.random((B, S + 1)) < 0.05
        toks = np.where(noise, rng.integers(0, V, size=(B, S + 1)), toks)
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
            "mask": np.ones((B, S), np.float32),
        }
        if self.cfg.frontend == "audio":
            emb = rng.standard_normal((B, S, self.cfg.d_model)).astype(
                np.float32)
            batch = {"frames": emb,
                     "targets": rng.integers(0, V, (B, S)).astype(np.int32),
                     "mask": np.ones((B, S), np.float32)}
        elif self.cfg.frontend == "vision":
            nv = self.cfg.n_vision_tokens
            batch["tokens"] = batch["tokens"][:, : S - nv]
            batch["patches"] = rng.standard_normal(
                (B, nv, self.cfg.d_model)).astype(np.float32)
        return batch

    def shard(self, batch: Dict[str, np.ndarray], replica: int,
              n_replicas: int) -> Dict[str, np.ndarray]:
        per = self.global_batch // n_replicas
        return {k: v[replica * per:(replica + 1) * per] for k, v in
                batch.items()}


@dataclasses.dataclass(frozen=True)
class RequestPipeline:
    """Synthetic inference-request stream following the paper's §VI-B
    distributions (thresholds α, δ), seekable by tick."""

    n_users: int
    n_services: int
    seq_len: int = 32
    vocab: int = 256
    delta_max: float = 10.0
    seed: int = 0

    def requests_at(self, tick: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, tick]))
        return {
            "service": rng.integers(0, self.n_services, self.n_users),
            "alpha": 1.0 - np.clip(rng.exponential(0.125, self.n_users), 0, 1),
            "delta": np.clip(rng.exponential(1.5, self.n_users), 0,
                             self.delta_max),
            "prompts": rng.integers(
                0, self.vocab, (self.n_users, self.seq_len)).astype(np.int32),
        }
