"""Arrival processes — deterministic, seekable by ``(seed, tick)``.

Every process answers two questions as *pure functions* of ``(seed, tick)``
(the same contract as :class:`repro.data.TokenPipeline`): how many requests
arrive during a control tick (:meth:`ArrivalProcess.count_at`) and at what
wall-clock offsets within the tick (:meth:`ArrivalProcess.times_in_tick`).
A replacement worker that joins mid-horizon reproduces the stream without
replaying it, and two policies evaluated on the same seed see byte-identical
traffic.

* :class:`PoissonArrivals` — homogeneous Poisson (the steady baseline).
* :class:`MMPPArrivals` — Markov-modulated Poisson in block-renewal form:
  the modulating quiet/burst chain is resampled per ``block`` of ticks from
  a per-block hash, which keeps O(1) seeking (a literal 2-state chain would
  need the full history) while preserving the bursty, flash-crowd marginal
  statistics — geometric-ish burst episodes of mean length ``block``.
* :class:`DiurnalArrivals` — sinusoidal rate modulation (day/night cycle).
* :class:`TraceArrivals` — replay of a recorded per-tick request-count
  trace (cyclic), for real-world workload traces.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "ArrivalProcess",
    "PoissonArrivals",
    "MMPPArrivals",
    "DiurnalArrivals",
    "TraceArrivals",
]

# Stream tags namespace the per-purpose RNG draws so e.g. the burst-state
# stream never collides with the count stream at the same (seed, tick).
_TAG_COUNT = 0x0A1
_TAG_TIMES = 0x0A2
_TAG_BURST = 0x0A3


def _rng(seed: int, tag: int, *idx: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([int(seed), int(tag), *map(int, idx)]))


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Base: Poisson counts around a (possibly tick-varying) rate."""

    def rate_at(self, seed: int, tick: int) -> float:
        raise NotImplementedError

    def count_at(self, seed: int, tick: int) -> int:
        """Number of requests arriving during ``tick`` (Poisson draw)."""
        lam = max(float(self.rate_at(seed, tick)), 0.0)
        return int(_rng(seed, _TAG_COUNT, tick).poisson(lam))

    def times_in_tick(self, seed: int, tick: int,
                      tick_duration: float = 1.0) -> np.ndarray:
        """Sorted arrival offsets (seconds from horizon start) within
        ``[tick·T, (tick+1)·T)`` — conditional-uniform given the count,
        which is exact for a Poisson process."""
        n = self.count_at(seed, tick)
        u = np.sort(_rng(seed, _TAG_TIMES, tick).random(n))
        return (tick + u) * float(tick_duration)


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson: constant expected ``rate`` requests per tick."""

    rate: float = 64.0

    def rate_at(self, seed: int, tick: int) -> float:
        return self.rate


@dataclasses.dataclass(frozen=True)
class MMPPArrivals(ArrivalProcess):
    """Flash-crowd bursts: block-renewal Markov-modulated Poisson.

    Ticks are grouped into blocks of ``block`` ticks; block ``b`` is in the
    burst state with probability ``p_burst`` (independent per-block hash of
    ``(seed, b)``), during which the rate jumps from ``base_rate`` to
    ``burst_rate``. Seekable in O(1) by construction.
    """

    base_rate: float = 40.0
    burst_rate: float = 128.0
    p_burst: float = 0.3
    block: int = 2

    def is_burst(self, seed: int, tick: int) -> bool:
        b = int(tick) // max(int(self.block), 1)
        return bool(_rng(seed, _TAG_BURST, b).random() < self.p_burst)

    def rate_at(self, seed: int, tick: int) -> float:
        return self.burst_rate if self.is_burst(seed, tick) else self.base_rate


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Sinusoidal day/night modulation:
    ``rate(t) = base · (1 + amplitude · sin(2π (t + phase) / period))``."""

    base_rate: float = 64.0
    amplitude: float = 0.6
    period: int = 8
    phase: float = 0.0

    def rate_at(self, seed: int, tick: int) -> float:
        ang = 2.0 * np.pi * (tick + self.phase) / float(self.period)
        return self.base_rate * (1.0 + self.amplitude * float(np.sin(ang)))


@dataclasses.dataclass(frozen=True)
class TraceArrivals(ArrivalProcess):
    """Replay a recorded per-tick count trace (cyclic beyond its length).

    Counts are exact (no Poisson resampling) so a recorded trace reproduces
    itself; arrival offsets within the tick remain hash-derived.
    """

    counts: Tuple[int, ...] = (32, 64, 96, 64)

    @classmethod
    def from_sequence(cls, counts: Sequence[int]) -> "TraceArrivals":
        return cls(counts=tuple(int(c) for c in counts))

    @classmethod
    def from_file(cls, path) -> "TraceArrivals":
        """Load a per-tick count trace from a text file.

        Accepts one count per line or several per line, separated by
        whitespace and/or commas (plain CSV). Lines starting with ``#`` and
        blank lines are skipped; floats are truncated to ints (some traces
        record average rates).
        """
        import os

        counts = []
        with open(os.fspath(path)) as f:
            for line in f:
                line = line.split("#", 1)[0].strip()
                if not line:
                    continue
                for tok in line.replace(",", " ").split():
                    counts.append(int(float(tok)))
        if not counts:
            raise ValueError(f"trace file {path!r} contains no counts")
        return cls.from_sequence(counts)

    @classmethod
    def from_azure_csv(cls, path, *, minutes_per_tick: int = 60,
                       target_mean: Optional[float] = None
                       ) -> "TraceArrivals":
        """Load an Azure-Functions-style per-interval invocation trace.

        Expects a CSV whose data rows are
        ``<interval start, minutes>,<invocation count>`` (header line and
        extra trailing columns tolerated; ``#`` comments and blank lines
        skipped) — the shape of the per-interval aggregates derived from
        the Azure Functions 2019 dataset. Two unit normalizations map the
        platform-scale log onto one edge deployment's control loop:

        * **time**: counts are summed into buckets of ``minutes_per_tick``
          minutes — one bucket per control tick;
        * **scale**: with ``target_mean``, counts are linearly rescaled so
          the *mean per-tick count* equals it (platform logs record
          millions of invocations; an edge cell serves a slot pool), then
          rounded. Relative structure — diurnal swing, burst ratios — is
          preserved exactly; absolute scale becomes deployment-sized.
        """
        import os

        per_minute: dict = {}
        with open(os.fspath(path)) as f:
            for line in f:
                line = line.split("#", 1)[0].strip()
                if not line:
                    continue
                cells = [c.strip() for c in line.split(",")]
                try:
                    minute, count = float(cells[0]), float(cells[1])
                except (IndexError, ValueError):
                    continue  # header or malformed row
                if minute < 0.0:
                    # a clock-skewed export would otherwise fold into the
                    # *last* tick via negative indexing — corrupt quietly
                    raise ValueError(
                        f"azure trace {path!r}: negative interval start "
                        f"{minute} (row {line!r})")
                per_minute[minute] = per_minute.get(minute, 0.0) + count
        if not per_minute:
            raise ValueError(
                f"azure trace {path!r} contains no (minute, count) rows")
        mpt = max(int(minutes_per_tick), 1)
        n_ticks = int(max(per_minute) // mpt) + 1
        buckets = np.zeros(n_ticks, np.float64)
        for minute, count in per_minute.items():
            buckets[int(minute // mpt)] += count
        if target_mean is not None:
            mean = float(buckets.mean())
            if mean <= 0.0:
                raise ValueError(
                    f"azure trace {path!r} has zero total invocations — "
                    f"cannot normalize to target_mean={target_mean}")
            buckets = buckets * (float(target_mean) / mean)
        return cls.from_sequence(np.rint(buckets).astype(int))

    def rate_at(self, seed: int, tick: int) -> float:
        return float(self.counts[int(tick) % len(self.counts)])

    def count_at(self, seed: int, tick: int) -> int:
        return int(self.counts[int(tick) % len(self.counts)])
