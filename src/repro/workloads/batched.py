"""Batched accelerator-side scenario evaluation.

Monte-Carlo sweeps over (scenario × seed × tick) evaluate hundreds of
independent :class:`PIESInstance`\\ s. Doing that with a Python loop pays a
dispatch + trace per instance; instead, :func:`pad_instances` pads every
instance to the batch's fixed (U, P, E) envelope and stacks them into a
single batched :class:`~repro.core.instance.JaxInstance` pytree, and
:func:`evaluate_batch` runs QoS-matrix construction, greedy placement
(:func:`egp_place_jax` / :func:`agp_place_jax`) and the σ objective for the
*whole stack* inside one ``jax.jit``'d ``vmap`` — one accelerator call per
sweep.

Padding conventions (chosen so padded rows are provably inert):

* **users** — padded slots request the dummy service id ``S`` that no model
  implements (eligibility row ≡ False ⇒ zero QoS, zero greedy gain, zero σ)
  and are covered by a padded edge, so they never enter a real edge's user
  mask or satisfaction test;
* **models** — padded rows carry the distinct dummy service ``S + 1`` (no
  user requests it) and an effectively-infinite storage cost, so they are
  never feasible;
* **edges** — padded edges have zero storage, so the greedy loops exit
  immediately; at least one padded edge always exists to host padded users.

``evaluate_host`` is the NumPy reference path (per-instance
``egp_np``/``agp_np`` + ``sigma_np``) the batched results are validated
against — see ``tests/test_workloads.py`` and ``benchmarks/scenarios.py``.

Two scale paths sit on top of the global-pad evaluator:

* **Bucketed batching** (:func:`bucket_instances` / :class:`BucketedBatch`)
  — instances are grouped into geometric (power-of-two) ``(U, P, E)`` size
  classes and each bucket is padded to its *own* envelope, so one outlier
  no longer inflates every instance's pad. The bucket envelope is a pure
  function of each instance's own dims (never of its batch neighbours),
  which keeps per-item results independent of batch composition — the
  property sweep resume/fleet-merge byte-identity rests on.
  :func:`evaluate_batch` accepts either batch type; bucket pad waste is
  reported on the ``placement.bucket_pad_waste`` obs gauge.
* **Sparse top-k candidates** (:func:`evaluate_sparse`) — skips the dense
  ``[U, P]`` QoS matrix entirely: per-user top-k candidate pairs
  (:mod:`repro.core.candidates`) feed
  :func:`repro.core.placement.egp_place_sparse_jax`, with memory O(U·k)
  instead of O(U·P·E). Exact vs the host path when ``k`` keeps every
  eligible implementation (the default).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.instance import JaxInstance, PIESInstance
from repro.core.placement import agp_np, egp_np
from repro.core.qos import qos_matrix_np
from repro.core.scheduling import sigma_np

__all__ = [
    "PaddedBatch",
    "BucketedBatch",
    "pad_instances",
    "bucket_envelope",
    "bucket_indices",
    "bucket_instances",
    "single_evaluator",
    "evaluate_batch",
    "evaluate_sparse",
    "evaluate_host",
    "sweep",
]

#: Storage cost assigned to padded model rows — larger than any edge budget.
_PAD_STORAGE = 1e9


@dataclasses.dataclass
class PaddedBatch:
    """A stack of instances padded to a common (U, P, E) envelope."""

    jax_instance: JaxInstance      # every leaf is batched: [B, ...]
    n_services: int                # static scatter width (incl. dummy ids)
    dims: List[Tuple[int, int, int]]   # true (U, P, E) per instance

    @property
    def B(self) -> int:
        return len(self.dims)


@dataclasses.dataclass
class BucketedBatch:
    """Instances grouped into per-size-class :class:`PaddedBatch`\\ es.

    ``index[b]`` maps bucket ``b``'s rows back to positions in the original
    instance sequence; ``envelopes[b]`` is the bucket's ``(U_pad, P_pad,
    E_pad)``. Buckets are ordered by envelope (deterministic regardless of
    input order).
    """

    buckets: List[PaddedBatch]
    index: List[np.ndarray]
    envelopes: List[Tuple[int, int, int]]
    dims: List[Tuple[int, int, int]]   # true (U, P, E) in original order

    @property
    def B(self) -> int:
        return len(self.dims)

    @property
    def pad_waste(self) -> float:
        """Fraction of evaluated (U·P·E) cells that are padding, in [0, 1).

        The quantity the bucketing exists to shrink: under a single global
        envelope every instance pays the max instance's cell count."""
        true = sum(u * p * (e + 1) for u, p, e in self.dims)
        padded = sum(len(idx) * up * pp * ep
                     for idx, (up, pp, ep) in zip(self.index, self.envelopes))
        return 1.0 - true / padded if padded else 0.0


def _pow2_ceil(n: int) -> int:
    return 1 if n <= 1 else 1 << (int(n) - 1).bit_length()


def bucket_envelope(U: int, P: int, E: int,
                    cap: Optional[Tuple[int, int, int]] = None
                    ) -> Tuple[int, int, int]:
    """Geometric (power-of-two) size class of one instance's dims.

    Pure function of ``(U, P, E)`` (and the static ``cap``, e.g. a sweep
    group's :func:`repro.sweeps.spec.envelope_for` envelope) — deliberately
    *not* of any batch neighbour, so an item's evaluated envelope is
    identical however the sweep is chunked, resumed, or fleet-split. The
    edge axis buckets ``E + 1`` (a padded host edge always exists).
    """
    env = (_pow2_ceil(U), _pow2_ceil(P), _pow2_ceil(E + 1))
    if cap is not None:
        env = tuple(min(a, int(c)) for a, c in zip(env, cap))
    assert env[0] >= U and env[1] >= P and env[2] > E, \
        f"cap {cap} below instance dims ({U},{P},{E})"
    return env


def bucket_indices(instances: Sequence[PIESInstance],
                   cap: Optional[Tuple[int, int, int]] = None
                   ) -> List[Tuple[Tuple[int, int, int], List[int]]]:
    """Group instance positions by :func:`bucket_envelope`, sorted by
    envelope; within a bucket, original order is preserved."""
    groups: Dict[Tuple[int, int, int], List[int]] = {}
    for i, inst in enumerate(instances):
        groups.setdefault(bucket_envelope(inst.U, inst.P, inst.E, cap),
                          []).append(i)
    return sorted(groups.items())


def bucket_instances(instances: Sequence[PIESInstance],
                     cap: Optional[Tuple[int, int, int]] = None
                     ) -> BucketedBatch:
    """Stack ``instances`` into one :class:`PaddedBatch` per size bucket."""
    assert instances, "cannot bucket an empty batch"
    buckets, index, envelopes = [], [], []
    for env, idx in bucket_indices(instances, cap):
        buckets.append(pad_instances([instances[i] for i in idx], *env))
        index.append(np.asarray(idx))
        envelopes.append(env)
    return BucketedBatch(buckets=buckets, index=index, envelopes=envelopes,
                         dims=[(i.U, i.P, i.E) for i in instances])


def _share_factors(inst: PIESInstance) -> Tuple[np.ndarray, np.ndarray]:
    counts = inst.covered_counts()
    return (counts[inst.u_edge] / inst.K[inst.u_edge],
            counts[inst.u_edge] / inst.W[inst.u_edge])


def pad_instances(instances: Sequence[PIESInstance],
                  u_pad: Optional[int] = None,
                  p_pad: Optional[int] = None,
                  e_pad: Optional[int] = None) -> PaddedBatch:
    """Stack ``instances`` into one batched, fixed-shape JaxInstance."""
    import jax.numpy as jnp

    assert instances, "cannot pad an empty batch"
    U_pad = u_pad or max(i.U for i in instances)
    P_pad = p_pad or max(i.P for i in instances)
    # +1 guarantees a padded edge exists in every instance (hosts pad users)
    E_pad = e_pad or (max(i.E for i in instances) + 1)
    S_max = max(int(i.sm_service.max()) + 1 if i.P else 0 for i in instances)
    user_dummy, model_dummy = S_max, S_max + 1

    rows: Dict[str, List[np.ndarray]] = {f.name: [] for f in
                                         dataclasses.fields(JaxInstance)}
    dims = []
    for inst in instances:
        U, P, E = inst.U, inst.P, inst.E
        assert U <= U_pad and P <= P_pad and E < E_pad, \
            f"instance ({U},{P},{E}) exceeds pad envelope " \
            f"({U_pad},{P_pad},{E_pad})"
        dims.append((U, P, E))
        du, dp, de = U_pad - U, P_pad - P, E_pad - E
        share_k, share_w = _share_factors(inst)

        def upad(a, fill):
            return np.concatenate([np.asarray(a, np.float64),
                                   np.full(du, fill)])

        def ppad(a, fill):
            return np.concatenate([np.asarray(a, np.float64),
                                   np.full(dp, fill)])

        rows["u_alpha"].append(upad(inst.u_alpha, 0.0))
        rows["u_delta"].append(upad(inst.u_delta, 0.0))
        rows["u_share_k"].append(upad(share_k, 0.0))
        rows["u_share_w"].append(upad(share_w, 0.0))
        rows["u_service"].append(np.concatenate(
            [inst.u_service, np.full(du, user_dummy, dtype=np.int64)]))
        rows["u_edge"].append(np.concatenate(
            [inst.u_edge, np.full(du, E_pad - 1, dtype=np.int64)]))
        rows["sm_service"].append(np.concatenate(
            [inst.sm_service, np.full(dp, model_dummy, dtype=np.int64)]))
        rows["sm_acc"].append(ppad(inst.sm_acc, 0.0))
        rows["sm_k"].append(ppad(inst.sm_k, 0.0))
        rows["sm_w"].append(ppad(inst.sm_w, 0.0))
        rows["sm_r"].append(ppad(inst.sm_r, _PAD_STORAGE))
        rows["R"].append(np.concatenate([inst.R, np.zeros(de)]))
        rows["delta_max"].append(np.float64(inst.delta_max))

    int_fields = {"u_service", "u_edge", "sm_service"}
    leaves = {
        name: jnp.asarray(np.stack(vals),
                          jnp.int32 if name in int_fields else jnp.float32)
        for name, vals in rows.items()
    }
    return PaddedBatch(jax_instance=JaxInstance(**leaves),
                       n_services=model_dummy + 1, dims=dims)


def single_evaluator(algo: str, n_services: int, max_iters: int):
    """The per-instance evaluator ``JaxInstance -> (value, x)`` — the unit
    that :func:`evaluate_batch` vmaps and :mod:`repro.sweeps.shard` wraps in
    ``shard_map(vmap(...))`` across mesh batch axes."""
    from repro.core.placement import agp_place_jax, egp_place_jax
    from repro.core.qos import eligibility_jnp, qos_matrix_jnp
    from repro.core.scheduling import sigma_jnp

    def one(inst: JaxInstance):
        Q = qos_matrix_jnp(inst)
        elig = eligibility_jnp(inst)
        if algo == "egp":
            x = egp_place_jax(Q, elig, inst.u_edge, inst.u_service,
                              inst.sm_service, inst.sm_r, inst.R,
                              n_services, max_iters=max_iters)
        elif algo == "agp":
            x = agp_place_jax(Q, elig, inst.u_edge, inst.sm_r, inst.R,
                              max_iters=max_iters)
        else:
            raise ValueError(f"unknown batched algorithm {algo!r}")
        value = sigma_jnp(Q, elig, inst.u_edge, x)
        return value, x

    return one


def _build_evaluator(algo: str, n_services: int, max_iters: int):
    import jax

    return jax.jit(jax.vmap(single_evaluator(algo, n_services, max_iters)))


@functools.lru_cache(maxsize=16)
def _cached_evaluator(algo: str, n_services: int, max_iters: int):
    return _build_evaluator(algo, n_services, max_iters)


def evaluate_batch(batch, algo: str = "egp", max_iters: int = 512):
    """Batched placement evaluation: ``(values [B], x)``.

    For a :class:`PaddedBatch` this is one jitted accelerator call and
    ``x`` is ``[B, E_pad, P_pad]``. For a :class:`BucketedBatch` each
    bucket runs through the same jitted evaluator at its own envelope
    (one call per size class) and results are re-assembled in original
    instance order — ``values`` is a float64 NumPy array and ``x`` a list
    of per-instance ``[E_pad_b, P_pad_b]`` placements (envelopes differ
    across buckets). Pad waste is published on the
    ``placement.bucket_pad_waste`` gauge.

    ``values[b]`` is σ(EGP/AGP placement) of instance ``b``; padding
    contributes exactly zero (see module docstring), so values match the
    per-instance host path up to float32 accumulation.
    """
    if isinstance(batch, BucketedBatch):
        from repro import obs

        values = np.empty(batch.B, dtype=np.float64)
        xs: List = [None] * batch.B
        for pb, idx in zip(batch.buckets, batch.index):
            v, x = evaluate_batch(pb, algo=algo, max_iters=max_iters)
            values[idx] = np.asarray(v, np.float64)
            for j, i in enumerate(idx):
                xs[int(i)] = x[j]
        tracer = obs.get_tracer()
        if tracer is not None:
            tracer.metrics.gauge("placement.bucket_pad_waste").set(
                batch.pad_waste)
        return values, xs
    fn = _cached_evaluator(algo, batch.n_services, max_iters)
    values, x = fn(batch.jax_instance)
    return values, x


@functools.lru_cache(maxsize=16)
def _sparse_evaluator(max_iters: int, use_kernel: bool):
    import jax

    from repro.core.placement import egp_place_sparse_jax, sigma_sparse_jnp

    def run(cand_idx, cand_q, u_edge, sm_service, sm_r, R):
        x = egp_place_sparse_jax(cand_idx, cand_q, u_edge, sm_service,
                                 sm_r, R, max_iters=max_iters,
                                 use_kernel=use_kernel)
        return sigma_sparse_jnp(cand_idx, cand_q, u_edge, x), x

    return jax.jit(run)


def evaluate_sparse(instances: Sequence[PIESInstance], algo: str = "egp",
                    k: Optional[int] = None, max_iters: Optional[int] = None,
                    use_kernel: bool = False):
    """Top-k sparse placement per instance: ``(values [B], x list)``.

    The scale path: no ``[U, P]`` QoS matrix — per-user candidate pairs
    (``k`` defaults to *all* eligible implementations, making the result
    exact vs :func:`evaluate_host`; smaller ``k`` is the documented
    approximation) drive the lock-step sparse EGP loop.
    ``max_iters=None`` uses ``P + 1`` (an edge never picks more than P
    models, so the greedy runs to its natural stop). ``use_kernel`` routes
    segmented QoS and the per-edge argmax through the Pallas kernels.
    The effective ``k`` is published on the ``placement.candidate_k``
    gauge.
    """
    if algo != "egp":
        raise ValueError(f"sparse path implements 'egp' only, got {algo!r}")
    from repro import obs
    from repro.core.candidates import impl_table_np
    from repro.kernels.qos_matrix.ops import qos_candidates_from_instance

    values, xs = [], []
    tracer = obs.get_tracer()
    for inst in instances:
        ji = inst.as_jax()
        table = impl_table_np(inst.sm_service, inst.S)
        cand_idx, cand_q = qos_candidates_from_instance(
            ji, table, k, use_kernel=use_kernel)
        if tracer is not None:
            tracer.metrics.gauge("placement.candidate_k").set(
                int(cand_idx.shape[1]))
        mi = int(max_iters) if max_iters is not None else inst.P + 1
        v, x = _sparse_evaluator(mi, use_kernel)(
            cand_idx, cand_q, ji.u_edge, ji.sm_service, ji.sm_r, ji.R)
        values.append(float(v))
        xs.append(x)
    return np.asarray(values, np.float64), xs


def evaluate_host(instances: Sequence[PIESInstance],
                  algo: str = "egp") -> np.ndarray:
    """NumPy reference: per-instance greedy placement + σ, no batching."""
    place = {"egp": egp_np, "agp": agp_np}[algo]
    out = []
    for inst in instances:
        Q = qos_matrix_np(inst)
        out.append(sigma_np(inst, place(inst, Q), Q))
    return np.asarray(out)


def sweep(scenario_names: Sequence[str], seeds: Sequence[int],
          n_ticks: Optional[int] = None, algo: str = "egp",
          **overrides) -> Dict:
    """Monte-Carlo sweep: every (scenario, seed, tick) instance evaluated
    in a single jitted call.

    Returns ``{"values": {name: [n_seeds, n_ticks] np.ndarray},
    "instances": [...], "labels": [(name, seed, tick)], "batch": batch}``.
    """
    from .scenarios import get_scenario

    instances: List[PIESInstance] = []
    labels: List[Tuple[str, int, int]] = []
    ticks_of: Dict[str, int] = {}
    for name in scenario_names:
        scenario = get_scenario(name, **overrides)
        T = int(n_ticks or scenario.n_ticks)
        ticks_of[name] = T
        for seed in seeds:
            for tick, inst in enumerate(scenario.horizon(seed, T)):
                instances.append(inst)
                labels.append((name, int(seed), tick))

    batch = bucket_instances(instances)
    values, _ = evaluate_batch(batch, algo=algo)
    values = np.asarray(values, np.float64)

    shaped: Dict[str, np.ndarray] = {}
    off = 0
    for name in scenario_names:
        T = ticks_of[name]
        n = len(seeds) * T
        shaped[name] = values[off:off + n].reshape(len(seeds), T)
        off += n
    return {"values": shaped, "instances": instances, "labels": labels,
            "batch": batch}
