"""Batched accelerator-side scenario evaluation.

Monte-Carlo sweeps over (scenario × seed × tick) evaluate hundreds of
independent :class:`PIESInstance`\\ s. Doing that with a Python loop pays a
dispatch + trace per instance; instead, :func:`pad_instances` pads every
instance to the batch's fixed (U, P, E) envelope and stacks them into a
single batched :class:`~repro.core.instance.JaxInstance` pytree, and
:func:`evaluate_batch` runs QoS-matrix construction, greedy placement
(:func:`egp_place_jax` / :func:`agp_place_jax`) and the σ objective for the
*whole stack* inside one ``jax.jit``'d ``vmap`` — one accelerator call per
sweep.

Padding conventions (chosen so padded rows are provably inert):

* **users** — padded slots request the dummy service id ``S`` that no model
  implements (eligibility row ≡ False ⇒ zero QoS, zero greedy gain, zero σ)
  and are covered by a padded edge, so they never enter a real edge's user
  mask or satisfaction test;
* **models** — padded rows carry the distinct dummy service ``S + 1`` (no
  user requests it) and an effectively-infinite storage cost, so they are
  never feasible;
* **edges** — padded edges have zero storage, so the greedy loops exit
  immediately; at least one padded edge always exists to host padded users.

``evaluate_host`` is the NumPy reference path (per-instance
``egp_np``/``agp_np`` + ``sigma_np``) the batched results are validated
against — see ``tests/test_workloads.py`` and ``benchmarks/scenarios.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.instance import JaxInstance, PIESInstance
from repro.core.placement import agp_np, egp_np
from repro.core.qos import qos_matrix_np
from repro.core.scheduling import sigma_np

__all__ = [
    "PaddedBatch",
    "pad_instances",
    "single_evaluator",
    "evaluate_batch",
    "evaluate_host",
    "sweep",
]

#: Storage cost assigned to padded model rows — larger than any edge budget.
_PAD_STORAGE = 1e9


@dataclasses.dataclass
class PaddedBatch:
    """A stack of instances padded to a common (U, P, E) envelope."""

    jax_instance: JaxInstance      # every leaf is batched: [B, ...]
    n_services: int                # static scatter width (incl. dummy ids)
    dims: List[Tuple[int, int, int]]   # true (U, P, E) per instance

    @property
    def B(self) -> int:
        return len(self.dims)


def _share_factors(inst: PIESInstance) -> Tuple[np.ndarray, np.ndarray]:
    counts = inst.covered_counts()
    return (counts[inst.u_edge] / inst.K[inst.u_edge],
            counts[inst.u_edge] / inst.W[inst.u_edge])


def pad_instances(instances: Sequence[PIESInstance],
                  u_pad: Optional[int] = None,
                  p_pad: Optional[int] = None,
                  e_pad: Optional[int] = None) -> PaddedBatch:
    """Stack ``instances`` into one batched, fixed-shape JaxInstance."""
    import jax.numpy as jnp

    assert instances, "cannot pad an empty batch"
    U_pad = u_pad or max(i.U for i in instances)
    P_pad = p_pad or max(i.P for i in instances)
    # +1 guarantees a padded edge exists in every instance (hosts pad users)
    E_pad = e_pad or (max(i.E for i in instances) + 1)
    S_max = max(int(i.sm_service.max()) + 1 if i.P else 0 for i in instances)
    user_dummy, model_dummy = S_max, S_max + 1

    rows: Dict[str, List[np.ndarray]] = {f.name: [] for f in
                                         dataclasses.fields(JaxInstance)}
    dims = []
    for inst in instances:
        U, P, E = inst.U, inst.P, inst.E
        assert U <= U_pad and P <= P_pad and E < E_pad, \
            f"instance ({U},{P},{E}) exceeds pad envelope " \
            f"({U_pad},{P_pad},{E_pad})"
        dims.append((U, P, E))
        du, dp, de = U_pad - U, P_pad - P, E_pad - E
        share_k, share_w = _share_factors(inst)

        def upad(a, fill):
            return np.concatenate([np.asarray(a, np.float64),
                                   np.full(du, fill)])

        def ppad(a, fill):
            return np.concatenate([np.asarray(a, np.float64),
                                   np.full(dp, fill)])

        rows["u_alpha"].append(upad(inst.u_alpha, 0.0))
        rows["u_delta"].append(upad(inst.u_delta, 0.0))
        rows["u_share_k"].append(upad(share_k, 0.0))
        rows["u_share_w"].append(upad(share_w, 0.0))
        rows["u_service"].append(np.concatenate(
            [inst.u_service, np.full(du, user_dummy, dtype=np.int64)]))
        rows["u_edge"].append(np.concatenate(
            [inst.u_edge, np.full(du, E_pad - 1, dtype=np.int64)]))
        rows["sm_service"].append(np.concatenate(
            [inst.sm_service, np.full(dp, model_dummy, dtype=np.int64)]))
        rows["sm_acc"].append(ppad(inst.sm_acc, 0.0))
        rows["sm_k"].append(ppad(inst.sm_k, 0.0))
        rows["sm_w"].append(ppad(inst.sm_w, 0.0))
        rows["sm_r"].append(ppad(inst.sm_r, _PAD_STORAGE))
        rows["R"].append(np.concatenate([inst.R, np.zeros(de)]))
        rows["delta_max"].append(np.float64(inst.delta_max))

    int_fields = {"u_service", "u_edge", "sm_service"}
    leaves = {
        name: jnp.asarray(np.stack(vals),
                          jnp.int32 if name in int_fields else jnp.float32)
        for name, vals in rows.items()
    }
    return PaddedBatch(jax_instance=JaxInstance(**leaves),
                       n_services=model_dummy + 1, dims=dims)


def single_evaluator(algo: str, n_services: int, max_iters: int):
    """The per-instance evaluator ``JaxInstance -> (value, x)`` — the unit
    that :func:`evaluate_batch` vmaps and :mod:`repro.sweeps.shard` wraps in
    ``shard_map(vmap(...))`` across mesh batch axes."""
    from repro.core.placement import agp_place_jax, egp_place_jax
    from repro.core.qos import eligibility_jnp, qos_matrix_jnp
    from repro.core.scheduling import sigma_jnp

    def one(inst: JaxInstance):
        Q = qos_matrix_jnp(inst)
        elig = eligibility_jnp(inst)
        if algo == "egp":
            x = egp_place_jax(Q, elig, inst.u_edge, inst.u_service,
                              inst.sm_service, inst.sm_r, inst.R,
                              n_services, max_iters=max_iters)
        elif algo == "agp":
            x = agp_place_jax(Q, elig, inst.u_edge, inst.sm_r, inst.R,
                              max_iters=max_iters)
        else:
            raise ValueError(f"unknown batched algorithm {algo!r}")
        value = sigma_jnp(Q, elig, inst.u_edge, x)
        return value, x

    return one


def _build_evaluator(algo: str, n_services: int, max_iters: int):
    import jax

    return jax.jit(jax.vmap(single_evaluator(algo, n_services, max_iters)))


@functools.lru_cache(maxsize=16)
def _cached_evaluator(algo: str, n_services: int, max_iters: int):
    return _build_evaluator(algo, n_services, max_iters)


def evaluate_batch(batch: PaddedBatch, algo: str = "egp",
                   max_iters: int = 512):
    """One jitted accelerator call: ``(values [B], x [B, E_pad, P_pad])``.

    ``values[b]`` is σ(EGP/AGP placement) of instance ``b``; padding
    contributes exactly zero (see module docstring), so values match the
    per-instance host path up to float32 accumulation.
    """
    fn = _cached_evaluator(algo, batch.n_services, max_iters)
    values, x = fn(batch.jax_instance)
    return values, x


def evaluate_host(instances: Sequence[PIESInstance],
                  algo: str = "egp") -> np.ndarray:
    """NumPy reference: per-instance greedy placement + σ, no batching."""
    place = {"egp": egp_np, "agp": agp_np}[algo]
    out = []
    for inst in instances:
        Q = qos_matrix_np(inst)
        out.append(sigma_np(inst, place(inst, Q), Q))
    return np.asarray(out)


def sweep(scenario_names: Sequence[str], seeds: Sequence[int],
          n_ticks: Optional[int] = None, algo: str = "egp",
          **overrides) -> Dict:
    """Monte-Carlo sweep: every (scenario, seed, tick) instance evaluated
    in a single jitted call.

    Returns ``{"values": {name: [n_seeds, n_ticks] np.ndarray},
    "instances": [...], "labels": [(name, seed, tick)], "batch": batch}``.
    """
    from .scenarios import get_scenario

    instances: List[PIESInstance] = []
    labels: List[Tuple[str, int, int]] = []
    ticks_of: Dict[str, int] = {}
    for name in scenario_names:
        scenario = get_scenario(name, **overrides)
        T = int(n_ticks or scenario.n_ticks)
        ticks_of[name] = T
        for seed in seeds:
            for tick, inst in enumerate(scenario.horizon(seed, T)):
                instances.append(inst)
                labels.append((name, int(seed), tick))

    batch = pad_instances(instances)
    values, _ = evaluate_batch(batch, algo=algo)
    values = np.asarray(values, np.float64)

    shaped: Dict[str, np.ndarray] = {}
    off = 0
    for name in scenario_names:
        T = ticks_of[name]
        n = len(seeds) * T
        shaped[name] = values[off:off + n].reshape(len(seeds), T)
        off += n
    return {"values": shaped, "instances": instances, "labels": labels,
            "batch": batch}
