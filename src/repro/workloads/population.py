"""Population dynamics: popularity, churn, and mobility.

All draws are pure functions of ``(seed, tick, user-slot)`` built on a
vectorized splitmix64 counter hash, so any worker can materialize the
population at any tick without shared state or stream replay:

* :func:`hash_uniform` — the counter-based U(0,1) primitive;
* :class:`ZipfPopularity` — Zipf service popularity with hot-spot drift
  (the rank-1 "hot" service rotates every ``drift_period`` ticks);
* :class:`ChurnModel` — per-slot user churn: slot ``u`` is re-rolled every
  ``lifetime`` ticks at a slot-specific phase, so each tick a ~``1/lifetime``
  fraction of users leave and are replaced — attributes are a function of
  the slot's *generation* ``(tick + phase_u) // lifetime``, which makes the
  process O(1)-seekable (no history walk);
* :class:`MarkovMobility` — users random-walk across edge clouds (a ring
  topology: geographic adjacency) with per-tick move probability
  ``p_move``. The chain is genuinely Markov, so seeking to tick ``t``
  replays ``t`` vectorized transition steps — O(t·U) but deterministic:
  the step-``k`` coin flips are hashed from ``(seed, k, u)``, never from a
  stateful stream. Migration permutes coverage only; it conserves the
  user population (no slot is created or destroyed by a move).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "hash_uniform",
    "ZipfPopularity",
    "ChurnModel",
    "MarkovMobility",
]

_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)

# Stream tags (distinct from repro.workloads.arrivals tags).
TAG_SERVICE = 0x0B1
TAG_ALPHA = 0x0B2
TAG_DELTA = 0x0B3
TAG_PHASE = 0x0B4
TAG_HOME = 0x0B5
TAG_MOVE = 0x0B6
TAG_DEST = 0x0B7


def _mix(z: np.ndarray) -> np.ndarray:
    z = (z ^ (z >> np.uint64(30))) * _MIX1
    z = (z ^ (z >> np.uint64(27))) * _MIX2
    return z ^ (z >> np.uint64(31))


def hash_u64(seed: int, *components) -> np.ndarray:
    """splitmix64-style counter hash; components broadcast like arrays."""
    z = np.asarray(np.uint64(int(seed) & 0xFFFFFFFFFFFFFFFF))
    with np.errstate(over="ignore"):  # uint64 wraparound is the algorithm
        for c in components:
            c = np.asarray(c, dtype=np.uint64)
            z = _mix((z + _GAMMA) ^ (c * _MIX1 + _GAMMA))
    return z


def hash_uniform(seed: int, *components) -> np.ndarray:
    """Deterministic U(0,1) draws indexed by integer components."""
    return (hash_u64(seed, *components) >> np.uint64(11)).astype(
        np.float64) * (2.0 ** -53)


@dataclasses.dataclass(frozen=True)
class ZipfPopularity:
    """Zipf(``exponent``) service popularity with rotating hot spot.

    The popularity of service ``s`` at tick ``t`` is the Zipf weight of its
    *rotated rank* ``(s - hot(t)) mod S`` where ``hot(t) = (t //
    drift_period) · drift_step mod S`` — the head of the distribution
    drifts across the catalog, which is what makes per-tick re-placement
    churn (and hysteresis matter). ``drift_period = 0`` disables drift.
    """

    n_services: int
    exponent: float = 1.1
    drift_period: int = 0
    drift_step: int = 1

    def weights_at(self, tick: int) -> np.ndarray:
        ranks = np.arange(self.n_services, dtype=np.float64)
        if self.drift_period > 0:
            hot = (int(tick) // self.drift_period) * self.drift_step
            ranks = (ranks - hot) % self.n_services
        w = 1.0 / np.power(ranks + 1.0, self.exponent)
        return w / w.sum()

    def sample(self, uniforms: np.ndarray, tick: int) -> np.ndarray:
        """Inverse-CDF map of U(0,1) draws onto service ids at ``tick``."""
        cdf = np.cumsum(self.weights_at(tick))
        cdf[-1] = 1.0  # guard the top bin against cumsum round-off
        return np.searchsorted(cdf, uniforms, side="right").astype(np.int64)


@dataclasses.dataclass(frozen=True)
class ChurnModel:
    """Generation-indexed churn over a fixed pool of user slots.

    Slot ``u``'s generation at tick ``t`` is ``(t + phase_u) // lifetime``
    with ``phase_u = hash(seed, u) mod lifetime``; attributes (requested
    service, α, δ) are drawn from the generation index, so they persist for
    ``lifetime`` ticks and then re-roll — a fraction ``≈ 1/lifetime`` of
    the population churns every tick, de-phased across slots.

    α/δ follow the paper's §VI-B threshold distributions
    (``α = 1 − clip(Exp(alpha_scale))``, ``δ = clip(Exp(delta_scale), 0,
    δ_max)``) via inverse-CDF of the hash uniforms.
    """

    lifetime: int = 16
    alpha_scale: float = 0.125
    delta_scale: float = 1.5
    delta_max: float = 10.0

    def generation_at(self, seed: int, tick: int, n_slots: int) -> np.ndarray:
        slots = np.arange(n_slots)
        phase = hash_u64(seed, TAG_PHASE, slots) % np.uint64(self.lifetime)
        return (int(tick) + phase.astype(np.int64)) // self.lifetime

    def attributes_at(self, seed: int, tick: int, n_slots: int,
                      popularity: ZipfPopularity):
        """Returns ``(u_service, u_alpha, u_delta)`` for every slot."""
        slots = np.arange(n_slots)
        gen = self.generation_at(seed, tick, n_slots)
        u_svc = hash_uniform(seed, TAG_SERVICE, slots, gen)
        u_a = hash_uniform(seed, TAG_ALPHA, slots, gen)
        u_d = hash_uniform(seed, TAG_DELTA, slots, gen)
        service = popularity.sample(u_svc, tick)
        # inverse-CDF exponentials; 1-u ∈ (0, 1] so log is finite
        alpha = 1.0 - np.clip(-self.alpha_scale * np.log1p(-u_a), 0.0, 1.0)
        delta = np.clip(-self.delta_scale * np.log1p(-u_d), 0.0,
                        self.delta_max)
        return service, alpha, delta


@dataclasses.dataclass(frozen=True)
class MarkovMobility:
    """Ring random walk across edge clouds.

    Each tick, user ``u`` moves to an adjacent edge (``±1`` on the ring —
    neighboring coverage areas) with probability ``p_move``. Home edges at
    tick 0 are hash-uniform. ``p_move = 0`` degenerates to static coverage.
    """

    n_edges: int
    p_move: float = 0.0

    def home_edges(self, seed: int, n_slots: int) -> np.ndarray:
        slots = np.arange(n_slots)
        u = hash_uniform(seed, TAG_HOME, slots)
        return np.minimum((u * self.n_edges).astype(np.int64),
                          self.n_edges - 1)

    def edges_at(self, seed: int, tick: int, n_slots: int) -> np.ndarray:
        """User → edge assignment at ``tick`` (replays the walk)."""
        return self.trajectory(seed, tick + 1, n_slots)[-1]

    def trajectory(self, seed: int, n_ticks: int, n_slots: int) -> np.ndarray:
        """[n_ticks, n_slots] edge assignment; row 0 is the home state."""
        slots = np.arange(n_slots)
        out = np.empty((n_ticks, n_slots), dtype=np.int64)
        e = self.home_edges(seed, n_slots)
        out[0] = e
        for k in range(1, n_ticks):
            move = hash_uniform(seed, TAG_MOVE, k, slots) < self.p_move
            step = np.where(hash_uniform(seed, TAG_DEST, k, slots) < 0.5,
                            -1, 1)
            e = np.where(move, (e + step) % self.n_edges, e)
            out[k] = e
        return out
