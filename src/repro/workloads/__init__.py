"""repro.workloads — workload generators, scenarios, batched evaluation.

The dynamic counterpart of :mod:`repro.core`: deterministic ``(seed,
tick)``-seekable arrival processes and population dynamics compose into a
registry of named end-to-end scenarios (``steady``, ``diurnal``,
``flash_crowd``, ``mobility_churn``, ``edge_failure``), each yielding a
sequence of :class:`~repro.core.instance.PIESInstance`\\ s; the batched
engine pads instance stacks to fixed shapes and evaluates whole
(scenario × seed × tick) Monte-Carlo sweeps in one jitted ``vmap``'d
accelerator call.
"""
from .arrivals import (
    ArrivalProcess,
    PoissonArrivals,
    MMPPArrivals,
    DiurnalArrivals,
    TraceArrivals,
)
from .population import (
    hash_uniform,
    ZipfPopularity,
    ChurnModel,
    MarkovMobility,
)
from .scenarios import (
    Scenario,
    register_scenario,
    get_scenario,
    list_scenarios,
    horizon,
)
from .batched import (
    PaddedBatch,
    BucketedBatch,
    pad_instances,
    bucket_envelope,
    bucket_instances,
    evaluate_batch,
    evaluate_sparse,
    evaluate_host,
    sweep,
)

__all__ = [
    "ArrivalProcess", "PoissonArrivals", "MMPPArrivals", "DiurnalArrivals",
    "TraceArrivals",
    "hash_uniform", "ZipfPopularity", "ChurnModel", "MarkovMobility",
    "Scenario", "register_scenario", "get_scenario", "list_scenarios",
    "horizon",
    "PaddedBatch", "BucketedBatch", "pad_instances", "bucket_envelope",
    "bucket_instances", "evaluate_batch", "evaluate_sparse", "evaluate_host",
    "sweep",
]
